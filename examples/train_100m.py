"""End-to-end training driver: a ~100M-param qwen2-family model for a few
hundred steps on the local devices, with checkpoint/restart fault tolerance
(an injected failure at step 60 recovers transparently) and async
checkpointing — the same runtime path a pod-scale job uses.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.models import Model
from repro.optim import adamw, cosine_schedule
from repro.runtime import elastic
from repro.runtime.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    ap.add_argument("--inject-failure", type=int, default=60)
    args = ap.parse_args()

    # ~100M params: a narrow 12-layer qwen2-family decoder.
    cfg = get_config("qwen2_1_5b").replace(
        n_layers=12, d_model=512, n_heads=8, n_kv=2, head_dim=64,
        d_ff=2048, vocab=32000, remat="none", param_dtype="float32")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"model: {model.param_count() / 1e6:.1f}M params")

    opt = adamw(lr=cosine_schedule(3e-4, 20, args.steps))
    opt_state = opt.init(params)
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch)
    train_step = jax.jit(make_train_step(model, opt),
                         donate_argnums=(0, 1))

    losses = []

    def step_fn(state, batch, step):
        p, o = state
        p, o, metrics = train_step(p, o, batch, jax.random.PRNGKey(step))
        losses.append(float(metrics["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        return (p, o)

    injector = elastic.FailureInjector(
        fail_after_steps=(args.inject_failure,)
        if args.inject_failure else ())
    t0 = time.time()
    out = elastic.run_elastic(
        (params, opt_state), step_fn, data.batch, num_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=25, injector=injector)
    dt = time.time() - t0

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\n{out['steps_run']} steps in {dt:.1f}s "
          f"({out['restarts']} restart(s) from injected failure)")
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'LEARNING' if last < first - 0.1 else 'check config'})")


if __name__ == "__main__":
    main()
