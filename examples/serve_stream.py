"""The always-on scheduler service in ~50 lines: a Poisson-burst arrival
storm streamed through the bounded admission queue into the fused
warm-started ``waterwise-forecast`` pipeline, one decision round per
boundary, with the full service report (stream accounting, queue depths,
p50/p99 round latency, cold vs warm Sinkhorn iterations) at the end.

  PYTHONPATH=src python examples/serve_stream.py                # ~1 min
  PYTHONPATH=src python examples/serve_stream.py --duration 30 \\
      --round-s 5 --assert-clean                                # CI smoke

``--queue-bound 20`` makes the storm actually shed (accounted, never
silent — shed jobs are deadline misses in the report); ``--assert-clean``
exits non-zero unless the service finished with zero deadline misses and
non-empty round metrics.
"""
import argparse
import sys

import repro.obs as obs
from repro.core import telemetry
from repro.policy.pipeline import forecast_pipeline
from repro.serve import DecisionLoop, PoissonBurstArrivals, ServeConfig
from repro.sim.engine import EventSimulator, SimConfig
from repro.sim.trace import scale_capacity_for_utilization


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=600.0,
                    help="simulated seconds to serve")
    ap.add_argument("--jobs-per-day", type=float, default=1e5)
    ap.add_argument("--round-s", type=float, default=30.0,
                    help="decision-round period (simulated seconds)")
    ap.add_argument("--queue-bound", type=int, default=10_000)
    ap.add_argument("--shed-policy", default="reject-new",
                    choices=["reject-new", "drop-oldest"])
    ap.add_argument("--burst", type=float, default=1.0,
                    help="burst-train amplitude (0 = plain diurnal Poisson)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--assert-clean", action="store_true",
                    help="exit 1 unless zero deadline misses and non-empty "
                         "round metrics (the CI smoke contract)")
    args = ap.parse_args()

    tele = telemetry.generate(days=1, seed=0)
    rate = args.jobs_per_day / 86400.0
    src = PoissonBurstArrivals(rate, seed=args.seed,
                               num_regions=tele.num_regions, tolerance=4.0,
                               burst=args.burst, horizon_s=args.duration)
    probe = PoissonBurstArrivals(rate, seed=args.seed,
                                 num_regions=tele.num_regions, tolerance=4.0,
                                 burst=args.burst, horizon_s=args.duration)
    cap = scale_capacity_for_utilization(probe.poll(args.duration),
                                         args.duration / 86400.0,
                                         tele.num_regions, 0.15)
    ctl = forecast_pipeline(tele, forecaster="oracle", risk=0.0,
                            slot_s=1800.0, defer_eps=1e-4, backend="fused",
                            warm=True)
    loop = DecisionLoop(EventSimulator(tele, cap, SimConfig()), ctl, src,
                        ServeConfig(round_s=args.round_s,
                                    queue_bound=args.queue_bound,
                                    shed_policy=args.shed_policy))
    print(f"serving {args.duration:.0f}s of a {args.jobs_per_day:.0f} "
          f"jobs/day storm (burst={args.burst}, round={args.round_s:.0f}s, "
          f"queue bound {args.queue_bound}, {args.shed_policy})")
    with obs.capture(fold=False) as reg:
        rep = loop.run(args.duration)
    for k, v in sorted(rep.to_dict().items()):
        print(f"  {k:>22} = {v:.3f}" if isinstance(v, float)
              else f"  {k:>22} = {v}")
    rounds = reg.hists.get("serve.round_wall_ms")
    if rep.deadline_misses == 0 and rounds is not None and rounds.count > 0:
        print(f"OK: {rep.placed} jobs placed, zero deadline misses, "
              f"{rounds.count} instrumented rounds")
        return 0
    print(f"service finished with {rep.deadline_misses} deadline misses "
          f"({rep.shed} shed, {rep.violations} over tolerance)")
    return 1 if args.assert_clean else 0


if __name__ == "__main__":
    sys.exit(main())
