"""Workflow (DAG) scheduling end to end in ~50 lines.

Builds the ``workflow-diurnal`` cell — chain / fan-out / diamond /
Montage-like task graphs with critical-path-derived per-task deadlines —
and replays it through plain ``waterwise`` and the three-way
``waterwise-embodied`` controller. Prints per-policy totals including the
embodied-carbon accounting column, the workflow-deadline miss rate, and
the precedence-violation count (always zero: the engine releases a task
only when every predecessor has finished):

  PYTHONPATH=src python examples/workflow_run.py               # ~30 s
  PYTHONPATH=src python examples/workflow_run.py --days 0.05 --assert-clean
"""
import argparse
import copy

from repro.sim import metrics
from repro.sim.engine import EventSimulator, SimConfig
from repro.sim.scenarios import get_scenario
from repro.workflows import precedence_violations, workflow_miss_rate

SCHEDULERS = ["waterwise", "waterwise-embodied[lam_embodied=0.35]"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=0.15)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--jobs-per-day", type=float, default=6000.0)
    ap.add_argument("--assert-clean", action="store_true",
                    help="exit non-zero on any precedence violation or "
                         "unfinished task (CI smoke)")
    args = ap.parse_args()

    inst = get_scenario("workflow-diurnal").build(
        args.days, args.seed, args.jobs_per_day, 0.15)
    n_wf = len({j.workflow_id for j in inst.jobs
                if j.workflow_id is not None})
    print(f"workflow-diurnal: {len(inst.jobs)} tasks / {n_wf} workflows "
          f"({args.days} days, seed {args.seed})\n")

    clean = True
    for spec in SCHEDULERS:
        res = EventSimulator(inst.tele, inst.capacity, SimConfig()).run(
            copy.deepcopy(inst.jobs), spec)
        s = metrics.summarize(res)
        viol = precedence_violations(res["records"])
        miss, _ = workflow_miss_rate(res["records"])
        clean &= viol == 0 and res["unfinished"] == 0
        print(f"{spec:>42}: operational {s['carbon_kg']:7.2f} kg  "
              f"embodied {s['embodied_kg']:6.2f} kg  "
              f"water {s['water_kl']:.3f} kL  "
              f"cpath_miss {100 * miss:.1f}%  "
              f"precedence_violations {viol}  "
              f"unfinished {res['unfinished']}")

    if args.assert_clean and not clean:
        raise SystemExit("assert-clean failed: precedence violation or "
                         "unfinished task")


if __name__ == "__main__":
    main()
