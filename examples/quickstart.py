"""Quickstart: the WaterWise scheduler end-to-end in ~30 lines.

Generates one day of per-region sustainability telemetry, replays two hours
of a Borg-like trace through the carbon+water co-optimizing controller, and
prints the savings against the carbon/water-unaware baseline. Schedulers
are declarative policy specs (``repro.policy``) — the engine builds them
straight from their string form.

    PYTHONPATH=src python examples/quickstart.py
"""
import copy

from repro.core import telemetry
from repro.sim import Simulator, borg_trace, savings_vs, summarize
from repro.sim.trace import scale_capacity_for_utilization

DAYS = 0.1

tele = telemetry.generate(days=2, seed=0)
jobs = borg_trace(days=DAYS, seed=0, tolerance=0.5)
capacity = scale_capacity_for_utilization(jobs, DAYS, 5, utilization=0.15)
print(f"{len(jobs)} jobs over {DAYS * 24:.1f} h, "
      f"{capacity.sum()} servers in {tele.num_regions} regions\n")

results = {}
for name in ("baseline", "waterwise", "carbon-greedy-opt",
             "water-greedy-opt"):
    # The engine accepts policy-spec strings directly (repro.policy).
    results[name] = summarize(Simulator(tele, capacity).run(
        copy.deepcopy(jobs), name))

base = results["baseline"]
print(f"{'scheduler':20s} {'carbon kg':>10s} {'water kL':>9s} "
      f"{'carbon sav':>10s} {'water sav':>9s} {'svc':>6s} {'viol%':>6s}")
for name, s in results.items():
    sv = savings_vs(base, s)
    print(f"{name:20s} {s['carbon_kg']:10.1f} {s['water_kl']:9.2f} "
          f"{sv['carbon_savings_pct']:9.1f}% {sv['water_savings_pct']:8.1f}% "
          f"{s['mean_service_ratio']:6.3f} {s['violation_pct']:6.2f}")
print("\nNote the tension: the carbon oracle *hurts* water and vice versa;"
      "\nWaterWise lands near both oracles simultaneously (paper Fig 5).")
