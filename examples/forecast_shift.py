"""Forecast-driven temporal shifting in ~40 lines.

Runs one delay-tolerant Borg-like cell through the reactive ``waterwise``
controller, the Holt-Winters-driven ``waterwise-forecast`` planner, the
same planner on the *learned* RG-LRU forecaster
(``waterwise-forecast[forecaster=learned]`` — it trains on the warm-start
telemetry archive inside the pricer, then re-conditions on each hourly
refit), and the true-future ``waterwise-oracle`` upper bound — under
nominal telemetry and under the ``forecast-error`` regime (the planner's
forecast is +30% biased and 15% noisy while physics stay nominal). Prints
the tidy table with the forecast-accuracy and deferral-latency columns,
then the joint-cost summary:

  PYTHONPATH=src python examples/forecast_shift.py              # ~2 min
  PYTHONPATH=src python examples/forecast_shift.py --days 0.05  # CI smoke
"""
import argparse

from repro.sim import scenarios

SCHEDULERS = ["waterwise", "waterwise-forecast",
              "waterwise-forecast[forecaster=learned]", "waterwise-oracle"]
SCENARIOS = ["nominal", "forecast-error"]
COLS = ("scenario", "scheduler", "jobs", "carbon_kg", "water_kl",
        "violation_pct", "forecast_mape", "mean_defer_s", "deferred_pct",
        "wall_s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=0.2)
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="delay tolerance (TOL x exec time of slack) — "
                         "temporal shifting needs slack to shift")
    args = ap.parse_args()

    rows = scenarios.sweep(SCHEDULERS, SCENARIOS, days=args.days, seed=0,
                           tolerance=args.tolerance)
    print(scenarios.to_table(rows, COLS))
    print()
    for scen in SCENARIOS:
        # Rows arrive scenario-major in SCHEDULERS order.
        srows = [r for r in rows if r["scenario"] == scen]
        ww = srows[0]
        for spec, r in zip(SCHEDULERS[1:], srows[1:]):
            joint = 0.5 * (r["carbon_kg"] / ww["carbon_kg"]
                           + r["water_kl"] / ww["water_kl"])
            print(f"{scen:>16} {spec}: joint carbon+water cost "
                  f"{100 * (1 - joint):+.2f}% vs reactive waterwise "
                  f"({r['deferred_pct']:.1f}% of jobs time-shifted, "
                  f"forecast MAPE {r['forecast_mape']:.1f}%)")


if __name__ == "__main__":
    main()
