"""Forecast-driven temporal shifting in ~30 lines.

Runs one delay-tolerant Borg-like cell through the reactive ``waterwise``
controller, the Holt-Winters-driven ``waterwise-forecast`` planner, and the
true-future ``waterwise-oracle`` upper bound — under nominal telemetry and
under the ``forecast-error`` regime (the planner's forecast is +30% biased
and 15% noisy while physics stay nominal). Prints the tidy table with the
forecast-accuracy and deferral-latency columns, then the joint-cost summary:

  PYTHONPATH=src python examples/forecast_shift.py              # ~1 min
  PYTHONPATH=src python examples/forecast_shift.py --days 0.05  # CI smoke
"""
import argparse

from repro.sim import scenarios

SCHEDULERS = ["waterwise", "waterwise-forecast", "waterwise-oracle"]
COLS = ("scenario", "scheduler", "jobs", "carbon_kg", "water_kl",
        "violation_pct", "forecast_mape", "mean_defer_s", "deferred_pct",
        "wall_s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=0.2)
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="delay tolerance (TOL x exec time of slack) — "
                         "temporal shifting needs slack to shift")
    args = ap.parse_args()

    rows = scenarios.sweep(SCHEDULERS, ["nominal", "forecast-error"],
                           days=args.days, seed=0,
                           tolerance=args.tolerance)
    print(scenarios.to_table(rows, COLS))
    print()
    for scen in ("nominal", "forecast-error"):
        cells = {r["scheduler"]: r for r in rows if r["scenario"] == scen}
        ww = cells["waterwise"]
        for name in ("waterwise-forecast", "waterwise-oracle"):
            r = cells[name]
            joint = 0.5 * (r["carbon_kg"] / ww["carbon_kg"]
                           + r["water_kl"] / ww["water_kl"])
            print(f"{scen:>16} {name}: joint carbon+water cost "
                  f"{100 * (1 - joint):+.2f}% vs reactive waterwise "
                  f"({r['deferred_pct']:.1f}% of jobs time-shifted, "
                  f"forecast MAPE {r['forecast_mape']:.1f}%)")


if __name__ == "__main__":
    main()
