"""Scenario sweep in ~20 lines: schedulers × environmental regimes.

Runs a small Borg-like trace through three schedulers under three regimes —
nominal, a drought summer (elevated WUE + scarcity), and a full outage of
the greenest region — on the event-driven engine, then prints the tidy
results table. The full registry (``scenarios.list_scenarios()``) and
paper-scale traces are driven the same way:

  PYTHONPATH=src python examples/scenario_sweep.py
  PYTHONPATH=src python -m benchmarks.run --sweep --full   # 100k jobs, 10d
"""
from repro.sim import scenarios

SCHEDULERS = ["baseline", "least-load", "waterwise"]
SCENARIOS = ["nominal", "drought-summer", "capacity-loss"]


def main() -> None:
    rows = scenarios.sweep(SCHEDULERS, SCENARIOS, days=0.1, seed=0)
    print(scenarios.to_table(rows))
    ww = {r["scenario"]: r for r in rows if r["scheduler"] == "waterwise"}
    for name, row in ww.items():
        print(f"waterwise under {name}: {row['carbon_savings_pct']:.1f}% "
              f"carbon, {row['water_savings_pct']:.1f}% water saved "
              f"vs baseline")


if __name__ == "__main__":
    main()
