"""Scenario sweep in ~30 lines: policy specs × environmental regimes.

Runs a small Borg-like trace through three scheduling policies under three
regimes — nominal, a drought summer (elevated WUE + scarcity), and a full
outage of the greenest region — on the event-driven engine, then prints the
tidy results table. Schedulers are *policy specs*: bracketed strings that
parameterize the registry (``waterwise[lam_h2o=0.7,backend=jax]``), so the
same flag drives any variant, and every output row carries a ``spec``
column that rebuilds its scheduler exactly. The full registries
(``scenarios.list_scenarios()``, ``policy.list_policies()``) and
paper-scale traces are driven the same way:

  PYTHONPATH=src python examples/scenario_sweep.py
  PYTHONPATH=src python examples/scenario_sweep.py \\
      --schedulers 'baseline,waterwise[lam_h2o=0.7,backend=flow]'
  PYTHONPATH=src python -m benchmarks.run --sweep --full   # 100k jobs, 10d
"""
import argparse

from repro import policy
from repro.sim import scenarios

SCHEDULERS = "baseline,least-load,waterwise"
SCENARIOS = "nominal,drought-summer,capacity-loss"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=0.1)
    ap.add_argument("--schedulers", default=SCHEDULERS,
                    help="comma-separated policy specs (bracketed params OK)")
    ap.add_argument("--scenarios", default=SCENARIOS)
    args = ap.parse_args()

    specs = policy.split_specs(args.schedulers)
    rows = scenarios.sweep(specs, args.scenarios.split(","),
                           days=args.days, seed=0)
    print(scenarios.to_table(rows))
    for row in rows:
        assert policy.parse(row["spec"])     # every row is reproducible
        if row["scheduler"] == "baseline" or "carbon_savings_pct" not in row:
            continue                         # savings need baseline in sweep
        print(f"{row['spec']} under {row['scenario']}: "
              f"{row['carbon_savings_pct']:.1f}% carbon, "
              f"{row['water_savings_pct']:.1f}% water saved vs baseline")


if __name__ == "__main__":
    main()
