"""Scenario sweep in ~40 lines: an ExperimentPlan × executor backends.

Runs a small Borg-like trace through three scheduling policies under three
regimes — nominal, a drought summer (elevated WUE + scarcity), and a full
outage of the greenest region — on the event-driven engine, then prints the
tidy results table. Everything is declarative data: schedulers are *policy
specs* (``waterwise[lam_h2o=0.7,backend=jax]``), regimes are *scenario
specs* (``diurnal[days=10,jobs_per_day=1e6,tolerance=0.5]``), the grid is
an ``ExperimentPlan`` (JSON-serializable), and the executor is one of three
interchangeable backends producing identical rows:

  PYTHONPATH=src python examples/scenario_sweep.py
  PYTHONPATH=src python examples/scenario_sweep.py \\
      --schedulers 'baseline,waterwise[lam_h2o=0.7,backend=flow]'
  PYTHONPATH=src python examples/scenario_sweep.py \\
      --scenarios 'diurnal[jobs_per_day=46000.0]' --executor 'sharded[shards=2]'
  PYTHONPATH=src python examples/scenario_sweep.py \\
      --scenarios 'workflow-diurnal,workflow-burst' \\
      --schedulers 'waterwise,waterwise-embodied[lam_embodied=0.35]'
      # precedence-constrained DAG traces (see examples/workflow_run.py)
  PYTHONPATH=src python -m benchmarks.run --sweep --full   # 100k jobs, 10d
"""
import argparse

from repro import experiments, policy
from repro.spec import split_specs

SCHEDULERS = "baseline,least-load,waterwise"
SCENARIOS = "nominal,drought-summer,capacity-loss"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=0.1)
    ap.add_argument("--schedulers", default=SCHEDULERS,
                    help="comma-separated policy specs (bracketed params OK)")
    ap.add_argument("--scenarios", default=SCENARIOS,
                    help="comma-separated scenario specs (bracketed params "
                         "OK; DAG cells: workflow-diurnal, workflow-burst)")
    ap.add_argument("--executor", default="process",
                    help="serial | process | sharded[shards=N] — all three "
                         "produce identical rows")
    ap.add_argument("--seeds", default="",
                    help="seed axis for multi-seed replication, e.g. '0,1,2'")
    args = ap.parse_args()

    plan = experiments.ExperimentPlan.build(
        scenarios=[experiments.parse_scenario(s).with_defaults(days=args.days)
                   for s in split_specs(args.scenarios)],
        policies=split_specs(args.schedulers),
        seeds=[int(s) for s in args.seeds.split(",")] if args.seeds else None)
    rows = plan.run(executor=args.executor)
    print(experiments.to_table(rows))
    for row in rows:
        # Every row is reproducible from its spec columns alone.
        assert policy.parse(row["spec"])
        assert experiments.parse_scenario(row["scenario_spec"])
        if row["scheduler"] == "baseline" or "carbon_savings_pct" not in row:
            continue                         # savings need baseline in sweep
        print(f"{row['spec']} under {row['scenario_spec']}: "
              f"{row['carbon_savings_pct']:.1f}% carbon, "
              f"{row['water_savings_pct']:.1f}% water saved vs baseline")


if __name__ == "__main__":
    main()
