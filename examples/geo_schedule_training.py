"""WaterWise scheduling *of training jobs* — the paper's scheduler driving
the TPU-adaptation workload (DESIGN.md §2).

Each job is a training run of one assigned architecture; its energy is
derived from the dry-run roofline (dominant-term step time × chip power ×
chips × steps) and its migration cost L[m,n] is its real sharded-checkpoint
size over the WAN model. WaterWise then places/moves jobs across the five
regions exactly as it does for PARSEC jobs.

    PYTHONPATH=src python examples/geo_schedule_training.py
"""
import copy
import glob
import json

import numpy as np

from repro.configs import get_config
from repro.core import telemetry
from repro.models import Model
from repro.sim import Simulator, savings_vs, summarize
from repro.core.problem import Job

CHIP_W = 250.0          # v5e chip power draw under load
CHIPS = 256
STEPS = 2000            # steps per training job


def job_from_dryrun(cell, job_id, home, submit_s):
    """Energy/duration from the roofline terms; package = checkpoint bytes
    (params + fp32 Adam moments)."""
    r = cell["roofline"]
    step_s = max(r["t_compute"], r["t_memory"], r["t_collective"])
    exec_s = step_s * STEPS
    energy_kwh = CHIP_W * CHIPS * exec_s / 3.6e6
    ckpt_bytes = cell["params"] * (2 + 4 + 4)          # bf16 + fp32 mu/nu
    return Job(job_id=job_id, home_region=home, submit_time_s=submit_s,
               exec_time_s=exec_s, energy_kwh=energy_kwh,
               package_bytes=ckpt_bytes, tolerance=0.5,
               arch=cell["arch"])


def main():
    cells = []
    for p in sorted(glob.glob("results/dryrun/*.train_4k.pod1.baseline.json")):
        d = json.load(open(p))
        if not d.get("skipped"):
            cells.append(d)
    if not cells:
        print("run `python -m repro.launch.dryrun --all` first")
        return

    tele = telemetry.generate(days=4, seed=0)
    rng = np.random.default_rng(0)
    jobs = []
    for i in range(60):                      # 60 training runs over 2 days
        cell = cells[i % len(cells)]
        jobs.append(job_from_dryrun(cell, i, int(rng.integers(0, 5)),
                                    float(rng.uniform(0, 2 * 86400))))
    cap = np.full(5, 6)                      # 6 pods per region

    print(f"{len(jobs)} training jobs ({len(cells)} archs), "
          f"mean duration {np.mean([j.exec_time_s for j in jobs])/3600:.2f} h,"
          f" mean checkpoint "
          f"{np.mean([j.package_bytes for j in jobs])/1e9:.0f} GB\n")

    results = {}
    for name in ("baseline", "waterwise"):
        # Policy-spec strings build through the registry (repro.policy).
        results[name] = summarize(Simulator(tele, cap).run(
            copy.deepcopy(jobs), name))
    sv = savings_vs(results["baseline"], results["waterwise"])
    b, w = results["baseline"], results["waterwise"]
    print(f"baseline : {b['carbon_kg']:10.1f} kg CO2  {b['water_kl']:8.1f} kL")
    print(f"waterwise: {w['carbon_kg']:10.1f} kg CO2  {w['water_kl']:8.1f} kL"
          f"  (moved {w['moved_pct']:.0f}% of jobs)")
    print(f"savings  : carbon {sv['carbon_savings_pct']:.1f}%  "
          f"water {sv['water_savings_pct']:.1f}%  "
          f"(service ×{w['mean_service_ratio']:.3f}, "
          f"violations {w['violation_pct']:.2f}%)")


if __name__ == "__main__":
    main()
