"""Batched serving example: prefill a prompt batch through a reduced
gemma3-family model (sliding-window + global attention) and decode greedily
with sharded KV caches — the decode path the decode_32k/long_500k dry-run
cells lower.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.runtime.serve_loop import Server

cfg = get_config("gemma3_4b", reduced=True).replace(
    n_layers=6, d_model=256, n_heads=4, n_kv=2, head_dim=64, d_ff=1024,
    vocab=32000, window=64)
model = Model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
print(f"serving {model.param_count() / 1e6:.1f}M-param gemma3-family model "
      f"(5:1 local:global, window={cfg.window})")

B, S, NEW = 4, 128, 24
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
server = Server(model, params)

t0 = time.time()
out = server.generate(dict(tokens=prompts), max_new=NEW)
dt = time.time() - t0
print(f"prefill {B}x{S} + decode {NEW} tokens in {dt:.2f}s "
      f"({B * NEW / dt:.1f} tok/s on CPU)")
print("generated token ids (first sequence):", out[0].tolist())
assert out.shape == (B, NEW)
assert np.isfinite(out).all()
print("OK")
