"""Core cell runner: one (scenario spec × policy spec × seed) → tidy row.

Deterministic in the cell's specs — safe to run in a worker process, every
input is rebuilt from primitives — and shared by all executor backends:
``serial``/``process`` call :func:`run_cell` whole, the ``sharded`` backend
reuses :func:`execute` / :func:`finalize_row` around its slice machinery.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

import repro.obs as obs
from repro import policy
from repro.experiments.plan import Cell
from repro.experiments.scenario import build_instance
from repro.sim.engine import EventSimulator, SimConfig
from repro.sim.metrics import stress_water_kl, summarize


class CellError(RuntimeError):
    """A cell failed. Carries the failing cell's identity so a sweep
    driver (or a human reading a log) can reproduce it: ``err.scenario``
    and ``err.spec`` are the re-parseable spec strings; when raised by
    ``ExperimentPlan.run(strict=True)`` the completed rows ride along as
    ``err.rows``."""

    def __init__(self, scenario_spec: str, policy_spec: str, cause: str):
        super().__init__(
            f"experiment cell failed: scenario {scenario_spec!r} × "
            f"policy {policy_spec!r}: {cause}")
        self.scenario = scenario_spec
        self.spec = policy_spec
        self.cause = cause
        self.rows: List[Dict] = []


def resolve_policy_spec(cell: Cell, inst) -> policy.PolicySpec:
    """The cell's fully resolved policy spec: ``sched_kwargs``-style
    overrides are already in the spec; a scenario's forecast-error regime
    (bias/noise injection) is folded in here so the row's ``spec`` column
    reproduces the *injected* scheduler exactly."""
    spec = policy.as_spec(cell.policy)
    if policy.get_policy(spec.name).forecast_driven \
            and (inst.forecast_bias != 1.0 or inst.forecast_noise > 0.0):
        spec = spec.with_defaults(forecast_bias=inst.forecast_bias,
                                  forecast_noise=inst.forecast_noise,
                                  forecast_seed=cell.seed_value)
    return spec


def forecast_stats(sched, n_jobs: int) -> Optional[Dict]:
    """Deferral/forecast telemetry of one scheduler instance, if it is
    forecast-driven (``None`` otherwise). Carries the raw job counts so
    shard-merged rows can aggregate job-weighted (``merge_forecast_stats``
    in ``repro.experiments.shard``) instead of dropping the fields."""
    if not hasattr(sched, "forecast_mape"):
        return None
    deferred = int(sched.deferred_jobs)
    return dict(forecast_mape=float(sched.forecast_mape),
                mean_defer_s=float(sched.mean_defer_s),
                deferred_jobs=deferred, jobs=int(n_jobs),
                deferred_pct=100.0 * deferred / max(n_jobs, 1))


def finalize_row(cell: Cell, spec: policy.PolicySpec, inst, result: Dict,
                 wall_s: float, stats: Optional[Dict] = None,
                 return_result: bool = False) -> Dict:
    """Build the tidy row for one executed cell from its engine result."""
    row = dict(scenario=cell.scenario.name, scheduler=spec.name,
               spec=str(spec), scenario_spec=str(cell.resolved_scenario()),
               seed=cell.seed_value, error="", **summarize(result))
    row["wall_s"] = wall_s
    row["unfinished"] = result["unfinished"]
    weight = (inst.water_weight if inst.water_weight is not None
              else np.ones(inst.tele.num_regions))
    row["stress_water_kl"] = stress_water_kl(result, weight)
    if stats is not None:
        row["forecast_mape"] = stats["forecast_mape"]
        row["mean_defer_s"] = stats["mean_defer_s"]
        row["deferred_pct"] = stats["deferred_pct"]
    if return_result:
        row["_result"] = result
    return row


def error_row(cell: Cell, exc: BaseException) -> Dict:
    """Tidy row for a crashed cell: identity columns + the ``error``
    column; metrics stay empty so downstream aggregation skips it."""
    try:
        scenario_spec = str(cell.resolved_scenario())
    except Exception:                       # the scenario spec itself broke
        scenario_spec = str(cell.scenario)
    return dict(scenario=cell.scenario.name, scheduler=cell.policy.name,
                spec=str(cell.policy), scenario_spec=scenario_spec,
                seed=cell.seed_value,
                error=f"{type(exc).__name__}: {exc}")


def execute(cell: Cell, extra_build_kwargs: Optional[Dict] = None):
    """Build and run one cell; returns ``(inst, spec, sched, result,
    wall_s)`` for callers that post-process the raw engine result."""
    from repro.core import solvers

    solvers.available_backends()     # one-time backend imports, off the clock
    inst, cellkw = build_instance(cell.resolved_scenario(),
                                  extra_build_kwargs)
    spec = resolve_policy_spec(cell, inst)
    sched = policy.build(spec, inst.tele)
    sim = EventSimulator(inst.tele, inst.capacity,
                         SimConfig(window_s=cellkw["window_s"]),
                         capacity_events=inst.capacity_events)
    with obs.timed("cell.run", scenario=cell.scenario.name,
                   scheduler=spec.name, jobs=len(inst.jobs)) as t:
        result = sim.run(inst.jobs, sched)
    return inst, spec, sched, result, t.elapsed_s


def run_cell(cell: Cell, extra_build_kwargs: Optional[Dict] = None,
             return_result: bool = False) -> Dict:
    """The unsharded cell runner (serial and process backends; also the
    module-level picklable entry point for pool workers)."""
    inst, spec, sched, result, wall = execute(cell, extra_build_kwargs)
    return finalize_row(cell, spec, inst, result, wall,
                        stats=forecast_stats(sched, len(inst.jobs)),
                        return_result=return_result)


def run_cell_obs(cell: Cell) -> Dict:
    """``run_cell`` with obs collection enabled inside the worker process
    (``repro.obs`` registries are per-process, so a fresh pool worker is
    otherwise dark). Ships the worker's metrics snapshot in the private
    ``_obs`` row key — popped and merged into the driver registry by
    ``ProcessExecutor``; ``to_csv``'s fixed column set never sees it."""
    with obs.capture(fold=False) as reg:
        row = run_cell(cell)
        row["_obs"] = reg.snapshot()
    return row
