"""Declarative scenario specs: ``"diurnal[days=10,jobs_per_day=1e6]"``.

The experiment-side counterpart of ``repro.policy``'s ``PolicySpec``: a
*scenario spec* names a registered scenario (``repro.sim.scenarios``) plus
explicitly overridden, typed cell parameters — and round-trips through its
string form exactly (``parse_scenario(str(spec)) == spec``), so an
experiment cell is reproducible from a CSV row, a CLI flag, or a JSON plan
alone.

Two layers of parameters compose a scenario spec's schema:

* **cell params** (``CELL_PARAMS``) — shared by every scenario: the trace
  span (``days``), RNG ``seed``, arrival rate (``jobs_per_day``), capacity
  scaling target (``utilization``), and scheduling-round period
  (``window_s``). These were the positional-kwargs pile of the old
  ``run_cell(scenario, sched, days=..., seed=..., ...)`` surface.
* **builder params** — introspected per scenario from its builder
  signature (``Scenario.params``): ``tolerance``, ``trace``,
  ``ewif_table``, a CSV scenario's own knobs, ... Unknown or ill-typed
  keys fail fast with a did-you-mean, exactly like policy specs.

Builder arguments that cannot be expressed as spec text (e.g. ``regions``
— a list of region objects) remain available in-process through
``build_instance(..., extra_build_kwargs=...)`` and are never serialized.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

from repro.sim import scenarios
from repro.spec import (Param, Spec, parse_raw, validate_params)

#: Cell-level parameters shared by every scenario (the former positional
#: kwargs of ``scenarios.run_cell``). ``window_s`` configures the engine,
#: the rest parameterize the builder's four positional arguments.
CELL_PARAMS: Dict[str, Param] = {p.name: p for p in (
    Param("days", float, 0.2, "simulated trace span (days)"),
    Param("seed", int, 0, "trace + telemetry RNG seed"),
    Param("jobs_per_day", float, 23000.0, "target arrival rate (jobs/day)"),
    Param("utilization", float, 0.15,
          "mean fleet utilization the capacity is scaled for"),
    Param("window_s", float, 30.0, "scheduling-round period (seconds)"),
)}


@dataclasses.dataclass(frozen=True)
class ScenarioSpec(Spec):
    """A fully parameterized experiment cell's *environment* as data:
    registered scenario name + explicit typed cell/builder params."""

    def with_params(self, **overrides) -> "ScenarioSpec":
        """New spec with ``overrides`` replacing/adding params (validated)."""
        return make_scenario_spec(self.name, **{**self.params, **overrides})

    def with_defaults(self, **defaults) -> "ScenarioSpec":
        """New spec with ``defaults`` filled only where not already set."""
        return make_scenario_spec(self.name, **{**defaults, **self.params})

    def cell_kwargs(self) -> Dict[str, object]:
        """The five cell-level values, defaults filled in."""
        return {k: self.params.get(k, p.default)
                for k, p in CELL_PARAMS.items()}

    def build_kwargs(self) -> Dict[str, object]:
        """The builder-specific overrides (everything not cell-level)."""
        return {k: v for k, v in self.params.items() if k not in CELL_PARAMS}


SpecLike = Union[str, ScenarioSpec]


def scenario_schema(name: str) -> Dict[str, Param]:
    """Full param schema of one scenario: shared cell params + the
    builder's introspected params (raises with did-you-mean on unknown
    scenario names)."""
    return {**CELL_PARAMS, **scenarios.get_scenario(name).params}


def make_scenario_spec(name: str, **params) -> ScenarioSpec:
    """Validated, coerced ``ScenarioSpec`` (the registry-side constructor)."""
    return ScenarioSpec(name, validate_params(
        "scenario", name, scenario_schema(name), params))


def parse_scenario(text: SpecLike) -> ScenarioSpec:
    """Parse + validate a scenario spec string against the registry.

    Accepts an existing ``ScenarioSpec`` too (re-validated), so every
    consumer can take either form; bare names parse to all-default specs.
    """
    if isinstance(text, ScenarioSpec):
        return make_scenario_spec(text.name, **text.params)
    name, raw = parse_raw(text, kind="scenario")
    return make_scenario_spec(name, **raw)


as_scenario_spec = parse_scenario      # readability alias


def build_instance(spec: SpecLike,
                   extra_build_kwargs: Optional[Dict] = None
                   ) -> Tuple["scenarios.ScenarioInstance", Dict[str, object]]:
    """Materialize a scenario spec: ``(ScenarioInstance, cell_kwargs)``.

    ``extra_build_kwargs`` forwards builder arguments the grammar cannot
    express (``regions`` objects, ...); they are merged *over* the spec's
    builder params and never serialized (in-process figure studies only).
    """
    s = parse_scenario(spec)
    cell = s.cell_kwargs()
    build_kw = s.build_kwargs()
    build_kw.update(extra_build_kwargs or {})
    inst = scenarios.get_scenario(s.name).build(
        cell["days"], cell["seed"], cell["jobs_per_day"],
        cell["utilization"], **build_kw)
    return inst, cell


def describe_scenarios(markdown: bool = False) -> str:
    """Scenario-registry dump including the shared cell params (the
    ``--list-scenarios`` surface and the README scenario table source)."""
    shared = ", ".join(f"`{p.describe()}`" for p in CELL_PARAMS.values())
    if markdown:
        return (f"Shared cell parameters (every scenario): {shared}\n\n"
                + scenarios.describe(markdown=True))
    head = "shared cell params: " + ", ".join(
        p.describe() for p in CELL_PARAMS.values())
    return head + "\n\n" + scenarios.describe(markdown=False)
