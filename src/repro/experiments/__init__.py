"""Declarative experiment API: scenario specs, plans, sharded execution.

The experiment-layer counterpart of ``repro.policy``: *what to run* is
data, not kwargs. A ``ScenarioSpec`` names a registered scenario with
typed, validated cell parameters (``"diurnal[days=10,jobs_per_day=1e6]"``);
an ``ExperimentPlan`` is the (scenarios × policies × seeds) grid, JSON-
serializable; ONE ``Executor`` abstraction runs a plan's cells on three
interchangeable backends — ``serial``, ``process`` (one worker per cell),
and ``sharded`` (one cell split by arrival time across workers with
engine-state handoff and boundary stitching). All backends produce
identical tidy rows; carbon/water/violation totals are bit-identical to
the serial run by construction.

Typical use::

    from repro import experiments

    plan = experiments.ExperimentPlan.build(
        scenarios=["diurnal[days=10,jobs_per_day=1e5]", "drought-summer"],
        policies=["baseline", "waterwise[lam_h2o=0.7]"],
        seeds=[0, 1, 2])
    rows = plan.run(executor="sharded[shards=4]")
    print(experiments.to_table(rows))
    plan.save("plan.json")                 # reviewable, re-runnable artifact

Everything a spec cannot express (an unknown scenario, a typo'd or
ill-typed param) fails fast with a did-you-mean message, before any cell
runs. The legacy ``repro.sim.scenarios.run_cell`` / ``sweep`` surface
survives as thin shims over this package.
"""
from repro.experiments.executor import (Executor, ProcessExecutor,
                                        SerialExecutor, ShardedExecutor,
                                        describe_executors, executor_schema,
                                        get_executor, list_executors)
from repro.experiments.plan import (CSV_COLS, TABLE_COLS, Cell,
                                    ExperimentPlan, aggregate_seeds,
                                    attach_savings, seed_group_key, t95,
                                    to_csv, to_table)
from repro.experiments.runner import CellError, run_cell
from repro.experiments.scenario import (CELL_PARAMS, ScenarioSpec,
                                        as_scenario_spec, build_instance,
                                        describe_scenarios,
                                        make_scenario_spec, parse_scenario,
                                        scenario_schema)
from repro.experiments.shard import (auto_handoff_s, merge_forecast_stats,
                                     run_sharded_cell, states_match)

__all__ = [
    # scenario specs
    "ScenarioSpec", "parse_scenario", "as_scenario_spec",
    "make_scenario_spec", "scenario_schema", "build_instance",
    "describe_scenarios", "CELL_PARAMS",
    # plans
    "ExperimentPlan", "Cell", "attach_savings", "TABLE_COLS", "CSV_COLS",
    "to_table", "to_csv", "aggregate_seeds", "seed_group_key", "t95",
    # running
    "run_cell", "CellError",
    # executors
    "Executor", "SerialExecutor", "ProcessExecutor", "ShardedExecutor",
    "get_executor", "list_executors", "executor_schema",
    "describe_executors",
    # sharding
    "run_sharded_cell", "auto_handoff_s", "merge_forecast_stats",
    "states_match",
]
