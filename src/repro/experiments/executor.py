"""ONE ``Executor`` abstraction, three interchangeable backends.

Every backend maps a list of experiment cells to tidy rows with identical
values — the backend choice is an operational knob (latency, parallelism,
scale), never a semantic one (pinned by parity tests):

* ``serial``   — in-process loop; zero overhead, fully deterministic.
* ``process``  — today's sweep pool: one worker process per *cell* (cells
  are independent and rebuilt from primitives).
* ``sharded``  — splits each *single* cell's trace by arrival time across
  worker processes with engine-state handoff + boundary stitching
  (``repro.experiments.shard``); the scale-out path for 1M+-job cells.

Executors are themselves spec-addressable through the shared grammar —
``"sharded[shards=4,max_workers=4]"`` — with schemas introspected from the
backend constructors, so ``--executor`` CLI flags, plan runners, and tests
all speak the same validated language as policies and scenarios.

A crashed cell never aborts the others on any backend: its row carries the
failure in the ``error`` column and execution continues (the old sweep's
bare ``f.result()`` abort is gone).
"""
from __future__ import annotations

import concurrent.futures
import os
from typing import Dict, List, Optional, Union

import repro.obs as obs
from repro.experiments import runner
from repro.experiments.plan import Cell
from repro.spec import (Param, parse_raw, params_from_signature,
                        unknown_name_error, validate_params)


class Executor:
    """Maps cells to tidy rows; subclasses define *where* cells run."""

    name = "?"

    def run(self, cells: List[Cell]) -> List[Dict]:
        raise NotImplementedError

    def _guarded(self, fn, cell: Cell) -> Dict:
        try:
            return fn(cell)
        except Exception as e:              # noqa: BLE001 — error-row contract
            return runner.error_row(cell, e)


class SerialExecutor(Executor):
    """In-process, one cell after another."""

    name = "serial"

    def run(self, cells: List[Cell]) -> List[Dict]:
        return [self._guarded(runner.run_cell, c) for c in cells]


class ProcessExecutor(Executor):
    """One worker process per cell (the classic sweep fan-out).

    ``max_workers=0`` auto-sizes to ``min(cpu_count, len(cells))``. Serial
    and process runs produce identical rows: every cell is deterministic
    in its specs and rebuilt from primitives inside the worker.
    """

    name = "process"

    def __init__(self, max_workers: int = 0):
        self.max_workers = int(max_workers)

    def run(self, cells: List[Cell]) -> List[Dict]:
        workers = self.max_workers or min(os.cpu_count() or 1, len(cells))
        if workers <= 1 or len(cells) <= 1:
            return SerialExecutor().run(cells)
        rows: List[Dict] = []
        fn = runner.run_cell_obs if obs.enabled() else runner.run_cell
        with concurrent.futures.ProcessPoolExecutor(workers) as pool:
            futs = [pool.submit(fn, c) for c in cells]
            for cell, fut in zip(cells, futs):
                try:
                    row = fut.result()
                    snap = row.pop("_obs", None)
                    if snap:
                        obs.merge(snap)
                    rows.append(row)
                except Exception as e:      # noqa: BLE001 — error-row contract
                    rows.append(runner.error_row(cell, e))
        return rows


class ShardedExecutor(Executor):
    """Splits each cell's trace across ``shards`` worker slices
    (``repro.experiments.shard``): the single-cell scale-out backend.

    ``shards`` trace slices per cell; ``max_workers=0`` auto-sizes the
    per-cell pool; ``handoff_s=0`` auto-sizes the warm-up handoff window
    from the trace's longest possible in-flight span. Cells run one after
    another — the parallelism lives *inside* each cell.
    """

    name = "sharded"

    def __init__(self, shards: int = 2, max_workers: int = 0,
                 handoff_s: float = 0.0):
        self.shards = int(shards)
        self.max_workers = int(max_workers)
        self.handoff_s = float(handoff_s)

    def run(self, cells: List[Cell]) -> List[Dict]:
        from repro.experiments import shard

        def one(cell: Cell) -> Dict:
            return shard.run_sharded_cell(
                cell, shards=self.shards,
                max_workers=self.max_workers or None,
                handoff_s=self.handoff_s)

        return [self._guarded(one, c) for c in cells]


_EXECUTORS = {cls.name: cls
              for cls in (SerialExecutor, ProcessExecutor, ShardedExecutor)}

ExecutorLike = Union[str, Executor]


def list_executors() -> List[str]:
    return sorted(_EXECUTORS)


def executor_schema(name: str) -> Dict[str, Param]:
    cls = _EXECUTORS.get(name)
    if cls is None:
        raise unknown_name_error("executor", name, list(_EXECUTORS))
    return {p.name: p
            for p in params_from_signature(cls.__init__, drop_positional=1)}


def get_executor(spec: ExecutorLike, **overrides) -> Executor:
    """Resolve an executor spec — ``"sharded[shards=4]"`` — to a backend
    instance. ``overrides`` (CLI flags; ``None`` values ignored) are
    validated against the backend's introspected schema exactly like any
    other spec params."""
    if isinstance(spec, Executor):
        return spec
    name, raw = parse_raw(spec, kind="executor")
    schema = executor_schema(name)
    merged = dict(raw)
    merged.update({k: v for k, v in overrides.items() if v is not None})
    return _EXECUTORS[name](**validate_params("executor", name, schema,
                                              merged))


def describe_executors() -> str:
    lines = []
    for name in list_executors():
        cls = _EXECUTORS[name]
        doc = (cls.__doc__ or "").strip().splitlines()[0]
        lines.append(f"{name:10s} {doc}")
        for p in executor_schema(name).values():
            lines.append(f"    {p.describe()}")
    return "\n".join(lines)
