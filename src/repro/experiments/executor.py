"""ONE ``Executor`` abstraction, four interchangeable backends.

Every backend maps a list of experiment cells to tidy rows with identical
values — the backend choice is an operational knob (latency, parallelism,
scale), never a semantic one (pinned by parity tests):

* ``serial``   — in-process loop; zero overhead, fully deterministic.
* ``process``  — today's sweep pool: one worker process per *cell* (cells
  are independent and rebuilt from primitives).
* ``sharded``  — splits each *single* cell's trace by arrival time across
  worker processes with engine-state handoff + boundary stitching
  (``repro.experiments.shard``); the scale-out path for 1M+-job cells.
* ``device``   — runs many cells' scheduling rounds as device-parallel
  jitted programs: one engine thread per cell, every ``fused``-backend
  solve intercepted and batched across cells into ONE vmapped /
  shard_mapped dispatch per (bucket, dtype, statics) group
  (``repro.core.round.fused_round_batch``). Cells the batch program cannot
  serve (forecast-driven policies, non-``fused`` solver backends) fall
  back to the serial path, so any plan runs on any backend.

Executors are themselves spec-addressable through the shared grammar —
``"sharded[shards=4,max_workers=4]"`` — with schemas introspected from the
backend constructors, so ``--executor`` CLI flags, plan runners, and tests
all speak the same validated language as policies and scenarios.

A crashed cell never aborts the others on any backend: its row carries the
failure in the ``error`` column and execution continues (the old sweep's
bare ``f.result()`` abort is gone).
"""
from __future__ import annotations

import concurrent.futures
import os
import threading
from typing import Dict, List, Optional, Union

import repro.obs as obs
from repro.core import solvers
from repro.experiments import runner
from repro.experiments.plan import Cell
from repro.spec import (Param, parse_raw, params_from_signature,
                        unknown_name_error, validate_params)


class Executor:
    """Maps cells to tidy rows; subclasses define *where* cells run."""

    name = "?"

    def run(self, cells: List[Cell]) -> List[Dict]:
        raise NotImplementedError

    def _guarded(self, fn, cell: Cell) -> Dict:
        try:
            return fn(cell)
        except Exception as e:              # noqa: BLE001 — error-row contract
            return runner.error_row(cell, e)


class SerialExecutor(Executor):
    """In-process, one cell after another."""

    name = "serial"

    def run(self, cells: List[Cell]) -> List[Dict]:
        return [self._guarded(runner.run_cell, c) for c in cells]


class ProcessExecutor(Executor):
    """One worker process per cell (the classic sweep fan-out).

    ``max_workers=0`` auto-sizes to ``min(cpu_count, len(cells))``. Serial
    and process runs produce identical rows: every cell is deterministic
    in its specs and rebuilt from primitives inside the worker.
    """

    name = "process"

    def __init__(self, max_workers: int = 0):
        self.max_workers = int(max_workers)

    def run(self, cells: List[Cell]) -> List[Dict]:
        workers = self.max_workers or min(os.cpu_count() or 1, len(cells))
        if workers <= 1 or len(cells) <= 1:
            return SerialExecutor().run(cells)
        rows: List[Dict] = []
        fn = runner.run_cell_obs if obs.enabled() else runner.run_cell
        with concurrent.futures.ProcessPoolExecutor(workers) as pool:
            futs = [pool.submit(fn, c) for c in cells]
            for cell, fut in zip(cells, futs):
                try:
                    row = fut.result()
                    snap = row.pop("_obs", None)
                    if snap:
                        obs.merge(snap)
                    rows.append(row)
                except Exception as e:      # noqa: BLE001 — error-row contract
                    rows.append(runner.error_row(cell, e))
        return rows


class ShardedExecutor(Executor):
    """Splits each cell's trace across ``shards`` worker slices
    (``repro.experiments.shard``): the single-cell scale-out backend.

    ``shards`` trace slices per cell; ``max_workers=0`` auto-sizes the
    per-cell pool; ``handoff_s=0`` auto-sizes the warm-up handoff window
    from the trace's longest possible in-flight span. Cells run one after
    another — the parallelism lives *inside* each cell.
    """

    name = "sharded"

    def __init__(self, shards: int = 2, max_workers: int = 0,
                 handoff_s: float = 0.0):
        self.shards = int(shards)
        self.max_workers = int(max_workers)
        self.handoff_s = float(handoff_s)

    def run(self, cells: List[Cell]) -> List[Dict]:
        from repro.experiments import shard

        def one(cell: Cell) -> Dict:
            return shard.run_sharded_cell(
                cell, shards=self.shards,
                max_workers=self.max_workers or None,
                handoff_s=self.handoff_s)

        return [self._guarded(one, c) for c in cells]


class _CellBatcher:
    """Lockstep cross-cell solve batcher (the ``device`` backend's core).

    Every participating cell runs in its own thread and funnels each
    ``fused`` solve here via :func:`repro.core.solvers.intercepted`;
    :meth:`submit` blocks until the whole wave's requests are flushed as
    one device-parallel batch (``flush_fn``) and the caller's result is
    back.

    Liveness invariant: a flush fires exactly when every *active* thread
    is blocked in :meth:`submit` — the last arrival executes the flush.
    A thread that will submit nothing more MUST :meth:`finish` (the
    executor does so in a ``finally``), which both removes it from the
    barrier arithmetic and flushes any wave it was holding up. Cells make
    different numbers of solves (different round counts, hard + soft
    fallback rounds): late waves simply batch across whichever cells are
    still running, down to single-request "batches" for the last cell
    standing — identical results, less amortization.

    A flush exception fans out to every waiting ``submit`` (re-raised in
    each cell thread → that cell's error row); the batcher itself stays
    usable for the survivors.
    """

    def __init__(self, flush_fn):
        self._flush_fn = flush_fn
        self._cv = threading.Condition()
        self._active = 0
        self._pending: List[list] = []      # [request, result, exception]

    def register(self) -> None:
        with self._cv:
            self._active += 1

    def finish(self) -> None:
        with self._cv:
            self._active -= 1
            self._maybe_flush()

    def submit(self, request):
        item = [request, None, None]
        with self._cv:
            self._pending.append(item)
            self._maybe_flush()
            while item[1] is None and item[2] is None:
                self._cv.wait()
        if item[2] is not None:
            raise item[2]
        return item[1]

    def _maybe_flush(self) -> None:
        # Caller holds the lock. Every active thread pending -> flush now.
        # (The non-submitting threads are all inside submit(), waiting, so
        # holding the lock across the flush serializes nothing that could
        # otherwise run.)
        if not self._pending or len(self._pending) < self._active:
            return
        batch, self._pending = self._pending, []
        try:
            results = self._flush_fn([it[0] for it in batch])
            for it, res in zip(batch, results):
                it[1] = res
        except BaseException as e:          # noqa: BLE001 — fan out to cells
            for it in batch:
                it[2] = e
        self._cv.notify_all()


class DeviceExecutor(Executor):
    """Device-parallel cell execution: one engine thread per cell, the
    cells' fused scheduling solves batched into ONE vmapped/shard_mapped
    XLA dispatch per round wave (``repro.core.round.fused_round_batch``).

    ``devices=0`` auto-sizes to every visible XLA device (configure the
    host split with ``repro.launch.devices.set_host_platform_device_count``
    *before* backend init); ``max_cells=0`` runs all batchable cells as one
    wave, else waves of at most ``max_cells`` threads. Cells whose policy
    cannot batch — forecast-driven pipelines (their fused path pre-solves
    inside pricing) and non-``fused`` solver backends — run on the serial
    path first; rows come back in plan order either way, bit-identical to
    ``serial`` (pinned).
    """

    name = "device"

    def __init__(self, devices: int = 0, max_cells: int = 0):
        self.devices = int(devices)
        self.max_cells = int(max_cells)

    @staticmethod
    def _batchable(cell: Cell) -> bool:
        """True when the cell's every hard/soft solve goes through solver
        backend ``"fused"`` — the one program the batch path serves.
        Forecast-driven policies are excluded even with ``backend=fused``:
        their fused path pre-solves inside pricing (``PricedPlan.presolved``)
        and never reaches ``solvers.solve``, so a barrier slot for them
        could deadlock the wave. Anything unclassifiable is non-batchable
        (clean fallback beats a wrong classification)."""
        from repro import policy
        try:
            spec = policy.as_spec(cell.policy)
            entry = policy.get_policy(spec.name)
            if entry.forecast_driven:
                return False
            backend = spec.params.get("backend")
            if backend is None:
                p = entry.params.get("backend")
                backend = None if p is None else p.default
            return backend == "fused"
        except Exception:                   # noqa: BLE001 — conservative
            return False

    def _run_threaded(self, cell: Cell, i: int, rows: List,
                      batcher: _CellBatcher) -> None:
        from repro.core.round import SolveRequest

        def hook(cost, allowed, capacity, *, backend, soften, overrun, tol,
                 sigma):
            if backend != "fused":
                return None                 # decline: solve runs in-thread
            return batcher.submit(SolveRequest(
                cost=cost, allowed=allowed, capacity=capacity,
                soften=soften, overrun=overrun, tol=tol, sigma=sigma))

        try:
            with solvers.intercepted(hook):
                rows[i] = self._guarded(runner.run_cell, cell)
        finally:
            batcher.finish()

    def run(self, cells: List[Cell]) -> List[Dict]:
        import jax

        from repro.core import round as fused_round

        avail = len(jax.devices())
        devices = self.devices or avail
        if devices > avail:
            obs.warn("executor.device_clamp",
                     f"device executor asked for {devices} devices but only "
                     f"{avail} XLA device(s) are visible — clamping (set "
                     f"the host split via repro.launch.devices before "
                     f"backend init)")
            devices = avail
        rows: List[Optional[Dict]] = [None] * len(cells)
        batched = [i for i, c in enumerate(cells) if self._batchable(c)]
        serial = [i for i in range(len(cells)) if i not in set(batched)]
        for i in serial:
            rows[i] = self._guarded(runner.run_cell, cells[i])
        wave = self.max_cells or max(len(batched), 1)
        for start in range(0, len(batched), wave):
            chunk = batched[start:start + wave]
            batcher = _CellBatcher(
                lambda reqs: fused_round.fused_round_batch(
                    reqs, devices=devices))
            threads = []
            for i in chunk:
                batcher.register()
                threads.append(threading.Thread(
                    target=self._run_threaded, args=(cells[i], i, rows,
                                                     batcher),
                    name=f"device-cell-{i}", daemon=True))
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        return rows


_EXECUTORS = {cls.name: cls
              for cls in (SerialExecutor, ProcessExecutor, ShardedExecutor,
                          DeviceExecutor)}

ExecutorLike = Union[str, Executor]


def list_executors() -> List[str]:
    return sorted(_EXECUTORS)


def executor_schema(name: str) -> Dict[str, Param]:
    cls = _EXECUTORS.get(name)
    if cls is None:
        raise unknown_name_error("executor", name, list(_EXECUTORS))
    return {p.name: p
            for p in params_from_signature(cls.__init__, drop_positional=1)}


def get_executor(spec: ExecutorLike, **overrides) -> Executor:
    """Resolve an executor spec — ``"sharded[shards=4]"`` — to a backend
    instance. ``overrides`` (CLI flags; ``None`` values ignored) are
    validated against the backend's introspected schema exactly like any
    other spec params."""
    if isinstance(spec, Executor):
        return spec
    name, raw = parse_raw(spec, kind="executor")
    schema = executor_schema(name)
    merged = dict(raw)
    merged.update({k: v for k, v in overrides.items() if v is not None})
    return _EXECUTORS[name](**validate_params("executor", name, schema,
                                              merged))


def describe_executors() -> str:
    lines = []
    for name in list_executors():
        cls = _EXECUTORS[name]
        doc = (cls.__doc__ or "").strip().splitlines()[0]
        lines.append(f"{name:10s} {doc}")
        for p in executor_schema(name).values():
            lines.append(f"    {p.describe()}")
    return "\n".join(lines)
