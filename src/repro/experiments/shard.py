"""Sharded cell execution: split one large cell's trace by arrival time,
run the slices on worker processes, stitch the boundaries, aggregate exact
totals.

A fleet-scale cell (1M+ jobs over days) is one long sequential simulation —
the ROADMAP's first open item is splitting it across workers *without
changing its result*. The mechanism here keeps sharded output **bit-
identical** to the unsharded run (same placements, same per-job footprints,
same violation totals), by construction rather than by tolerance:

**Chained handoff (always exact).** ``EventSimulator.run`` can stop at a
boundary and export an ``EngineState`` (clock + grid phase, pending queue,
in-flight completions, capacity cursor); resuming the next slice from that
state with the *same scheduler object* reproduces the single run exactly.
This sequential chain is the fallback spine — and the only path for
*stateful* policies (history learners, deferral queues), whose internal
state cannot cross process boundaries.

**Speculative warm-up (parallel, validated).** For registry policies marked
``stateless``, each shard ``k`` starts a *handoff window* before its
boundary ``B_k``: it seeds an empty engine at a grid-aligned instant
``B_k - handoff_s`` (the engine's round grid is a deterministic float
accumulation from the first arrival, so the driver can replay it bit-for-
bit), simulates the warm-up arrivals with ``hold_grid=True`` (ticking the
grid through idle exactly as the busy unsharded engine would), and exports
its *speculated* entry state at ``B_k``. All shards run in parallel; the
driver then walks the boundaries left to right comparing each shard's
speculated entry state against the **true** exported state of the accepted
run before it — clock bitwise, pending queue, completion heap, capacity —
and accepts the shard's slice records only on exact match. A mismatched
shard is re-run sequentially from the true state (correctness never
depends on the speculation; only speed does). Warm-up records are
discarded — every job's record comes from exactly one accepted slice run.

Totals then aggregate exactly: records concatenate in the unsharded
placement order, so summed carbon/water/violation match the serial run
bit-for-bit (per-record accounting is elementwise — ``Telemetry.mean_over``
is a closed-form antiderivative lookup). Utilization is recomposed from
per-slice busy integrals over an analytic capacity integral (equal in
value, not guaranteed to the last bit — float association differs).
"""
from __future__ import annotations

import concurrent.futures
import contextlib
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import repro.obs as obs
from repro import policy
from repro.experiments.plan import Cell
from repro.experiments.runner import (execute, finalize_row, forecast_stats,
                                      resolve_policy_spec)
from repro.experiments.scenario import build_instance
from repro.sim.engine import (EngineState, EventSimulator, SimConfig,
                              resolve_capacity)
from repro.sim.trace import pick_shard_boundaries, slice_by_arrival


def auto_handoff_s(jobs: Sequence) -> float:
    """Default handoff-window span: 1.5× the longest possible in-flight
    stretch of any single job — ``(1 + TOL) × exec`` covers intentional
    oracle delays (``planned_start_s``) on top of the execution itself,
    and the extra half gives queue effects room to converge. Too short a
    window only costs speed (validation rejects the shard), never
    correctness."""
    return 1.5 * max(((1.0 + j.tolerance) * j.exec_time_s * j.time_scale
                      for j in jobs), default=0.0)


def _grid_at(t0: float, window_s: float, target: float) -> float:
    """Replay the engine's float-accumulated round grid (anchored at the
    first arrival ``t0``) to the first instant ``>= target`` — bitwise the
    same value the unsharded engine's ``now += w`` chain produces there."""
    now = t0
    while now < target:
        now += window_s
    return now


def _empty_seed(now: float, base_capacity: np.ndarray,
                events: Sequence[Tuple[float, object]]) -> EngineState:
    """Speculated engine state at a warm-up start: empty fleet, no pending,
    clock at a grid instant, capacity events up to ``now`` pre-applied."""
    base = np.asarray(base_capacity, np.int64)
    cap = base.copy()
    applied = 0
    for t, payload in events:
        if t > now:
            break
        cap = resolve_capacity(payload, base)
        applied += 1
    zeros = np.zeros_like(cap)
    return EngineState(now=now, pending=[], applied_events=applied,
                       cluster=dict(capacity=cap, busy=zeros.copy(),
                                    completions=[], busy_integral_s=0.0,
                                    cap_integral_s=0.0, last_t=now,
                                    max_finish=0.0, peak_busy=zeros.copy()))


def states_match(a: Optional[EngineState], b: Optional[EngineState]) -> bool:
    """Exact (bitwise) equivalence of the decision-relevant engine state:
    clock/grid phase, pending queue identity+order, in-flight completion
    heap, capacity and its event cursor. Utilization integrals and peak
    counters are bookkeeping, not decision inputs, and are merged
    separately — they don't participate."""
    if a is None or b is None:
        return False
    if a.now != b.now or a.applied_events != b.applied_events:
        return False
    if [j.job_id for j in a.pending] != [j.job_id for j in b.pending]:
        return False
    ca, cb = a.cluster, b.cluster
    return (np.array_equal(ca["busy"], cb["busy"])
            and np.array_equal(ca["capacity"], cb["capacity"])
            and sorted(ca["completions"]) == sorted(cb["completions"]))


def _cap_integral(base: np.ndarray, events: Sequence[Tuple[float, object]],
                  horizon_s: float) -> float:
    """Analytic ∫ total-capacity dt over [0, horizon] (server-seconds),
    the denominator of the merged utilization."""
    base = np.asarray(base, np.int64)
    total, last_t, cap = 0.0, 0.0, float(base.sum())
    for t, payload in sorted(events, key=lambda e: e[0]):
        if t >= horizon_s:
            break
        if t > last_t:
            total += cap * (t - last_t)
            last_t = t
        cap = float(resolve_capacity(payload, base).sum())
    total += cap * max(horizon_s - last_t, 0.0)
    return total


# ---------------------------------------------------------------------------
# Shard worker (module-level: picklable for the process pool)
# ---------------------------------------------------------------------------

def _slice_stats(res: Dict, entry: Optional[EngineState],
                 keep_records: bool = False) -> Dict:
    """Per-slice pieces of the merged result, with the warm-up stage's
    contribution (rounds, solve times, busy integral) subtracted out.

    Workers ship the columnar ``frame`` (fast numpy pickle) instead of the
    record-object list unless ``keep_records`` (in-driver re-runs, where
    nothing crosses a process boundary)."""
    rounds0 = entry.rounds if entry is not None else 0
    busy0 = entry.cluster["busy_integral_s"] if entry is not None else 0.0
    st = res["solve_times"]
    return dict(records=res["records"] if keep_records else [],
                frame=res["frame"],
                solve_times=st[min(rounds0, len(st)):],
                rounds=res["rounds"] - rounds0,
                busy_integral_s=res["busy_integral_s"] - busy0,
                unfinished=res["unfinished"], horizon_s=res["horizon_s"],
                peak_busy=res["peak_busy"])


def _run_shard(cell: Cell, spec_str: str, boundaries: Sequence[float],
               handoff_s: float, k: int, collect_obs: bool = False) -> Dict:
    """Run shard ``k`` of a cell speculatively: (warm-up →) slice.

    Rebuilds the scenario instance deterministically from the cell's specs
    (workers are driven by ``(spec, boundaries)`` alone — no trace bytes
    cross the process boundary inbound) and returns the slice frame plus
    the speculated entry state and exported exit state for validation.
    ``spec_str`` is the driver's fully *resolved* policy spec (scenario
    forecast-error injection applied), so every worker builds exactly the
    scheduler the row's ``spec`` column claims.

    ``collect_obs`` ships the slice run's metrics snapshot in the ``obs``
    key (``repro.obs`` registries are per-process — the driver merges the
    snapshots of *accepted* shards, so merged metrics cover exactly the
    work the merged row reports). Warm-up metrics are isolated and
    discarded: speculation is an implementation detail, not row work.
    """
    inst, cellkw = build_instance(cell.resolved_scenario())
    w = float(cellkw["window_s"])
    jobs = sorted(inst.jobs, key=lambda j: j.submit_time_s)
    slices = slice_by_arrival(jobs, boundaries)
    sl = slices[k]
    sched = policy.build(spec_str, inst.tele)
    sim = EventSimulator(inst.tele, inst.capacity, SimConfig(window_s=w),
                         capacity_events=inst.capacity_events)
    stop = boundaries[k] if k < len(boundaries) else None
    entry: Optional[EngineState] = None
    if k > 0:
        b = boundaries[k - 1]
        t0 = jobs[0].submit_time_s if jobs else 0.0
        s_k = _grid_at(t0, w, max(b - handoff_s, t0))
        warm = [j for j in jobs if s_k <= j.submit_time_s < b]
        seed = _empty_seed(s_k, inst.capacity, inst.capacity_events)
        iso = (obs.capture(fold=False) if collect_obs
               else contextlib.nullcontext())
        with iso:
            entry = sim.run(warm, sched, state=seed, stop_at=b,
                            export_state=True, hold_grid=True)["state"]
    shard_obs: Optional[Dict] = None
    if collect_obs:
        with obs.capture(fold=False) as reg:
            res = sim.run(sl, sched, state=entry, stop_at=stop,
                          export_state=stop is not None)
            shard_obs = reg.snapshot()
    else:
        res = sim.run(sl, sched, state=entry, stop_at=stop,
                      export_state=stop is not None)
    out = _slice_stats(res, entry)
    out.update(k=k, entry=entry, exit=res.get("state"),
               stats=forecast_stats(sched, len(sl)), n_jobs=len(sl),
               obs=shard_obs)
    return out


# ---------------------------------------------------------------------------
# Merging
# ---------------------------------------------------------------------------

def merge_forecast_stats(stats: Sequence[Optional[Dict]]) -> Optional[Dict]:
    """Job-weighted aggregation of per-shard deferral/forecast telemetry.

    ``forecast_mape`` weights by each shard's job count, ``mean_defer_s``
    by its *deferred* job count — so shards that never defer (or hold only
    a handful of jobs) neither drop the fields nor dilute the averages
    arithmetically. ``None`` entries (shards of a non-forecast policy)
    propagate: the merged row only carries the fields when at least one
    shard reported them.
    """
    present = [s for s in stats if s is not None]
    if not present:
        return None
    jobs = sum(s["jobs"] for s in present)
    deferred = sum(s["deferred_jobs"] for s in present)
    mape = (sum(s["forecast_mape"] * s["jobs"] for s in present)
            / max(jobs, 1))
    defer_s = (sum(s["mean_defer_s"] * s["deferred_jobs"] for s in present)
               / deferred if deferred else 0.0)
    return dict(forecast_mape=mape, mean_defer_s=defer_s,
                deferred_jobs=deferred, jobs=jobs,
                deferred_pct=100.0 * deferred / max(jobs, 1))


def _merge_results(parts: List[Dict], inst) -> Dict:
    """Stitch accepted per-slice results into one engine-result dict whose
    per-job frame equals the unsharded run's (same placement order ⇒ the
    same arrays ⇒ identical reductions bit-for-bit)."""
    records = [r for p in parts for r in p["records"]]
    frame = {key: np.concatenate([p["frame"][key] for p in parts])
             for key in parts[0]["frame"]} if parts else None
    if frame is not None and len(records) != int(frame["region"].size):
        # Workers ship frame-only (records stay behind the process
        # boundary): expose *no* record list rather than a silently
        # partial one — a consumer that needs records fails loudly.
        records = None
    sts = [np.asarray(p["solve_times"], np.float64) for p in parts]
    solve_times = (np.concatenate(sts) if sts
                   else np.zeros(0, np.float64))
    horizon = max((p["horizon_s"] for p in parts), default=1.0)
    busy = sum(p["busy_integral_s"] for p in parts)
    denom = _cap_integral(inst.capacity, inst.capacity_events, horizon)
    rounds = sum(p["rounds"] for p in parts)
    peak = np.max(np.stack([p["peak_busy"] for p in parts]), axis=0) \
        if parts else np.zeros_like(inst.capacity)
    return dict(records=records, frame=frame, solve_times=solve_times,
                rounds=rounds, windows=rounds, horizon_s=horizon,
                utilization=busy / max(denom, 1e-9), peak_busy=peak,
                unfinished=parts[-1]["unfinished"] if parts else 0,
                drain_s=horizon)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_sharded_cell(cell: Cell, *, shards: int = 2,
                     max_workers: Optional[int] = None,
                     handoff_s: float = 0.0) -> Dict:
    """Execute one cell sharded; returns its tidy row.

    Stateless policies take the speculative parallel path (validated per
    boundary, per-shard sequential re-run on mismatch); stateful policies
    run the exact chained handoff (sequential by nature — the scheduler
    object itself is the carried state). ``handoff_s=0`` picks the
    ``auto_handoff_s`` window. The row is bit-identical to the serial
    executor's for carbon/water/violation totals on every path.
    """
    with obs.timed("cell.run_sharded", shards=shards) as t:
        inst, cellkw = build_instance(cell.resolved_scenario())
        w = float(cellkw["window_s"])
        jobs = sorted(inst.jobs, key=lambda j: j.submit_time_s)
        boundaries = pick_shard_boundaries(jobs, shards)
        spec = resolve_policy_spec(cell, inst)
        entry = policy.get_policy(spec.name)
        if not boundaries:                      # degenerate: nothing to split
            inst, spec, sched, result, wall = execute(cell)
            return finalize_row(cell, spec, inst, result, wall,
                                stats=forecast_stats(sched, len(inst.jobs)))
        if handoff_s <= 0.0:
            handoff_s = auto_handoff_s(jobs)
        slices = slice_by_arrival(jobs, boundaries)
        sim_cfg = SimConfig(window_s=w)

        def _rerun(k: int, state: Optional[EngineState]) -> Dict:
            """Sequential exact run of slice ``k`` from the true state."""
            sched = policy.build(spec, inst.tele)
            sim = EventSimulator(inst.tele, inst.capacity, sim_cfg,
                                 capacity_events=inst.capacity_events)
            stop = boundaries[k] if k < len(boundaries) else None
            res = sim.run(slices[k], sched, state=state, stop_at=stop,
                          export_state=stop is not None)
            out = _slice_stats(res, None, keep_records=True)
            # A resumed run's rounds/integrals continue the imported state's
            # cumulative values; the fresh scheduler's solve_times don't —
            # subtract only where the chain carried over.
            if state is not None:
                out["rounds"] = res["rounds"] - state.rounds
                out["busy_integral_s"] = (res["busy_integral_s"]
                                          - state.cluster["busy_integral_s"])
            out.update(k=k, entry=state, exit=res.get("state"),
                       stats=forecast_stats(sched, len(slices[k])),
                       n_jobs=len(slices[k]))
            return out

        accepted: List[Dict]
        collect = obs.enabled()
        if entry.stateless:
            n = len(slices)
            workers = max_workers or min(os.cpu_count() or 1, n)
            if workers > 1:
                with concurrent.futures.ProcessPoolExecutor(workers) as pool:
                    futs = [pool.submit(_run_shard, cell, str(spec), boundaries,
                                        handoff_s, k, collect)
                            for k in range(n)]
                    outs = [f.result() for f in futs]
            else:
                outs = [_run_shard(cell, str(spec), boundaries, handoff_s, k,
                                   collect) for k in range(n)]
            accepted = [outs[0]]
            true_exit = outs[0]["exit"]
            for k in range(1, n):
                if states_match(true_exit, outs[k]["entry"]):
                    accepted.append(outs[k])
                else:                           # speculation missed: exact redo
                    obs.counter("shard/speculation_miss")
                    accepted.append(_rerun(k, true_exit))
                true_exit = accepted[-1]["exit"]
            if collect:
                # Fold the accepted shards' shipped metrics into the
                # driver registry (re-runs recorded live in-driver and
                # ship no snapshot; rejected speculations are dropped).
                for p in accepted:
                    if p.get("obs"):
                        obs.merge(p["obs"])
        else:
            # Stateful policy: exact chained handoff with one scheduler
            # instance carried across every slice (sequential by nature). The
            # engine's carried state keeps its counters and utilization
            # integrals *cumulative*, so the final slice's result already
            # reports whole-run values bit-identical to the serial path —
            # only the per-slice record streams need concatenating.
            sched = policy.build(spec, inst.tele)
            sim = EventSimulator(inst.tele, inst.capacity, sim_cfg,
                                 capacity_events=inst.capacity_events)
            records, frames = [], []
            state: Optional[EngineState] = None
            res: Dict = {}
            for k, sl in enumerate(slices):
                stop = boundaries[k] if k < len(boundaries) else None
                res = sim.run(sl, sched, state=state, stop_at=stop,
                              export_state=stop is not None)
                state = res.get("state")
                records.extend(res["records"])
                frames.append(res["frame"])
            result = dict(res, records=records,
                          frame={key: np.concatenate([f[key] for f in frames])
                                 for key in frames[0]})
            result.pop("state", None)
            stats = forecast_stats(sched, len(jobs))
            return finalize_row(cell, spec, inst, result, t.elapsed(),
                                stats=stats)

        stats = merge_forecast_stats([p.get("stats") for p in accepted])
        result = _merge_results(accepted, inst)
        return finalize_row(cell, spec, inst, result, t.elapsed(),
                            stats=stats)
