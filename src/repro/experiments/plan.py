"""``ExperimentPlan``: the (scenarios × policies × seeds) grid as data.

A plan is the declarative form of a whole experiment: every axis is a spec
(scenario specs, policy specs, seed overrides), the cross product is the
cell list, and the whole object serializes to/from JSON — so a fleet-scale
study is one reviewable artifact instead of a kwargs pile, and a shard
worker or a remote host can be driven by the plan text alone.

    plan = ExperimentPlan.build(
        scenarios=["diurnal[days=10,jobs_per_day=1e5]", "drought-summer"],
        policies=["baseline", "waterwise[lam_h2o=0.7]"],
        seeds=[0, 1, 2])
    rows = plan.run(executor="process")          # or "sharded[shards=4]"

Each cell yields one tidy row (``TABLE_COLS`` / ``CSV_COLS`` schema); rows
carry re-parseable ``spec`` (policy) and ``scenario_spec`` columns plus the
``seed``, so any CSV line reproduces its cell exactly. Failed cells don't
abort the others: their rows carry an ``error`` column (see
``ExperimentPlan.run(strict=...)``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro import policy
from repro.experiments.scenario import ScenarioSpec, parse_scenario
from repro.sim.metrics import savings_vs

PlanLike = Union[str, "ExperimentPlan"]


@dataclasses.dataclass(frozen=True)
class Cell:
    """One experiment cell: a scenario spec × a policy spec × a seed
    override (``None`` = use the scenario spec's own ``seed`` param)."""
    scenario: ScenarioSpec
    policy: policy.PolicySpec
    seed: Optional[int] = None

    def resolved_scenario(self) -> ScenarioSpec:
        """The scenario spec with the seed override applied."""
        if self.seed is None:
            return self.scenario
        return self.scenario.with_params(seed=self.seed)

    @property
    def seed_value(self) -> int:
        if self.seed is not None:
            return self.seed
        return int(self.scenario.params.get("seed", 0))

    def label(self) -> str:
        return (f"{self.resolved_scenario()} × {self.policy}")


@dataclasses.dataclass(frozen=True)
class ExperimentPlan:
    """The full experiment grid; axes are tuples of validated specs."""
    scenarios: Tuple[ScenarioSpec, ...]
    policies: Tuple[policy.PolicySpec, ...]
    seeds: Tuple[Optional[int], ...] = (None,)

    @classmethod
    def build(cls, scenarios: Sequence, policies: Sequence,
              seeds: Optional[Sequence[Optional[int]]] = None
              ) -> "ExperimentPlan":
        """Validated plan from spec strings/objects (fails fast on typos —
        a misspelled scenario, policy, or param raises before any cell
        runs, with a did-you-mean message)."""
        return cls(
            scenarios=tuple(parse_scenario(s) for s in scenarios),
            policies=tuple(policy.as_spec(p) for p in policies),
            seeds=tuple(seeds) if seeds else (None,))

    def cells(self) -> List[Cell]:
        """The cross product, scenario-major (scenario → seed → policy),
        matching the old ``sweep`` row order for the default seed axis."""
        return [Cell(sc, pol, seed)
                for sc in self.scenarios
                for seed in self.seeds
                for pol in self.policies]

    # -- JSON round-trip -----------------------------------------------------

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(
            dict(scenarios=[str(s) for s in self.scenarios],
                 policies=[str(p) for p in self.policies],
                 seeds=list(self.seeds)), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentPlan":
        d = json.loads(text)
        unknown = set(d) - {"scenarios", "policies", "seeds"}
        if unknown:
            raise ValueError(f"unknown ExperimentPlan keys {sorted(unknown)} "
                             f"(accepts: scenarios, policies, seeds)")
        return cls.build(d["scenarios"], d["policies"], d.get("seeds"))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "ExperimentPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- execution -----------------------------------------------------------

    def run(self, executor: str = "serial", *, strict: bool = False,
            baseline: str = "baseline", **options) -> List[Dict]:
        """Run every cell through ``executor`` and return the tidy rows.

        ``executor`` is an executor spec — ``"serial"``, ``"process"``,
        ``"process[max_workers=4]"``, ``"sharded[shards=4]"`` — resolved by
        ``repro.experiments.executor``; ``options`` are validated overrides
        merged into it. Every backend produces identical rows for
        identical plans (pinned in tests/test_experiments.py).

        A crashed cell never aborts the others: its row records the
        failure in the ``error`` column (metrics empty). With
        ``strict=True`` a ``CellError`` naming the failing (scenario,
        policy) cell is raised *after* all cells finish; the completed
        rows ride on the exception as ``err.rows``.

        Within each (scenario, seed) group, savings percentages are
        attached relative to the ``baseline`` policy when present.
        """
        from repro.experiments.executor import get_executor
        from repro.experiments.runner import CellError

        rows = get_executor(executor, **options).run(self.cells())
        attach_savings(rows, baseline=baseline)
        if strict:
            failed = [r for r in rows if r.get("error")]
            if failed:
                first = failed[0]
                err = CellError(first["scenario_spec"], first["spec"],
                                first["error"])
                err.rows = rows
                raise err
        return rows


def attach_savings(rows: Sequence[Dict], baseline: str = "baseline") -> None:
    """Attach % savings vs the in-group baseline row, including the
    stress-weighted water view. Groups key on the full resolved
    ``scenario_spec`` (plus seed), not the bare scenario name — two
    param-variants of one scenario in a plan each get their own baseline.
    Error rows neither serve as baselines nor receive savings."""
    def key(row):
        return (row.get("scenario_spec", row["scenario"]),
                row.get("seed", 0))

    by_group: Dict[Tuple, Dict] = {}
    for row in rows:
        if row["scheduler"] == baseline and not row.get("error"):
            by_group[key(row)] = row
    for row in rows:
        if row.get("error"):
            continue
        base = by_group.get(key(row))
        if base is None:
            continue
        row.update(savings_vs(base, row))
        bw = base["stress_water_kl"]
        row["stress_water_savings_pct"] = (
            100.0 * (bw - row["stress_water_kl"]) / bw if bw else 0.0)


# ---------------------------------------------------------------------------
# Tidy-row schema
# ---------------------------------------------------------------------------

# "unfinished" stays in the default view: a scheduler that strands jobs
# accrues less footprint than one that ran everything — savings read from a
# row with unfinished > 0 are not comparable to the baseline's.
TABLE_COLS = ("scenario", "scheduler", "jobs", "unfinished", "carbon_kg",
              "water_kl", "stress_water_kl", "carbon_savings_pct",
              "water_savings_pct", "violation_pct", "mean_service_ratio",
              "wall_s")
CSV_COLS = TABLE_COLS + ("stress_water_savings_pct", "p99_service_ratio",
                         "utilization", "mean_solve_ms", "moved_pct",
                         "forecast_mape", "mean_defer_s", "deferred_pct",
                         "seed", "scenario_spec", "error", "spec")


def to_table(rows: Sequence[Dict], cols: Sequence[str] = TABLE_COLS) -> str:
    """Fixed-width tidy table (one line per experiment cell)."""
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.2f}"
        return str(v)
    table = [[fmt(r.get(c, "")) for c in cols] for r in rows]
    widths = [max(len(c), *(len(t[i]) for t in table)) if table else len(c)
              for i, c in enumerate(cols)]
    lines = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for t in table:
        lines.append("  ".join(v.rjust(w) for v, w in zip(t, widths)))
    return "\n".join(lines)


def to_csv(rows: Sequence[Dict], path: str,
           cols: Sequence[str] = CSV_COLS) -> None:
    """Write tidy rows as CSV. Uses the stdlib writer so the ``spec`` /
    ``scenario_spec`` columns — whose bracketed params contain commas — are
    quoted and every row stays re-parseable (``policy.parse(row["spec"])``
    and ``experiments.parse_scenario(row["scenario_spec"])`` rebuild the
    cell exactly)."""
    import csv
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(cols)
        for r in rows:
            w.writerow([r.get(c, "") for c in cols])
