"""``ExperimentPlan``: the (scenarios × policies × seeds) grid as data.

A plan is the declarative form of a whole experiment: every axis is a spec
(scenario specs, policy specs, seed overrides), the cross product is the
cell list, and the whole object serializes to/from JSON — so a fleet-scale
study is one reviewable artifact instead of a kwargs pile, and a shard
worker or a remote host can be driven by the plan text alone.

    plan = ExperimentPlan.build(
        scenarios=["diurnal[days=10,jobs_per_day=1e5]", "drought-summer"],
        policies=["baseline", "waterwise[lam_h2o=0.7]"],
        seeds=[0, 1, 2])
    rows = plan.run(executor="process")          # or "sharded[shards=4]"

Each cell yields one tidy row (``TABLE_COLS`` / ``CSV_COLS`` schema); rows
carry re-parseable ``spec`` (policy) and ``scenario_spec`` columns plus the
``seed``, so any CSV line reproduces its cell exactly. Failed cells don't
abort the others: their rows carry an ``error`` column (see
``ExperimentPlan.run(strict=...)``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro import policy
from repro.experiments.scenario import ScenarioSpec, parse_scenario
from repro.sim.metrics import savings_vs

PlanLike = Union[str, "ExperimentPlan"]


@dataclasses.dataclass(frozen=True)
class Cell:
    """One experiment cell: a scenario spec × a policy spec × a seed
    override (``None`` = use the scenario spec's own ``seed`` param)."""
    scenario: ScenarioSpec
    policy: policy.PolicySpec
    seed: Optional[int] = None

    def resolved_scenario(self) -> ScenarioSpec:
        """The scenario spec with the seed override applied."""
        if self.seed is None:
            return self.scenario
        return self.scenario.with_params(seed=self.seed)

    @property
    def seed_value(self) -> int:
        if self.seed is not None:
            return self.seed
        return int(self.scenario.params.get("seed", 0))

    def label(self) -> str:
        return (f"{self.resolved_scenario()} × {self.policy}")


@dataclasses.dataclass(frozen=True)
class ExperimentPlan:
    """The full experiment grid; axes are tuples of validated specs."""
    scenarios: Tuple[ScenarioSpec, ...]
    policies: Tuple[policy.PolicySpec, ...]
    seeds: Tuple[Optional[int], ...] = (None,)

    @classmethod
    def build(cls, scenarios: Sequence, policies: Sequence,
              seeds: Optional[Sequence[Optional[int]]] = None
              ) -> "ExperimentPlan":
        """Validated plan from spec strings/objects (fails fast on typos —
        a misspelled scenario, policy, or param raises before any cell
        runs, with a did-you-mean message)."""
        return cls(
            scenarios=tuple(parse_scenario(s) for s in scenarios),
            policies=tuple(policy.as_spec(p) for p in policies),
            seeds=tuple(seeds) if seeds else (None,))

    def cells(self) -> List[Cell]:
        """The cross product, scenario-major (scenario → seed → policy),
        matching the old ``sweep`` row order for the default seed axis."""
        return [Cell(sc, pol, seed)
                for sc in self.scenarios
                for seed in self.seeds
                for pol in self.policies]

    # -- JSON round-trip -----------------------------------------------------

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(
            dict(scenarios=[str(s) for s in self.scenarios],
                 policies=[str(p) for p in self.policies],
                 seeds=list(self.seeds)), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentPlan":
        d = json.loads(text)
        unknown = set(d) - {"scenarios", "policies", "seeds"}
        if unknown:
            raise ValueError(f"unknown ExperimentPlan keys {sorted(unknown)} "
                             f"(accepts: scenarios, policies, seeds)")
        return cls.build(d["scenarios"], d["policies"], d.get("seeds"))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "ExperimentPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- execution -----------------------------------------------------------

    def run(self, executor: str = "serial", *, strict: bool = False,
            baseline: str = "baseline", **options) -> List[Dict]:
        """Run every cell through ``executor`` and return the tidy rows.

        ``executor`` is an executor spec — ``"serial"``, ``"process"``,
        ``"process[max_workers=4]"``, ``"sharded[shards=4]"`` — resolved by
        ``repro.experiments.executor``; ``options`` are validated overrides
        merged into it. Every backend produces identical rows for
        identical plans (pinned in tests/test_experiments.py).

        A crashed cell never aborts the others: its row records the
        failure in the ``error`` column (metrics empty). With
        ``strict=True`` a ``CellError`` naming the failing (scenario,
        policy) cell is raised *after* all cells finish; the completed
        rows ride on the exception as ``err.rows``.

        Within each (scenario, seed) group, savings percentages are
        attached relative to the ``baseline`` policy when present.
        """
        from repro.experiments.executor import get_executor
        from repro.experiments.runner import CellError

        rows = get_executor(executor, **options).run(self.cells())
        attach_savings(rows, baseline=baseline)
        if strict:
            failed = [r for r in rows if r.get("error")]
            if failed:
                first = failed[0]
                err = CellError(first["scenario_spec"], first["spec"],
                                first["error"])
                err.rows = rows
                raise err
        return rows


def attach_savings(rows: Sequence[Dict], baseline: str = "baseline") -> None:
    """Attach % savings vs the in-group baseline row, including the
    stress-weighted water view. Groups key on the full resolved
    ``scenario_spec`` (plus seed), not the bare scenario name — two
    param-variants of one scenario in a plan each get their own baseline.
    Error rows neither serve as baselines nor receive savings."""
    def key(row):
        return (row.get("scenario_spec", row["scenario"]),
                row.get("seed", 0))

    by_group: Dict[Tuple, Dict] = {}
    for row in rows:
        if row["scheduler"] == baseline and not row.get("error"):
            by_group[key(row)] = row
    for row in rows:
        if row.get("error"):
            continue
        base = by_group.get(key(row))
        if base is None:
            continue
        row.update(savings_vs(base, row))
        bw = base["stress_water_kl"]
        row["stress_water_savings_pct"] = (
            100.0 * (bw - row["stress_water_kl"]) / bw if bw else 0.0)


# ---------------------------------------------------------------------------
# Multi-seed confidence intervals
# ---------------------------------------------------------------------------

# Two-sided 95% Student-t critical values t_{0.975, df} for df = 1..30
# (normal beyond) — hardcoded so the CI math has no scipy dependency and is
# bit-deterministic across hosts.
_T95 = {
    1: 12.706204736432095, 2: 4.302652729911275, 3: 3.182446305284263,
    4: 2.7764451051977987, 5: 2.570581835636197, 6: 2.4469118487916806,
    7: 2.3646242510102993, 8: 2.306004135033371, 9: 2.2621571627409915,
    10: 2.2281388519649385, 11: 2.200985160082949, 12: 2.1788128296634177,
    13: 2.160368656461013, 14: 2.1447866879169273, 15: 2.131449545559323,
    16: 2.1199052992210112, 17: 2.1098155778331806, 18: 2.100922040241039,
    19: 2.093024054408263, 20: 2.0859634472658364, 21: 2.0796138447276626,
    22: 2.0738730679040147, 23: 2.0686576104190406, 24: 2.0638985616280205,
    25: 2.059538552753294, 26: 2.055529438642871, 27: 2.0518305164802833,
    28: 2.048407141795244, 29: 2.0452296421327034, 30: 2.0422724563012373,
}


def t95(df: int) -> float:
    """t_{0.975, df} (95% two-sided); normal approximation past df=30."""
    return _T95.get(df, 1.959963984540054)


def _strip_bracket_param(spec_str: str, key: str) -> str:
    """Drop ``key=value`` from a bracketed spec string textually (no
    registry lookup, so it works on rows from scenarios that are no longer
    registered in this process)."""
    m = re.match(r"^(.*)\[(.*)\]$", spec_str.strip())
    if not m:
        return spec_str
    name, body = m.groups()
    parts = [p.strip() for p in body.split(",")
             if p.strip() and not p.strip().startswith(key + "=")]
    return f"{name}[{','.join(parts)}]" if parts else name


def seed_group_key(row: Dict) -> Tuple[str, str]:
    """Identity of a row modulo its seed: the scenario spec with ``seed``
    stripped × the policy spec with ``forecast_seed`` stripped (the one
    param ``resolve_policy_spec`` varies per seed)."""
    scen = str(row.get("scenario_spec") or row.get("scenario", ""))
    spec = str(row.get("spec") or row.get("scheduler", ""))
    return (_strip_bracket_param(scen, "seed"),
            _strip_bracket_param(spec, "forecast_seed"))


def aggregate_seeds(rows: Sequence[Dict]) -> List[Dict]:
    """Collapse multi-seed replicate rows into one row per cell carrying
    mean ± 95% CI (ROADMAP's rolling multi-seed studies item).

    Rows that differ only in their seed (see :func:`seed_group_key`) are
    grouped; every numeric metric becomes its across-seed mean under the
    original key plus a ``<key>_ci95`` half-width (Student-t, two-sided
    95%, sample std with ddof=1). Aggregated rows carry ``n_seeds`` and a
    comma-joined ``seed`` column. Single rows pass through untouched; error
    rows are never aggregated and ride along at the end.
    """
    groups: Dict[Tuple, List[Dict]] = {}
    order: List[Tuple] = []
    err_rows: List[Dict] = []
    for r in rows:
        if r.get("error"):
            err_rows.append(r)
            continue
        k = seed_group_key(r)
        if k not in groups:
            groups[k] = []
            order.append(k)
        groups[k].append(r)
    out: List[Dict] = []
    for k in order:
        g = groups[k]
        if len(g) == 1:
            out.append(g[0])
            continue
        agg = dict(g[0])
        # The aggregated row describes the whole seed group: its spec
        # columns are the seed-stripped forms (the group key), not the
        # first replicate's seed-bearing specs.
        scen_stripped, spec_stripped = k
        if "scenario_spec" in agg:
            agg["scenario_spec"] = scen_stripped
        if "spec" in agg:
            agg["spec"] = spec_stripped
        agg["seed"] = ",".join(str(r.get("seed", "")) for r in g)
        agg["n_seeds"] = len(g)
        n = len(g)
        crit = t95(n - 1)
        for key in g[0]:
            if key == "seed":          # identity, not a metric
                continue
            vals = [r.get(key) for r in g]
            if not all(isinstance(v, (int, float))
                       and not isinstance(v, bool) for v in vals):
                continue
            m = sum(vals) / n
            var = sum((v - m) ** 2 for v in vals) / (n - 1)
            agg[key] = float(m)
            agg[f"{key}_ci95"] = float(crit * math.sqrt(var / n))
        out.append(agg)
    return out + err_rows


def _has_seed_replicates(rows: Sequence[Dict]) -> bool:
    seen: Dict[Tuple, set] = {}
    for r in rows:
        if r.get("error"):
            continue
        seeds = seen.setdefault(seed_group_key(r), set())
        seeds.add(r.get("seed"))
        if len(seeds) > 1:
            return True
    return False


# ---------------------------------------------------------------------------
# Tidy-row schema
# ---------------------------------------------------------------------------

# "unfinished" stays in the default view: a scheduler that strands jobs
# accrues less footprint than one that ran everything — savings read from a
# row with unfinished > 0 are not comparable to the baseline's.
TABLE_COLS = ("scenario", "scheduler", "jobs", "unfinished", "carbon_kg",
              "water_kl", "stress_water_kl", "carbon_savings_pct",
              "water_savings_pct", "violation_pct", "mean_service_ratio",
              "wall_s")
CSV_COLS = TABLE_COLS + ("stress_water_savings_pct", "p99_service_ratio",
                         "utilization", "mean_solve_ms", "moved_pct",
                         "forecast_mape", "mean_defer_s", "deferred_pct",
                         "seed", "scenario_spec", "error", "spec")


def to_table(rows: Sequence[Dict], cols: Sequence[str] = TABLE_COLS, *,
             ci: Union[bool, str] = "auto") -> str:
    """Fixed-width tidy table (one line per experiment cell).

    When the rows contain multi-seed replicates (a plan with ≥ 2 seeds)
    they are collapsed through :func:`aggregate_seeds` and every numeric
    cell renders as ``mean±ci95``. ``ci=False`` disables the aggregation,
    ``ci=True`` forces it, the default ``"auto"`` detects replicates.
    """
    rows = list(rows)
    if ci is True or (ci == "auto" and _has_seed_replicates(rows)):
        rows = aggregate_seeds(rows)

    def fmt(r, c):
        v = r.get(c, "")
        hw = r.get(f"{c}_ci95")
        if hw is not None and isinstance(v, float):
            return f"{v:.2f}±{hw:.2f}"
        if isinstance(v, float):
            return f"{v:.2f}"
        return str(v)
    table = [[fmt(r, c) for c in cols] for r in rows]
    widths = [max(len(c), *(len(t[i]) for t in table)) if table else len(c)
              for i, c in enumerate(cols)]
    lines = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for t in table:
        lines.append("  ".join(v.rjust(w) for v, w in zip(t, widths)))
    return "\n".join(lines)


def to_csv(rows: Sequence[Dict], path: str,
           cols: Sequence[str] = CSV_COLS) -> None:
    """Write tidy rows as CSV. Uses the stdlib writer so the ``spec`` /
    ``scenario_spec`` columns — whose bracketed params contain commas — are
    quoted and every row stays re-parseable (``policy.parse(row["spec"])``
    and ``experiments.parse_scenario(row["scenario_spec"])`` rebuild the
    cell exactly)."""
    import csv
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(cols)
        for r in rows:
            w.writerow([r.get(c, "") for c in cols])
