"""Forecaster interface + reference models (persistence, seasonal-naive,
oracle, error-injection wrapper).

Every forecaster consumes an *hourly history matrix* ``[T, R]`` — one column
per region (or per stacked signal×region, see ``ForecastController``) — and
produces a ``Forecast``: point predictions plus a symmetric-in-probability
quantile band for the next ``H`` hours. The models here are the classical
baselines every forecasting study must beat (Hyndman & Athanasopoulos §5.2);
the Holt–Winters model lives in ``repro.forecast.holtwinters``.

All forecasters are deterministic given their inputs (the error-injection
wrapper takes an explicit seed), so scenario sweeps that embed them stay
reproducible cell-for-cell.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Type

import numpy as np

from repro import spec as _spec

HOUR = 3600.0

# Default band quantiles and the matching standard-normal z (the models use
# Gaussian residual bands: cheap, and calibrated enough for risk weighting).
QUANTILES: Tuple[float, float] = (0.1, 0.9)
_Z90 = 1.2815515655446004


@dataclasses.dataclass
class Forecast:
    """Point + quantile-band forecast for hours ``issue_hour+1 .. +H``.

    ``mean/lo/hi`` are ``[H, C]`` (C = columns of the fitted history);
    ``anchor`` is the last *observed* row, used to interpolate sub-hourly
    lookups continuously from the present into the forecast horizon.
    """
    issue_hour: int
    mean: np.ndarray
    lo: np.ndarray
    hi: np.ndarray
    anchor: np.ndarray
    quantiles: Tuple[float, float] = QUANTILES

    @property
    def horizon(self) -> int:
        return self.mean.shape[0]

    def at(self, t_s: float, which: str = "mean") -> np.ndarray:
        """Linearly interpolated forecast row at absolute time ``t_s``.

        Sample points sit on the hour grid: ``anchor`` at hour ``issue_hour``
        and ``mean[j]`` at hour ``issue_hour+1+j``. Times at or before the
        anchor return it; times beyond the horizon hold the last row.
        """
        return self.at_many(np.asarray([t_s]), which)[0]

    def at_many(self, t_s: np.ndarray, which: str = "mean") -> np.ndarray:
        """Vectorized ``at``: K times → [K, C] interpolated rows."""
        series = getattr(self, which)
        grid = np.vstack([self.anchor[None, :], series])
        u = np.clip(np.asarray(t_s, np.float64) / HOUR - self.issue_hour,
                    0.0, float(self.horizon))
        k = np.minimum(u.astype(np.int64), self.horizon - 1)
        frac = (u - k)[:, None]
        return (1.0 - frac) * grid[k] + frac * grid[k + 1]

    def _antiderivative(self, u: np.ndarray, which: str) -> np.ndarray:
        """A(u) = ∫_0^u g — g is the piecewise-linear forecast in hour
        coordinates (u = t/HOUR − issue_hour), held constant outside
        [0, horizon]. Returns [K, C] in value·hours."""
        grid = np.vstack([self.anchor[None, :], getattr(self, which)])
        seg = 0.5 * (grid[:-1] + grid[1:])
        cum = np.vstack([np.zeros((1, grid.shape[1])),
                         np.cumsum(seg, axis=0)])       # [H+1, C]
        u = np.asarray(u, np.float64)
        H = self.horizon
        below = np.minimum(u, 0.0)[:, None] * grid[0][None, :]
        above = np.maximum(u - H, 0.0)[:, None] * grid[-1][None, :]
        uc = np.clip(u, 0.0, H)
        k = np.minimum(uc.astype(np.int64), H - 1)
        f = (uc - k)[:, None]
        inner = cum[k] + grid[k] * f + 0.5 * (grid[k + 1] - grid[k]) * f ** 2
        return below + inner + above

    def mean_many(self, t0_s: np.ndarray, t1_s: np.ndarray,
                  which: str = "mean") -> np.ndarray:
        """Exact time-mean of the piecewise-linear forecast over [t0, t1],
        vectorized over K windows → [K, C].

        This is the planner's pricing primitive: the simulator accounts each
        job with the integrated telemetry over its execution window, so
        plan-time costs must integrate the *forecast* over the same window —
        with the oracle forecaster the two coincide exactly.
        """
        u0 = np.asarray(t0_s, np.float64) / HOUR - self.issue_hour
        u1 = np.maximum(np.asarray(t1_s, np.float64) / HOUR - self.issue_hour,
                        u0 + 1e-9)
        return ((self._antiderivative(u1, which)
                 - self._antiderivative(u0, which)) / (u1 - u0)[:, None])


class Forecaster:
    """``fit(history) -> self`` then ``predict(horizon) -> Forecast``."""

    name = "base"
    description = ""

    def fit(self, history: np.ndarray) -> "Forecaster":
        raise NotImplementedError

    def update(self, history: np.ndarray) -> "Forecaster":
        """Walk-forward refresh between full refits. For the stateless
        classical models this *is* a full refit (their ``fit`` is cheap);
        stateful models (the learned forecaster) override it to re-condition
        on the new history without retraining."""
        return self.fit(history)

    def predict(self, horizon: int) -> Forecast:
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------

    @staticmethod
    def _gaussian_band(mean: np.ndarray, sigma: np.ndarray) -> Tuple:
        """10/90% band around ``mean`` with per-step spread ``sigma`` that
        widens with lead time like a random walk (sqrt-of-horizon)."""
        H = mean.shape[0]
        widen = np.sqrt(np.arange(1, H + 1))[:, None]
        spread = _Z90 * sigma[None, :] * widen
        return mean - spread, mean + spread


class Persistence(Forecaster):
    """Tomorrow looks exactly like right now (the naive / random-walk model)."""

    name = "persistence"
    description = ("random-walk baseline: every lead repeats the last "
                   "observation")

    def fit(self, history: np.ndarray) -> "Persistence":
        y = np.asarray(history, np.float64)
        assert y.ndim == 2 and y.shape[0] >= 1
        self._last = y[-1]
        self._T = y.shape[0]
        d = np.diff(y, axis=0)
        self._sigma = d.std(axis=0) if d.shape[0] else np.zeros(y.shape[1])
        return self

    def predict(self, horizon: int) -> Forecast:
        mean = np.tile(self._last, (horizon, 1))
        lo, hi = self._gaussian_band(mean, self._sigma)
        return Forecast(self._T - 1, mean, lo, hi, self._last.copy())


class SeasonalNaive(Forecaster):
    """Tomorrow's hour h looks like today's hour h (period=24 by default).

    The right baseline for diurnal grid signals: carbon intensity and WUE are
    dominated by the solar/temperature cycle, which persistence is blind to.
    Falls back to persistence while history is shorter than one period.
    """

    name = "seasonal-naive"
    description = ("period-24 baseline: tomorrow's hour h repeats today's "
                   "hour h (persistence fallback below one period)")

    def __init__(self, period: int = 24):
        self.period = period

    def fit(self, history: np.ndarray) -> "SeasonalNaive":
        y = np.asarray(history, np.float64)
        self._T = y.shape[0]
        if self._T < self.period + 1:
            self._fallback: Optional[Persistence] = Persistence().fit(y)
            return self
        self._fallback = None
        self._season = y[-self.period:]        # season[k] = lag-(period-k)
        self._last = y[-1]
        resid = y[self.period:] - y[:-self.period]
        self._sigma = resid.std(axis=0) if resid.shape[0] else \
            np.zeros(y.shape[1])
        return self

    def predict(self, horizon: int) -> Forecast:
        if self._fallback is not None:
            return self._fallback.predict(horizon)
        idx = np.arange(horizon) % self.period
        mean = self._season[idx]
        lo, hi = self._gaussian_band(mean, self._sigma)
        return Forecast(self._T - 1, mean, lo, hi, self._last.copy())


class Oracle(Forecaster):
    """Reads the true future — the infeasible upper bound for planner studies.

    Holds the full ground-truth matrix ``[T_all, C]``; ``fit`` only records
    how much of it the caller has "seen". Lookups past the end wrap
    periodically, matching ``telemetry.Telemetry.at``.
    """

    name = "oracle"

    def __init__(self, truth: np.ndarray):
        self._truth = np.asarray(truth, np.float64)

    def fit(self, history: np.ndarray) -> "Oracle":
        self._T = np.asarray(history).shape[0]
        return self

    def predict(self, horizon: int) -> Forecast:
        T_all = self._truth.shape[0]
        idx = (self._T + np.arange(horizon)) % T_all
        mean = self._truth[idx]
        return Forecast(self._T - 1, mean, mean.copy(), mean.copy(),
                        self._truth[(self._T - 1) % T_all].copy())


class Perturbed(Forecaster):
    """Error-injection wrapper: systematic bias × multiplicative noise.

    Drives the ``forecast_error`` scenario regime — a planner must degrade
    gracefully when its forecaster over-/under-predicts (bias ≠ 1) or is
    simply noisy. Deterministic given ``seed`` and the fit history length.
    Bands are *not* widened: the planner believes its bad forecast, which is
    exactly the failure mode under study.
    """

    name = "perturbed"

    def __init__(self, inner: Forecaster, bias: float = 1.0,
                 noise: float = 0.0, seed: int = 0):
        self.inner = inner
        self.bias = float(bias)
        self.noise = float(noise)
        self.seed = int(seed)

    def fit(self, history: np.ndarray) -> "Perturbed":
        self.inner.fit(history)
        self._T = np.asarray(history).shape[0]
        return self

    def predict(self, horizon: int) -> Forecast:
        fc = self.inner.predict(horizon)
        rng = np.random.default_rng((self.seed, self._T))
        factor = self.bias * np.exp(
            self.noise * rng.standard_normal(fc.mean.shape))
        mean = fc.mean * factor
        return Forecast(fc.issue_hour, mean, fc.lo * factor, fc.hi * factor,
                        fc.anchor, fc.quantiles)


_MODELS: Dict[str, Type[Forecaster]] = {
    Persistence.name: Persistence,
    SeasonalNaive.name: SeasonalNaive,
}


def register_model(cls: Type[Forecaster]) -> Type[Forecaster]:
    _MODELS[cls.name] = cls
    return cls


def _ensure_models() -> None:
    # The HoltWinters / learned registrations are import side effects of
    # their modules; importing the package pulls them in. Guard for callers
    # that imported ``repro.forecast.base`` directly.
    if "holtwinters" not in _MODELS or "learned" not in _MODELS:
        import repro.forecast  # noqa: F401


def make_forecaster(name: str, **kw) -> Forecaster:
    """Instantiate a history-driven forecaster by name.

    Unknown names raise the shared did-you-mean ``UnknownNameError`` (a
    ``KeyError`` subclass, matching the policy/scenario registries).
    ``oracle`` is not constructible here — it needs ground truth, which only
    the caller (controller / backtest harness) holds.
    """
    _ensure_models()
    if name not in _MODELS:
        raise _spec.unknown_name_error("forecaster", name, sorted(_MODELS))
    return _MODELS[name](**kw)


def list_forecasters() -> list:
    _ensure_models()
    return sorted(_MODELS)


def forecaster_schema(name: str) -> Dict[str, _spec.Param]:
    """Typed constructor-parameter schema of a registered forecaster,
    introspected from its ``__init__`` signature (the same derivation the
    policy registry uses, so documented defaults can never drift)."""
    _ensure_models()
    if name not in _MODELS:
        raise _spec.unknown_name_error("forecaster", name, sorted(_MODELS))
    return {p.name: p for p in _spec.params_from_signature(_MODELS[name])}


def describe_forecasters(markdown: bool = False) -> str:
    """Human-readable registry dump (the ``--list-forecasters`` surface and
    the source of the README forecaster table)."""
    entries: List[Type[Forecaster]] = [_MODELS[n]
                                       for n in list_forecasters()]
    if markdown:
        lines = ["| forecaster | parameters | description |", "|---|---|---|"]
        for cls in entries:
            ps = ", ".join(f"`{p.describe()}`"
                           for p in forecaster_schema(cls.name).values()) \
                or "—"
            lines.append(f"| `{cls.name}` | {ps} | {cls.description} |")
        return "\n".join(lines)
    lines = []
    for cls in entries:
        lines.append(f"{cls.name:16s} {cls.description}")
        for p in forecaster_schema(cls.name).values():
            lines.append(f"    {p.describe()}")
    return "\n".join(lines)
