"""Forecasting + temporal-shifting subsystem.

``base``        Forecaster interface, persistence / seasonal-naive baselines,
                the true-future Oracle, and the error-injection Perturbed
                wrapper (the ``forecast_error`` scenario regime).
``holtwinters`` Damped-trend seasonal Holt–Winters fit with ``jax.lax.scan``,
                jitted once per history shape.
``backtest``    Walk-forward MAPE / pinball-loss / coverage scoring against
                telemetry series.
``planner``     Spatio-temporal (regions × horizon-slots) assignment builder
                + the deferral queue used by ``core.controller
                .ForecastController``.
"""
from repro.forecast import holtwinters as _holtwinters  # registers the model
from repro.forecast.backtest import (backtest, backtest_telemetry, mape,
                                     pinball_loss)
from repro.forecast.base import (Forecast, Forecaster, Oracle, Persistence,
                                 Perturbed, SeasonalNaive, list_forecasters,
                                 make_forecaster)
from repro.forecast.holtwinters import HoltWinters
from repro.forecast.planner import DeferralQueue, TemporalPlan, \
    build_temporal_plan

__all__ = [
    "Forecast", "Forecaster", "Persistence", "SeasonalNaive", "Oracle",
    "Perturbed", "HoltWinters", "make_forecaster", "list_forecasters",
    "backtest", "backtest_telemetry", "mape", "pinball_loss",
    "DeferralQueue", "TemporalPlan", "build_temporal_plan",
]
