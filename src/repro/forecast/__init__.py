"""Forecasting + temporal-shifting subsystem.

``base``        Forecaster interface + registry (did-you-mean errors,
                introspected param schemas), persistence / seasonal-naive
                baselines, the true-future Oracle, and the error-injection
                Perturbed wrapper (the ``forecast_error`` scenario regime).
``holtwinters`` Damped-trend seasonal Holt–Winters fit with ``jax.lax.scan``,
                jitted once per history shape.
``learned``     Learned forecaster: RG-LRU (Griffin) sequence head from
                ``repro.models.rglru`` with q10/q50/q90 quantile outputs,
                trained on sliding telemetry windows via ``repro.optim
                .adamw``, checkpointed through ``repro.checkpoint.store``.
``backtest``    Walk-forward MAPE / pinball-loss / coverage scoring against
                telemetry series, with a fit/refit cadence for models whose
                training is expensive.
``planner``     Spatio-temporal (regions × horizon-slots) assignment builder
                + the deferral queue used by the forecast pipeline.
"""
from repro.forecast import holtwinters as _holtwinters  # registers the model
from repro.forecast import learned as _learned          # registers the model
from repro.forecast.backtest import (backtest, backtest_telemetry, mape,
                                     pinball_loss)
from repro.forecast.base import (Forecast, Forecaster, Oracle, Persistence,
                                 Perturbed, SeasonalNaive,
                                 describe_forecasters, forecaster_schema,
                                 list_forecasters, make_forecaster)
from repro.forecast.holtwinters import HoltWinters
from repro.forecast.learned import LearnedForecaster
from repro.forecast.planner import DeferralQueue, TemporalPlan, \
    build_temporal_plan

__all__ = [
    "Forecast", "Forecaster", "Persistence", "SeasonalNaive", "Oracle",
    "Perturbed", "HoltWinters", "LearnedForecaster", "make_forecaster",
    "list_forecasters", "forecaster_schema", "describe_forecasters",
    "backtest", "backtest_telemetry", "mape", "pinball_loss",
    "DeferralQueue", "TemporalPlan", "build_temporal_plan",
]
