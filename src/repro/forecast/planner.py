"""Temporal-shifting planner: spatio-temporal assignment + deferral queue.

The reactive controller solves ``jobs × regions`` at every round. The
forecast-driven planner widens the decision space to
``jobs × (regions × horizon-slots)``: slot 0 is "run now" priced at the live
telemetry snapshot, slots 1..S−1 are "hold and run later" priced at the
forecast (optionally risk-adjusted toward the upper quantile band). The
flattened problem is still a capacitated transportation problem — the same
bucketed/padded Sinkhorn (or any other) backend solves it unchanged.

Deadline feasibility is a *mask*, not a penalty: a (region, slot) cell is
allowed only when the job's remaining tolerance budget covers the wait until
the slot start plus the transfer, with ``guard_s`` of budget left over — so a
deferred job can always still be placed (at minimum at home) when its slot
arrives. No job can miss its deadline by being deferred.

``DeferralQueue`` owns the held jobs between rounds: release at the planned
slot, early release when slack runs low (the guard), FIFO within equal
slack, and an explicit drain for horizon end.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import repro.obs as obs
from repro.core import footprint, problem
from repro.core.problem import Job, ProblemInstance


@dataclasses.dataclass
class TemporalPlan:
    """Flattened ``jobs × (regions × slots)`` instance (column = s·N + n)."""
    cost: np.ndarray          # [M, N*S] objective coefficients
    allowed: np.ndarray       # [M, N*S] deadline-feasibility mask
    capacity: np.ndarray      # [N*S]
    slot_offsets: np.ndarray  # [S] seconds from now to each slot start
    num_regions: int
    num_slots: int

    def decode(self, flat: int) -> Tuple[int, int]:
        """Flat column index -> (slot, region)."""
        return flat // self.num_regions, flat % self.num_regions


def build_temporal_plan(inst: ProblemInstance, now_s: float,
                        ci: np.ndarray, ewif: np.ndarray, wue: np.ndarray,
                        pue: np.ndarray, wsf: np.ndarray,
                        slot_offsets: np.ndarray,
                        server: footprint.ServerSpec,
                        lam_co2: float, lam_h2o: float,
                        lam_ref: float = 0.0,
                        co2_ref: Optional[np.ndarray] = None,
                        h2o_ref: Optional[np.ndarray] = None,
                        defer_eps: float = 1e-3,
                        guard_s: float = 240.0) -> TemporalPlan:
    """Extend a slot-0 ``ProblemInstance`` with forecast-priced future slots.

    Args:
      inst: the reactive instance built at ``now_s`` — its latency, overrun
        mask, and capacity are reused; its snapshot costs are *not* (cells
        are re-priced from the signal tensors so "now" and "later" are
        compared on the same footing).
      ci/ewif/wue: [M, S, R] per-(job, slot) signal estimates — typically the
        forecast evaluated at each job's execution-window midpoint, which
        approximates the integrated accounting the simulator applies.
      pue/wsf: [R] static region attributes.
      slot_offsets: [S] seconds from ``now_s`` to each slot start (entry 0
        must be 0).
      defer_eps: per-slot tie-break cost — deferral must *earn* its delay.
      guard_s: tolerance budget that must remain at the slot start for any
        deferred cell (early-release safety margin, see ``DeferralQueue``).

    Eq-7 normalizers are recomputed as the per-job max over *all* cells so
    slot costs are mutually comparable; the λ_ref history term (constant per
    region) is replicated across slots, exactly as in the reactive objective.
    """
    jobs = inst.jobs
    M, N = inst.shape
    S = len(slot_offsets)
    assert slot_offsets[0] == 0.0 and ci.shape == (M, S, N)
    E = np.array([j.energy_kwh for j in jobs])
    t = np.array([j.exec_time_s for j in jobs])

    co2 = footprint.job_carbon(E[:, None, None], t[:, None, None], ci, server)
    h2o = footprint.job_water(E[:, None, None], t[:, None, None],
                              pue[None, None, :], ewif, wue,
                              wsf[None, None, :], server)

    co2_max = np.maximum(co2.max(axis=(1, 2)), 1e-9)
    h2o_max = np.maximum(h2o.max(axis=(1, 2)), 1e-9)
    obj = (lam_co2 * co2 / co2_max[:, None, None]
           + lam_h2o * h2o / h2o_max[:, None, None])
    if co2_ref is not None and h2o_ref is not None:
        obj = obj + lam_ref * (lam_co2 * co2_ref
                               + lam_h2o * h2o_ref)[None, None, :]
    obj = obj + defer_eps * np.arange(S)[None, :, None]

    # Deadline mask: waiting to slot s + transfer must leave ``guard_s`` of
    # tolerance budget (slot 0 keeps the exact Eq-11 mask — no guard — so the
    # planner is never *less* feasible than the reactive controller).
    budget = problem.slack_budget(jobs, now_s)                  # [M]
    need = slot_offsets[None, :, None] + inst.latency[:, None, :]
    allowed = need + guard_s <= budget[:, None, None] + 1e-9
    allowed[:, 0, :] = inst.allowed

    cap = np.tile(np.asarray(inst.capacity, np.int64), S)
    return TemporalPlan(cost=obj.reshape(M, S * N),
                        allowed=allowed.reshape(M, S * N),
                        capacity=cap,
                        slot_offsets=np.asarray(slot_offsets, np.float64),
                        num_regions=N, num_slots=S)


# ---------------------------------------------------------------------------
# Deferral queue
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Held:
    job: Job
    release_s: float      # planned slot start
    held_at_s: float      # when the hold began
    seq: int              # insertion order (FIFO tie-break)


class DeferralQueue:
    """Held jobs between scheduling rounds.

    Invariants (tested):
      * a job is released no later than its planned slot start;
      * a job is force-released early as soon as its remaining tolerance
        budget drops to ``guard_s`` — deferral can never cause a deadline
        miss that immediate placement would have avoided;
      * among jobs due in the same round with equal remaining slack, release
        order is FIFO (insertion order);
      * ``drain()`` empties the queue (horizon end / shutdown).
    """

    def __init__(self, guard_s: float = 240.0):
        self.guard_s = float(guard_s)
        self._held: Dict[int, _Held] = {}
        self._seq = 0
        # Stats for the sweep's deferral columns. ``released`` counts hold
        # *episodes* (a job re-deferred at its slot counts again);
        # ``unique_held`` counts distinct jobs ever time-shifted.
        self.released = 0
        self.total_defer_s = 0.0
        self.unique_held: set = set()

    def __len__(self) -> int:
        return len(self._held)

    def __contains__(self, job_id: int) -> bool:
        return job_id in self._held

    def hold(self, job: Job, release_s: float, now_s: float,
             held_at_s: Optional[float] = None) -> None:
        """Hold ``job`` until ``release_s``. ``held_at_s`` backdates the
        episode start — a re-planned job that gets held again continues its
        original episode instead of opening a new one (receding-horizon
        re-planning, ``policy.ReplanQueueDeferral``)."""
        assert job.job_id not in self._held
        start = now_s if held_at_s is None else held_at_s
        self._held[job.job_id] = _Held(job, release_s, start, self._seq)
        self.unique_held.add(job.job_id)
        self._seq += 1

    def pop_for_replan(self, job_id: int) -> float:
        """Remove a held job so it can re-enter pricing *without* closing
        its hold episode; returns the episode's start time. The caller
        either re-holds it (``hold(..., held_at_s=start)`` — the episode
        continues) or, if the re-plan ran it, closes the episode via
        ``close_replan(start, ran_at_s)``."""
        return self._held.pop(job_id).held_at_s

    def close_replan(self, held_at_s: float, ran_at_s: float) -> None:
        """Close the hold episode of a re-planned job that left the queue
        (the re-pricing round chose to run it, or stopped holding it)."""
        self._note_release(max(ran_at_s - held_at_s, 0.0))

    def next_release_s(self) -> Optional[float]:
        if not self._held:
            return None
        return min(h.release_s for h in self._held.values())

    def partition(self, jobs: Sequence[Job], now_s: float
                  ) -> Tuple[List[Job], List[Job]]:
        """Split a pending set into (due, still-held).

        Due = not held, planned slot reached, or slack ≤ guard. Released jobs
        are ordered by remaining slack ascending, FIFO within equal slack;
        jobs the queue never held keep their incoming order, after releases.
        """
        due_new: List[Job] = []
        released: List[Tuple[float, int, Job]] = []
        held: List[Job] = []
        for j in jobs:
            h = self._held.get(j.job_id)
            if h is None:
                due_new.append(j)
                continue
            slack = j.slack_budget_s(now_s)
            if now_s + 1e-9 >= h.release_s or slack <= self.guard_s:
                self._release(h, now_s)
                released.append((slack, h.seq, j))
            else:
                held.append(j)
        released.sort(key=lambda r: (r[0], r[1]))
        return [r[2] for r in released] + due_new, held

    def drain(self, now_s: float) -> List[Job]:
        """Release everything (FIFO), e.g. at horizon end."""
        out = sorted(self._held.values(), key=lambda h: h.seq)
        for h in out:
            self._release(h, now_s, pop=False)
        self._held.clear()
        return [h.job for h in out]

    def _release(self, h: _Held, now_s: float, pop: bool = True) -> None:
        self._note_release(max(now_s - h.held_at_s, 0.0))
        if pop:
            del self._held[h.job.job_id]

    def _note_release(self, hold_s: float) -> None:
        self.released += 1
        self.total_defer_s += hold_s
        obs.observe("deferral.hold_s", hold_s)   # simulated-time duration

    @property
    def mean_defer_s(self) -> float:
        """Mean total held time per distinct time-shifted job (hold episodes
        of a re-deferred job accumulate)."""
        n = len(self.unique_held)
        return self.total_defer_s / n if n else 0.0
