"""Holt–Winters damped-trend seasonal forecaster, fit with ``jax.lax.scan``.

The additive damped-trend seasonal recursions (Hyndman & Athanasopoulos §7.3,
the ETS(A,Ad,A) filter) over hourly history ``y[t]``:

    l_t = α·(y_t − s_{t−m}) + (1−α)·(l_{t−1} + φ·b_{t−1})
    b_t = β·(l_t − l_{t−1}) + (1−β)·φ·b_{t−1}
    s_t = γ·(y_t − l_t) + (1−γ)·s_{t−m}

"Fitting" here = one forward filter pass per candidate smoothing-parameter
triple, selecting the per-column triple with the lowest post-warmup one-step
SSE. The filter is a ``lax.scan`` over time, ``vmap``-ed over the parameter
grid, and jitted **once per history shape** — the scheduler refits every
simulated hour with a growing-but-bucketed window, so the same compiled
executable serves thousands of refits (the test suite pins the ≥10× second-
fit speedup).

Point forecasts are closed-form from the final state; quantile bands use the
selected triple's one-step residual σ widened with √horizon.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.forecast import base

# Candidate smoothing parameters (α, β, γ). A coarse grid is standard for
# online refitting: the SSE surface is flat near the optimum and the filter
# cost is P parallel scans, all fused into one compiled program.
_ALPHAS = (0.2, 0.4, 0.7)
_BETAS = (0.05, 0.15)
_GAMMAS = (0.1, 0.3)
PARAM_GRID = np.array([(a, b, g) for a in _ALPHAS for b in _BETAS
                       for g in _GAMMAS], np.float32)
PHI = 0.98        # trend damping (φ<1: long-horizon forecasts flatten out)

# History windows are clipped to at most MAX_FIT_PERIODS seasonal periods and
# padded up to the next bucket (a small set of whole-period multiples) so the
# jitted filter compiles for a handful of shapes, not one per simulated hour.
# Padding prepends a cyclic extension of the oldest period, which keeps the
# seasonal phase of the padded series identical to the real one.
FIT_BUCKET_PERIODS = (2, 3, 4, 6, 8, 12, 14)
MAX_FIT_PERIODS = FIT_BUCKET_PERIODS[-1]


def fit_bucket_for(rows: int, period: int) -> int:
    """Smallest whole-period bucket ≥ rows."""
    for k in FIT_BUCKET_PERIODS:
        if rows <= k * period:
            return k * period
    return MAX_FIT_PERIODS * period


def _hw_filter_impl(y: jnp.ndarray, params: jnp.ndarray, valid0: jnp.ndarray,
                    period: int):
    """Forward ETS(A,Ad,A) filter over ``y`` for every parameter triple.

    Args:
      y: [T, C] history (oldest first).
      params: [P, 3] (α, β, γ) candidates.
      valid0: scalar int — rows before this index are padding replicas of the
        oldest observation; their one-step errors are excluded from the SSE.
      period: seasonal period (static → part of the compile key).

    Returns:
      level [P, C], trend [P, C], season [P, period, C] (season[0] is the
      seasonal term for the *next* time step), sse [P, C], count [].
    """
    T, C = y.shape
    l0 = jnp.mean(y[:period], axis=0)                         # [C]
    s0 = y[:period] - l0[None, :]                             # [period, C]
    b0 = jnp.zeros((C,), y.dtype)
    warmup = valid0 + period

    def one(abg):
        alpha, beta, gamma = abg[0], abg[1], abg[2]

        def step(carry, inp):
            l, b, s, sse, cnt = carry
            y_t, t = inp
            s_prev = s[0]
            yhat = l + PHI * b + s_prev
            err = y_t - yhat
            l_new = alpha * (y_t - s_prev) + (1 - alpha) * (l + PHI * b)
            b_new = beta * (l_new - l) + (1 - beta) * PHI * b
            s_new = gamma * (y_t - l_new) + (1 - gamma) * s_prev
            s = jnp.concatenate([s[1:], s_new[None, :]], axis=0)
            use = (t >= warmup).astype(y.dtype)
            return (l_new, b_new, s, sse + use * err * err, cnt + use), None

        init = (l0, b0, s0, jnp.zeros((C,), y.dtype), jnp.zeros((), y.dtype))
        (l, b, s, sse, cnt), _ = jax.lax.scan(
            step, init, (y, jnp.arange(T, dtype=y.dtype)))
        return l, b, s, sse, cnt

    return jax.vmap(one)(params)


_hw_filter = functools.partial(jax.jit, static_argnames=("period",))(
    _hw_filter_impl)


def damped_sum(horizon: int, phi: float = PHI) -> np.ndarray:
    """[Σ_{i=1..h} φ^i for h=1..H] — the damped-trend forecast multiplier."""
    return np.cumsum(phi ** np.arange(1, horizon + 1))


@base.register_model
class HoltWinters(base.Forecaster):
    """Damped-trend seasonal Holt–Winters with grid-selected smoothing."""

    name = "holtwinters"
    description = ("damped-trend seasonal ETS(A,Ad,A) filter on "
                   "jax.lax.scan, grid-selected smoothing, jitted once "
                   "per padded history shape")

    def __init__(self, period: int = 24):
        self.period = period

    def fit(self, history: np.ndarray) -> "HoltWinters":
        y = np.asarray(history, np.float64)
        self._T = y.shape[0]
        self._last = y[-1]
        # Too short for a seasonal init: delegate (which itself falls back to
        # persistence below one full period).
        if self._T < 2 * self.period:
            self._fallback = base.SeasonalNaive(self.period).fit(y)
            return self
        self._fallback = None
        y = y[-MAX_FIT_PERIODS * self.period:]
        rows = y.shape[0]
        pad = fit_bucket_for(rows, self.period) - rows
        if pad:
            # Cyclic extension of the oldest period, aligned so the row just
            # before y[0] is y[period-1]: the padded series is exactly
            # periodic, preserving seasonal phase and init.
            reps = int(np.ceil(pad / self.period))
            ext = np.tile(y[:self.period], (reps, 1))[-pad:] \
                if pad % self.period == 0 else \
                np.tile(y[:self.period], (reps + 1, 1))[
                    self.period - (pad % self.period):][:pad]
            y = np.vstack([ext, y])
        level, trend, season, sse, cnt = _hw_filter(
            jnp.asarray(y, jnp.float32), jnp.asarray(PARAM_GRID),
            jnp.asarray(pad, jnp.float32), self.period)
        level, trend = np.asarray(level), np.asarray(trend)
        season, sse = np.asarray(season), np.asarray(sse)
        best = np.argmin(sse, axis=0)                      # [C]
        cols = np.arange(y.shape[1])
        self._level = level[best, cols].astype(np.float64)
        self._trend = trend[best, cols].astype(np.float64)
        self._season = season[best, :, cols].T.astype(np.float64)  # [m, C]
        n = max(float(np.asarray(cnt)[0]), 1.0)
        self._sigma = np.sqrt(sse[best, cols].astype(np.float64) / n)
        return self

    def predict(self, horizon: int) -> base.Forecast:
        if self._fallback is not None:
            return self._fallback.predict(horizon)
        damp = damped_sum(horizon)
        idx = np.arange(horizon) % self.period
        mean = (self._level[None, :] + damp[:, None] * self._trend[None, :]
                + self._season[idx])
        lo, hi = self._gaussian_band(mean, self._sigma)
        return base.Forecast(self._T - 1, mean, lo, hi, self._last.copy())
