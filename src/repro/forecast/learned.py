"""Learned telemetry forecaster: an RG-LRU sequence head with quantile
outputs, trained on sliding telemetry windows.

This is the point where the scheduling side of the repo finally exercises
the model stack: the recurrent core is the Griffin recurrent block from
``repro.models.rglru`` (conv1d → RG-LRU → gated output projection), the
optimizer is ``repro.optim.adamw``, checkpoints go through
``repro.checkpoint.store``, and the linear recurrence can optionally run
through the Pallas kernel (``repro.kernels.rglru_scan``) instead of the XLA
associative scan.

Model shape
-----------
Each history column (one region × signal series) is treated as an
independent univariate sample: the network consumes a normalized window of
the last ``window`` hours and emits, for each of the next ``horizon``
hours, three quantile *residuals* (q10 / q50 / q90) **on top of the
seasonal-naive continuation of the window**. The output head is
zero-initialized, so an untrained ``learned`` forecaster is *exactly*
seasonal-naive — training can only move it away from the strongest cheap
baseline, which is what makes the walk-forward comparison in the tests
stable under a fixed seed.

Fit / refit protocol
--------------------
``fit(history)`` trains on every sliding window of the history the first
time it is called (and again after ``retrain_every`` subsequent fits —
the walk-forward refit cadence), then *conditions* on the tail window to
produce forecasts. ``update(history)`` never retrains: it re-conditions on
the new tail with the existing parameters (trains only when none exist),
which is what ``forecast.backtest(..., refit_every=K)`` calls between full
refits. Histories too short to train or condition fall back to
seasonal-naive, mirroring ``HoltWinters``.

The train step is jitted once per (batch, window, horizon) shape and the
per-column inference pass is batched over columns (the vmap dimension),
padded to a column bucket and jitted once per padded shape — the same
compile-amortization discipline as the Holt–Winters grid filter.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

import repro.obs as obs

from repro.checkpoint import store
from repro.forecast import base
from repro.models import common, rglru
from repro.models.ssm import _causal_conv
from repro.optim import adamw as _adamw
from repro.optim import cosine_schedule

#: Quantile levels of the three output heads (the middle one is the point
#: forecast; the outer pair matches the 10/90 band every forecaster emits).
TRAIN_QUANTILES = (0.1, 0.5, 0.9)

#: Columns are padded to a multiple of this for the jitted inference pass,
#: so different region counts reuse a handful of compiled shapes.
COLUMN_BUCKET = 8

_D_CONV = 4


# ---------------------------------------------------------------------------
# Network
# ---------------------------------------------------------------------------

def init_params(key, d_model: int, horizon: int):
    """Parameter tree: 2-feature embed → Griffin recurrent block → quantile
    head. The head is zero-initialized (output = seasonal-naive residual 0,
    so the untrained model *is* seasonal-naive) and the causal conv starts
    as the identity tap so the recurrence sees the embedded series from
    step one. The head reads ``[h_T | a_T]`` — final recurrent state plus
    the final seasonal anomaly — so the strongest known residual structure
    (anomaly persistence) is one weight away from the init."""
    ks = jax.random.split(key, 2)
    tree = dict(
        inp=common.dense_init(ks[0], (2, d_model), ("embed", "mlp"),
                              jnp.float32, fan_in=2),
        inp_b=common.zeros_init((d_model,), ("mlp",), jnp.float32),
        block=rglru.block_init(ks[1], d_model, lru_width=d_model,
                               d_conv=_D_CONV),
        norm=common.zeros_init((d_model,), ("embed_nosplit",), jnp.float32),
        head=common.zeros_init((d_model + 1,
                                horizon * len(TRAIN_QUANTILES)),
                               ("mlp", "embed"), jnp.float32),
        head_b=common.zeros_init((horizon * len(TRAIN_QUANTILES),),
                                 ("embed",), jnp.float32),
    )
    params, _ = common.split_tree(tree)
    params["block"]["conv_w"] = params["block"]["conv_w"].at[-1].set(1.0)
    # Outer-quantile biases start at ∓0.25σ so the untrained band has
    # width (the q50 point forecast stays exactly seasonal-naive); training
    # calibrates both tails via the pinball loss.
    hb = params["head_b"].reshape(horizon, len(TRAIN_QUANTILES))
    hb = hb.at[:, 0].set(-0.25).at[:, -1].set(0.25)
    params["head_b"] = hb.reshape(-1)
    return params


def _recurrent_block(x, p, scan_impl: str):
    """Griffin recurrent block with a pluggable linear recurrence: the
    default delegates straight to ``models.rglru.block_apply`` (train
    path, XLA associative scan); ``pallas`` swaps only the scan for the
    ``repro.kernels.rglru_scan`` kernel (interpret mode off-TPU), keeping
    everything around it identical."""
    if scan_impl != "pallas":
        return rglru.block_apply(x, p)[0]
    gate = jax.nn.gelu(x @ p["in_gate"])
    u = x @ p["in_x"]
    u, _ = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, bx = rglru._gates(u, p)
    from repro.kernels.rglru_scan.ops import rglru_scan as kernel_scan
    y = kernel_scan(a, bx).astype(u.dtype)
    return (y * gate) @ p["out"]


def _quantiles_from_windows(params, xw, horizon: int, period: int,
                            scan_impl: str):
    """xw: [B, L] normalized windows → [B, horizon, Q] quantile forecasts
    = seasonal-naive continuation of each window + learned residuals.

    Per-step input features: the value and its seasonal anomaly (lag-period
    delta, zero over the first period) — the anomaly series carries the
    synoptic (multi-day) component the seasonal base is blind to.
    """
    B, L = xw.shape
    anom = jnp.concatenate(
        [jnp.zeros((B, period)), xw[:, period:] - xw[:, :-period]], axis=1)
    feats = jnp.stack([xw, anom], axis=-1)                       # [B, L, 2]
    h = feats @ params["inp"] + params["inp_b"]                  # [B, L, D]
    h = h + _recurrent_block(h, params["block"], scan_impl)
    h = common.rms_norm(h, params["norm"])
    head_in = jnp.concatenate([h[:, -1], anom[:, -1:]], axis=-1)
    out = head_in @ params["head"] + params["head_b"]
    deltas = out.reshape(B, horizon, len(TRAIN_QUANTILES))
    idx = (L - period) + (jnp.arange(horizon) % period)
    base_rows = xw[:, idx]                                       # [B, H]
    return base_rows[..., None] + deltas


def _pinball(q, y):
    """Mean pinball loss of the three quantile heads. q: [B, H, Q],
    y: [B, H]."""
    levels = jnp.asarray(TRAIN_QUANTILES, jnp.float32)
    d = y[..., None] - q
    return jnp.mean(jnp.maximum(levels * d, (levels - 1.0) * d))


#: Bound on the per-config jitted train/infer caches below. Sweeps iterate
#: over many forecaster configs in one process; an unbounded cache pins
#: every config's compiled executables (and their device buffers) for the
#: process lifetime. LRU-evicting a config merely costs a retrace if it
#: comes back.
CACHE_CONFIGS = 32

#: Factory-build counters: each build is one fresh set of jit compilations
#: (a cache miss OR a re-build after LRU eviction), so ``builds − misses``
#: counts evictions and ``builds`` counts retraces. Read via
#: :func:`cache_stats` (the perf harness reports these); every build also
#: bumps the shared ``jit/builds/*`` counters in ``repro.obs``, so traced
#: runs fold retrace accounting into the same snapshot as everything else.
_BUILDS = {"train_step": 0, "predict_fn": 0}


def cache_stats() -> dict:
    """Cache/retrace accounting for the perf harness: per-cache lru stats
    (hits/misses/currsize/maxsize) plus total factory builds (== jit
    retrace sets, counting rebuilds after eviction)."""
    out = {}
    for name, fn in (("train_step", _train_step),
                     ("predict_fn", _predict_fn)):
        info = fn.cache_info()
        out[name] = dict(hits=info.hits, misses=info.misses,
                         currsize=info.currsize, maxsize=info.maxsize,
                         builds=_BUILDS[name])
    return out


@functools.lru_cache(maxsize=CACHE_CONFIGS)
def _train_step(horizon: int, period: int, scan_impl: str, lr: float,
                weight_decay: float, train_steps: int):
    """(optimizer, jitted step) — cached per config so refits and multiple
    forecaster instances share one compiled executable per batch shape."""
    _BUILDS["train_step"] += 1
    obs.counter("jit/builds/train_step")
    opt = _adamw(
        lr=cosine_schedule(lr, max(train_steps // 10, 1),
                           max(train_steps, 1)),
        weight_decay=weight_decay)

    def loss_fn(params, xb, yb):
        return _pinball(
            _quantiles_from_windows(params, xb, horizon, period, scan_impl),
            yb)

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        new_params, new_state, _ = opt.update(grads, state, params)
        return new_params, new_state, loss

    return opt, step, jax.jit(loss_fn)


@functools.lru_cache(maxsize=CACHE_CONFIGS)
def _predict_fn(horizon: int, period: int, scan_impl: str):
    """Jitted batched (per-column) inference, compiled once per padded
    [columns, window] shape."""
    _BUILDS["predict_fn"] += 1
    obs.counter("jit/builds/predict_fn")
    @jax.jit
    def run(params, xw):
        return _quantiles_from_windows(params, xw, horizon, period,
                                       scan_impl)
    return run


# ---------------------------------------------------------------------------
# The forecaster
# ---------------------------------------------------------------------------

@base.register_model
class LearnedForecaster(base.Forecaster):
    """RG-LRU sequence head over sliding telemetry windows with quantile
    outputs (residual over seasonal-naive; zero-init == seasonal-naive)."""

    name = "learned"
    description = ("RG-LRU (Griffin) sequence head with q10/q50/q90 "
                   "outputs, trained on sliding telemetry windows as a "
                   "residual over seasonal-naive")

    def __init__(self, period: int = 24, window: int = 48,
                 horizon: int = 24, d_model: int = 16,
                 train_steps: int = 300, batch: int = 64,
                 lr: float = 1e-3, weight_decay: float = 0.1,
                 retrain_every: int = 24, seed: int = 0,
                 scan_impl: str = "assoc", checkpoint: str = ""):
        """Args:
          period: seasonal period (hours) of the residual base.
          window: conditioning window length (hours); must cover ≥ 1 period.
          horizon: trained lead hours; longer ``predict`` horizons extend
            periodically.
          d_model: embed width == RG-LRU width.
          train_steps / batch / lr / weight_decay: training-loop knobs
            (``repro.optim.adamw`` with cosine schedule + global-norm clip).
          retrain_every: retrain after this many subsequent ``fit`` calls
            (the walk-forward refit cadence; 0 = train once, never again).
          seed: PRNG seed for init and batch sampling (fully deterministic).
          scan_impl: linear-recurrence implementation for BOTH training and
            inference — ``assoc`` (XLA associative scan) or ``pallas`` (the
            ``repro.kernels.rglru_scan`` kernel; interpret mode off-TPU).
            The kernel is differentiable via its custom VJP, so training
            runs through it too.
          checkpoint: optional directory saved by :meth:`save` — restores
            the trained parameters (and their config) at construction.
        """
        if window < period:
            raise ValueError(f"window ({window}) must cover at least one "
                             f"period ({period})")
        if scan_impl not in ("assoc", "pallas"):
            raise ValueError(f"scan_impl must be 'assoc' or 'pallas', "
                             f"got {scan_impl!r}")
        self.period = int(period)
        self.window = int(window)
        self.horizon = int(horizon)
        self.d_model = int(d_model)
        self.train_steps = int(train_steps)
        self.batch = int(batch)
        self.lr = float(lr)
        self.weight_decay = float(weight_decay)
        self.retrain_every = int(retrain_every)
        self.seed = int(seed)
        self.scan_impl = scan_impl
        self._params = None
        self._mu: Optional[np.ndarray] = None
        self._sd: Optional[np.ndarray] = None
        self._fallback: Optional[base.Forecaster] = None
        self._fits_since_train = 0
        self.train_count = 0          # full training runs so far
        self.train_seconds = 0.0      # wall time spent training
        self.last_loss = float("nan")
        if checkpoint:
            self._restore(checkpoint)

    # -- fit / update --------------------------------------------------------

    def fit(self, history: np.ndarray) -> "LearnedForecaster":
        """Walk-forward entry point: trains on the first call (and again
        every ``retrain_every`` fits), then conditions on the tail window."""
        return self._ingest(np.asarray(history, np.float64),
                            allow_train=True)

    def update(self, history: np.ndarray) -> "LearnedForecaster":
        """Cheap walk-forward refresh: re-condition on the new tail without
        retraining (trains only if no trained parameters exist yet)."""
        return self._ingest(np.asarray(history, np.float64),
                            allow_train=False)

    def _ingest(self, y: np.ndarray, allow_train: bool) -> "LearnedForecaster":
        assert y.ndim == 2 and y.shape[0] >= 1
        self._T = y.shape[0]
        self._last = y[-1].copy()
        can_condition = self._T >= max(self.window, self.period + 1)
        can_train = self._T >= self.window + self.horizon + 4
        wrong_cols = (self._params is not None
                      and y.shape[1] != self._mu.shape[0])
        if self._params is None or wrong_cols:
            if not can_train:
                obs.warn("forecast.fallback_seasonal_naive",
                         f"history of {self._T} hours is below the "
                         f"{self.window + self.horizon + 4}-hour training "
                         "minimum; serving seasonal-naive instead")
                self._fallback = base.SeasonalNaive(self.period).fit(y)
                return self
            self._train(y)
        elif allow_train:
            # Only fit() calls advance the retrain cadence — update() is
            # documented to never retrain and never count toward it.
            self._fits_since_train += 1
            if (can_train and self.retrain_every > 0
                    and self._fits_since_train >= self.retrain_every):
                self._train(y)
        if not can_condition:
            self._fallback = base.SeasonalNaive(self.period).fit(y)
            return self
        self._fallback = None
        self._condition(y)
        return self

    # -- training ------------------------------------------------------------

    def _train(self, y: np.ndarray) -> None:
        with obs.timed("forecast.fit", hours=int(y.shape[0]),
                       columns=int(y.shape[1]),
                       train_steps=self.train_steps) as t:
            self._train_impl(y)
            t.set(loss=self.last_loss)
        self.train_seconds += t.elapsed_s

    def _train_impl(self, y: np.ndarray) -> None:
        self._mu = y.mean(axis=0)
        self._sd = np.maximum(y.std(axis=0), 1e-9)
        z = (y - self._mu) / self._sd                           # [T, C]
        L, H = self.window, self.horizon
        n_origins = z.shape[0] - L - H + 1
        X = np.stack([z[o:o + L] for o in range(n_origins)])    # [n, L, C]
        Y = np.stack([z[o + L:o + L + H] for o in range(n_origins)])
        # Hold out the most recent ~20% of window origins (all columns) as
        # a validation fold: the returned parameters are the best-on-val
        # snapshot of the trajectory, *including the seasonal-naive init* —
        # so on histories too short to generalize from, training can only
        # tie the baseline, never silently regress far below it.
        n_val = int(round(0.2 * n_origins)) if n_origins >= 5 else 0
        n_tr = n_origins - n_val

        def flat(a):
            return np.ascontiguousarray(
                a.transpose(0, 2, 1)).reshape(-1, a.shape[1])

        Xtr, Ytr = flat(X[:n_tr]), flat(Y[:n_tr])
        params = init_params(jax.random.PRNGKey(self.seed), self.d_model, H)
        # Training runs whatever recurrence the config selects: the Pallas
        # kernel carries a custom VJP (its backward pass is one more kernel
        # scan on reversed time — see kernels/rglru_scan/ops.py), with
        # gradient parity against the associative scan pinned in tests.
        opt, step, val_loss = _train_step(
            H, self.period, self.scan_impl, self.lr, self.weight_decay,
            self.train_steps)
        state = opt.init(params)
        rng = np.random.default_rng(self.seed)
        N = Xtr.shape[0]
        B = min(self.batch, N)
        if n_val:
            Xva = jnp.asarray(flat(X[n_tr:]), jnp.float32)
            Yva = jnp.asarray(flat(Y[n_tr:]), jnp.float32)
            best = (float(val_loss(params, Xva, Yva)), params)
        loss = np.nan
        eval_every = 10
        for s in range(self.train_steps):
            idx = rng.integers(0, N, size=B)
            params, state, loss = step(
                params, state, jnp.asarray(Xtr[idx], jnp.float32),
                jnp.asarray(Ytr[idx], jnp.float32))
            if n_val and (s % eval_every == eval_every - 1
                          or s == self.train_steps - 1):
                v = float(val_loss(params, Xva, Yva))
                if v < best[0]:
                    best = (v, params)
        self._params = best[1] if n_val else params
        self.last_loss = float(loss)
        self._fits_since_train = 0
        self.train_count += 1

    # -- conditioning + prediction -------------------------------------------

    def _condition(self, y: np.ndarray) -> None:
        """Run the (jitted, column-batched) inference pass on the tail
        window; caches the denormalized [H, C, Q] quantile tensor."""
        with obs.span("forecast.infer", columns=int(y.shape[1])):
            z = (y[-self.window:] - self._mu) / self._sd
            xw = np.ascontiguousarray(z.T)                      # [C, L]
            C = xw.shape[0]
            Cp = -(-C // COLUMN_BUCKET) * COLUMN_BUCKET
            if Cp > C:
                xw = np.vstack([xw, np.zeros((Cp - C, self.window))])
            run = _predict_fn(self.horizon, self.period, self.scan_impl)
            q = np.asarray(run(self._params, jnp.asarray(xw, jnp.float32)),
                           np.float64)[:C]                      # [C, H, Q]
        q = np.sort(q, axis=-1)        # enforce q10 ≤ q50 ≤ q90 pointwise
        q = q.transpose(1, 0, 2)                                # [H, C, Q]
        self._q = q * self._sd[None, :, None] + self._mu[None, :, None]

    def predict(self, horizon: int) -> base.Forecast:
        if self._fallback is not None:
            return self._fallback.predict(horizon)
        q = self._q
        H = q.shape[0]
        if horizon > H:
            extra = np.arange(H, horizon)
            if H >= self.period:      # extend periodically from the tail
                idx = H - self.period + (extra - H) % self.period
            else:                     # degenerate config: hold the last row
                idx = np.full(extra.shape, H - 1)
            q = np.concatenate([q, q[idx]], axis=0)
        q = q[:horizon]
        return base.Forecast(self._T - 1, q[..., 1], q[..., 0], q[..., 2],
                             self._last.copy())

    # -- checkpointing -------------------------------------------------------

    def save(self, directory: str, step: int = 0) -> str:
        """Persist the trained parameters + normalization through
        ``repro.checkpoint.store`` (atomic commit); the manifest carries the
        model config so :meth:`load` reconstructs without arguments."""
        if self._params is None:
            raise ValueError("nothing to save: forecaster has not trained")
        tree = dict(params=self._params, mu=np.asarray(self._mu),
                    sd=np.asarray(self._sd))
        extra = dict(kind="learned-forecaster", config=self._config())
        return store.save_checkpoint(directory, step, tree, extra)

    def _config(self) -> dict:
        return dict(period=self.period, window=self.window,
                    horizon=self.horizon, d_model=self.d_model,
                    scan_impl=self.scan_impl,
                    n_columns=int(self._mu.shape[0]))

    def _restore(self, directory: str, step: Optional[int] = None) -> None:
        step = store.latest_step(directory) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory!r}")
        with open(os.path.join(directory, f"step-{step}",
                               "manifest.json")) as f:
            cfg = json.load(f)["config"]
        n_cols = cfg.pop("n_columns")
        for k, v in cfg.items():
            setattr(self, k, v)
        target = dict(
            params=init_params(jax.random.PRNGKey(0), self.d_model,
                               self.horizon),
            mu=np.zeros(n_cols), sd=np.ones(n_cols))
        tree = store.restore_checkpoint(directory, step, target)
        self._params = tree["params"]
        self._mu = np.asarray(tree["mu"], np.float64)
        self._sd = np.asarray(tree["sd"], np.float64)
        self._fits_since_train = 0

    @classmethod
    def load(cls, directory: str, step: Optional[int] = None
             ) -> "LearnedForecaster":
        """Reconstruct a trained forecaster from a :meth:`save` directory
        (config from the manifest; call ``update(history)`` to condition)."""
        f = cls()
        f._restore(directory, step)
        return f
