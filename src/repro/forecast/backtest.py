"""Walk-forward backtesting of forecasters against telemetry series.

``backtest(series, make)`` replays the classic expanding-window protocol:
at every origin t ≥ warmup the forecaster is fit on ``series[:t]`` and
scored against the true ``series[t:t+horizon]`` with

  * MAPE        — point accuracy (% of truth magnitude), per lead hour and
                  overall;
  * pinball loss — quantile-band calibration at the forecaster's (lo, hi)
                  quantiles (mean over both tails);
  * band coverage — fraction of truth inside [lo, hi].

``backtest_telemetry`` is the convenience entry for the generator's hourly
signals (ci / ewif / wue / water intensity).
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core import telemetry
from repro.forecast import base


def mape(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Mean absolute percentage error (%), guarded against zero truth.

    The denominator is floored at ``|t| = 1e-9``, so the result is always
    finite: an exact prediction of a zero truth contributes 0, while a
    nonzero prediction of a zero truth contributes a huge (but finite and
    deterministic) term — a signal that percentage error is the wrong
    metric for that series (use ``pinball_loss``/MAE on near-zero signals;
    the telemetry signals this repo forecasts are strictly positive).
    Accepts any matching shapes, including scalars and length-1 series.
    """
    t = np.asarray(y_true, np.float64)
    p = np.asarray(y_pred, np.float64)
    return float(100.0 * np.mean(np.abs(p - t) / np.maximum(np.abs(t), 1e-9)))


def pinball_loss(y_true: np.ndarray, y_pred: np.ndarray, q: float) -> float:
    """Quantile (pinball) loss for quantile level ``q``.

    At ``q = 0.5`` this is exactly half the mean absolute error (pinned by
    a property test), which is why the q50 head of a quantile forecaster is
    also its point forecast. Defined for any matching shapes, length-1 and
    all-zero series included (no division anywhere).
    """
    d = np.asarray(y_true, np.float64) - np.asarray(y_pred, np.float64)
    return float(np.mean(np.maximum(q * d, (q - 1.0) * d)))


def backtest(series: np.ndarray, make: Callable[[], base.Forecaster], *,
             horizon: int = 6, warmup: int = 30, stride: int = 1,
             refit_every: int = 1) -> Dict:
    """Expanding-window backtest of a ``make()`` forecaster over ``series``.

    One forecaster instance walks forward through the origins: it is fully
    re-``fit`` at the first origin and every ``refit_every``-th origin after
    that, and cheaply ``update``-d (re-conditioned on the grown history) in
    between. For the stateless classical models ``update`` *is* ``fit``, so
    ``refit_every`` only matters for models with a real training cost (the
    learned forecaster trains on refits and re-conditions on updates).

    Args:
      series: [T, C] hourly truth.
      make: zero-arg factory returning the forecaster to walk forward.
      horizon: lead hours scored per origin.
      warmup: first origin (minimum history length).
      stride: hours between consecutive origins.
      refit_every: full-refit cadence in origins (1 = refit every origin).

    Returns a dict with overall ``mape``, per-lead ``mape_by_lead`` [horizon],
    ``pinball`` (mean of both tails), ``coverage`` in [0, 1], and
    ``n_origins``.
    """
    y = np.asarray(series, np.float64)
    T = y.shape[0]
    origins = range(warmup, T - horizon + 1, stride)
    abs_pct = []        # [n, horizon] per-origin per-lead APE means
    pin, cover = [], []
    n = 0
    f = make()
    for i, t in enumerate(origins):
        if refit_every <= 1 or i % refit_every == 0:
            f.fit(y[:t])
        else:
            f.update(y[:t])
        fc = f.predict(horizon)
        truth = y[t:t + horizon]
        ape = np.abs(fc.mean - truth) / np.maximum(np.abs(truth), 1e-9)
        abs_pct.append(100.0 * ape.mean(axis=1))
        q_lo, q_hi = fc.quantiles
        pin.append(0.5 * (pinball_loss(truth, fc.lo, q_lo)
                          + pinball_loss(truth, fc.hi, q_hi)))
        cover.append(float(np.mean((truth >= fc.lo) & (truth <= fc.hi))))
        n += 1
    if n == 0:
        raise ValueError("series too short for the requested warmup/horizon")
    by_lead = np.mean(abs_pct, axis=0)
    return dict(mape=float(by_lead.mean()), mape_by_lead=by_lead,
                pinball=float(np.mean(pin)), coverage=float(np.mean(cover)),
                n_origins=n)


def backtest_telemetry(tele: telemetry.Telemetry, key: str, name: str, *,
                       horizon: int = 6, warmup: int = 30, stride: int = 1,
                       refit_every: int = 1, **model_kw) -> Dict:
    """Backtest a named forecaster on one telemetry signal.

    ``key`` ∈ {"ci", "ewif", "wue", "water_intensity"}; ``name`` is a
    registered model name or ``"oracle"``; ``refit_every`` sets the
    walk-forward full-refit cadence (see :func:`backtest`); ``model_kw``
    are constructor overrides for the named model (e.g. ``train_steps``
    for ``learned``).
    """
    series = getattr(tele, key)
    if name == "oracle":
        make = lambda: base.Oracle(series)
    else:
        make = lambda: base.make_forecaster(name, **model_kw)
    return backtest(series, make, horizon=horizon, warmup=warmup,
                    stride=stride, refit_every=refit_every)
