"""Walk-forward backtesting of forecasters against telemetry series.

``backtest(series, make)`` replays the classic expanding-window protocol:
at every origin t ≥ warmup the forecaster is fit on ``series[:t]`` and
scored against the true ``series[t:t+horizon]`` with

  * MAPE        — point accuracy (% of truth magnitude), per lead hour and
                  overall;
  * pinball loss — quantile-band calibration at the forecaster's (lo, hi)
                  quantiles (mean over both tails);
  * band coverage — fraction of truth inside [lo, hi].

``backtest_telemetry`` is the convenience entry for the generator's hourly
signals (ci / ewif / wue / water intensity).
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core import telemetry
from repro.forecast import base


def mape(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Mean absolute percentage error (%), guarded against zero truth."""
    t = np.asarray(y_true, np.float64)
    p = np.asarray(y_pred, np.float64)
    return float(100.0 * np.mean(np.abs(p - t) / np.maximum(np.abs(t), 1e-9)))


def pinball_loss(y_true: np.ndarray, y_pred: np.ndarray, q: float) -> float:
    """Quantile (pinball) loss for quantile level ``q``."""
    d = np.asarray(y_true, np.float64) - np.asarray(y_pred, np.float64)
    return float(np.mean(np.maximum(q * d, (q - 1.0) * d)))


def backtest(series: np.ndarray, make: Callable[[], base.Forecaster], *,
             horizon: int = 6, warmup: int = 30, stride: int = 1) -> Dict:
    """Expanding-window backtest of ``make()`` forecasters over ``series``.

    Args:
      series: [T, C] hourly truth.
      make: zero-arg factory returning a fresh forecaster per origin.
      horizon: lead hours scored per origin.
      warmup: first origin (minimum history length).
      stride: hours between consecutive origins.

    Returns a dict with overall ``mape``, per-lead ``mape_by_lead`` [horizon],
    ``pinball`` (mean of both tails), ``coverage`` in [0, 1], and
    ``n_origins``.
    """
    y = np.asarray(series, np.float64)
    T = y.shape[0]
    origins = range(warmup, T - horizon + 1, stride)
    abs_pct = []        # [n, horizon] per-origin per-lead APE means
    pin, cover = [], []
    n = 0
    for t in origins:
        fc = make().fit(y[:t]).predict(horizon)
        truth = y[t:t + horizon]
        ape = np.abs(fc.mean - truth) / np.maximum(np.abs(truth), 1e-9)
        abs_pct.append(100.0 * ape.mean(axis=1))
        q_lo, q_hi = fc.quantiles
        pin.append(0.5 * (pinball_loss(truth, fc.lo, q_lo)
                          + pinball_loss(truth, fc.hi, q_hi)))
        cover.append(float(np.mean((truth >= fc.lo) & (truth <= fc.hi))))
        n += 1
    if n == 0:
        raise ValueError("series too short for the requested warmup/horizon")
    by_lead = np.mean(abs_pct, axis=0)
    return dict(mape=float(by_lead.mean()), mape_by_lead=by_lead,
                pinball=float(np.mean(pin)), coverage=float(np.mean(cover)),
                n_origins=n)


def backtest_telemetry(tele: telemetry.Telemetry, key: str, name: str, *,
                       horizon: int = 6, warmup: int = 30, stride: int = 1,
                       **model_kw) -> Dict:
    """Backtest a named forecaster on one telemetry signal.

    ``key`` ∈ {"ci", "ewif", "wue", "water_intensity"}; ``name`` is a
    registered model name or ``"oracle"``.
    """
    series = getattr(tele, key)
    if name == "oracle":
        make = lambda: base.Oracle(series)
    else:
        make = lambda: base.make_forecaster(name, **model_kw)
    return backtest(series, make, horizon=horizon, warmup=warmup,
                    stride=stride)
