"""Architecture assembly: every family, scan-over-layers, three modes.

All stacks use ``jax.lax.scan`` over layer-stacked parameters so the HLO
stays one-layer-sized regardless of depth (essential for 512-device dry-run
compiles and the standard MaxText-style structure XLA pipelines well).
Heterogeneous stacks (gemma3 local/global, griffin rec/rec/attn, vision
cross groups, deepseek first-dense) are expressed as grouped scans or
per-layer flag arrays — never unrolled.

Modes: ``train`` (logits, no cache), ``prefill`` (logits + built cache),
``decode`` (one token in, cache updated in place).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, common, mla, moe, rglru, ssm
from repro.models.common import (P, apply_norm, embed_tokens, embedding_init,
                                 logits_from_hidden, mlp_apply, mlp_init,
                                 norm_init, split_tree, stack_axes,
                                 vmap_stack)

BIG_WINDOW = 1 << 30


def _current_mesh():
    """Version-compat mesh lookup.

    ``jax.sharding.get_abstract_mesh`` landed after the pinned JAX release;
    on older versions the mesh in effect is the thread-local physical mesh
    pushed by ``with Mesh(...):`` (and, under the sharding-in-types mode,
    the internal abstract mesh). Returns None when no mesh is active.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        mesh = getter()
        if mesh is not None and mesh.axis_names:
            return mesh
        # An empty abstract mesh does not rule out a `with Mesh(...)`
        # context: fall through to the thread-local physical mesh.
    try:
        from jax._src import mesh as _mesh_internal
        phys = _mesh_internal.thread_resources.env.physical_mesh
        if phys is not None and phys.axis_names:
            return phys
        abstract_getter = getattr(_mesh_internal, "get_abstract_mesh", None)
        if abstract_getter is not None:
            mesh = abstract_getter()
            if mesh is not None and getattr(mesh, "axis_names", ()):
                return mesh
    except Exception:
        return None
    return None


def constrain(x, axes):
    """with_sharding_constraint by logical axes — no-op outside a mesh
    context (smoke tests), divisibility-aware inside one. This pins the
    activation layout at the embedding/logits boundary; SPMD propagation
    can otherwise pick a replicated layout for whole forward passes (it
    resolves ties arbitrarily — observed on MLA archs)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    from repro.runtime import sharding as shd
    spec = shd.spec_for(axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def _policy(remat: str):
    if remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return None


def _maybe_remat(fn, cfg, mode):
    if mode == "train" and cfg.remat != "none":
        return jax.checkpoint(fn, policy=_policy(cfg.remat))
    return fn


# ---------------------------------------------------------------------------
# Layer inits
# ---------------------------------------------------------------------------

def _attn_init(cfg, key, kv_input_dim=None):
    return attention.init(key, cfg.d_model, cfg.n_heads, cfg.n_kv,
                          cfg.head_dim_, qkv_bias=cfg.qkv_bias,
                          dtype=cfg.params_dtype, kv_input_dim=kv_input_dim)


def decoder_layer_init(cfg, key, use_moe: bool, d_ff: Optional[int] = None):
    ks = jax.random.split(key, 2)
    p = dict(ln1=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype))
    if cfg.ssm:
        p["mixer"] = ssm.block_init(
            ks[0], cfg.d_model, d_inner=cfg.d_inner,
            head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
            d_state=cfg.ssm_state, dtype=cfg.params_dtype)
        return p
    if cfg.mla:
        p["attn"] = mla.init(ks[0], cfg.d_model, cfg.n_heads,
                             q_lora=cfg.q_lora, kv_lora=cfg.kv_lora,
                             d_nope=cfg.d_nope, d_rope=cfg.d_rope,
                             d_v=cfg.d_v, dtype=cfg.params_dtype)
    else:
        p["attn"] = _attn_init(cfg, ks[0])
    p["ln2"] = norm_init(cfg.d_model, cfg.norm, cfg.params_dtype)
    if use_moe:
        p["mlp"] = moe.init(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                            n_shared=cfg.n_shared, dtype=cfg.params_dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, d_ff or cfg.d_ff,
                            cfg.params_dtype)
    return p


def rec_layer_init(cfg, key):
    ks = jax.random.split(key, 2)
    return dict(ln1=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype),
                mixer=rglru.block_init(ks[0], cfg.d_model,
                                       lru_width=cfg.lru_width,
                                       dtype=cfg.params_dtype),
                ln2=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype),
                mlp=mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.params_dtype,
                             gate="gelu"))


def cross_layer_init(cfg, key):
    """Gated cross-attention layer (llama-3.2-vision style)."""
    ks = jax.random.split(key, 2)
    return dict(ln1=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype),
                cross=_attn_init(cfg, ks[0]),
                gate_attn=common.zeros_init((1,), ("scalar",),
                                            cfg.params_dtype),
                ln2=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype),
                mlp=mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.params_dtype),
                gate_mlp=common.zeros_init((1,), ("scalar",),
                                           cfg.params_dtype))


def encdec_dec_layer_init(cfg, key):
    ks = jax.random.split(key, 3)
    return dict(ln1=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype),
                self=_attn_init(cfg, ks[0]),
                ln2=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype),
                cross=_attn_init(cfg, ks[1]),
                ln3=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype),
                mlp=mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.params_dtype))


# ---------------------------------------------------------------------------
# Layer applies
# ---------------------------------------------------------------------------

def _gemma3_layer_args(cfg, flag):
    """Per-layer (window, theta) from the is_global flag (traced-safe)."""
    window = jnp.where(flag > 0, jnp.int32(BIG_WINDOW),
                       jnp.int32(max(cfg.window, 1)))
    theta = jnp.where(flag > 0,
                      jnp.float32(cfg.rope_theta_global or cfg.rope_theta),
                      jnp.float32(cfg.rope_theta))
    return window, theta


def decoder_layer_apply(cfg, p, x, positions, flag, mode, cache, decode_pos,
                        use_moe: bool):
    h = apply_norm(x, p["ln1"], cfg.norm)
    if cfg.ssm:
        mix, new_cache = ssm.block_apply(h, p["mixer"], cfg, mode=mode,
                                         cache=cache, chunk=cfg.ssd_chunk)
        return x + mix, new_cache
    if cfg.mla:
        mix, new_cache = mla.apply(
            h, p["attn"], n_heads=cfg.n_heads, q_lora=cfg.q_lora,
            kv_lora=cfg.kv_lora, d_nope=cfg.d_nope, d_rope=cfg.d_rope,
            d_v=cfg.d_v, positions=positions, block_kv=cfg.block_kv,
            cache=cache if mode == "decode" else None, decode_pos=decode_pos)
        if mode == "prefill":
            # MLA prefill cache = the compressed latents, recomputed cheaply.
            c_kv, k_rope = mla._latent(h, p["attn"], cfg.kv_lora, positions)
            new_cache = (c_kv, k_rope)
    else:
        if cfg.family == "gemma3":
            window, theta = _gemma3_layer_args(cfg, flag)
            kind = "sliding"
        else:
            window, theta, kind = cfg.window, cfg.rope_theta, \
                ("sliding" if cfg.window else "causal")
        mix, kv = attention.apply(
            h, p["attn"], n_kv=cfg.n_kv, n_heads=cfg.n_heads,
            positions=positions, kind=kind, window=window, rope_theta=theta,
            block_kv=cfg.block_kv, softmax_scale=cfg.softmax_scale,
            cache=cache if mode == "decode" else None, decode_pos=decode_pos)
        if mode == "prefill" and kv is None:
            k, v = attention.project_kv(h, p["attn"], theta, positions)
            kv = (k, v)
        new_cache = kv if mode != "train" else None
    x = x + mix
    h2 = apply_norm(x, p["ln2"], cfg.norm)
    if use_moe:
        y = moe.apply(h2, p["mlp"], top_k=cfg.top_k, n_experts=cfg.n_experts,
                      capacity_factor=cfg.moe_capacity_factor)
    else:
        y = mlp_apply(h2, p["mlp"])
    return x + y, new_cache


def rec_layer_apply(cfg, p, x, mode, cache):
    h = apply_norm(x, p["ln1"], cfg.norm)
    mix, new_cache = rglru.block_apply(h, p["mixer"], mode=mode, cache=cache)
    x = x + mix
    h2 = apply_norm(x, p["ln2"], cfg.norm)
    return x + mlp_apply(h2, p["mlp"], gate="gelu"), new_cache


def attn_layer_apply(cfg, p, x, positions, mode, cache, decode_pos):
    """Griffin local-attention layer (MQA, sliding window)."""
    h = apply_norm(x, p["ln1"], cfg.norm)
    mix, kv = attention.apply(
        h, p["attn"], n_kv=cfg.n_kv, n_heads=cfg.n_heads,
        positions=positions, kind="sliding", window=cfg.window,
        rope_theta=cfg.rope_theta, block_kv=cfg.block_kv,
        cache=cache if mode == "decode" else None, decode_pos=decode_pos)
    if mode == "prefill" and kv is None:
        kv = attention.project_kv(h, p["attn"], cfg.rope_theta, positions)
    x = x + mix
    h2 = apply_norm(x, p["ln2"], cfg.norm)
    return x + mlp_apply(h2, p["mlp"], gate="gelu"), \
        (kv if mode != "train" else None)


def cross_layer_apply(cfg, p, x, img_kv, mode, positions):
    """Gated cross-attention to static image/encoder KV (never updates it)."""
    h = apply_norm(x, p["ln1"], cfg.norm)
    if mode == "decode":
        mix, _ = attention.apply(
            h, p["cross"], n_kv=cfg.n_kv, n_heads=cfg.n_heads,
            positions=positions, kind="full", rope_theta=None,
            cache=img_kv, decode_pos=0)
    else:
        k, v = img_kv
        kv_pos = jax.lax.broadcasted_iota(jnp.int32, (k.shape[1],), 0)
        q = attention.project_q(h, p["cross"], None, positions)
        B, Sq = q.shape[:2]
        q = q.reshape(B, Sq, cfg.n_kv, cfg.n_heads // cfg.n_kv, -1)
        o = attention.blocked_attention(q, k, v, positions, kv_pos,
                                        kind="full", block_kv=cfg.block_kv)
        mix = attention.project_out(o.reshape(B, Sq, cfg.n_heads, -1),
                                    p["cross"])
    x = x + jnp.tanh(p["gate_attn"].astype(x.dtype)) * mix
    h2 = apply_norm(x, p["ln2"], cfg.norm)
    return x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * mlp_apply(h2,
                                                                   p["mlp"])


# ---------------------------------------------------------------------------
# Stack runners
# ---------------------------------------------------------------------------

def _scan_stack(cfg, stacked, x, flags, caches, mode, layer_fn):
    """Generic scan over a homogeneous stack. ``layer_fn(x, lp, flag, cache)``
    → (x, cache_out). caches=None in train mode."""
    def body(carry, inp):
        if caches is None:
            lp, fl = inp
            y, c = layer_fn(carry, lp, fl, None)
        else:
            lp, fl, cache = inp
            y, c = layer_fn(carry, lp, fl, cache)
        return y, c

    body = _maybe_remat(body, cfg, mode)
    xs = (stacked, flags) if caches is None else (stacked, flags, caches)
    return jax.lax.scan(body, x, xs)


# ---------------------------------------------------------------------------
# Family assemblies
# ---------------------------------------------------------------------------

def init(cfg, key):
    """Full parameter tree (P leaves)."""
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = dict(
        embed=embedding_init(ks[0], cfg.padded_vocab, cfg.d_model,
                             cfg.params_dtype, tied=cfg.tie_embeddings),
        final_norm=norm_init(cfg.d_model, cfg.norm, cfg.params_dtype))

    if cfg.family in ("decoder", "gemma3"):
        use_moe = cfg.n_experts > 0
        if cfg.first_dense:
            params["dense_layers"] = vmap_stack(
                lambda k: decoder_layer_init(cfg, k, False,
                                             d_ff=cfg.dense_d_ff),
                ks[1], cfg.first_dense)
        params["layers"] = vmap_stack(
            lambda k: decoder_layer_init(cfg, k, use_moe), ks[2],
            cfg.n_layers - cfg.first_dense)

    elif cfg.family == "griffin":
        n_groups, rem = divmod(cfg.n_layers, 3)

        def group_init(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return dict(rec1=rec_layer_init(cfg, k1),
                        rec2=rec_layer_init(cfg, k2),
                        attn=dict(ln1=norm_init(cfg.d_model, cfg.norm,
                                                cfg.params_dtype),
                                  attn=_attn_init(cfg, k3),
                                  ln2=norm_init(cfg.d_model, cfg.norm,
                                                cfg.params_dtype),
                                  mlp=mlp_init(jax.random.fold_in(k3, 1),
                                               cfg.d_model, cfg.d_ff,
                                               cfg.params_dtype,
                                               gate="gelu")))
        params["groups"] = vmap_stack(group_init, ks[1], n_groups)
        if rem:
            params["tail"] = vmap_stack(lambda k: rec_layer_init(cfg, k),
                                        ks[2], rem)

    elif cfg.family == "vision":
        per = cfg.cross_every
        n_groups = cfg.n_layers // per

        def group_init(k):
            k1, k2 = jax.random.split(k)
            return dict(cross=cross_layer_init(cfg, k1),
                        selfs=vmap_stack(
                            lambda kk: decoder_layer_init(cfg, kk, False),
                            k2, per - 1))
        params["groups"] = vmap_stack(group_init, ks[1], n_groups)

    elif cfg.family == "encdec":
        params["enc_layers"] = vmap_stack(
            lambda k: dict(ln1=norm_init(cfg.d_model, cfg.norm,
                                         cfg.params_dtype),
                           attn=_attn_init(cfg, k),
                           ln2=norm_init(cfg.d_model, cfg.norm,
                                         cfg.params_dtype),
                           mlp=mlp_init(jax.random.fold_in(k, 1), cfg.d_model,
                                        cfg.d_ff, cfg.params_dtype)),
            ks[1], cfg.enc_layers)
        params["enc_norm"] = norm_init(cfg.d_model, cfg.norm,
                                       cfg.params_dtype)
        params["layers"] = vmap_stack(lambda k: encdec_dec_layer_init(cfg, k),
                                      ks[2], cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return params


def _encode(cfg, params, frames):
    """Bidirectional encoder over stub frame embeddings [B, S_src, d]."""
    x = frames.astype(cfg.compute_dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def layer(xc, lp, fl, cache):
        h = apply_norm(xc, lp["ln1"], cfg.norm)
        mix, _ = attention.apply(h, lp["attn"], n_kv=cfg.n_kv,
                                 n_heads=cfg.n_heads, positions=positions,
                                 kind="full", rope_theta=cfg.rope_theta,
                                 block_kv=cfg.block_kv)
        xc = xc + mix
        h2 = apply_norm(xc, lp["ln2"], cfg.norm)
        return xc + mlp_apply(h2, lp["mlp"]), None

    flags = jnp.zeros(cfg.enc_layers)
    x, _ = _scan_stack(cfg, params["enc_layers"], x, flags, None, "train",
                       layer)
    return apply_norm(x, params["enc_norm"], cfg.norm)


def apply(cfg, params, batch, mode, cache=None, decode_pos=None):
    """Returns (logits, new_cache). batch: tokens [B,S] (+frames/patches)."""
    dtype = cfg.compute_dtype
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(tokens, params["embed"], dtype)
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model).astype(dtype)
    if mode == "decode":
        positions = jnp.full((1,), decode_pos, jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)

    new_cache = None
    if cfg.family in ("decoder", "gemma3"):
        use_moe = cfg.n_experts > 0
        n_rest = cfg.n_layers - cfg.first_dense
        if cfg.family == "gemma3":
            idx = np.arange(n_rest)
            flags = jnp.asarray((idx % cfg.attn_every) == cfg.attn_every - 1,
                                jnp.float32)
        else:
            flags = jnp.zeros(n_rest)
        c_dense, c_rest = (cache if cache is not None else (None, None))
        if cfg.first_dense:
            fl0 = jnp.zeros(cfg.first_dense)
            x, c_dense = _scan_stack(
                cfg, params["dense_layers"], x, fl0, c_dense, mode,
                lambda xc, lp, fl, cc: decoder_layer_apply(
                    cfg, lp, xc, positions, fl, mode, cc, decode_pos, False))
        x, c_rest = _scan_stack(
            cfg, params["layers"], x, flags, c_rest, mode,
            lambda xc, lp, fl, cc: decoder_layer_apply(
                cfg, lp, xc, positions, fl, mode, cc, decode_pos, use_moe))
        if mode != "train":
            new_cache = (c_dense, c_rest)

    elif cfg.family == "griffin":
        def group_apply(xc, gp, fl, gc):
            gc = gc or {}
            c1 = gc.get("rec1") if gc else None
            xc, o1 = rec_layer_apply(cfg, gp["rec1"], xc, mode, c1)
            c2 = gc.get("rec2") if gc else None
            xc, o2 = rec_layer_apply(cfg, gp["rec2"], xc, mode, c2)
            ca = gc.get("attn") if gc else None
            xc, oa = attn_layer_apply(cfg, gp["attn"], xc, positions, mode,
                                      ca, decode_pos)
            out = dict(rec1=o1, rec2=o2, attn=oa) if mode != "train" else None
            return xc, out

        gcache, tcache = (cache if cache is not None else (None, None))
        n_groups = cfg.n_layers // 3
        x, gout = _scan_stack(cfg, params["groups"], x,
                              jnp.zeros(n_groups), gcache, mode, group_apply)
        tout = None
        if "tail" in params:
            rem = cfg.n_layers - 3 * n_groups
            x, tout = _scan_stack(
                cfg, params["tail"], x, jnp.zeros(rem), tcache, mode,
                lambda xc, lp, fl, cc: rec_layer_apply(cfg, lp, xc, mode, cc))
        if mode != "train":
            new_cache = (gout, tout)

    elif cfg.family == "vision":
        patches = batch.get("patches")
        per = cfg.cross_every
        n_groups = cfg.n_layers // per

        def group_apply(xc, gp, fl, gc):
            if mode == "decode":
                img_kv = gc["img"]
            else:
                k, v = attention.project_kv(
                    patches.astype(dtype), gp["cross"]["cross"], None,
                    jnp.arange(patches.shape[1], dtype=jnp.int32) * 0)
                img_kv = (k, v)
            xc = cross_layer_apply(cfg, gp["cross"], xc, img_kv, mode,
                                   positions)
            sc = gc["selfs"] if gc else None
            xc, souts = _scan_stack(
                cfg, gp["selfs"], xc, jnp.zeros(per - 1), sc, mode,
                lambda xx, lp, f2, cc: decoder_layer_apply(
                    cfg, lp, xx, positions, f2, mode, cc, decode_pos, False))
            out = (dict(img=img_kv, selfs=souts) if mode != "train" else None)
            return xc, out

        x, gout = _scan_stack(cfg, params["groups"], x, jnp.zeros(n_groups),
                              cache, mode, group_apply)
        if mode != "train":
            new_cache = gout

    elif cfg.family == "encdec":
        if mode == "decode":
            memory = None
        else:
            memory = _encode(cfg, params, batch["frames"])
        mem_pos = (jnp.arange(memory.shape[1], dtype=jnp.int32)
                   if memory is not None else None)

        def dec_layer(xc, lp, fl, cc):
            c_self = cc["self"] if cc else None
            h = apply_norm(xc, lp["ln1"], cfg.norm)
            mix, kv = attention.apply(
                h, lp["self"], n_kv=cfg.n_kv, n_heads=cfg.n_heads,
                positions=positions, kind="causal",
                rope_theta=cfg.rope_theta, block_kv=cfg.block_kv,
                cache=c_self if mode == "decode" else None,
                decode_pos=decode_pos)
            if mode == "prefill" and kv is None:
                kv = attention.project_kv(h, lp["self"], cfg.rope_theta,
                                          positions)
            xc = xc + mix
            h2 = apply_norm(xc, lp["ln2"], cfg.norm)
            if mode == "decode":
                xmix, _ = attention.apply(
                    h2, lp["cross"], n_kv=cfg.n_kv, n_heads=cfg.n_heads,
                    positions=positions, kind="full", rope_theta=None,
                    cache=cc["cross"], decode_pos=0)
                cross_kv = cc["cross"]
            else:
                ck, cv = attention.project_kv(memory, lp["cross"], None,
                                              mem_pos)
                q = attention.project_q(h2, lp["cross"], None, positions)
                Bq, Sq = q.shape[:2]
                q = q.reshape(Bq, Sq, cfg.n_kv, cfg.n_heads // cfg.n_kv, -1)
                o = attention.blocked_attention(q, ck, cv, positions, mem_pos,
                                                kind="full",
                                                block_kv=cfg.block_kv)
                xmix = attention.project_out(
                    o.reshape(Bq, Sq, cfg.n_heads, -1), lp["cross"])
                cross_kv = (ck, cv)
            xc = xc + xmix
            h3 = apply_norm(xc, lp["ln3"], cfg.norm)
            xc = xc + mlp_apply(h3, lp["mlp"])
            out = (dict(self=kv, cross=cross_kv) if mode != "train" else None)
            return xc, out

        x, couts = _scan_stack(cfg, params["layers"], x,
                               jnp.zeros(cfg.n_layers), cache, mode,
                               dec_layer)
        if mode != "train":
            new_cache = couts
    else:
        raise ValueError(cfg.family)

    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = logits_from_hidden(x, params["embed"], cfg.vocab, dtype)
    logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"))
    return logits, new_cache
