"""Model facade: init/specs, train loss, prefill, decode, cache builders.

The cache builders return P-leaf trees (value + logical axes) whose
*structure matches exactly what transformer.apply's scans expect* — the same
builders serve real serving (zeros) and the dry-run (eval_shape →
ShapeDtypeStruct with shardings attached).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common, transformer
from repro.models.common import P, is_param, split_tree, softmax_xent


class Model:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- params ---------------------------------------------------------------

    def init(self, key):
        return split_tree(transformer.init(self.cfg, key))

    def abstract_params(self):
        """(ShapeDtypeStruct tree, logical-axes tree) without allocation."""
        tree = jax.eval_shape(
            lambda k: transformer.init(self.cfg, k), jax.random.PRNGKey(0))
        return split_tree(tree)

    def param_count(self) -> int:
        shapes, _ = self.abstract_params()
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))

    # -- steps ----------------------------------------------------------------

    def loss(self, params, batch):
        logits, _ = transformer.apply(self.cfg, params, batch, "train")
        return softmax_xent(logits, batch["labels"])

    def prefill(self, params, batch):
        logits, cache = transformer.apply(self.cfg, params, batch, "prefill")
        return logits[:, -1], cache

    def decode(self, params, cache, tokens, pos):
        logits, cache = transformer.apply(self.cfg, params,
                                          dict(tokens=tokens), "decode",
                                          cache=cache, decode_pos=pos)
        return logits[:, 0], cache

    # -- cache builders --------------------------------------------------------

    def _kv_cache(self, B, S):
        cfg = self.cfg
        dt = cfg.compute_dtype
        mk = lambda: P(jnp.zeros((B, S, cfg.n_kv, cfg.head_dim_), dt),
                       ("cache_batch", "cache_seq", "kv_heads", "head_dim"))
        return (mk(), mk())

    def _mla_cache(self, B, S):
        cfg = self.cfg
        dt = cfg.compute_dtype
        return (P(jnp.zeros((B, S, cfg.kv_lora), dt),
                  ("cache_batch", "cache_seq", "mla_latent")),
                P(jnp.zeros((B, S, cfg.d_rope), dt),
                  ("cache_batch", "cache_seq", "rope_dim")))

    def _ssm_cache(self, B):
        cfg = self.cfg
        dt = cfg.compute_dtype
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        H = cfg.d_inner // cfg.ssm_head_dim
        return dict(
            conv=P(jnp.zeros((B, 3, conv_dim), dt),
                   ("cache_batch", "conv", "conv_channels")),
            state=P(jnp.zeros((B, H, cfg.ssm_head_dim, cfg.ssm_state), dt),
                    ("cache_batch", "heads", "head_dim", "ssm_state")))

    def _rglru_cache(self, B):
        cfg = self.cfg
        dt = cfg.compute_dtype
        return dict(conv=P(jnp.zeros((B, 3, cfg.lru_width), dt),
                           ("cache_batch", "conv", "mlp")),
                    state=P(jnp.zeros((B, cfg.lru_width), dt),
                            ("cache_batch", "mlp")))

    @staticmethod
    def _stack(tree, n):
        return jax.tree.map(
            lambda p: P(jnp.zeros((n,) + p.value.shape, p.value.dtype),
                        ("layers",) + p.axes), tree, is_leaf=is_param)

    def init_cache(self, batch: int, max_seq: int, *, src_len: int = 0,
                   n_img: int = 0):
        """Decode cache (P-leaf tree). ``split_tree`` it before use."""
        cfg = self.cfg
        dt = cfg.compute_dtype
        if cfg.family in ("decoder", "gemma3"):
            if cfg.ssm:
                layer = self._ssm_cache(batch)
            elif cfg.mla:
                layer = self._mla_cache(batch, max_seq)
            else:
                layer = self._kv_cache(batch, max_seq)
            rest = self._stack(layer, cfg.n_layers - cfg.first_dense)
            dense = (self._stack(self._kv_cache(batch, max_seq)
                                 if not cfg.mla else
                                 self._mla_cache(batch, max_seq),
                                 cfg.first_dense)
                     if cfg.first_dense else None)
            return (dense, rest)
        if cfg.family == "griffin":
            n_groups, rem = divmod(cfg.n_layers, 3)
            group = dict(rec1=self._rglru_cache(batch),
                         rec2=self._rglru_cache(batch),
                         attn=self._kv_cache(batch, max_seq))
            out = self._stack(group, n_groups)
            tail = self._stack(self._rglru_cache(batch), rem) if rem else None
            return (out, tail)
        if cfg.family == "vision":
            per = cfg.cross_every
            img = (P(jnp.zeros((batch, n_img, cfg.n_kv, cfg.head_dim_), dt),
                     ("cache_batch", "cache_img", "kv_heads", "head_dim")),
                   P(jnp.zeros((batch, n_img, cfg.n_kv, cfg.head_dim_), dt),
                     ("cache_batch", "cache_img", "kv_heads", "head_dim")))
            group = dict(img=img,
                         selfs=self._stack(self._kv_cache(batch, max_seq),
                                           per - 1))
            return self._stack(group, cfg.n_layers // per)
        if cfg.family == "encdec":
            layer = dict(
                self=self._kv_cache(batch, max_seq),
                cross=(P(jnp.zeros((batch, src_len, cfg.n_kv,
                                    cfg.head_dim_), dt),
                         ("cache_batch", "cache_img", "kv_heads",
                          "head_dim")),
                       P(jnp.zeros((batch, src_len, cfg.n_kv,
                                    cfg.head_dim_), dt),
                         ("cache_batch", "cache_img", "kv_heads",
                          "head_dim"))))
            return self._stack(layer, cfg.n_layers)
        raise ValueError(cfg.family)

    # -- input builders ---------------------------------------------------------

    def make_inputs(self, shape, concrete: bool = False,
                    enc_ctx: int = 4096):
        """P-leaf tree of step inputs for a ShapeSpec cell.

        train: {tokens, labels [, frames | patches]}
        prefill: {tokens [, frames | patches]}
        decode: {tokens [B,1], cache, pos}
        ``concrete=True`` materializes arrays (smoke tests); otherwise call
        under eval_shape / use .value ShapeDtypeStructs for the dry-run.
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        dt = cfg.compute_dtype
        tok = lambda s: P(jnp.zeros((B, s), jnp.int32),
                          ("act_batch", "act_seq"))
        out: Dict[str, Any] = {}
        if shape.kind in ("train", "prefill"):
            out["tokens"] = tok(S)
            if shape.kind == "train":
                out["labels"] = tok(S)
            if cfg.family == "encdec":
                out["frames"] = P(jnp.zeros((B, S, cfg.d_model), dt),
                                  ("act_batch", "act_seq", "act_embed"))
            if cfg.family == "vision":
                out["patches"] = P(
                    jnp.zeros((B, cfg.n_img_tokens, cfg.d_model), dt),
                    ("act_batch", "act_img", "act_embed"))
        else:  # decode
            out["tokens"] = tok(1)
            out["cache"] = self.init_cache(
                B, S, src_len=(enc_ctx if cfg.family == "encdec" else 0),
                n_img=cfg.n_img_tokens)
        return out
