"""Shared model building blocks: params-with-axes, norms, MLPs, RoPE, embed.

Parameter convention
--------------------
Init functions return pytrees whose leaves are ``P(value, axes)`` — the
array together with its *logical* sharding axes (e.g. ("embed", "heads",
"head_dim")). ``split_tree`` separates them into (params, specs); the
runtime resolves logical axes to mesh ``PartitionSpec``s via
``runtime/sharding.py``. Keeping value+axes co-located at init time makes it
impossible for the two trees to drift.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class P:
    """A parameter leaf: array + logical sharding axes.

    Registered as a pytree node whose *aux data* is the axes tuple — so
    ``jax.vmap`` over an init function stacks the value while the logical
    axes ride along statically (then ``stack_axes`` prepends "layers").
    """
    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[str, ...]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"P({getattr(self.value, 'shape', self.value)}, {self.axes})"


jax.tree_util.register_pytree_node(
    P, lambda p: ((p.value,), p.axes), lambda axes, ch: P(ch[0], axes))


def is_param(x) -> bool:
    return isinstance(x, P)


def split_tree(tree):
    """(params, specs) from a tree of P leaves."""
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    specs = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return params, specs


def stack_axes(tree, axis_name: str = "layers"):
    """Prepend a stacking axis to every P leaf's logical axes (used after
    vmap-stacking per-layer inits)."""
    return jax.tree.map(lambda p: P(p.value, (axis_name,) + p.axes), tree,
                        is_leaf=is_param)


def vmap_stack(init_fn, key, n: int):
    """Stack ``n`` copies of ``init_fn(key_i)`` along a leading layer axis."""
    keys = jax.random.split(key, n)
    return stack_axes(jax.vmap(init_fn)(keys))


def trunc_normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def dense_init(key, shape, axes, dtype, fan_in=None):
    """Fan-in-scaled init (the MaxText default)."""
    fan_in = fan_in if fan_in is not None else shape[0]
    return P(trunc_normal(key, shape, 1.0 / np.sqrt(fan_in), dtype), axes)


def embed_init(key, shape, axes, dtype):
    return P(trunc_normal(key, shape, 1.0, dtype), axes)


def zeros_init(shape, axes, dtype):
    return P(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype):
    return P(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm_init(d, kind, dtype):
    if kind == "rmsnorm":
        return dict(scale=zeros_init((d,), ("embed_nosplit",), dtype))
    return dict(scale=ones_init((d,), ("embed_nosplit",), dtype),
                bias=zeros_init((d,), ("embed_nosplit",), dtype))


def apply_norm(x, p, kind):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, dtype, gate="silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(
        wi=dense_init(k1, (d_model, d_ff), ("embed", "mlp"), dtype),
        wg=dense_init(k2, (d_model, d_ff), ("embed", "mlp"), dtype),
        wo=dense_init(k3, (d_ff, d_model), ("mlp", "embed"), dtype,
                      fan_in=d_ff),
    )


def mlp_apply(x, p, gate="silu"):
    act = jax.nn.silu if gate == "silu" else jax.nn.gelu
    h = act(x @ p["wi"].astype(x.dtype)) * (x @ p["wg"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [d/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,s,1,d/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Token embedding + logits head (padded vocab)
# ---------------------------------------------------------------------------

def pad_vocab(vocab: int, multiple: int = 2048) -> int:
    return int(np.ceil(vocab / multiple) * multiple)


def embedding_init(key, vocab_padded, d_model, dtype, tied=True):
    k1, k2 = jax.random.split(key)
    # 1/sqrt(d) rows keep tied logits ~unit-scale at init (models with
    # embed_scale=True multiply activations back up by sqrt(d), gemma-style).
    out = dict(tokens=P(trunc_normal(k1, (vocab_padded, d_model),
                                     1.0 / np.sqrt(d_model), dtype),
                        ("vocab", "embed")))
    if not tied:
        out["head"] = dense_init(k2, (d_model, vocab_padded),
                                 ("embed", "vocab"), dtype)
    return out


def embed_tokens(tokens, p, dtype):
    return p["tokens"].astype(dtype)[tokens]


def logits_from_hidden(h, p, true_vocab, dtype):
    table = p.get("head")
    if table is None:
        logits = h @ p["tokens"].astype(dtype).T
    else:
        logits = h @ table.astype(dtype)
    # Mask the padded vocab tail out of the partition function.
    iota = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[-1],), 0)
    return jnp.where(iota < true_vocab, logits, -1e9)


def softmax_xent(logits, labels):
    """Mean cross-entropy in fp32. labels: int32 same leading shape."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
