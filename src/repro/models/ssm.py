"""Mamba-2 blocks via SSD (state-space duality) — arXiv:2405.21060.

Training/prefill runs the chunked SSD algorithm: the sequence is split into
chunks; within a chunk the recurrence is computed in its quadratic "dual"
attention form (MXU-friendly), and a [H, P, N] state is passed between
chunks with a sequential lax.scan. Decode is the O(1) recurrent update.

Shapes: x [B, S, H, P] (H heads × P head_dim = d_inner), B/C [B, S, G, N]
(G groups broadcast over heads), dt [B, S, H], A [H] (negative).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P as ParamP, dense_init, zeros_init, ones_init


# ---------------------------------------------------------------------------
# Core SSD scan (chunked)
# ---------------------------------------------------------------------------

def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1..i] (−inf j>i)."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int = 256, init_state=None):
    """Returns (y [B,S,H,P], final_state [B,H,P,N]).

    All computation in fp32 internally for the cumulative sums.
    """
    b, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    S0 = S
    pad = (-S) % chunk
    if pad:
        # dt=0 padding is exact: a = dt·A = 0 ⇒ decay 1 (state preserved),
        # x·dt = 0 ⇒ nothing injected; padded outputs are sliced away.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // chunk
    rep = H // G

    xf = x.astype(jnp.float32)
    a = (dt.astype(jnp.float32) * A.astype(jnp.float32))       # [B,S,H] (<0)
    xdt = xf * dt.astype(jnp.float32)[..., None]               # fold dt into x
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)       # [B,S,H,N]
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc, ac, Bc, Cc = map(to_chunks, (xdt, a, Bf, Cf))

    def per_chunk(xk, ak, Bk, Ck, state):
        # ak: [B,L,H] → cumulative decay within chunk
        acs = jnp.cumsum(ak, axis=1)                           # [B,L,H]
        # Intra-chunk (dual quadratic form):
        Lmat = jnp.exp(_segsum(ak.transpose(0, 2, 1)))         # [B,H,L,L]
        scores = jnp.einsum("blhn,bshn->bhls", Ck, Bk) * Lmat
        y_intra = jnp.einsum("bhls,bshp->blhp", scores, xk)
        # Inter-chunk: contribution of the carried state.
        y_inter = jnp.einsum("blhn,bhpn,blh->blhp", Ck, state,
                             jnp.exp(acs))
        # New state: decay old + inject this chunk.
        decay_tail = jnp.exp(acs[:, -1:, :] - acs)             # [B,L,H]
        state_new = (state * jnp.exp(acs[:, -1, :])[..., None, None]
                     + jnp.einsum("blhn,blhp,blh->bhpn", Bk, xk, decay_tail))
        return y_intra + y_inter, state_new

    def body(state, inp):
        xk, ak, Bk, Ck = inp
        y, state = per_chunk(xk, ak, Bk, Ck, state)
        return state, y

    # Carry seeded from x (data dependence) so SPMD keeps it batch-sharded.
    state0 = (xf[:, 0, :, :, None] * 0.0 + jnp.zeros((N,), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))
    xs = (xc.transpose(1, 0, 2, 3, 4), ac.transpose(1, 0, 2, 3),
          Bc.transpose(1, 0, 2, 3, 4), Cc.transpose(1, 0, 2, 3, 4))
    final, ys = jax.lax.scan(body, state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, S, H, Pd)[:, :S0]
    return y.astype(x.dtype), final.astype(x.dtype)


def ssd_step(x, dt, A, Bm, Cm, state):
    """O(1) decode: x [B,1,H,P], state [B,H,P,N] → (y, new_state)."""
    rep = state.shape[1] // Bm.shape[2]
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)[:, 0]  # [B,H,N]
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)[:, 0]
    a = jnp.exp(dt.astype(jnp.float32)[:, 0] * A.astype(jnp.float32))  # [B,H]
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])[:, 0]
    state_new = (state.astype(jnp.float32) * a[..., None, None]
                 + jnp.einsum("bhn,bhp->bhpn", Bf, xdt))
    y = jnp.einsum("bhn,bhpn->bhp", Cf, state_new)
    return y[:, None].astype(x.dtype), state_new.astype(state.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 block (in_proj → conv → SSD → gate → out_proj)
# ---------------------------------------------------------------------------

def block_init(key, d_model, *, d_inner, head_dim, n_groups, d_state,
               d_conv=4, dtype=jnp.float32):
    H = d_inner // head_dim
    ks = jax.random.split(key, 5)
    conv_dim = d_inner + 2 * n_groups * d_state
    return dict(
        in_proj=dense_init(ks[0],
                           (d_model, 2 * d_inner + 2 * n_groups * d_state + H),
                           ("embed", "mlp"), dtype),
        conv_w=zeros_init((d_conv, conv_dim), ("conv", "mlp"), dtype),
        conv_b=zeros_init((conv_dim,), ("mlp",), dtype),
        A_log=zeros_init((H,), ("heads_nosplit",), jnp.float32),
        D=ones_init((H,), ("heads_nosplit",), jnp.float32),
        dt_bias=zeros_init((H,), ("heads_nosplit",), jnp.float32),
        norm_scale=zeros_init((d_inner,), ("mlp",), dtype),
        out_proj=dense_init(ks[1], (d_inner, d_model), ("mlp", "embed"),
                            dtype, fan_in=d_inner),
    )


def _causal_conv(u, w, b, state=None):
    """Depthwise causal conv, width d_conv. u: [B, S, C]; w: [d_conv, C].

    state: [B, d_conv-1, C] trailing context for decode. Returns (y, new
    state of the last d_conv-1 inputs)."""
    d_conv = w.shape[0]
    if state is None:
        u_pad = jnp.pad(u, ((0, 0), (d_conv - 1, 0), (0, 0)))
    else:
        u_pad = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    y = sum(u_pad[:, i:i + u.shape[1], :] * w[i] for i in range(d_conv))
    new_state = u_pad[:, -(d_conv - 1):, :]
    return jax.nn.silu(y + b), new_state


def block_apply(x, p, cfg, mode="train", cache=None, chunk=256):
    """cfg: object with d_inner, ssm_head_dim, ssm_groups, ssm_state.
    mode: train (no cache out) | prefill (returns final state as cache) |
    decode (cache: dict(conv=[B,3,C], state=[B,H,P,N]), O(1) update)."""
    d_inner = cfg.d_inner
    Pd, G, N = cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    H = d_inner // Pd
    Bsz, S, _ = x.shape

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * G * N], axis=-1)
    conv_state = None if mode != "decode" else cache["conv"]
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype), conv_state)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(Bsz, S, H, Pd)
    Bm = Bm.reshape(Bsz, S, G, N)
    Cm = Cm.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if mode == "decode":
        y, ssm_state = ssd_step(xs, dt, A, Bm, Cm, cache["state"])
        new_cache = dict(conv=conv_state, state=ssm_state)
    else:
        y, final = ssd_chunked(xs, dt, A, Bm, Cm, chunk=min(chunk, S))
        new_cache = (dict(conv=conv_state, state=final)
                     if mode == "prefill" else None)

    y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, d_inner)
    # Gated RMSNorm (Mamba-2 norm-before-out_proj).
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + p["norm_scale"].astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype), new_cache
