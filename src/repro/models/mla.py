"""Multi-head Latent Attention (DeepSeek-V2, MiniCPM3).

KV is compressed into a low-rank latent c_kv (kv_lora_rank) plus one shared
RoPE key head (d_rope). Train/prefill expands to full K/V and reuses the
blocked flash attention. Decode uses the *absorbed* form: the up-projection
W^UK folds into the query and W^UV into the output, so the decode cache is
only [B, S, kv_lora + d_rope] — the property that makes DeepSeek-V2's 32k
decode cheap (and its checkpoint migration in WaterWise terms light).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, common
from repro.models.common import dense_init, norm_init, apply_norm


def init(key, d_model, n_heads, *, q_lora, kv_lora, d_nope, d_rope, d_v,
         dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    p = dict(
        wkv_a=dense_init(ks[0], (d_model, kv_lora + d_rope),
                         ("embed", "mla_latent"), dtype),
        kv_norm=norm_init(kv_lora, "rmsnorm", dtype),
        wkv_b_k=dense_init(ks[1], (kv_lora, n_heads, d_nope),
                           ("mla_latent", "heads", "head_dim"), dtype),
        wkv_b_v=dense_init(ks[2], (kv_lora, n_heads, d_v),
                           ("mla_latent", "heads", "head_dim"), dtype),
        wo=dense_init(ks[3], (n_heads, d_v, d_model),
                      ("heads", "head_dim", "embed"), dtype,
                      fan_in=n_heads * d_v),
    )
    if q_lora:
        p["wq_a"] = dense_init(ks[4], (d_model, q_lora),
                               ("embed", "mla_latent"), dtype)
        p["q_norm"] = norm_init(q_lora, "rmsnorm", dtype)
        p["wq_b"] = dense_init(ks[5], (q_lora, n_heads, d_nope + d_rope),
                               ("mla_latent", "heads", "head_dim"), dtype)
    else:
        p["wq"] = dense_init(ks[4], (d_model, n_heads, d_nope + d_rope),
                             ("embed", "heads", "head_dim"), dtype)
    return p


def _queries(x, p, d_nope, d_rope, positions):
    if "wq_a" in p:
        cq = apply_norm(x @ p["wq_a"].astype(x.dtype), p["q_norm"], "rmsnorm")
        q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    q_rope = common.apply_rope(q_rope, positions)
    return q_nope, q_rope


def _latent(x, p, kv_lora, positions):
    ckr = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_rope = ckr[..., :kv_lora], ckr[..., kv_lora:]
    c_kv = apply_norm(c_kv, p["kv_norm"], "rmsnorm")
    k_rope = common.apply_rope(k_rope[:, :, None, :], positions)[:, :, 0]
    return c_kv, k_rope


def apply(x, p, *, n_heads, q_lora, kv_lora, d_nope, d_rope, d_v,
          positions, block_kv=1024, cache=None, decode_pos=None):
    """Returns (out, new_cache). Cache = (c_kv [B,S,kv_lora],
    k_rope [B,S,d_rope])."""
    B, Sq, _ = x.shape
    scale = 1.0 / np.sqrt(d_nope + d_rope)
    q_nope, q_rope = _queries(x, p, d_nope, d_rope, positions)

    if cache is None:
        c_kv, k_rope = _latent(x, p, kv_lora, positions)
        # Expand to per-head K/V, run blocked flash attention (MHA: Kh=H,G=1).
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv,
                            p["wkv_b_k"].astype(x.dtype))
        v = jnp.einsum("bsl,lhv->bshv", c_kv, p["wkv_b_v"].astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, Sq, n_heads, d_rope))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attention.blocked_attention(
            q[:, :, :, None, :], k, v, positions, positions, kind="causal",
            block_kv=block_kv, softmax_scale=scale)[:, :, :, 0]
        new_cache = None
    else:
        cc, cr = cache
        c_new, r_new = _latent(x, p, kv_lora, positions)
        cc = jax.lax.dynamic_update_slice_in_dim(
            cc, c_new.astype(cc.dtype), decode_pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cr, r_new.astype(cr.dtype), decode_pos, axis=1)
        # Absorbed attention over the compressed cache.
        q_lat = jnp.einsum("bshk,lhk->bshl", q_nope,
                           p["wkv_b_k"].astype(x.dtype))   # [B,1,H,kv_lora]
        s = (jnp.einsum("bshl,btl->bhst", q_lat, cc.astype(x.dtype))
             + jnp.einsum("bshk,btk->bhst", q_rope, cr.astype(x.dtype)))
        s = (s * scale).astype(jnp.float32)
        kv_pos = jax.lax.broadcasted_iota(jnp.int32, (cc.shape[1],), 0)
        s = jnp.where(kv_pos[None, None, None, :] <= decode_pos, s,
                      attention.NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btl->bshl", w, cc.astype(x.dtype))
        out = jnp.einsum("bshl,lhv->bshv", o_lat,
                         p["wkv_b_v"].astype(x.dtype))
        new_cache = (cc, cr)

    return jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype)), new_cache
