"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The real-gated linear recurrent unit:

    r_t = σ(W_a x_t + b_a)           recurrence gate
    i_t = σ(W_x x_t + b_x)           input gate
    a_t = exp(−c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill parallelizes the linear recurrence with an associative
scan over (a, b) pairs; decode is the O(1) update. The full residual block
is conv1d(4) → RG-LRU, with a linear in/out projection pair (Griffin's
"recurrent block").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, zeros_init
from repro.models.ssm import _causal_conv

_C = 8.0  # Griffin's fixed constant


def block_init(key, d_model, *, lru_width, d_conv=4, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return dict(
        in_x=dense_init(ks[0], (d_model, lru_width), ("embed", "mlp"), dtype),
        in_gate=dense_init(ks[1], (d_model, lru_width), ("embed", "mlp"),
                           dtype),
        conv_w=zeros_init((d_conv, lru_width), ("conv", "mlp"), dtype),
        conv_b=zeros_init((lru_width,), ("mlp",), dtype),
        w_a=dense_init(ks[2], (lru_width, lru_width), ("mlp", "mlp_in"),
                       dtype, fan_in=lru_width),
        b_a=zeros_init((lru_width,), ("mlp",), dtype),
        w_x=dense_init(ks[3], (lru_width, lru_width), ("mlp", "mlp_in"),
                       dtype, fan_in=lru_width),
        b_x=zeros_init((lru_width,), ("mlp",), dtype),
        lam=zeros_init((lru_width,), ("mlp",), jnp.float32),
        out=dense_init(ks[4], (lru_width, d_model), ("mlp", "embed"), dtype,
                       fan_in=lru_width),
    )


def _gates(x, p):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32)
                       + p["b_x"].astype(jnp.float32))
    # softplus(Λ) with Λ initialized so a ∈ (0.9, 0.999) at r=1.
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xf)
    return a, gated_x


def rglru_scan(x, p, h0=None):
    """x: [B, S, W] → (y, h_final). Associative scan over the recurrence."""
    a, bx = _gates(x, p)

    if h0 is not None:
        # Fold the carried state in as a virtual step 0.
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        bx = jnp.concatenate([h0.astype(jnp.float32)[:, None], bx], axis=1)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    ya, yb = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = yb
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1]


def rglru_step(x, p, h):
    """x: [B, 1, W], h: [B, W] → (y [B,1,W], h_new)."""
    a, bx = _gates(x, p)
    h_new = a[:, 0] * h.astype(jnp.float32) + bx[:, 0]
    return h_new[:, None].astype(x.dtype), h_new.astype(h.dtype)


def block_apply(x, p, mode="train", cache=None):
    """Griffin recurrent block. mode: train | prefill | decode."""
    gate = jax.nn.gelu(x @ p["in_gate"].astype(x.dtype))
    u = x @ p["in_x"].astype(x.dtype)
    conv_state = None if mode != "decode" else cache["conv"]
    u, conv_state = _causal_conv(u, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    if mode == "decode":
        y, h = rglru_step(u, p, cache["state"])
        new_cache = dict(conv=conv_state, state=h)
    else:
        y, h = rglru_scan(u, p)
        new_cache = (dict(conv=conv_state, state=h.astype(x.dtype))
                     if mode == "prefill" else None)
    return (y * gate) @ p["out"].astype(x.dtype), new_cache
