"""Expert-parallel Mixture-of-Experts (DBRX, DeepSeek-V2).

Top-k softmax router + sort-based capacity dispatch:

  1. router scores [T, E] → top-k (expert ids, gate weights) per token;
  2. the T·k assignments are sorted by expert id; each assignment's rank
     within its expert segment is its capacity slot;
  3. tokens scatter into an [E, C, d] buffer (slot ≥ C drops — weights are
     renormalized so dropped experts don't leak probability mass);
  4. batched per-expert GEMMs [E, C, d]×[E, d, f] run with E sharded over
     the "model"/"expert" mesh axis (expert parallelism — the scatter/gather
     around them is where XLA inserts the all-to-all traffic);
  5. results scatter back and combine with gate weights.

This is the index-based (no [T, E, C] one-hot) formulation — the only one
whose memory survives T = 65k tokens/shard with E = 160 experts.
DeepSeek-V2 additionally has ``n_shared`` always-on experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import dense_init


def init(key, d_model, d_ff, n_experts, *, n_shared=0, shared_d_ff=None,
         dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    p = dict(
        router=dense_init(ks[0], (d_model, n_experts), ("embed", "experts"),
                          dtype),
        wi=dense_init(ks[1], (n_experts, d_model, d_ff),
                      ("experts", "embed", "mlp"), dtype),
        wg=dense_init(ks[2], (n_experts, d_model, d_ff),
                      ("experts", "embed", "mlp"), dtype),
        wo=dense_init(ks[3], (n_experts, d_ff, d_model),
                      ("experts", "mlp", "embed"), dtype, fan_in=d_ff),
    )
    if n_shared:
        p["shared"] = common.mlp_init(ks[4], d_model,
                                      shared_d_ff or d_ff * n_shared, dtype)
    return p


def apply(x, p, *, top_k, n_experts, capacity_factor=1.25,
          router_dtype=jnp.float32):
    """x: [B, S, d] → [B, S, d]. Router runs in fp32 (standard practice).

    Dispatch is vmapped over the batch row: sort/scatter/gather become
    *batched* ops, which SPMD shards along the (data-parallel) batch axis —
    a global-token argsort would instead force an all-gather of every
    token onto every device (measured: 7.5 GiB/device buffers on
    deepseek-v2). Capacity is therefore per (row, expert):
    C = ceil(S·k/E · cf), the same expected load as global dispatch.
    """
    B, S, d = x.shape
    capacity = max(int(S * top_k / n_experts * capacity_factor), 1)
    A = S * top_k                                            # assignments/row

    def route_row(xt):
        """xt: [S, d] → (buf [E, C, d], combine metadata)."""
        logits = (xt.astype(router_dtype)
                  @ p["router"].astype(router_dtype))        # [S, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gate, ids = jax.lax.top_k(probs, top_k)              # [S, k]
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        flat_ids = ids.reshape(-1)                           # [A]
        sort_idx = jnp.argsort(flat_ids)
        sorted_ids = flat_ids[sort_idx]
        seg_starts = jnp.searchsorted(sorted_ids, jnp.arange(n_experts))
        slot = jnp.arange(A) - seg_starts[sorted_ids]
        keep = slot < capacity
        token_of = sort_idx // top_k

        buf = jnp.zeros((n_experts, capacity, d), xt.dtype)
        buf = buf.at[jnp.where(keep, sorted_ids, 0),
                     jnp.where(keep, slot, 0)].add(
            jnp.where(keep[:, None], xt[token_of], 0.0))
        return buf, (gate, sort_idx, sorted_ids, slot, keep, token_of)

    buf, meta = jax.vmap(route_row)(x)                       # [B, E, C, d]

    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(x.dtype))
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * g,
                   p["wo"].astype(x.dtype))                  # [B, E, C, d]

    def combine_row(y_row, xt, m):
        gate, sort_idx, sorted_ids, slot, keep, token_of = m
        out_sorted = y_row[jnp.where(keep, sorted_ids, 0),
                           jnp.where(keep, slot, 0)]
        out_sorted = jnp.where(keep[:, None], out_sorted, 0.0)
        gate_sorted = gate.reshape(-1)[sort_idx]
        contrib = out_sorted * gate_sorted[:, None].astype(xt.dtype)
        return (xt * 0).at[token_of].add(contrib)

    out = jax.vmap(combine_row)(y, x, meta)

    if "shared" in p:
        out = out + common.mlp_apply(x, p["shared"])
    return out


def aux_load_balance_loss(logits, ids, n_experts, top_k):
    """Switch-style auxiliary load-balancing loss (used in training)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=0)                                  # [E]
    one_hot = jax.nn.one_hot(ids, n_experts).sum(1)          # [T, E]
    ce = one_hot.mean(axis=0) / top_k
    return n_experts * jnp.sum(me * ce)
