"""Workload-side model zoo: the ten assigned architectures in pure JAX.

Every architecture is expressed through one ``ModelConfig`` (configs/base.py)
and assembled by ``transformer.py`` from family building blocks:

  attention.py   blocked (flash-style) GQA/MQA attention: causal, sliding-
                 window, bidirectional, cross; decode with sharded KV caches
  mla.py         Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3) with
                 compressed-KV decode caches
  moe.py         expert-parallel MoE (top-k router, sort-based dispatch)
  ssm.py         Mamba-2 SSD blocks (chunked state-passing scan + O(1) decode)
  rglru.py       RG-LRU recurrent blocks (RecurrentGemma)
  model.py       the Model facade: init/specs, train_loss, prefill, decode
"""
from repro.models.model import Model
