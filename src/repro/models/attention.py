"""Blocked (flash-style) multi-head attention in pure JAX.

Memory-safe at 32k+ sequence lengths: scores are never materialized at
[Sq, Skv] — the KV axis is processed in blocks under an online-softmax
running maximum (exactly the recurrence the Pallas kernel in
``kernels/flash_attention`` implements for TPU; this jnp version is both the
oracle for that kernel and the path XLA partitions for the dry-run).

Supports GQA/MQA (grouped query heads), causal / sliding-window /
bidirectional masking, cross-attention, and single-token decode against a
sharded KV cache.

Shapes (canonical): q [B, Sq, Kh, G, D]; k, v [B, Skv, Kh, D] where
Kh = kv heads, G = query-group fan-out (n_heads = Kh·G).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common
from repro.models.common import P, dense_init, zeros_init

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init(key, d_model, n_heads, n_kv, head_dim, *, qkv_bias=False,
         dtype=jnp.float32, kv_input_dim=None):
    """QKV + output projections. ``kv_input_dim`` ≠ None → cross-attention
    (K/V read from the other stream)."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    kv_in = kv_input_dim or d_model
    p = dict(
        wq=dense_init(kq, (d_model, n_heads, head_dim),
                      ("embed", "heads", "head_dim"), dtype),
        wk=dense_init(kk, (kv_in, n_kv, head_dim),
                      ("embed", "kv_heads", "head_dim"), dtype),
        wv=dense_init(kv, (kv_in, n_kv, head_dim),
                      ("embed", "kv_heads", "head_dim"), dtype),
        wo=dense_init(ko, (n_heads, head_dim, d_model),
                      ("heads", "head_dim", "embed"), dtype,
                      fan_in=n_heads * head_dim),
    )
    if qkv_bias:
        p["bq"] = zeros_init((n_heads, head_dim), ("heads", "head_dim"), dtype)
        p["bk"] = zeros_init((n_kv, head_dim), ("kv_heads", "head_dim"), dtype)
        p["bv"] = zeros_init((n_kv, head_dim), ("kv_heads", "head_dim"), dtype)
    return p


def project_q(x, p, rope_theta, positions):
    """``rope_theta=None`` disables RoPE (the theta value itself may be a
    traced per-layer array, e.g. gemma3's dual base)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    if rope_theta is not None:
        q = common.apply_rope(q, positions, rope_theta)
    return q


def project_kv(x, p, rope_theta, positions):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if rope_theta is not None:
        k = common.apply_rope(k, positions, rope_theta)
    return k, v


def project_out(o, p):
    # o: [B, Sq, H, D]
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def mask_bias(q_pos, kv_pos, kind: str, window: int):
    """Additive mask bias [Sq, bk] from position vectors."""
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    valid = kp >= 0                                   # KV padding
    if kind == "causal":
        valid &= kp <= qp
    elif kind == "sliding":
        valid &= (kp <= qp) & (qp - kp < window)
    elif kind == "full":
        pass
    else:
        raise ValueError(kind)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Blocked attention (train / prefill)
# ---------------------------------------------------------------------------

def blocked_attention(q, k, v, q_pos, kv_pos, *, kind="causal", window=0,
                      block_kv=1024, softmax_scale=None):
    """Online-softmax attention, KV visited in blocks.

    q: [B, Sq, Kh, G, D]; k, v: [B, Skv, Kh, D]. Returns [B, Sq, Kh, G, D].
    """
    B, Sq, Kh, G, D = q.shape
    Skv, Dv = k.shape[1], v.shape[-1]     # Dv may differ from D (MLA)
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    bk = min(block_kv, Skv)
    nblk = int(np.ceil(Skv / bk))
    pad = nblk * bk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)

    kb = k.reshape(B, nblk, bk, Kh, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, bk, Kh, Dv).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(nblk, bk)

    qf = (q * scale).astype(jnp.float32)

    @jax.checkpoint
    def body(carry, blk):
        # Rematerialized: backward recomputes each block's scores instead of
        # saving [Sq, bk] s/p for every block — the flash-attention backward
        # memory profile (residuals per layer stay O(Sq·D), not O(Sq·Skv)).
        acc, m, l = carry
        kc, vc, pc = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc.astype(jnp.float32))
        s = s + mask_bias(q_pos, pc, kind, window)[None, None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p_ = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p_.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p_, vc.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    # Seed the scan carry FROM q (data dependence), not jnp.zeros: SPMD
    # propagation otherwise replicates the loop carry across the batch
    # sharding, blowing per-device memory by the data-parallel factor.
    qT = qf.transpose(0, 2, 3, 1, 4)                        # [B,Kh,G,Sq,D]
    seed = qT[..., :1] * 0.0                                # [B,Kh,G,Sq,1]
    acc0 = seed + jnp.zeros((Dv,), jnp.float32)
    m0 = seed[..., 0] + NEG_INF
    l0 = seed[..., 0]
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)   # [B,Sq,Kh,G,D]


# ---------------------------------------------------------------------------
# Decode attention (single query position against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, cache_k, cache_v, pos, *, kind="causal", window=0,
                     softmax_scale=None):
    """q: [B, 1, Kh, G, D]; cache_k/v: [B, Smax, Kh, D]; pos: scalar int —
    the position being generated. The cache already contains this token's
    own K/V at index ``pos`` (self-attention includes itself). ``full`` kind
    (cross-attention) attends the whole cache."""
    B, _, Kh, G, D = q.shape
    Smax = cache_k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    kv_pos = jax.lax.broadcasted_iota(jnp.int32, (Smax,), 0)
    if kind == "full":
        valid = jnp.ones((Smax,), bool)
    else:
        valid = kv_pos <= pos
        if kind == "sliding":      # window may be a traced per-layer value
            valid &= kv_pos > pos - window
    s = jnp.einsum("bqhgd,bkhd->bhgqk", (q * scale).astype(jnp.float32),
                   cache_k.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p_ = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p_, cache_v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def update_cache(cache_k, cache_v, k_new, v_new, pos):
    """Insert [B, 1, Kh, D] new KV at position ``pos``."""
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(
        cache_k.dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(
        cache_v.dtype), pos, axis=1)
    return ck, cv


# ---------------------------------------------------------------------------
# Full module forward (used by transformer.py)
# ---------------------------------------------------------------------------

def apply(x, p, *, n_kv, n_heads, positions, kind="causal", window=0,
          rope_theta=10000.0, block_kv=1024, kv_x=None, kv_positions=None,
          softmax_scale=None, cache=None, decode_pos=None):
    """One attention sub-layer.

    Train/prefill (cache=None): blocked attention; ``kv_x`` ≠ None makes it
    cross-attention (kind should be "full").
    Decode (cache=(k, v), decode_pos set): x is [B, 1, d]. Self-attention
    writes this token's K/V at ``decode_pos`` then attends [0, decode_pos];
    cross-attention (kind="full") attends the static (encoder/image) cache
    without writing.
    Returns (out, new_cache_or_None).
    """
    G = n_heads // n_kv
    q = project_q(x, p, rope_theta, positions)
    B, Sq = q.shape[:2]
    q = q.reshape(B, Sq, n_kv, G, -1)

    if cache is None:
        src = x if kv_x is None else kv_x
        kv_pos = positions if kv_positions is None else kv_positions
        k, v = project_kv(src, p, rope_theta, kv_pos)
        out = blocked_attention(q, k, v, positions, kv_pos, kind=kind,
                                window=window, block_kv=block_kv,
                                softmax_scale=softmax_scale)
        new_cache = None
    else:
        ck, cv = cache
        if kind != "full":          # self-attention: write this token's KV
            k, v = project_kv(x, p, rope_theta, positions)
            ck, cv = update_cache(ck, cv, k, v, decode_pos)
        out = decode_attention(q, ck, cv, decode_pos, kind=kind,
                               window=window, softmax_scale=softmax_scale)
        new_cache = (ck, cv)

    out = out.reshape(B, Sq, n_heads, -1)
    return project_out(out, p), new_cache
