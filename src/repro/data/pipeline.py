"""Deterministic, shard-aware synthetic token pipeline.

Step-indexed PRNG: batch ``i`` is a pure function of (seed, i), so a
restarted/migrated job resumes mid-stream with no pipeline state to
checkpoint — the property WaterWise's checkpoint-migration relies on.
Tokens follow a Zipfian unigram draw so the loss curve is non-trivial
(not uniform noise).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def _unigram_logits(self) -> np.ndarray:
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** self.zipf_a
        return np.log(p / p.sum())

    def batch(self, step: int, extras: Optional[Dict] = None) -> Dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        logits = jnp.asarray(self._unigram_logits(), jnp.float32)
        toks = jax.random.categorical(
            key, logits, shape=(self.global_batch, self.seq_len + 1))
        out = dict(tokens=toks[:, :-1].astype(jnp.int32),
                   labels=toks[:, 1:].astype(jnp.int32))
        if extras:
            out.update(extras)
        return out


def make_batch_iterator(vocab: int, seq_len: int, global_batch: int,
                        seed: int = 0, start_step: int = 0,
                        extras: Optional[Dict] = None) -> Iterator[Dict]:
    src = SyntheticTokens(vocab, seq_len, global_batch, seed)
    step = start_step
    while True:
        yield src.batch(step, extras)
        step += 1
