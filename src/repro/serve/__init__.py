"""repro.serve: the always-on streaming scheduler service.

Batch replay answers "what would this policy have done over that trace";
a *service* must answer it continuously: arrivals stream in, each decision
round has a wall-clock budget, the admission buffer is bounded, and held
jobs are re-planned as forecasts refresh. This package is that seam over
the same engine and policies:

* ``arrivals``  — pull-based ``ArrivalSource`` streams (trace replay,
                  endless Poisson-burst, JSONL file tail) and the bounded
                  ``AdmissionQueue`` with explicit shed accounting;
* ``loop``      — the ``DecisionLoop`` driving ``EngineStepper`` rounds
                  (inject → step-to-boundary) with round-latency metrics,
                  and the ``ServeReport``.

Receding-horizon re-planning and the Sinkhorn warm-start carry live in
the *policy* (``waterwise-forecast[replan=true,warm=true]``) — the loop
just drives rounds; see ``policy.ReplanQueueDeferral`` and
``core.round.SinkhornWarmStart``. Entry points: ``examples/serve_stream.py``
and ``python -m benchmarks.serve_bench``.
"""
from repro.serve.arrivals import (DROP_OLDEST, REJECT_NEW, AdmissionQueue,
                                  ArrivalSource, FileTailArrivals,
                                  PoissonBurstArrivals, ReplayArrivals)
from repro.serve.loop import DecisionLoop, ServeConfig, ServeReport

__all__ = [
    "ArrivalSource", "ReplayArrivals", "PoissonBurstArrivals",
    "FileTailArrivals", "AdmissionQueue", "REJECT_NEW", "DROP_OLDEST",
    "DecisionLoop", "ServeConfig", "ServeReport",
]
