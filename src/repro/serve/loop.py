"""The always-on decision loop: bounded-latency rounds over the stepable
engine, receding-horizon re-planning, and the service report.

Structure of one round at boundary ``t_k`` (simulated time):

  1. ``source.poll(t_k)``      — arrivals of the last round period;
  2. ``admission.offer(...)``  — bounded buffering, explicit shed;
  3. ``admission.take(...)``   — up to ``max_round_jobs`` enter the engine;
  4. ``stepper.inject(...)``   — arrivals join the un-consumed trace tail;
  5. ``stepper.step(t_k)``     — the engine advances to the boundary,
                                 scheduling rounds firing on its own grid.

Because ``EngineStepper.step`` uses the chained-handoff ``stop_at``
semantics (proven bit-exact by the sharded-execution tests), a
``DecisionLoop`` over ``ReplayArrivals`` with no admission bound pressure
reproduces ``EventSimulator.run`` of the same trace *bit for bit* — batch
replay and live serving are one engine (pinned in tests/test_serve.py).

Wall-clock round latency is measured around step 5 (pricing + Sinkhorn +
extraction all live there) and fed to a ``runtime.StepWatchdog``; rounds
over ``round_budget_s`` count as budget overruns. The Sinkhorn warm-start
carry (``core.round.SinkhornWarmStart``) lives inside the scheduler
pipeline (``waterwise-forecast[warm=true]``) and is surfaced per-service
in the report as cold vs warm iterations-to-converge.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

import repro.obs as obs
from repro.runtime.elastic import StepWatchdog
from repro.serve.arrivals import REJECT_NEW, AdmissionQueue, ArrivalSource
from repro.sim.engine import EventSimulator


@dataclasses.dataclass
class ServeConfig:
    """Decision-loop knobs (simulated-time cadence, wall-time budget)."""
    round_s: float = 30.0            # decision-round period (simulated)
    queue_bound: int = 10_000        # admission buffer bound
    shed_policy: str = REJECT_NEW    # who pays when the bound binds
    max_round_jobs: Optional[int] = None   # per-round injection cap
    round_budget_s: Optional[float] = None # wall-clock budget per round


def _pctl(values: List[float], q: float) -> float:
    return float(np.percentile(values, q)) if values else 0.0


@dataclasses.dataclass
class ServeReport:
    """What the service did — stream accounting + footprint + latency."""
    duration_s: float
    rounds: int                      # decision-loop rounds (boundaries)
    engine_rounds: int               # scheduler rounds the engine fired
    jobs_in: int                     # arrivals pulled from the source
    admitted: int
    shed: int
    placed: int
    violations: int                  # placed jobs over tolerance
    deadline_misses: int             # violations + shed (shed = missed)
    carbon_kg: float
    water_kl: float
    mean_defer_s: float
    replans: int
    budget_overruns: int             # rounds over the wall-clock budget
    p50_round_ms: float
    p99_round_ms: float
    max_admission_depth: int
    max_engine_depth: int
    sinkhorn_cold_iters: float       # mean iterations, cold starts
    sinkhorn_warm_iters: float       # mean iterations, warm starts
    utilization: float

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class DecisionLoop:
    """Drive scheduler + engine against an arrival stream (module doc)."""

    def __init__(self, sim: EventSimulator, scheduler,
                 source: ArrivalSource,
                 config: Optional[ServeConfig] = None):
        self.sim = sim
        self.cfg = config or ServeConfig()
        self.source = source
        self.stepper = sim.stepper(scheduler)
        self.admission = AdmissionQueue(self.cfg.queue_bound,
                                        self.cfg.shed_policy)
        self.watchdog = StepWatchdog(self.cfg.round_budget_s
                                     if self.cfg.round_budget_s is not None
                                     else float("inf"))
        self.budget_overruns = 0
        self.rounds = 0
        self.max_engine_depth = 0

    def run_round(self, t_k: float) -> float:
        """One decision round up to boundary ``t_k``; returns the wall
        seconds the engine step took."""
        cfg = self.cfg
        arrivals = self.source.poll(t_k)
        with obs.span("serve.round", boundary_s=t_k,
                      arrivals=len(arrivals)) as sp:
            self.admission.offer(arrivals, self.stepper.now)
            batch = self.admission.take(cfg.max_round_jobs)
            self.stepper.inject(batch)
            t0 = time.perf_counter()
            self.stepper.step(t_k)
            wall = time.perf_counter() - t0
            if self.watchdog.observe(wall):
                self.budget_overruns += 1
                obs.counter("serve.budget_overrun")
            depth = len(self.stepper.pending)
            self.max_engine_depth = max(self.max_engine_depth, depth)
            if obs.enabled():
                obs.observe("serve.round_wall_ms", wall * 1e3)
                obs.gauge("serve.engine_depth", float(depth))
            sp.set(injected=len(batch), wall_ms=round(wall * 1e3, 3),
                   engine_depth=depth)
        self.rounds += 1
        return wall

    def run(self, duration_s: float, drain: bool = True) -> ServeReport:
        """Serve for ``duration_s`` of simulated time (then drain)."""
        cfg = self.cfg
        k = 1
        while (k - 1) * cfg.round_s < duration_s:
            self.run_round(min(k * cfg.round_s, duration_s))
            k += 1
        if drain:
            # Horizon end: whatever the admission buffer still holds enters
            # the engine, and the engine runs to empty.
            self.stepper.inject(self.admission.take())
            t0 = time.perf_counter()
            self.stepper.step(None)
            self.watchdog.observe(time.perf_counter() - t0)
        return self.report(duration_s)

    def report(self, duration_s: float) -> ServeReport:
        res = self.stepper.result()
        rec = res["records"]
        violations = sum(1 for r in rec if r.violated)
        sched = self.stepper.scheduler
        cold = getattr(sched, "sinkhorn_cold_iters", None) or []
        warm = getattr(sched, "sinkhorn_warm_iters", None) or []
        wall_ms = [w * 1e3 for w in self.watchdog.history]
        return ServeReport(
            duration_s=float(duration_s),
            rounds=self.rounds,
            engine_rounds=int(res["rounds"]),
            jobs_in=self.admission.offered,
            admitted=self.admission.admitted,
            shed=self.admission.shed,
            placed=len(rec),
            violations=violations,
            deadline_misses=violations + self.admission.shed,
            carbon_kg=float(sum(r.carbon_g for r in rec)) / 1e3,
            water_kl=float(sum(r.water_l for r in rec)) / 1e3,
            mean_defer_s=float(getattr(sched, "mean_defer_s", 0.0)),
            replans=int(getattr(sched, "replans", 0)),
            budget_overruns=self.budget_overruns,
            p50_round_ms=_pctl(wall_ms, 50),
            p99_round_ms=_pctl(wall_ms, 99),
            max_admission_depth=self.admission.peak_depth,
            max_engine_depth=self.max_engine_depth,
            sinkhorn_cold_iters=float(np.mean(cold)) if cold else 0.0,
            sinkhorn_warm_iters=float(np.mean(warm)) if warm else 0.0,
            utilization=float(res["utilization"]))
