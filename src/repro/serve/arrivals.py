"""Arrival streams + bounded admission for the always-on scheduler service.

Batch replay hands the engine the whole trace up front; a *service* sees
jobs only as they arrive. An ``ArrivalSource`` is the pull side of that
stream: ``poll(until_s)`` returns every job that has arrived strictly
before ``until_s`` (simulated time) and not been returned yet, in submit
order — the decision loop polls once per round boundary and injects the
chunk into the stepable engine. Three sources cover the serving regimes:

* ``ReplayArrivals``   — an in-memory trace replayed as a stream (the
                         batch-parity reference: chunked polling must be
                         bit-identical to handing the engine the list);
* ``PoissonBurstArrivals`` — endless synthetic load, lazily generated in
                         hourly chunks with the same diurnal × burst-train
                         modulation as ``sim.trace`` (storm testing);
* ``FileTailArrivals`` — tails a JSONL file, consuming complete lines
                         only (the live ingestion seam).

Between the stream and the engine sits the ``AdmissionQueue``: a *bounded*
buffer with an explicit shed policy. Under a burst storm the service must
choose — queue without bound (latency collapse), or shed with accounting.
Shedding is never silent: every shed job is counted, listed, and folded
into the service report as a deadline miss.
"""
from __future__ import annotations

import json
from typing import List, Optional, Sequence

import numpy as np

import repro.obs as obs
from repro.core.problem import Job
from repro.sim import trace as sim_trace

DAY = sim_trace.DAY


class ArrivalSource:
    """Pull-based arrival stream (see module docstring)."""

    def poll(self, until_s: float) -> List[Job]:
        """Jobs with ``submit_time_s < until_s`` not yet returned, in
        submit order. Monotone: later calls never return earlier jobs."""
        raise NotImplementedError

    def next_arrival_s(self) -> Optional[float]:
        """Submit time of the next pending arrival, if knowable."""
        return None

    @property
    def exhausted(self) -> bool:
        """True when no future ``poll`` can return more jobs."""
        return False


class ReplayArrivals(ArrivalSource):
    """An in-memory trace replayed as a stream (batch-parity reference)."""

    def __init__(self, jobs: Sequence[Job]):
        self._jobs = sorted(jobs, key=lambda j: j.submit_time_s)
        self._i = 0

    def poll(self, until_s: float) -> List[Job]:
        out: List[Job] = []
        while self._i < len(self._jobs) \
                and self._jobs[self._i].submit_time_s < until_s:
            out.append(self._jobs[self._i])
            self._i += 1
        return out

    def next_arrival_s(self) -> Optional[float]:
        if self._i < len(self._jobs):
            return self._jobs[self._i].submit_time_s
        return None

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._jobs)


class PoissonBurstArrivals(ArrivalSource):
    """Endless synthetic load: inhomogeneous Poisson with diurnal and
    burst-train modulation, generated lazily in fixed chunks.

    The intensity matches ``sim.trace._arrivals`` (diurnal sine of depth
    ``diurnal_depth``; 30-minute hot windows every 4 h multiplying the
    rate by ``1 + 4·burst``), but generation is *chunked*: chunk ``c``
    covers ``[c·chunk_s, (c+1)·chunk_s)`` and draws from its own
    ``default_rng((seed, c))``, so an always-on service can stream for
    days without materializing the future, deterministically — the same
    (seed, chunk) always yields the same jobs regardless of polling
    cadence. Job ids are globally unique and arrival-ordered.
    """

    def __init__(self, rate_per_s: float, *, seed: int = 0,
                 num_regions: int = 5, tolerance: float = 0.25,
                 diurnal_depth: float = 0.45, burst: float = 0.0,
                 duration_jitter: float = 0.35, chunk_s: float = 3600.0,
                 horizon_s: Optional[float] = None):
        self.rate_per_s = float(rate_per_s)
        self.seed = int(seed)
        self.num_regions = int(num_regions)
        self.tolerance = float(tolerance)
        self.diurnal_depth = float(diurnal_depth)
        self.burst = float(burst)
        self.duration_jitter = float(duration_jitter)
        self.chunk_s = float(chunk_s)
        self.horizon_s = horizon_s
        self._chunk = 0               # next chunk index to generate
        self._buffer: List[Job] = []  # generated, not yet polled
        self._next_id = 0

    def _gen_chunk(self) -> None:
        t0 = self._chunk * self.chunk_s
        t1 = t0 + self.chunk_s
        rng = np.random.default_rng((self.seed, self._chunk))
        lam_max = (self.rate_per_s * (1 + self.diurnal_depth)
                   * (1 + self.burst * 4))
        n_cand = rng.poisson(lam_max * self.chunk_s)
        t = np.sort(rng.uniform(t0, t1, n_cand))
        lam = self.rate_per_s * (
            1 + self.diurnal_depth * np.sin(t / DAY * 2 * np.pi))
        if self.burst > 0:
            phase = (t % (4 * 3600.0)) < 1800.0
            lam = lam * np.where(phase, 1 + 4 * self.burst, 1.0)
        keep = rng.uniform(0, lam_max, n_cand) < lam
        arrivals = t[keep]
        if self.horizon_s is not None:
            arrivals = arrivals[arrivals < self.horizon_s]
        jobs = sim_trace._make_jobs(rng, arrivals, self.num_regions,
                                    self.tolerance, self.duration_jitter)
        for j in jobs:                # globally unique, arrival-ordered ids
            j.job_id = self._next_id
            self._next_id += 1
        self._buffer.extend(jobs)
        self._chunk += 1

    def _covered_s(self) -> float:
        end = self._chunk * self.chunk_s
        return end if self.horizon_s is None else min(end, self.horizon_s)

    def poll(self, until_s: float) -> List[Job]:
        while self._covered_s() < until_s and not self.exhausted:
            self._gen_chunk()
        cut = 0
        while cut < len(self._buffer) \
                and self._buffer[cut].submit_time_s < until_s:
            cut += 1
        out, self._buffer = self._buffer[:cut], self._buffer[cut:]
        return out

    def next_arrival_s(self) -> Optional[float]:
        # Peek without forcing generation of the infinite future: only the
        # already-buffered head is knowable cheaply.
        if self._buffer:
            return self._buffer[0].submit_time_s
        return None

    @property
    def exhausted(self) -> bool:
        return (self.horizon_s is not None and not self._buffer
                and self._chunk * self.chunk_s >= self.horizon_s)


class FileTailArrivals(ArrivalSource):
    """Tails a JSONL file of job submissions (the live ingestion seam).

    Each line is one job: ``{"job_id": int, "home_region": int,
    "submit_s": float, "exec_s": float, "energy_kwh": float}`` plus
    optional ``tolerance`` / ``package_bytes``. Only *complete* lines
    (newline-terminated) are consumed — a writer mid-append never yields a
    half-parsed job; the partial line is picked up whole on a later poll.
    """

    def __init__(self, path: str, *, tolerance: float = 0.25,
                 package_bytes: float = 2e9):
        self.path = path
        self.tolerance = float(tolerance)
        self.package_bytes = float(package_bytes)
        self._offset = 0
        self._buffer: List[Job] = []
        self._closed = False

    def close(self) -> None:
        """Mark the stream finished: the file will receive no more lines."""
        self._closed = True

    def _ingest(self) -> None:
        try:
            with open(self.path, "rb") as f:
                f.seek(self._offset)
                data = f.read()
        except FileNotFoundError:
            return
        end = data.rfind(b"\n")
        if end < 0:
            return                    # no complete line yet
        complete, self._offset = data[:end + 1], self._offset + end + 1
        for line in complete.splitlines():
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            self._buffer.append(Job(
                job_id=int(d["job_id"]),
                home_region=int(d["home_region"]),
                submit_time_s=float(d["submit_s"]),
                exec_time_s=float(d["exec_s"]),
                energy_kwh=float(d["energy_kwh"]),
                package_bytes=float(d.get("package_bytes",
                                          self.package_bytes)),
                tolerance=float(d.get("tolerance", self.tolerance))))
        self._buffer.sort(key=lambda j: j.submit_time_s)

    def poll(self, until_s: float) -> List[Job]:
        self._ingest()
        cut = 0
        while cut < len(self._buffer) \
                and self._buffer[cut].submit_time_s < until_s:
            cut += 1
        out, self._buffer = self._buffer[:cut], self._buffer[cut:]
        return out

    def next_arrival_s(self) -> Optional[float]:
        if self._buffer:
            return self._buffer[0].submit_time_s
        return None

    @property
    def exhausted(self) -> bool:
        return self._closed and not self._buffer


# ---------------------------------------------------------------------------
# Bounded admission
# ---------------------------------------------------------------------------

REJECT_NEW, DROP_OLDEST = "reject-new", "drop-oldest"


class AdmissionQueue:
    """Bounded FIFO between the arrival stream and the decision loop.

    Invariants (hypothesis-property-tested in tests/test_serve.py):

      * ``len(queue) <= bound`` after every ``offer`` — under any storm;
      * conservation: every offered job is exactly once either admitted
        (eventually returned by ``take``), still queued, or in ``shed_ids``
        — nothing is silently dropped;
      * FIFO: ``take`` returns jobs in offer order.

    ``policy`` picks who pays when the bound binds: ``reject-new`` sheds
    the incoming overflow (protects queued work — default), ``drop-oldest``
    evicts the head to admit fresh arrivals (bounds staleness).
    """

    def __init__(self, bound: int, policy: str = REJECT_NEW):
        if policy not in (REJECT_NEW, DROP_OLDEST):
            raise ValueError(f"unknown shed policy {policy!r}")
        self.bound = int(bound)
        self.policy = policy
        self._q: List[Job] = []
        self.offered = 0
        self.admitted = 0
        self.shed = 0
        self.peak_depth = 0
        self.shed_ids: List[int] = []

    def __len__(self) -> int:
        return len(self._q)

    def offer(self, jobs: Sequence[Job], now_s: float) -> List[Job]:
        """Admit up to the bound; returns the shed jobs (accounted, never
        silent)."""
        jobs = list(jobs)
        self.offered += len(jobs)
        shed: List[Job] = []
        if self.policy == REJECT_NEW:
            room = self.bound - len(self._q)
            take, shed = jobs[:max(room, 0)], jobs[max(room, 0):]
            self._q.extend(take)
        else:                                    # drop-oldest
            self._q.extend(jobs)
            over = len(self._q) - self.bound
            if over > 0:
                shed, self._q = self._q[:over], self._q[over:]
        self.admitted += len(jobs) - len(shed)
        self.shed += len(shed)
        self.shed_ids.extend(j.job_id for j in shed)
        self.peak_depth = max(self.peak_depth, len(self._q))
        if obs.enabled():
            if shed:
                obs.counter("serve.shed", len(shed))
            obs.gauge("serve.admission_depth", float(len(self._q)))
        return shed

    def take(self, limit: Optional[int] = None) -> List[Job]:
        """Pop up to ``limit`` jobs (all, when ``None``) in FIFO order."""
        n = len(self._q) if limit is None else min(int(limit), len(self._q))
        out, self._q = self._q[:n], self._q[n:]
        return out
