"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, without allocating a single model buffer:

  * proof the distribution config is coherent (compile succeeds),
  * per-device memory from ``compiled.memory_analysis()``,
  * HLO FLOPs / bytes from ``compiled.cost_analysis()``,
  * per-collective byte totals parsed from the partitioned HLO text,
  * the three roofline terms (compute / memory / collective) for v5e.

Results cache to JSON (one file per cell) under --out; EXPERIMENTS.md's
tables are generated from these.

Usage:
  python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--variant baseline]
"""
import argparse
import json
import os
import re
import time
import traceback
from typing import Dict, Optional

# Forced 512-way host device split — MUST land in XLA_FLAGS before the jax
# import below can initialize a backend (jax locks the device count at first
# init). The merge helper preserves any flags the user already exported
# (the old bare ``os.environ[...] =`` assignment clobbered them) and warns —
# instead of silently no-op'ing — when some earlier import already brought
# the backend up with the real single-device view.
from repro.launch.devices import set_host_platform_device_count

set_host_platform_device_count(512, strict=False)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.models.common import split_tree
from repro.optim import adamw
from repro.runtime import sharding
from repro.runtime.train_loop import (make_decode_step, make_prefill_step,
                                      make_train_step)

# TPU v5e hardware constants (per chip).
HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_DUS_RE = re.compile(r"=\s*(\w+)\[([\d,]+)\]\S*\s+dynamic-update-slice\(")


def f32_widened_stack_bytes(hlo_text: str) -> int:
    """CPU-backend artifact: XLA CPU hoists bf16→f32 converts of remat
    residual stacks out of the backward loop, materializing an f32 copy of
    a stack that is bf16 at the jaxpr level (verified in
    tests/test_dryrun.py). A TPU compile keeps the bf16 stack and converts
    per-slice in VMEM. We report the f32 copies' bytes so the roofline
    table can show both raw and TPU-adjusted peak memory."""
    f32_stacks, bf16_stacks = {}, set()
    for m in _DUS_RE.finditer(hlo_text):
        dt, dims = m.group(1), m.group(2)
        if dt == "bf16":
            bf16_stacks.add(dims)
        elif dt == "f32":
            n = 1
            for d in dims.split(","):
                n *= int(d)
            f32_stacks[dims] = max(f32_stacks.get(dims, 0), 4 * n)
    return int(sum(b for dims, b in f32_stacks.items()
                   if dims in bf16_stacks or b > 2**28))


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-type bytes from partitioned HLO (per-device shapes).

    Model (ring algorithms): all-reduce moves 2× its result bytes per
    device; the others move ≈ their result bytes. ``-done`` ops are skipped
    (counted at ``-start``)."""
    out = {k: 0.0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[1][:40]:
            continue
        result_txt = m.group(1) or m.group(2)
        b = _shape_bytes(result_txt)
        kind = m.group(3)
        out[kind] += 2.0 * b if kind == "all-reduce" else float(b)
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def _grad_accum_for(cfg, shape, data_ways: int = 16) -> int:
    """Microbatching so per-device live activations stay v5e-sized.

    Activations shard over the data(+pod) axes only — every model-shard
    device holds the full per-data-shard batch — so the relevant quantity is
    tokens per *data shard*, not per chip. Target ≤ 4k tokens/microbatch
    (one 4k sequence), which keeps saved-residual memory at
    n_layers × 4096 × d_model × 2B (e.g. 5.4 GB for qwen2-72b)."""
    per_shard_seqs = max(shape.global_batch // data_ways, 1)
    tokens_budget = 4096
    seqs_per_micro = max(tokens_budget // shape.seq_len, 1)
    return max(1, per_shard_seqs // seqs_per_micro)


VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    # §Perf hillclimb variants (EXPERIMENTS.md records the full log):
    # 2-D activation sharding: embed dim of activations over "model" —
    # residual/logits traffic shards 16×, MoE combine becomes reduce-scatter.
    "act2d": {"rules": {"act_embed": ("model",)}},
    # 2-D cache sharding: decode caches shard over model as well as data —
    # batched decode reads 1/16th of the cache per device.
    "seqshard": {"rules": {"cache_seq": ("data", "model")}},
    "act2d_seqshard": {"rules": {"act_embed": ("model",),
                                 "cache_seq": ("data", "model")}},
    # remat=dots: keep matmul outputs, recompute elementwise only.
    "remat_dots": {"cfg_remat": "dots"},
    # Sequence parallelism: token axis sharded over model too (GQA KV is
    # the only cross-token tensor — far cheaper to gather than the full
    # residual stream).
    "seqpar": {"rules": {"act_seq": ("data", "model")}},
    "seqpar_seqshard": {"rules": {"act_seq": ("data", "model"),
                                  "cache_seq": ("data", "model")}},
    # int8 cross-pod gradient compression (train cells).
    "int8_grads": {"compress": "int8"},
}


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline", overrides: Optional[Dict] = None):
    """(step_fn, abstract_args, donate, mesh, meta) for one cell."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ov = dict(VARIANTS.get(variant, {}))
    ov.update(overrides or {})
    cfg_over = {k[4:]: v for k, v in ov.items() if k.startswith("cfg_")}
    if cfg_over:
        cfg = cfg.replace(**cfg_over)
    rules = dict(sharding.DEFAULT_RULES)
    rules.update(ov.get("rules", {}))

    if shape_name == "long_500k" and not cfg.sub_quadratic:
        raise SkipCell(f"{arch} is pure full-attention; long_500k skipped "
                       f"per assignment (see DESIGN.md §Arch-applicability)")

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    pshapes, pspecs = model.abstract_params()
    params = sharding.abstract_with_sharding(pshapes, pspecs, mesh, rules)

    inputs = jax.eval_shape(lambda: model.make_inputs(shape))
    in_shapes, in_specs = split_tree(inputs)
    batch = sharding.abstract_with_sharding(in_shapes, in_specs, mesh, rules)

    meta = dict(arch=arch, shape=shape_name, kind=shape.kind,
                multi_pod=multi_pod, variant=variant,
                params=model.param_count(),
                mesh=str(dict(mesh.shape)))

    if shape.kind == "train":
        ga = int(ov.get("grad_accum", _grad_accum_for(cfg, shape)))
        meta["grad_accum"] = ga
        opt = adamw()
        ostate_shapes = jax.eval_shape(opt.init, pshapes)
        # mu/nu mirror the param sharding (FSDP'd optimizer state); the step
        # counter is replicated.
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        mu = sharding.abstract_with_sharding(ostate_shapes.mu, pspecs, mesh,
                                             rules)
        nu = sharding.abstract_with_sharding(ostate_shapes.nu, pspecs, mesh,
                                             rules)
        ostate = type(ostate_shapes)(step=jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=rep), mu=mu, nu=nu)
        step_fn = make_train_step(model, opt, grad_accum=ga,
                                  compress=ov.get("compress"))
        key = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rep)
        args = (params, ostate, batch, key)
        donate = (0, 1)
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(model)
        args = (params, batch)
        donate = ()
    else:  # decode
        cache = batch.pop("cache")
        step_fn = make_decode_step(model)
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
        args = (params, cache, batch["tokens"], pos)
        donate = (1,)
    return step_fn, args, donate, mesh, meta


class SkipCell(Exception):
    pass


# ---------------------------------------------------------------------------
# Lower + compile + analyse
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "baseline", overrides: Optional[Dict] = None,
             keep_hlo: bool = False) -> Dict:
    t0 = time.time()
    step_fn, args, donate, mesh, meta = build_cell(
        arch, shape_name, multi_pod, variant, overrides)
    chips = int(np.prod(list(mesh.shape.values())))
    ov = dict(VARIANTS.get(variant, {}))
    ov.update(overrides or {})
    # Bind the mesh + rule contexts: activation constraints inside the model
    # resolve against them (jax.set_mesh is also usable as a context manager).
    from repro.runtime import sharding as shd
    with jax.set_mesh(mesh), shd.rule_overrides(ov.get("rules")):
        lowered = jax.jit(step_fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    artifact = (f32_widened_stack_bytes(hlo)
                if meta["kind"] == "train" else 0)
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    mem_info = dict(
        argument_bytes=arg_b,
        output_bytes=getattr(mem, "output_size_in_bytes", 0),
        temp_bytes=tmp_b,
        peak_bytes=arg_b + tmp_b,
        cpu_f32_stack_artifact_bytes=artifact,
        adjusted_peak_bytes=arg_b + tmp_b - artifact)
    coll = collective_bytes(hlo)

    # cost_analysis flops on the partitioned module are per-device.
    t_compute = flops / HW["peak_flops"]
    t_memory = bytes_accessed / HW["hbm_bw"]
    t_coll = coll["total"] / HW["ici_bw"]
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]

    res = dict(meta, chips=chips, flops_per_device=flops,
               bytes_per_device=bytes_accessed, collectives=coll,
               memory=mem_info,
               cost_analysis={k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))},
               roofline=dict(t_compute=t_compute, t_memory=t_memory,
                             t_collective=t_coll, dominant=dominant),
               lower_s=round(t_lower, 1), compile_s=round(t_compile, 1))
    if keep_hlo:
        res["hlo_len"] = len(hlo)
    return res


def cell_path(out_dir, arch, shape_name, multi_pod, variant):
    tag = "pod2" if multi_pod else "pod1"
    return os.path.join(out_dir, f"{arch}.{shape_name}.{tag}.{variant}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if args.both_meshes else [args.multi_pod])
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        path = cell_path(args.out, a, s, mp, args.variant)
        if os.path.exists(path) and not args.force:
            print(f"cached  {path}")
            continue
        tag = "pod2" if mp else "pod1"
        try:
            res = run_cell(a, s, mp, args.variant)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(f"OK      {a:24s} {s:12s} {tag} compile={res['compile_s']:7.1f}s "
                  f"Tc={r['t_compute']:.3e} Tm={r['t_memory']:.3e} "
                  f"Tx={r['t_collective']:.3e} dom={r['dominant']}",
                  flush=True)
        except SkipCell as e:
            with open(path, "w") as f:
                json.dump(dict(arch=a, shape=s, multi_pod=mp, skipped=True,
                               reason=str(e)), f)
            print(f"SKIP    {a:24s} {s:12s} {tag}: {e}", flush=True)
        except Exception as e:
            print(f"FAIL    {a:24s} {s:12s} {tag}: {type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc(limit=6)


if __name__ == "__main__":
    main()
