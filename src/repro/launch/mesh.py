"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 v5e chips,
("data", "model"). Multi-pod: 2×16×16 = 512 chips, ("pod", "data",
"model") — the "pod" axis is the WaterWise migration/geo unit and the axis
cross-pod gradient compression applies to.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (smoke tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=_auto(2))
