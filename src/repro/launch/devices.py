"""Safe XLA host-platform device-count configuration.

``--xla_force_host_platform_device_count=N`` splits the host CPU backend
into N XLA devices — the standard way to develop/shard-test device-parallel
programs on a CPU box (SNIPPETS #2/#3 idiom). Two sharp edges this module
rounds off:

* the flag only takes effect if it is in ``XLA_FLAGS`` *before* the JAX
  backend initializes (first ``jax.devices()``/dispatch); set later it is a
  silent no-op, and code that assumed N devices misbehaves at a distance;
* naive ``os.environ["XLA_FLAGS"] = ...`` assignment clobbers every other
  flag the user exported (the old ``launch.dryrun`` bug).

:func:`set_host_platform_device_count` appends-and-replaces just this flag
(pure-string merge, preserving unrelated flags), detects a live backend and
— depending on ``strict`` — raises or warns instead of silently not working.
Import order is deliberate: nothing here imports ``jax`` at module scope, so
this module is safe to import before backend configuration.
"""
from __future__ import annotations

import os
import re
import sys
from typing import Optional

import repro.obs as obs

__all__ = ["merge_xla_flag", "backend_initialized", "device_count",
           "set_host_platform_device_count"]

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def merge_xla_flag(flags: Optional[str], name: str, value) -> str:
    """Pure string merge: replace any existing ``--name=...`` occurrence in
    an ``XLA_FLAGS`` string with ``--name=value``, preserving every other
    flag (and their order). ``flags=None`` means the variable was unset."""
    token = f"{name}={value}"
    parts = [p for p in (flags or "").split() if not
             re.fullmatch(re.escape(name) + r"(=\S*)?", p)]
    parts.append(token)
    return " ".join(parts)


def backend_initialized() -> bool:
    """True when a JAX backend is already live in this process (at which
    point platform flags can no longer take effect).

    Cheap and import-safe: if ``jax`` was never imported the backend cannot
    be initialized, so we do not import it just to ask. The live check goes
    through the ``xla_bridge`` backend registry (private but stable across
    the supported jax versions); if that moves, we conservatively report
    ``True`` once jax is imported — callers then warn rather than silently
    configure a dead flag.
    """
    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:                       # pragma: no cover — jax internals
        return True


def device_count() -> int:
    """``len(jax.devices())`` — initializes the backend (by design: callers
    ask this only when they are done configuring)."""
    import jax

    return len(jax.devices())


def set_host_platform_device_count(n: int, *, strict: bool = True) -> bool:
    """Arrange for the host (CPU) platform to expose ``n`` XLA devices.

    Must run before JAX backend init. Returns True when the flag is set (or
    the backend is already live with exactly ``n`` devices). When the
    backend is already initialized with a different count: raises
    ``RuntimeError`` if ``strict``, else warns via ``obs.warn`` and returns
    False — the caller keeps the real device view.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    if backend_initialized():
        live = device_count()
        if live == n:
            return True
        msg = (f"JAX backend already initialized with {live} device(s); "
               f"{_FORCE_FLAG}={n} can no longer take effect "
               f"(set it before the first jax.devices()/dispatch)")
        if strict:
            raise RuntimeError(msg)
        obs.warn("launch.xla_flags_late", msg)
        return False
    os.environ["XLA_FLAGS"] = merge_xla_flag(
        os.environ.get("XLA_FLAGS"), _FORCE_FLAG, n)
    return True
