"""One composable scheduling pipeline — paper §4 Algorithm 1, decomposed.

The reactive WaterWise controller and its forecast-driven variants used to
be a subclass pair (``Controller`` / ``ForecastController``); they are now
*configurations* of one ``PolicyPipeline`` assembled from three composable
stages:

  ``Pricer``          turns a scheduling round into a priced, arc-masked
                      assignment plan.  ``SnapshotPricer`` prices every job
                      at the live telemetry snapshot and offers one virtual
                      defer arc at the trailing-mean cost (the paper's
                      myopic controller); ``ForecastPricer`` widens the plan
                      to jobs × (regions × horizon-slots) priced by a
                      forecast integrated over each execution window.
  ``DeferralPolicy``  owns jobs the solver decided to hold.
                      ``NextRoundDeferral`` simply re-offers them next round
                      (reactive defer arc); ``QueueDeferral`` wraps the
                      slack-guarded ``forecast.DeferralQueue`` with planned
                      release times and engine wake-ups.
  solver backend      any ``repro.core.solvers`` backend name; hard solve
                      with soft (Eqs 12-13) slot-0 fallback is pipeline
                      logic, shared by every configuration.

All stages speak one protocol — ``schedule(jobs, now_s, capacity) ->
Decision`` — so the simulator treats rule baselines, the reactive
controller, and the forecast planner interchangeably, and every variant is
constructible from a declarative ``PolicySpec`` (see ``repro.policy``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

import repro.obs as obs
from repro.core import footprint, problem, slack, solvers, telemetry


@dataclasses.dataclass
class Decision:
    """One scheduling-round outcome (the uniform scheduler protocol's
    return value — rule baselines, the reactive pipeline, and the forecast
    pipeline all produce exactly this)."""
    scheduled: List[problem.Job]       # jobs with .region set by this round
    assign: np.ndarray                 # [len(scheduled)] region index
    deferred: List[problem.Job]        # jobs pushed to the next round
    solver: Optional[solvers.SolveResult]
    softened: bool
    # Earliest instant the scheduler plans to act on a held job. The engine
    # fast-forwards to it instead of stalling out when the fleet is idle and
    # no arrivals remain (temporal shifting holds jobs *on purpose*).
    wake_s: Optional[float] = None


@runtime_checkable
class Scheduler(Protocol):
    """What the simulation engines drive: one round, one ``Decision``."""

    def schedule(self, jobs: Sequence[problem.Job], now_s: float,
                 capacity: np.ndarray) -> Decision: ...


class HistoryLearner:
    """Trailing-window mean of regional carbon/water intensity.

    Two uses: (a) the normalized CO2_ref / H2O_ref of Eq (8) — regions that
    have *recently* been dirty/thirsty are discouraged even if momentarily
    attractive; (b) the raw trailing means price the *defer* arc — the
    expected cost of waiting for a more typical hour (window=10, λ_ref=0.1
    per §5)."""

    def __init__(self, num_regions: int, window: int = 10,
                 raw_window: int = 240):
        self.window = window
        self.ci = collections.deque(maxlen=window)
        self.wi = collections.deque(maxlen=window)
        # "Typical conditions" need a longer horizon than the Eq-8 ref term:
        # 240 rounds ≈ 2 h at the default 30 s scheduling period. Stored as a
        # ring buffer ([raw_window, 3, R]) — the per-round mean is one
        # vectorized reduction instead of rebuilding arrays from a deque of
        # dicts (this is on the simulator's per-round hot path).
        self.raw_window = raw_window
        self._raw = np.zeros((raw_window, 3, num_regions))
        self._raw_n = 0          # total observations so far
        self.num_regions = num_regions

    def observe(self, snap) -> None:
        ci, wi = snap["ci"], snap["water_intensity"]
        self.ci.append(ci / max(ci.max(), 1e-9))
        self.wi.append(wi / max(wi.max(), 1e-9))
        self._raw[self._raw_n % self.raw_window, 0] = ci
        self._raw[self._raw_n % self.raw_window, 1] = snap["ewif"]
        self._raw[self._raw_n % self.raw_window, 2] = snap["wue"]
        self._raw_n += 1

    @property
    def co2_ref(self) -> Optional[np.ndarray]:
        return np.mean(self.ci, axis=0) if self.ci else None

    @property
    def h2o_ref(self) -> Optional[np.ndarray]:
        return np.mean(self.wi, axis=0) if self.wi else None

    def mean_raw(self) -> Optional[dict]:
        if self._raw_n < 2:
            return None
        m = self._raw[:min(self._raw_n, self.raw_window)].mean(axis=0)
        return dict(ci=m[0], ewif=m[1], wue=m[2])


# ---------------------------------------------------------------------------
# Priced plans
# ---------------------------------------------------------------------------

# Decode actions: what one solver column means for a job.
RUN, HOLD, DEFER = "run", "hold", "defer"


@dataclasses.dataclass
class PricedPlan:
    """One round's priced, arc-masked assignment instance.

    Columns are whatever the pricer decided to offer — N regions, N regions
    plus a virtual defer arc, or N·S (region, slot) cells. ``overrun`` is
    carried per column so the soft-violation bookkeeping and window
    recording stay uniform across pricers.
    """
    cost: np.ndarray               # [M, C]
    allowed: np.ndarray            # [M, C]
    capacity: np.ndarray           # [C]
    overrun: np.ndarray            # [M, C]
    num_regions: int
    num_slots: int = 1
    slot_offsets: Optional[np.ndarray] = None   # [S] (forecast pricer only)
    # Slot-0 objective matrix when the pricer already computed it (reused by
    # the soft fallback instead of re-deriving from the instance).
    base_cost: Optional[np.ndarray] = None
    # Hard-solve result when the pricer already ran the solver as part of a
    # fused pricing+solving device program (``repro.core.round``); the
    # pipeline uses it instead of dispatching ``solvers.solve`` again.
    presolved: Optional[solvers.SolveResult] = None


class Pricer:
    """Stage 1: price one scheduling round into a ``PricedPlan``."""

    def bind(self, pipeline: "PolicyPipeline") -> None:
        self.pipe = pipeline

    def price(self, jobs: Sequence[problem.Job], now_s: float,
              inst: problem.ProblemInstance, snap: dict) -> PricedPlan:
        raise NotImplementedError

    def decode(self, plan: PricedPlan, col: int, now_s: float
               ) -> Tuple[str, Optional[float]]:
        """Column index -> (action, payload): (RUN, region), (HOLD,
        release_s) or (DEFER, None)."""
        raise NotImplementedError


class SnapshotPricer(Pricer):
    """Reactive pricing (the paper's myopic controller): every job is priced
    at the *current* telemetry snapshot, plus one virtual defer column priced
    at the trailing-mean cost + a margin (the delay-tolerance exploitation of
    paper Fig 5). The solver sends a job there exactly when *now* is a
    worse-than-typical hour everywhere it could run — it then waits for the
    next round. Arc-filtered by remaining slack so tolerance is never
    risked."""

    def __init__(self, defer_margin: float = 0.02,
                 defer_slack_s: float = 120.0):
        # Defer arc: waiting is priced at the trailing-mean cost plus a
        # margin; only jobs with > defer_slack_s of remaining TOL budget may
        # take it (they must still fit a later round + transfer).
        self.defer_margin = defer_margin
        self.defer_slack_s = defer_slack_s

    def price(self, jobs, now_s, inst, snap) -> PricedPlan:
        pipe = self.pipe
        history = pipe.history
        cost = inst.objective_matrix(pipe.lam_co2, pipe.lam_h2o, pipe.lam_ref,
                                     history.co2_ref, history.h2o_ref,
                                     lam_emb=pipe.lam_emb)
        capacity = np.asarray(inst.capacity)
        hist = history.mean_raw()
        if hist is None:
            return PricedPlan(cost=cost, allowed=inst.allowed,
                              capacity=capacity, overrun=inst.overrun,
                              num_regions=inst.shape[1], base_cost=cost)
        h_co2 = footprint.job_carbon(
            np.array([j.energy_kwh for j in jobs])[:, None],
            np.array([j.exec_time_s for j in jobs])[:, None],
            hist["ci"][None, :], pipe.server)
        h_h2o = footprint.job_water(
            np.array([j.energy_kwh for j in jobs])[:, None],
            np.array([j.exec_time_s for j in jobs])[:, None],
            snap["pue"][None, :], hist["ewif"][None, :],
            hist["wue"][None, :], snap["wsf"][None, :], pipe.server)
        h_obj = (pipe.lam_co2 * h_co2 / inst.co2_max[:, None]
                 + pipe.lam_h2o * h_h2o / inst.h2o_max[:, None])
        if pipe.lam_emb and inst.emb is not None:
            # Embodied amortization is time-invariant: waiting does not make
            # the fleet's embodied carbon cheaper, so the defer arc carries
            # the same per-region embodied term as the real arcs.
            h_obj = h_obj + pipe.lam_emb * inst.emb / inst.emb_max[:, None]
        # Same λ_ref history term as the real arcs — the defer arc must be
        # compared apples-to-apples or it is uniformly cheaper and every job
        # waits unconditionally (no temporal signal).
        if history.co2_ref is not None:
            h_obj = h_obj + pipe.lam_ref * (
                pipe.lam_co2 * history.co2_ref
                + pipe.lam_h2o * history.h2o_ref)[None, :]
        defer_cost = h_obj.min(axis=1) + self.defer_margin
        # ONE vectorized slack expression (problem.slack_budget) shared with
        # core.slack and the temporal planner — bit-identical to the former
        # per-job method loop; this runs every scheduling round.
        slack_left = problem.slack_budget(jobs, now_s)
        can_wait = slack_left > self.defer_slack_s
        return PricedPlan(
            cost=np.concatenate([cost, defer_cost[:, None]], axis=1),
            allowed=np.concatenate([inst.allowed, can_wait[:, None]], axis=1),
            capacity=np.concatenate([capacity, [len(jobs)]]),
            overrun=np.concatenate(
                [inst.overrun, np.zeros((len(jobs), 1))], axis=1),
            num_regions=inst.shape[1], base_cost=cost)

    def decode(self, plan, col, now_s):
        if col < plan.num_regions:
            return RUN, col
        return DEFER, None           # the virtual defer arc: retry next round


class ForecastPricer(Pricer):
    """Forecast-integrated pricing (beyond-paper subsystem).

    Replaces the reactive defer *arc* with a forecast-priced defer *grid*:
    every round prices ``jobs × (regions × horizon-slots)`` where slot 0 is
    "run now" at the live snapshot and slots 1..S−1 are "hold until t+s·Δ"
    priced at a forecast of (ci, ewif, wue) — Holt–Winters by default, the
    true-future ``oracle`` for upper-bound studies. Deadline feasibility is
    masked, never penalized, so deferral cannot cause a tolerance miss (see
    ``forecast.planner``).

    ``risk`` shades future-slot prices toward the upper quantile band
    (risk-averse deferral under forecast uncertainty); ``forecast_bias`` /
    ``forecast_noise`` inject systematic error for the ``forecast-error``
    scenario regime.
    """

    def __init__(self, *, forecaster: str = "holtwinters",
                 horizon_slots: int = 8, slot_s: float = 1800.0,
                 risk: float = 0.25, defer_eps: float = 1e-3,
                 guard_s: float = 240.0, warmup_hours: int = 96,
                 forecast_bias: float = 1.0, forecast_noise: float = 0.0,
                 forecast_seed: int = 0, warm: bool = False):
        # ``forecaster`` names any registered model ("holtwinters",
        # "seasonal-naive", "persistence", "learned", ...) or "oracle".
        from repro import forecast as fcast
        self._fcast = fcast
        self.forecaster_name = forecaster
        self.horizon_slots = int(horizon_slots)
        self.slot_s = float(slot_s)
        self.risk = float(risk)
        self.defer_eps = float(defer_eps)
        self.guard_s = float(guard_s)
        # Pre-run telemetry archive: production forecasters are warm-started
        # on months of history, but a simulation starts at t=0. The synthetic
        # telemetry is the single period of a periodic environment
        # (``Telemetry.at`` wraps), so its cyclic extension *is* the
        # environment's past — the archive at simulated hour h is the
        # ``warmup_hours`` wrapped hours ending at h. Set 0 for a cold start.
        self.warmup_hours = int(warmup_hours)
        self.forecast_bias = float(forecast_bias)
        self.forecast_noise = float(forecast_noise)
        self.forecast_seed = int(forecast_seed)
        # Warm-started Sinkhorn: carry the temporal OT's column potentials
        # between rounds (``core.round.SinkhornWarmStart``). Fused backend
        # only — the unfused path ignores it (warned once).
        self.warm = bool(warm)
        self.warm_state = None
        self._warm_warned = False
        self._truth = None
        self._fit_hour = -1
        self._forecast = None
        self._fitted = None
        # The forecaster object is created once and re-fit every refresh:
        # classical models reset fully on fit() (bit-identical to a fresh
        # instance), while stateful models (the learned forecaster) keep
        # their trained parameters across refits and decide internally when
        # to retrain (``retrain_every``) vs. just re-condition.
        self._forecaster_obj = None
        # Online forecast-accuracy bookkeeping (the sweep's accuracy column):
        # each refit scores the previous forecast against the hours that have
        # since realized.
        self._ape_sum = 0.0
        self._ape_n = 0

    def bind(self, pipeline) -> None:
        super().bind(pipeline)
        tele = pipeline.tele
        # Ground truth, stacked [T, 3R]: columns [ci | ewif | wue] — one
        # forecaster fit covers all three signals at once.
        self._truth = np.concatenate([tele.ci, tele.ewif, tele.wue], axis=1)

    # -- forecasting ---------------------------------------------------------

    def _make_forecaster(self):
        if self.forecaster_name == "oracle":
            f = self._fcast.Oracle(self._truth)
        else:
            f = self._fcast.make_forecaster(self.forecaster_name)
        if self.forecast_bias != 1.0 or self.forecast_noise > 0.0:
            f = self._fcast.Perturbed(f, self.forecast_bias,
                                      self.forecast_noise,
                                      self.forecast_seed)
        return f

    @property
    def forecast_mape(self) -> float:
        """Realized 1..H-hour-ahead MAPE (%) of the forecasts actually used."""
        return 100.0 * self._ape_sum / self._ape_n if self._ape_n else 0.0

    def _refresh_forecast(self, now_s: float) -> None:
        tele = self.pipe.tele
        h = min(int(now_s // telemetry.HOUR), tele.num_hours - 1)
        if h <= self._fit_hour:
            return
        if self._forecast is not None:
            fc = self._forecast
            for k in range(self._fit_hour + 1, h + 1):
                lead = k - fc.issue_hour - 1
                if 0 <= lead < fc.horizon:
                    truth = self._truth[k % self._truth.shape[0]]
                    pred = fc.mean[lead]
                    self._ape_sum += float(np.mean(
                        np.abs(pred - truth)
                        / np.maximum(np.abs(truth), 1e-9)))
                    self._ape_n += 1
        T = self._truth.shape[0]
        if self.forecaster_name == "oracle" or self.warmup_hours <= 0:
            hist = self._truth[:h + 1]       # oracle indexes truth absolutely
        else:
            idx = np.arange(h - self.warmup_hours + 1, h + 1) % T
            hist = self._truth[idx]
        if self._forecaster_obj is None:
            self._forecaster_obj = self._make_forecaster()
        self._fitted = self._forecaster_obj.fit(hist)
        self._fit_hour = h
        horizon_h = int(np.ceil(self.horizon_slots * self.slot_s
                                / telemetry.HOUR)) + 1
        self._forecast = self._predict(horizon_h)

    def _predict(self, horizon_h: int):
        fc = self._fitted.predict(horizon_h)
        if fc.issue_hour != self._fit_hour:
            # Re-anchor from archive-relative to absolute hours (wrapped
            # warm-start histories end at hour ``_fit_hour`` by construction).
            fc = dataclasses.replace(fc, issue_hour=self._fit_hour)
        return fc

    def _ensure_horizon(self, now_s: float, max_exec_s: float,
                        last_offset_s: float) -> None:
        """Grow the cached forecast so every execution window it will price
        — up to [last slot start, + longest exec] — lies inside the horizon
        (beyond it the forecast extrapolates flat, which would silently
        de-calibrate the pricing, oracle included)."""
        t_end = now_s + last_offset_s + max_exec_s
        needed = int(np.ceil(t_end / telemetry.HOUR)) - self._fit_hour + 1
        if needed > self._forecast.horizon:
            self._forecast = self._predict(needed)

    def _slot_signal_tensors(self, jobs: Sequence[problem.Job], now_s: float,
                             offsets: np.ndarray):
        """(ci, ewif, wue) estimates per (job, slot), each [M, S, R].

        Every cell is priced at the forecast's exact time-mean over the
        job's would-be execution window [slot_start, slot_start + exec] —
        the simulator accounts with the integrated telemetry over the same
        window, so "run now" and "run later" are compared on the accounting
        footing (with the oracle forecaster planned and accounted signal
        means coincide exactly). Future slots are shaded toward the upper
        quantile band by ``risk`` — deferring on an uncertain forecast must
        price the uncertainty in.
        """
        R = self.pipe.tele.num_regions
        M, S = len(jobs), len(offsets)
        exec_t = np.array([j.exec_time_s for j in jobs])
        self._ensure_horizon(now_s, float(exec_t.max()), float(offsets[-1]))
        t0 = np.broadcast_to(now_s + offsets[None, :], (M, S)).ravel()
        t1 = (now_s + offsets[None, :] + exec_t[:, None]).ravel()
        rows = self._forecast.mean_many(t0, t1)
        if self.risk > 0.0:
            hi = self._forecast.mean_many(t0, t1, "hi")
            shade = self.risk * (hi - rows)
            shade[np.arange(t0.size) % S == 0] = 0.0      # slot 0 is observed
            rows = rows + shade
        rows = np.maximum(rows, 1e-6)          # physical signals are positive
        rows = rows.reshape(M, S, 3 * R)
        return rows[..., :R], rows[..., R:2 * R], rows[..., 2 * R:]

    # -- pricing -------------------------------------------------------------

    def price(self, jobs, now_s, inst, snap) -> PricedPlan:
        pipe = self.pipe
        with obs.span("policy.forecast"):
            self._refresh_forecast(now_s)
            offsets = np.arange(self.horizon_slots) * self.slot_s
            ci, ewif, wue = self._slot_signal_tensors(jobs, now_s, offsets)
        if pipe.backend == "fused":
            # Pricing, masking, Sinkhorn, and extraction run as ONE jitted
            # program; the plan comes back already hard-solved (bit-identical
            # decisions to the unfused path — pinned in tests/test_round.py).
            from repro.core import round as fused_round
            if self.warm and self.warm_state is None \
                    and not pipe.record_windows:
                self.warm_state = fused_round.SinkhornWarmStart()
            cost, allowed, cap, res = fused_round.fused_temporal_round(
                inst, now_s, ci, ewif, wue, snap["pue"], snap["wsf"],
                offsets, pipe.server, pipe.lam_co2, pipe.lam_h2o,
                pipe.lam_ref, pipe.history.co2_ref, pipe.history.h2o_ref,
                defer_eps=self.defer_eps, guard_s=self.guard_s,
                want_plan=pipe.record_windows, warm_start=self.warm_state)
            S = len(offsets)
            return PricedPlan(cost=cost, allowed=allowed, capacity=cap,
                              overrun=np.tile(inst.overrun, (1, S)),
                              num_regions=inst.shape[1], num_slots=S,
                              slot_offsets=np.asarray(offsets, np.float64),
                              presolved=res)
        if self.warm and not self._warm_warned:
            self._warm_warned = True
            obs.warn("policy.warm_ignored",
                     "warm-started Sinkhorn requires backend='fused'; "
                     f"backend={pipe.backend!r} prices unfused — ignored")
        plan = self._fcast.build_temporal_plan(
            inst, now_s, ci, ewif, wue, snap["pue"], snap["wsf"], offsets,
            pipe.server, pipe.lam_co2, pipe.lam_h2o, pipe.lam_ref,
            pipe.history.co2_ref, pipe.history.h2o_ref,
            defer_eps=self.defer_eps, guard_s=self.guard_s)
        return PricedPlan(cost=plan.cost, allowed=plan.allowed,
                          capacity=plan.capacity,
                          overrun=np.tile(inst.overrun, (1, plan.num_slots)),
                          num_regions=plan.num_regions,
                          num_slots=plan.num_slots,
                          slot_offsets=plan.slot_offsets)

    def decode(self, plan, col, now_s):
        s, n = col // plan.num_regions, col % plan.num_regions
        if s == 0:
            return RUN, n
        return HOLD, now_s + float(plan.slot_offsets[s])

    @property
    def sinkhorn_cold_iters(self) -> List[int]:
        return self.warm_state.cold_iters if self.warm_state else []

    @property
    def sinkhorn_warm_iters(self) -> List[int]:
        return self.warm_state.warm_iters if self.warm_state else []


# ---------------------------------------------------------------------------
# Deferral policies
# ---------------------------------------------------------------------------

class DeferralPolicy:
    """Stage 3: what happens to jobs the solver decided not to run now."""

    def bind(self, pipeline: "PolicyPipeline") -> None:
        self.pipe = pipeline

    def admit(self, jobs: Sequence[problem.Job], now_s: float,
              capacity: Optional[int] = None
              ) -> Tuple[List[problem.Job], List[problem.Job]]:
        """Split the pending set into (due now, still intentionally held).
        ``capacity`` is the round's total free seats — policies that add
        rows (re-planning) use it to never displace genuinely due jobs."""
        return list(jobs), []

    def hold(self, job: problem.Job, release_s: float, now_s: float) -> None:
        """Record an intentional hold until ``release_s`` (HOLD decode)."""
        raise NotImplementedError

    def revise(self, job: problem.Job, action: str, payload, plan: PricedPlan,
               row: int, col: int, now_s: float) -> Tuple[str, Optional[float]]:
        """Last look at a decoded (action, payload) before it is applied —
        the hook where re-planning policies veto churn (see
        ``ReplanQueueDeferral``). Default: pass through."""
        return action, payload

    def wake_s(self) -> Optional[float]:
        """Earliest planned release (``Decision.wake_s``), if any."""
        return None


class NextRoundDeferral(DeferralPolicy):
    """Reactive deferral: a deferred job simply returns with the next
    round's pending set — no planned release, no engine wake-up."""


class QueueDeferral(DeferralPolicy):
    """Planned temporal holds backed by the slack-guarded
    ``forecast.DeferralQueue``: jobs assigned a future slot wait out their
    hold and are re-offered at the planned slot (or early, when their
    remaining tolerance budget drops to the guard)."""

    def __init__(self, guard_s: float = 240.0):
        from repro import forecast as fcast
        self.queue = fcast.DeferralQueue(guard_s)

    def admit(self, jobs, now_s, capacity=None):
        return self.queue.partition(jobs, now_s)

    def hold(self, job, release_s, now_s):
        self.queue.hold(job, release_s, now_s)

    def wake_s(self):
        return self.queue.next_release_s()

    @property
    def mean_defer_s(self) -> float:
        return self.queue.mean_defer_s

    @property
    def deferred_jobs(self) -> int:
        """Distinct jobs ever time-shifted (re-deferrals don't double-count)."""
        return len(self.queue.unique_held)


class ReplanQueueDeferral(QueueDeferral):
    """Receding-horizon re-planning over the deferral queue.

    ``QueueDeferral`` commits a held job to the slot priced at admission
    time; this variant sends held jobs *back into pricing every round*, so
    the plan is re-made against the freshest forecast — the rolling
    spatio-temporal shifting regime of Attenni et al. (arXiv:2512.08725)
    on top of WaterWise's carbon/water co-optimization. The solver may
    confirm the hold (same or new slot — the episode continues, stats
    uncounted), pull the job forward to run now, or push it later.

    The **re-plan guard**: a job within ``replan_guard_s`` of its planned
    release stays committed. Re-pricing that close to release cannot move
    the job materially but doubles solver load and can thrash the plan —
    the guard bounds both, and makes the commit monotone near release.

    The **hysteresis margin**: running is irreversible, holding is not.
    Each re-pricing round is a fresh draw from an approximate (entropic)
    solver on a slot grid re-anchored at *now* — without friction, a held
    job runs the first round the blur happens to favor slot 0, a ratchet
    that erodes planned deferrals (measurably worse footprints). So a
    re-planned "run now" is accepted only when it beats the job's
    committed slot by ``replan_margin`` *in the same cost matrix*;
    otherwise the hold is restored at its original release (``revise``).
    Re-planned holds (slot moves) carry no friction — they stay reversible.
    """

    def __init__(self, guard_s: float = 240.0,
                 replan_guard_s: float = 900.0,
                 replan_margin: float = 0.02):
        super().__init__(guard_s)
        self.replan_guard_s = float(replan_guard_s)
        self.replan_margin = float(replan_margin)
        self.replans = 0            # re-pricing episodes (job-rounds)
        self.replan_runs = 0        # re-plans that ran the job early
        self.replan_vetoes = 0      # early runs vetoed by the margin
        # Episodes opened before the current re-pricing round:
        # job_id -> (original held_at_s, pop round's now_s, committed
        # release_s). Entries are reclaimed by ``hold`` (job re-held:
        # episode continues) or closed at the next round for jobs that
        # left the queue.
        self._carried: dict = {}

    def admit(self, jobs, now_s, capacity=None):
        q = self.queue
        if self._carried:
            # Settle last round's popped-but-not-re-held episodes: a job
            # that ran (gone from pending) ends its episode at the pop
            # instant; one the solver dropped (defer / infeasible row) gets
            # its committed hold restored — re-planning must never *lose* a
            # commitment.
            incoming = {j.job_id: j for j in jobs}
            for jid, (held_at, popped_at, release_s) in self._carried.items():
                j = incoming.get(jid)
                if j is None:
                    q.close_replan(held_at, popped_at)
                else:
                    q.hold(j, release_s, now_s, held_at_s=held_at)
            self._carried.clear()
        due, held = q.partition(jobs, now_s)
        if not held:
            return due, held
        # Re-plan only into *spare* seats: an added row must never displace
        # a genuinely due job (urgent-trim) or tip the round into the soft
        # fallback — under a capacity crunch held jobs stay committed.
        spare = (len(held) if capacity is None
                 else max(int(capacity) - len(due), 0))
        keep: List[problem.Job] = []
        for j in held:
            release_s = q._held[j.job_id].release_s
            if spare > 0 and release_s - now_s > self.replan_guard_s:
                self._carried[j.job_id] = (q.pop_for_replan(j.job_id),
                                           now_s, release_s)
                self.replans += 1
                spare -= 1
                due.append(j)
            else:
                keep.append(j)
        if obs.enabled() and len(keep) < len(held):
            obs.counter("policy.replanned", len(held) - len(keep))
        return due, keep

    def revise(self, job, action, payload, plan, row, col, now_s):
        carried = self._carried.get(job.job_id)
        if carried is None or plan.slot_offsets is None or plan.num_slots < 2:
            return action, payload
        release_s = carried[2]
        S, N = plan.num_slots, plan.num_regions
        slot_s = float(plan.slot_offsets[1] - plan.slot_offsets[0])
        s = int(np.clip(np.rint((release_s - now_s) / slot_s), 1, S - 1))
        if action == HOLD and col // N == s:
            return action, payload          # plan confirmed (slot unchanged)
        ok = plan.allowed[row, s * N:(s + 1) * N]
        if not ok.any():
            return action, payload          # committed slot gone infeasible
        committed = float(np.min(np.where(
            ok, plan.cost[row, s * N:(s + 1) * N], np.inf)))
        if float(plan.cost[row, col]) <= committed - self.replan_margin:
            if action == RUN:
                self.replan_runs += 1
            return action, payload          # genuine improvement: move
        self.replan_vetoes += 1
        return HOLD, release_s              # restore the committed hold

    def hold(self, job, release_s, now_s):
        carried = self._carried.pop(job.job_id, None)
        self.queue.hold(job, release_s, now_s,
                        held_at_s=None if carried is None else carried[0])


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------

class PolicyPipeline:
    """Algorithm 1 over pluggable stages; ``schedule()`` is one invocation."""

    def __init__(self, tele: telemetry.Telemetry, pricer: Pricer,
                 deferral: Optional[DeferralPolicy] = None, *,
                 server: footprint.ServerSpec = None,
                 lam_co2: float = 0.5, lam_h2o: float = 0.5,
                 lam_ref: float = 0.1, window: int = 10,
                 sigma: float = 10.0, backend: str = "flow",
                 lam_emb: float = 0.0,
                 record_windows: bool = False):
        assert abs(lam_co2 + lam_h2o + lam_emb - 1.0) < 1e-9, \
            "footprint weights must sum to 1"
        self.tele = tele
        self.server = server or footprint.m5_metal()
        self.lam_co2, self.lam_h2o, self.lam_ref = lam_co2, lam_h2o, lam_ref
        self.lam_emb = lam_emb
        self.sigma = sigma
        self.backend = backend
        self.history = HistoryLearner(tele.num_regions, window)
        self.solve_times: List[float] = []
        # Offline queued-window replay: when enabled, every solved instance
        # (the one that produced the round's decision) is captured so the
        # whole run can be re-solved in bulk through ``solvers.solve_many``
        # (bucketed + vmapped Sinkhorn — one device dispatch per bucket).
        self.record_windows = record_windows
        self.recorded: List[dict] = []
        self.pricer = pricer
        self.deferral = deferral or NextRoundDeferral()
        self.pricer.bind(self)
        self.deferral.bind(self)

    def __getattr__(self, name: str):
        # Stage-specific surface (forecast_mape, queue, mean_defer_s, ...)
        # is reachable on the pipeline itself, so consumers can probe
        # capabilities with hasattr() regardless of configuration.
        if name.startswith("__"):
            raise AttributeError(name)
        for stage_attr in ("pricer", "deferral"):
            stage = self.__dict__.get(stage_attr)
            if stage is not None and hasattr(stage, name):
                return getattr(stage, name)
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")

    # -- offline replay ------------------------------------------------------

    def _record(self, cost, allowed, capacity, overrun, tol, soften) -> None:
        if self.record_windows:
            self.recorded.append(dict(
                cost=np.array(cost), allowed=np.array(allowed),
                capacity=np.array(capacity), overrun=np.array(overrun),
                tol=np.array(tol), soften=bool(soften)))

    def replay_recorded(self, backend: str = "jax") -> List[solvers.SolveResult]:
        """Re-solve every recorded scheduling window through the batched
        ``solvers.solve_many`` path; results come back in round order.

        Hard and soft rounds are batched separately (``soften`` is a batch-
        level flag); with the default ``jax`` backend each group buckets by
        padded shape and runs one vmapped Sinkhorn dispatch per bucket.
        """
        out: List[Optional[solvers.SolveResult]] = [None] * len(self.recorded)
        for soften in (False, True):
            idx = [i for i, w in enumerate(self.recorded)
                   if w["soften"] == soften]
            if not idx:
                continue
            res = solvers.solve_many(
                [self.recorded[i]["cost"] for i in idx],
                [self.recorded[i]["allowed"] for i in idx],
                [self.recorded[i]["capacity"] for i in idx],
                backend=backend, soften=soften,
                overruns=[self.recorded[i]["overrun"] for i in idx],
                tols=[self.recorded[i]["tol"] for i in idx],
                sigma=self.sigma)
            for i, r in zip(idx, res):
                out[i] = r
        return out

    # -- Algorithm 1 ---------------------------------------------------------

    def schedule(self, jobs: Sequence[problem.Job], now_s: float,
                 capacity: np.ndarray) -> Decision:
        jobs = list(jobs)                                    # J_all (line 3)
        if not jobs:
            return Decision([], np.zeros(0, np.int64), [], None, False)

        with obs.span("policy.admit", pending=len(jobs)):
            due, held = self.deferral.admit(jobs, now_s,
                                            capacity=int(capacity.sum()))
            if not due:
                return Decision([], np.zeros(0, np.int64), held, None, False,
                                wake_s=self.deferral.wake_s())

            total_cap = int(capacity.sum())
            deferred: List[problem.Job] = []
            if len(due) > total_cap:                         # lines 5-7
                due, deferred = slack.pick_most_urgent(
                    due, now_s, total_cap, bw_gbps=self.tele.wan_bw_gbps,
                    rtt_s=self.tele.wan_rtt_s)
            if not due:
                return Decision([], np.zeros(0, np.int64), deferred + held,
                                None, False, wake_s=self.deferral.wake_s())

        with obs.span("policy.build", jobs=len(due)):
            snap = self.tele.at(now_s)
            self.history.observe(snap)
            inst = problem.build(due, self.tele, now_s, capacity, self.server,
                                 snap=snap)
            tol = np.array([j.tolerance for j in due])
        with obs.span("policy.price", jobs=len(due)):
            plan = self.pricer.price(due, now_s, inst, snap)

        softened = False
        with obs.span("policy.solve", jobs=len(due),
                      presolved=plan.presolved is not None):
            if plan.presolved is not None:
                res = plan.presolved
            else:
                res = solvers.solve(plan.cost, plan.allowed, plan.capacity,
                                    backend=self.backend, soften=False,
                                    overrun=plan.overrun, tol=tol,
                                    sigma=self.sigma)
            if res.feasible:
                self._record(plan.cost, plan.allowed, plan.capacity,
                             plan.overrun, tol, False)
            else:                                            # lines 10-11
                # Soft fallback is slot-0 only: a job that must overrun its
                # tolerance should pay the Eq 12-13 penalty and run *now*,
                # not hide in a future slot or behind the defer arc.
                softened = True
                cost0 = plan.base_cost
                if cost0 is None:
                    cost0 = inst.objective_matrix(self.lam_co2, self.lam_h2o,
                                                  self.lam_ref,
                                                  self.history.co2_ref,
                                                  self.history.h2o_ref,
                                                  lam_emb=self.lam_emb)
                res = solvers.solve(cost0, inst.allowed, capacity,
                                    backend=self.backend, soften=True,
                                    overrun=inst.overrun, tol=tol,
                                    sigma=self.sigma)
                self._record(cost0, inst.allowed, capacity, inst.overrun,
                             tol, True)
            obs.annotate(softened=softened, status=res.status)
        self.solve_times.append(res.solve_time_s)

        scheduled: List[problem.Job] = []
        assign: List[int] = []
        with obs.span("policy.extract", jobs=len(due)):
            for row, (j, col) in enumerate(zip(due, res.assign)):
                col = int(col)
                if col < 0:
                    deferred.append(j)
                    continue
                if softened:
                    # Soft fallback is slot-0 only: run, no revision.
                    action, payload = RUN, col
                else:
                    action, payload = self.pricer.decode(plan, col, now_s)
                    action, payload = self.deferral.revise(
                        j, action, payload, plan, row, col, now_s)
                if action == RUN:
                    j.region = int(payload)
                    scheduled.append(j)
                    assign.append(int(payload))
                elif action == HOLD:
                    self.deferral.hold(j, float(payload), now_s)
                    deferred.append(j)
                else:                                        # DEFER
                    deferred.append(j)
            deferred += held
        if obs.enabled():
            q = getattr(getattr(self.deferral, "queue", None), "__len__",
                        None)
            if q is not None:
                obs.gauge("deferral.queue_depth", float(q()))
        return Decision(scheduled, np.asarray(assign, np.int64), deferred,
                        res, softened, wake_s=self.deferral.wake_s())


# ---------------------------------------------------------------------------
# Canonical configurations (the registry factories — and the deprecated
# ``Controller`` / ``ForecastController`` names — build through these)
# ---------------------------------------------------------------------------

def reactive_pipeline(tele: telemetry.Telemetry, *,
                      server: footprint.ServerSpec = None,
                      lam_co2: float = 0.5, lam_h2o: float = 0.5,
                      lam_ref: float = 0.1, window: int = 10,
                      sigma: float = 10.0, backend: str = "flow",
                      defer_margin: float = 0.02,
                      defer_slack_s: float = 120.0,
                      lam_emb: float = 0.0,
                      record_windows: bool = False) -> PolicyPipeline:
    """The paper's myopic co-optimizing controller (Algorithm 1): snapshot
    pricing + virtual defer arc, hard→soft MILP fallback. ``lam_emb`` adds
    the embodied-carbon dimension to the objective (``waterwise-embodied``)."""
    return PolicyPipeline(
        tele, SnapshotPricer(defer_margin, defer_slack_s),
        NextRoundDeferral(), server=server, lam_co2=lam_co2,
        lam_h2o=lam_h2o, lam_ref=lam_ref, window=window, sigma=sigma,
        backend=backend, lam_emb=lam_emb, record_windows=record_windows)


def forecast_pipeline(tele: telemetry.Telemetry, *,
                      forecaster: str = "holtwinters",
                      horizon_slots: int = 8, slot_s: float = 1800.0,
                      risk: float = 0.25, defer_eps: float = 1e-3,
                      guard_s: float = 240.0, warmup_hours: int = 96,
                      forecast_bias: float = 1.0,
                      forecast_noise: float = 0.0, forecast_seed: int = 0,
                      backend: str = "jax",
                      server: footprint.ServerSpec = None,
                      lam_co2: float = 0.5, lam_h2o: float = 0.5,
                      lam_ref: float = 0.1, window: int = 10,
                      sigma: float = 10.0,
                      warm: bool = False, replan: bool = False,
                      replan_guard_s: float = 900.0,
                      replan_margin: float = 0.02,
                      record_windows: bool = False) -> PolicyPipeline:
    """Predictive spatio-temporal configuration: forecast-grid pricing +
    slack-guarded deferral queue over the same pipeline.

    ``warm=True`` carries Sinkhorn column potentials between rounds
    (fused backend only); ``replan=True`` swaps the commit-at-admission
    queue for receding-horizon re-planning (``ReplanQueueDeferral``) with
    its ``replan_guard_s`` commit window and ``replan_margin``
    early-run hysteresis."""
    pricer = ForecastPricer(
        forecaster=forecaster, horizon_slots=horizon_slots, slot_s=slot_s,
        risk=risk, defer_eps=defer_eps, guard_s=guard_s,
        warmup_hours=warmup_hours, forecast_bias=forecast_bias,
        forecast_noise=forecast_noise, forecast_seed=forecast_seed,
        warm=warm)
    deferral = (ReplanQueueDeferral(guard_s, replan_guard_s, replan_margin)
                if replan else QueueDeferral(guard_s))
    return PolicyPipeline(
        tele, pricer, deferral, server=server,
        lam_co2=lam_co2, lam_h2o=lam_h2o, lam_ref=lam_ref, window=window,
        sigma=sigma, backend=backend, record_windows=record_windows)
