"""Declarative policy-spec grammar: ``name[key=value,key=value]``.

A scheduling policy is *data*: a registered name plus a dict of explicitly
overridden, typed parameters. The textual form round-trips —
``parse(str(spec)) == spec`` — so a spec survives CSV sweep rows, CLI flags,
and worker-process boundaries unchanged, and any sweep cell can be rebuilt
from its output row alone.

The grammar itself (syntax, type coercion, did-you-mean errors) lives in
``repro.spec`` — it is shared with scenario specs and executor specs
(``repro.experiments``). This module binds it to the *policy* registry:
``PolicySpec`` validates through ``repro.policy.registry``, and the error
names below keep their established identities (``UnknownPolicyError`` is
still a ``KeyError`` for backward compatibility with the old
``make_scheduler`` lambda-table lookup).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.spec import (ParamValueError, Spec, SpecError, SpecSyntaxError,
                        UnknownNameError, UnknownParamError, format_value,
                        split_specs)
from repro.spec import coerce_value as _coerce_value
from repro.spec import parse_raw as _parse_raw

#: Backward-compatible aliases: every policy-spec error is a shared
#: ``repro.spec`` error, so ``except PolicySpecError`` and
#: ``except UnknownPolicyError`` keep working across the extraction.
PolicySpecError = SpecError
UnknownPolicyError = UnknownNameError

__all__ = [
    "PolicySpec", "PolicySpecError", "SpecSyntaxError", "UnknownPolicyError",
    "UnknownParamError", "ParamValueError", "format_value", "coerce_value",
    "parse_raw", "split_specs",
]


@dataclasses.dataclass(frozen=True)
class PolicySpec(Spec):
    """A scheduler policy as data: registered name + explicit typed params.

    ``params`` holds only the *overridden* parameters — defaults stay with
    the registry entry, so ``str(spec)`` is terse and two specs compare equal
    exactly when they would build identically configured schedulers.
    """

    # -- functional updates (validated against the registry) -----------------

    def with_params(self, **overrides) -> "PolicySpec":
        """New spec with ``overrides`` replacing/adding params (validated —
        unknown or ill-typed keys raise, the silent-kwarg-drop fix)."""
        from repro.policy import registry
        return registry.get_policy(self.name).make_spec(
            **{**self.params, **overrides})

    def with_defaults(self, **defaults) -> "PolicySpec":
        """New spec with ``defaults`` filled in only where not already set
        (setdefault semantics; validated like ``with_params``)."""
        from repro.policy import registry
        return registry.get_policy(self.name).make_spec(
            **{**defaults, **self.params})


def coerce_value(raw: object, typ: type, *, policy: str, key: str) -> object:
    """Coerce ``raw`` to the declared param type (policy-flavoured wrapper
    over ``repro.spec.coerce_value``)."""
    return _coerce_value(raw, typ, owner=f"policy {policy!r}", key=key)


def parse_raw(text: str) -> Tuple[str, Dict[str, str]]:
    """Syntax-level parse: ``text`` -> (name, raw string params).

    Validates the grammar only; the registry layer (``repro.policy.parse``)
    types the values and checks the keys against the policy's schema.
    """
    return _parse_raw(text, kind="policy")
