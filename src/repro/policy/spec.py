"""Declarative policy-spec grammar: ``name[key=value,key=value]``.

A scheduling policy is *data*: a registered name plus a dict of explicitly
overridden, typed parameters. The textual form round-trips —
``parse(str(spec)) == spec`` — so a spec survives CSV sweep rows, CLI flags,
and worker-process boundaries unchanged, and any sweep cell can be rebuilt
from its output row alone.

Grammar (whitespace around tokens is ignored)::

    spec    :=  name [ '[' params ']' ]
    name    :=  [A-Za-z0-9._-]+
    params  :=  kv ( ',' kv )*  |  <empty>
    kv      :=  key '=' value
    key     :=  [A-Za-z0-9_]+
    value   :=  any run of characters except ',' ']' '='

Values are typed against the registered policy's parameter schema (see
``repro.policy.registry``), not guessed from their spelling: ``backend=jax``
stays a string because ``backend`` is declared ``str``, ``lam_h2o=0.7``
becomes a float because ``lam_h2o`` is declared ``float``. Formatting uses
``repr`` for floats, so parse∘format is exact (floats round-trip bit-for-bit
through ``repr``/``float``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Mapping, Tuple


class PolicySpecError(ValueError):
    """Base class for every spec-grammar / registry error."""


class SpecSyntaxError(PolicySpecError):
    """Malformed spec string (bad brackets, missing '=', empty key...)."""


class UnknownPolicyError(PolicySpecError, KeyError):
    """Spec names a policy that is not registered (KeyError for backward
    compatibility with the old ``make_scheduler`` lambda-table lookup)."""

    def __str__(self) -> str:        # KeyError would repr() the message
        return self.args[0] if self.args else ""


class UnknownParamError(PolicySpecError):
    """Spec carries a parameter the policy does not declare."""


class ParamValueError(PolicySpecError):
    """Parameter value cannot be coerced to its declared type."""


_NAME_RE = re.compile(r"^[A-Za-z0-9._-]+$")
_KEY_RE = re.compile(r"^[A-Za-z0-9_]+$")


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """A scheduler policy as data: registered name + explicit typed params.

    ``params`` holds only the *overridden* parameters — defaults stay with
    the registry entry, so ``str(spec)`` is terse and two specs compare equal
    exactly when they would build identically configured schedulers.
    """

    name: str
    params: Mapping[str, object] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "params", dict(self.params))

    # -- textual form --------------------------------------------------------

    def format(self) -> str:
        """Canonical string form (sorted params; omits brackets when empty)."""
        if not self.params:
            return self.name
        kv = ",".join(f"{k}={format_value(self.params[k])}"
                      for k in sorted(self.params))
        return f"{self.name}[{kv}]"

    def __str__(self) -> str:
        return self.format()

    # -- functional updates (validated against the registry) -----------------

    def with_params(self, **overrides) -> "PolicySpec":
        """New spec with ``overrides`` replacing/adding params (validated —
        unknown or ill-typed keys raise, the silent-kwarg-drop fix)."""
        from repro.policy import registry
        return registry.get_policy(self.name).make_spec(
            **{**self.params, **overrides})

    def with_defaults(self, **defaults) -> "PolicySpec":
        """New spec with ``defaults`` filled in only where not already set
        (setdefault semantics; validated like ``with_params``)."""
        from repro.policy import registry
        return registry.get_policy(self.name).make_spec(
            **{**defaults, **self.params})


def format_value(v: object) -> str:
    """Render one param value so that type-directed parsing recovers it."""
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, float):
        return repr(v)               # repr round-trips floats exactly
    return str(v)


def coerce_value(raw: object, typ: type, *, policy: str, key: str) -> object:
    """Coerce ``raw`` (a grammar string or an already-typed Python value) to
    the declared param type, raising ``ParamValueError`` on mismatch."""

    def bad(expected: str):
        return ParamValueError(
            f"policy {policy!r}: parameter {key!r} expects {expected}, "
            f"got {raw!r}")

    if typ is bool:
        if isinstance(raw, bool):
            return raw
        if isinstance(raw, (int, float)) and raw in (0, 1):
            return bool(raw)
        if isinstance(raw, str):
            low = raw.strip().lower()
            if low in ("true", "1", "yes", "on"):
                return True
            if low in ("false", "0", "no", "off"):
                return False
        raise bad("a bool (true/false)")
    if typ is int:
        if isinstance(raw, bool):
            raise bad("an int")
        if isinstance(raw, int):
            return raw
        if isinstance(raw, float) and raw == int(raw):
            return int(raw)
        if isinstance(raw, str):
            try:
                return int(raw.strip())
            except ValueError:
                raise bad("an int") from None
        raise bad("an int")
    if typ is float:
        if isinstance(raw, bool):
            raise bad("a float")
        if isinstance(raw, (int, float)):
            return float(raw)
        if isinstance(raw, str):
            try:
                return float(raw.strip())
            except ValueError:
                raise bad("a float") from None
        raise bad("a float")
    if typ is str:
        if isinstance(raw, str):
            return raw
        raise bad("a string")
    raise ParamValueError(f"policy {policy!r}: parameter {key!r} declares "
                          f"unsupported type {typ!r}")


def parse_raw(text: str) -> Tuple[str, Dict[str, str]]:
    """Syntax-level parse: ``text`` -> (name, raw string params).

    Validates the grammar only; the registry layer (``repro.policy.parse``)
    types the values and checks the keys against the policy's schema.
    """
    if not isinstance(text, str):
        raise SpecSyntaxError(f"policy spec must be a string, got {text!r}")
    s = text.strip()
    if "[" not in s:
        name, body = s, None
    else:
        name, _, rest = s.partition("[")
        if not rest.endswith("]"):
            raise SpecSyntaxError(f"unterminated '[' in policy spec {text!r}")
        body = rest[:-1]
        if "[" in body or "]" in body:
            raise SpecSyntaxError(f"nested brackets in policy spec {text!r}")
    name = name.strip()
    if not _NAME_RE.match(name):
        raise SpecSyntaxError(f"invalid policy name in spec {text!r}")
    params: Dict[str, str] = {}
    if body is not None and body.strip():
        for item in body.split(","):
            key, eq, value = item.partition("=")
            key, value = key.strip(), value.strip()
            if not eq:
                raise SpecSyntaxError(
                    f"expected key=value, got {item.strip()!r} in {text!r}")
            if not _KEY_RE.match(key):
                raise SpecSyntaxError(f"invalid parameter key {key!r} "
                                      f"in {text!r}")
            if not value:
                raise SpecSyntaxError(f"empty value for parameter {key!r} "
                                      f"in {text!r}")
            if key in params:
                raise SpecSyntaxError(f"duplicate parameter {key!r} "
                                      f"in {text!r}")
            params[key] = value
    return name, params


def split_specs(text: str) -> List[str]:
    """Split a comma-separated list of spec strings, honouring brackets:
    ``"a,b[x=1,y=2],c"`` -> ``["a", "b[x=1,y=2]", "c"]`` (the CLI
    ``--schedulers`` grammar)."""
    out: List[str] = []
    depth, cur = 0, []
    for ch in text:
        if ch == "[":
            depth += 1
        elif ch == "]":
            depth = max(depth - 1, 0)
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    return [s.strip() for s in out if s.strip()]
