"""Policy registry: ``@register_policy`` + typed param schemas.

Replaces the old ``baselines.make_scheduler`` lambda table and the
``TUNABLE_SCHEDULERS`` / ``FORECAST_SCHEDULERS`` frozensets: every scheduler
is registered once with a description and a parameter schema, unknown names
and params fail fast with a did-you-mean message (nothing is silently
dropped any more), and any registered policy can be built from a
``PolicySpec`` — or its string form — anywhere a scheduler is accepted.

The grammar/validation plumbing is the shared ``repro.spec`` module (also
used by scenario and executor specs); this registry contributes the policy
schemas and factories.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Union

from repro.policy.spec import PolicySpec, parse_raw
from repro.spec import (Param, unknown_name_error, unknown_param_error,
                        validate_params)

SpecLike = Union[str, PolicySpec]


@dataclasses.dataclass(frozen=True)
class PolicyEntry:
    """A registered scheduling policy."""
    name: str
    description: str
    params: Dict[str, Param]
    factory: Callable                 # (tele, **explicit_params) -> scheduler
    # Forecast-driven policies accept the scenario sweep's forecast-error
    # injection (forecast_bias / forecast_noise / forecast_seed defaults).
    forecast_driven: bool = False
    # Stateless policies carry no scheduler-internal state across fully
    # drained engine instants (no history window, no deferral queue, no
    # round-robin cursor), so a sharded executor may rebuild them fresh per
    # trace slice and still reproduce the unsharded run bit-for-bit when
    # slice boundaries are quiescent. Stateful policies shard via the
    # engine-state handoff chain instead (repro.experiments.shard).
    stateless: bool = False

    def make_spec(self, **params) -> PolicySpec:
        """Validated, coerced ``PolicySpec`` for this policy."""
        return PolicySpec(self.name, validate_params(
            "policy", self.name, self.params, params))

    def build(self, tele, spec: PolicySpec):
        return self.factory(tele, **dict(spec.params))


_REGISTRY: Dict[str, PolicyEntry] = {}


def register_policy(name: str, description: str,
                    params: List[Param] = (),
                    forecast_driven: bool = False,
                    stateless: bool = False):
    """Decorator: register ``fn(tele, **params) -> scheduler`` under ``name``."""
    def deco(fn):
        _REGISTRY[name] = PolicyEntry(
            name=name, description=description,
            params={p.name: p for p in params}, factory=fn,
            forecast_driven=forecast_driven, stateless=stateless)
        return fn
    return deco


def _ensure_builtins() -> None:
    # Import side-effect registration (lazy to keep the package import-cycle
    # free: builtin pulls in the rule schedulers which import the pipeline).
    if "waterwise" not in _REGISTRY:
        from repro.policy import builtin  # noqa: F401


def get_policy(name: str) -> PolicyEntry:
    _ensure_builtins()
    entry = _REGISTRY.get(name)
    if entry is None:
        raise unknown_name_error("policy", name, list(_REGISTRY))
    return entry


def list_policies() -> List[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)


def parse(text: SpecLike) -> PolicySpec:
    """Parse + validate a spec string against the registry.

    Accepts an existing ``PolicySpec`` too (re-validated), so every consumer
    can take either form.
    """
    if isinstance(text, PolicySpec):
        return get_policy(text.name).make_spec(**text.params)
    name, raw = parse_raw(text)
    return get_policy(name).make_spec(**raw)


as_spec = parse     # readability alias: as_spec("waterwise[...]") / (spec)


def build(spec: SpecLike, tele, **overrides):
    """Instantiate the scheduler a spec describes, against ``tele``.

    ``overrides`` are merged on top of the spec's params (validated), which
    is what the deprecated ``make_scheduler(name, tele, **kw)`` shim
    forwards to.
    """
    s = parse(spec)
    if overrides:
        s = s.with_params(**overrides)
    return get_policy(s.name).build(tele, s)


def describe(markdown: bool = False) -> str:
    """Human-readable registry dump (the ``--list-schedulers`` surface and
    the source of the README scheduler table)."""
    _ensure_builtins()
    entries = [_REGISTRY[n] for n in sorted(_REGISTRY)]
    if markdown:
        lines = ["| policy | parameters | description |", "|---|---|---|"]
        for e in entries:
            ps = ", ".join(f"`{p.describe()}`" for p in e.params.values()) \
                or "—"
            lines.append(f"| `{e.name}` | {ps} | {e.description} |")
        return "\n".join(lines)
    lines = []
    for e in entries:
        lines.append(f"{e.name:20s} {e.description}")
        for p in e.params.values():
            doc = f"  — {p.help}" if p.help else ""
            lines.append(f"    {p.describe():28s}{doc}")
    return "\n".join(lines)


# Exported for backward compatibility: ``Param`` originally lived here.
__all__ = ["Param", "PolicyEntry", "SpecLike", "register_policy",
           "get_policy", "list_policies", "parse", "as_spec", "build",
           "describe", "unknown_param_error"]
