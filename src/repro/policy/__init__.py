"""Declarative scheduling-policy API: specs, registry, one pipeline.

A scheduler is *data* here: a ``PolicySpec`` — registered name + typed,
validated params — that round-trips through its string form
(``"waterwise[lam_h2o=0.7,backend=jax]"``), sweep CSV rows, and CLI flags.
The registry (``@register_policy``) maps specs to builders; the paper's
controller family is a set of specs over ONE composable ``PolicyPipeline``
(Pricer × DeferralPolicy × solver backend), not a class hierarchy.

Typical use::

    from repro import policy

    sched = policy.build("waterwise[lam_h2o=0.7,backend=jax]", tele)
    spec  = policy.parse("waterwise-forecast[horizon_slots=8]")
    spec2 = spec.with_params(risk=0.5)        # validated; raises on typos
    print(policy.describe())                  # the full registry, documented

Everything a spec cannot express (an unknown policy, a typo'd or ill-typed
param) fails fast with a did-you-mean message — nothing is silently
dropped.
"""
from repro.policy.pipeline import (DEFER, HOLD, RUN, Decision, DeferralPolicy,
                                   ForecastPricer, HistoryLearner,
                                   NextRoundDeferral, PolicyPipeline,
                                   PricedPlan, Pricer, QueueDeferral,
                                   ReplanQueueDeferral, Scheduler,
                                   SnapshotPricer, forecast_pipeline,
                                   reactive_pipeline)
from repro.policy.registry import (Param, PolicyEntry, as_spec, build,
                                   describe, get_policy, list_policies,
                                   parse, register_policy)
from repro.policy.spec import (ParamValueError, PolicySpec, PolicySpecError,
                               SpecSyntaxError, UnknownParamError,
                               UnknownPolicyError, split_specs)

__all__ = [
    # spec grammar
    "PolicySpec", "PolicySpecError", "SpecSyntaxError", "UnknownPolicyError",
    "UnknownParamError", "ParamValueError", "split_specs",
    # registry
    "Param", "PolicyEntry", "register_policy", "get_policy", "list_policies",
    "parse", "as_spec", "build", "describe",
    # pipeline
    "Decision", "Scheduler", "HistoryLearner", "PolicyPipeline", "Pricer",
    "PricedPlan", "SnapshotPricer", "ForecastPricer", "DeferralPolicy",
    "NextRoundDeferral", "QueueDeferral", "ReplanQueueDeferral",
    "reactive_pipeline", "forecast_pipeline", "RUN", "HOLD", "DEFER",
]
