"""Built-in policy registrations: the paper's scheduler family as specs.

Param schemas for the pipeline-backed policies are *derived* from the
factory signatures (``reactive_pipeline`` / ``forecast_pipeline``), so a new
tunable added to a factory is automatically spec-addressable and the
documented defaults can never drift from the code. Rule-based baselines
declare their (few) params by hand.

The rule schedulers themselves are imported lazily inside the factories —
``repro.core.baselines`` imports the pipeline module, so importing it here
at module scope would cycle.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.policy.pipeline import forecast_pipeline, reactive_pipeline
from repro.policy.registry import Param, register_policy
from repro.spec import params_from_signature

_HELP: Dict[str, str] = {
    "lam_co2": "carbon weight λ_CO2 (λ_CO2 + λ_H2O must sum to 1; "
               "specifying only one sets the other to its complement)",
    "lam_h2o": "water weight λ_H2O (complement rule as for lam_co2)",
    "lam_ref": "history-term weight λ_ref (Eq 8)",
    "lam_emb": "embodied-carbon weight λ_emb (three-way Eq-8 extension; "
               "λ_CO2 + λ_H2O + λ_emb must sum to 1)",
    "window": "history-learner trailing window (rounds)",
    "sigma": "soft-violation penalty σ (Eqs 12-13)",
    "backend": "solver backend (flow / jax / fused / scipy / pulp)",
    "defer_margin": "defer-arc price margin over the trailing-mean cost",
    "defer_slack_s": "min remaining TOL budget (s) to offer the defer arc",
    "record_windows": "record every solved window for offline batched replay",
    "forecaster": "forecast model (holtwinters / seasonal-naive / "
                  "persistence / learned / oracle)",
    "horizon_slots": "number of future slots offered per round",
    "slot_s": "slot width (seconds)",
    "risk": "shade future slots toward the upper quantile band by this "
            "fraction",
    "defer_eps": "per-slot tie-break cost — deferral must earn its delay",
    "guard_s": "tolerance budget reserve forcing early release of held jobs",
    "warmup_hours": "telemetry archive hours used to warm-start the "
                    "forecaster (0 = cold start)",
    "forecast_bias": "multiplicative forecast error injection (1.0 = off)",
    "forecast_noise": "relative forecast noise injection (0.0 = off)",
    "forecast_seed": "seed for the injected forecast noise",
    "warm": "carry Sinkhorn potentials between rounds as warm starts "
            "(fused backend only)",
    "replan": "receding-horizon re-planning: held jobs re-enter pricing "
              "every round instead of committing at admission",
    "replan_guard_s": "commit window (s): held jobs this close to release "
                      "are not re-planned",
    "replan_margin": "hysteresis: a re-planned early run must beat the "
                     "committed slot by this cost margin",
}

# Constructor arguments that are not spec-addressable (non-serializable or
# simulator-internal).
_NON_SPEC = {"tele", "server"}


def _sig_params(fn, exclude: Sequence[str] = ()) -> List[Param]:
    """Derive a Param list from a factory's keyword-only signature (shared
    ``repro.spec`` introspection; non-spec-expressible defaults like the
    ``server`` object are skipped automatically)."""
    return params_from_signature(fn, skip=_NON_SPEC | set(exclude),
                                 help_text=_HELP)


# -- rule-based comparison schedulers (paper §5) ----------------------------

@register_policy("baseline",
                 "home region, carbon/water-unaware (paper's reference)",
                 stateless=True)
def _baseline(tele):
    from repro.core.baselines import Baseline
    return Baseline(tele)


@register_policy("round-robin",
                 "cyclic region placement, sustainability-unaware")
def _round_robin(tele):
    from repro.core.baselines import RoundRobin
    return RoundRobin(tele)


@register_policy("least-load",
                 "most-free-capacity region, sustainability-unaware",
                 stateless=True)
def _least_load(tele):
    from repro.core.baselines import LeastLoad
    return LeastLoad(tele)


@register_policy("carbon-greedy-opt",
                 "infeasible oracle: knows future carbon intensity, "
                 "delays/moves each job to its per-job best slot",
                 stateless=True)
def _carbon_greedy(tele):
    from repro.core.baselines import GreedyOpt
    return GreedyOpt(tele, "carbon")


@register_policy("water-greedy-opt",
                 "infeasible oracle: knows future water intensity, "
                 "delays/moves each job to its per-job best slot",
                 stateless=True)
def _water_greedy(tele):
    from repro.core.baselines import GreedyOpt
    return GreedyOpt(tele, "water")


@register_policy("ecovisor",
                 "home-region carbon scaler (customized [50]): resource-"
                 "scales jobs against a trailing carbon-intensity target",
                 params=[Param("window", int, 24,
                               "trailing carbon-target window (hours)")],
                 stateless=True)
def _ecovisor(tele, **p):
    from repro.core.baselines import Ecovisor
    return Ecovisor(tele, **p)


# -- pipeline-backed policies -----------------------------------------------

def _complete_lams(p: Dict) -> Dict:
    """Specifying one of the Eq-8 weights implies the other (they must sum
    to 1), so ``waterwise[lam_h2o=0.7]`` is a complete spec."""
    if "lam_h2o" in p and "lam_co2" not in p:
        p = dict(p, lam_co2=1.0 - p["lam_h2o"])
    elif "lam_co2" in p and "lam_h2o" not in p:
        p = dict(p, lam_h2o=1.0 - p["lam_co2"])
    return p


@register_policy("waterwise",
                 "the paper's myopic carbon+water co-optimizing controller "
                 "(Algorithm 1): snapshot pricing + defer arc + MILP",
                 params=_sig_params(reactive_pipeline))
def _waterwise(tele, **p):
    return reactive_pipeline(tele, **_complete_lams(p))


@register_policy("waterwise-embodied",
                 "three-way footprint controller: adds per-region amortized "
                 "embodied carbon to the Eq-8 objective "
                 "(λ_emb + equal-split operational weights sum to 1)",
                 params=[Param("lam_embodied", float, 0.2,
                               "embodied-carbon weight λ_emb; the remaining "
                               "(1-λ_emb) splits evenly between carbon and "
                               "water")]
                 + _sig_params(reactive_pipeline,
                               exclude=("lam_co2", "lam_h2o", "lam_emb")))
def _waterwise_embodied(tele, lam_embodied: float = 0.2, **p):
    op = (1.0 - lam_embodied) / 2.0
    return reactive_pipeline(tele, lam_co2=op, lam_h2o=op,
                             lam_emb=lam_embodied, **p)


@register_policy("waterwise-forecast",
                 "forecast-driven temporal shifting: jobs x (regions x "
                 "horizon-slots) priced by a Holt-Winters forecast",
                 params=_sig_params(forecast_pipeline),
                 forecast_driven=True)
def _waterwise_forecast(tele, **p):
    return forecast_pipeline(tele, **_complete_lams(p))


@register_policy("waterwise-oracle",
                 "upper-bound variant: temporal shifting priced by the "
                 "true future telemetry",
                 params=_sig_params(forecast_pipeline,
                                    exclude=("forecaster",)),
                 forecast_driven=True)
def _waterwise_oracle(tele, **p):
    return forecast_pipeline(tele, forecaster="oracle",
                             **_complete_lams(p))


@register_policy("carbon-forecast",
                 "carbon-only forecast shifting (λ_CO2=1): the "
                 "GreenCourier-style comparison point",
                 params=_sig_params(forecast_pipeline,
                                    exclude=("lam_co2", "lam_h2o")),
                 forecast_driven=True)
def _carbon_forecast(tele, **p):
    return forecast_pipeline(tele, lam_co2=1.0, lam_h2o=0.0, **p)
