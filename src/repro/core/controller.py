"""WaterWise Optimization Decision Controller — paper §4, Algorithm 1.

Ties together: problem construction (Eq 8 costs, Eq 11 arc filter), the
slack manager (Eq 14), the MILP solver with hard→soft fallback (Eqs 8-13),
and the history learner (the λ_ref·(λ_CO2·CO2_ref + λ_H2O·H2O_ref) term).

The controller is deliberately *myopic* (paper: "the scheduler cannot have
futuristic information") — it prices every job at the current telemetry
snapshot and lets delay tolerance + temporal variation create savings.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import footprint, problem, slack, solvers, telemetry


@dataclasses.dataclass
class Decision:
    """One scheduling-round outcome."""
    scheduled: List[problem.Job]       # jobs with .region set by this round
    assign: np.ndarray                 # [len(scheduled)] region index
    deferred: List[problem.Job]        # jobs pushed to the next round
    solver: solvers.SolveResult
    softened: bool


class HistoryLearner:
    """Trailing-window mean of regional carbon/water intensity.

    Two uses: (a) the normalized CO2_ref / H2O_ref of Eq (8) — regions that
    have *recently* been dirty/thirsty are discouraged even if momentarily
    attractive; (b) the raw trailing means price the *defer* arc — the
    expected cost of waiting for a more typical hour (window=10, λ_ref=0.1
    per §5)."""

    def __init__(self, num_regions: int, window: int = 10,
                 raw_window: int = 240):
        self.window = window
        self.ci = collections.deque(maxlen=window)
        self.wi = collections.deque(maxlen=window)
        # "Typical conditions" need a longer horizon than the Eq-8 ref term:
        # 240 rounds ≈ 2 h at the default 30 s scheduling period. Stored as a
        # ring buffer ([raw_window, 3, R]) — the per-round mean is one
        # vectorized reduction instead of rebuilding arrays from a deque of
        # dicts (this is on the simulator's per-round hot path).
        self.raw_window = raw_window
        self._raw = np.zeros((raw_window, 3, num_regions))
        self._raw_n = 0          # total observations so far
        self.num_regions = num_regions

    def observe(self, snap) -> None:
        ci, wi = snap["ci"], snap["water_intensity"]
        self.ci.append(ci / max(ci.max(), 1e-9))
        self.wi.append(wi / max(wi.max(), 1e-9))
        self._raw[self._raw_n % self.raw_window, 0] = ci
        self._raw[self._raw_n % self.raw_window, 1] = snap["ewif"]
        self._raw[self._raw_n % self.raw_window, 2] = snap["wue"]
        self._raw_n += 1

    @property
    def co2_ref(self) -> Optional[np.ndarray]:
        return np.mean(self.ci, axis=0) if self.ci else None

    @property
    def h2o_ref(self) -> Optional[np.ndarray]:
        return np.mean(self.wi, axis=0) if self.wi else None

    def mean_raw(self) -> Optional[dict]:
        if self._raw_n < 2:
            return None
        m = self._raw[:min(self._raw_n, self.raw_window)].mean(axis=0)
        return dict(ci=m[0], ewif=m[1], wue=m[2])


class Controller:
    """Algorithm 1. ``schedule()`` is one controller invocation."""

    def __init__(self, tele: telemetry.Telemetry,
                 server: footprint.ServerSpec = None,
                 lam_co2: float = 0.5, lam_h2o: float = 0.5,
                 lam_ref: float = 0.1, window: int = 10,
                 sigma: float = 10.0, backend: str = "flow",
                 defer_margin: float = 0.02, defer_slack_s: float = 120.0):
        assert abs(lam_co2 + lam_h2o - 1.0) < 1e-9, "weights must sum to 1"
        self.tele = tele
        self.server = server or footprint.m5_metal()
        self.lam_co2, self.lam_h2o, self.lam_ref = lam_co2, lam_h2o, lam_ref
        self.sigma = sigma
        self.backend = backend
        # Defer arc: waiting is priced at the trailing-mean cost plus a
        # margin; only jobs with > defer_slack_s of remaining TOL budget may
        # take it (they must still fit a later round + transfer).
        self.defer_margin = defer_margin
        self.defer_slack_s = defer_slack_s
        self.history = HistoryLearner(tele.num_regions, window)
        self.solve_times: List[float] = []

    # -- Algorithm 1 ---------------------------------------------------------

    def schedule(self, jobs: Sequence[problem.Job], now_s: float,
                 capacity: np.ndarray) -> Decision:
        jobs = list(jobs)                                    # J_all (line 3)
        if not jobs:
            return Decision([], np.zeros(0, np.int64), [], None, False)

        total_cap = int(capacity.sum())
        deferred: List[problem.Job] = []
        if len(jobs) > total_cap:                            # lines 5-7
            jobs, deferred = slack.pick_most_urgent(jobs, now_s, total_cap)
        if not jobs:
            return Decision([], np.zeros(0, np.int64), deferred, None, False)

        snap = self.tele.at(now_s)
        inst = problem.build(jobs, self.tele, now_s, capacity, self.server,
                             snap=snap)
        self.history.observe(snap)
        cost = inst.objective_matrix(self.lam_co2, self.lam_h2o, self.lam_ref,
                                     self.history.co2_ref,
                                     self.history.h2o_ref)
        tol = np.array([j.tolerance for j in jobs])

        # Temporal deferral arc (the delay-tolerance exploitation of paper
        # Fig 5): one virtual column priced at the trailing-mean cost + a
        # margin. The MILP sends a job there exactly when *now* is a worse-
        # than-typical hour everywhere it could run — it then waits for the
        # next round. Arc-filtered by remaining slack so tolerance is never
        # risked.
        N = self.tele.num_regions
        hist = self.history.mean_raw()
        cost_x, allowed_x, cap_x = cost, inst.allowed, np.asarray(capacity)
        overrun_x = inst.overrun
        if hist is not None:
            h_co2 = footprint.job_carbon(
                np.array([j.energy_kwh for j in jobs])[:, None],
                np.array([j.exec_time_s for j in jobs])[:, None],
                hist["ci"][None, :], self.server)
            h_h2o = footprint.job_water(
                np.array([j.energy_kwh for j in jobs])[:, None],
                np.array([j.exec_time_s for j in jobs])[:, None],
                snap["pue"][None, :], hist["ewif"][None, :],
                hist["wue"][None, :], snap["wsf"][None, :], self.server)
            h_obj = (self.lam_co2 * h_co2 / inst.co2_max[:, None]
                     + self.lam_h2o * h_h2o / inst.h2o_max[:, None])
            # Same λ_ref history term as the real arcs — the defer arc must
            # be compared apples-to-apples or it is uniformly cheaper and
            # every job waits unconditionally (no temporal signal).
            if self.history.co2_ref is not None:
                h_obj = h_obj + self.lam_ref * (
                    self.lam_co2 * self.history.co2_ref
                    + self.lam_h2o * self.history.h2o_ref)[None, :]
            defer_cost = h_obj.min(axis=1) + self.defer_margin
            slack_left = np.array(
                [j.tolerance * j.exec_time_s
                 - max(now_s - j.submit_time_s, 0.0) for j in jobs])
            can_wait = slack_left > self.defer_slack_s
            cost_x = np.concatenate([cost, defer_cost[:, None]], axis=1)
            allowed_x = np.concatenate([inst.allowed, can_wait[:, None]],
                                       axis=1)
            overrun_x = np.concatenate(
                [inst.overrun, np.zeros((len(jobs), 1))], axis=1)
            cap_x = np.concatenate([cap_x, [len(jobs)]])

        softened = len(jobs) > total_cap                     # line 7 path
        if softened:
            # Soft mode drops arc filters — the defer column must not be
            # offered there (a tolerance-violating job would "wait" forever
            # instead of paying its penalty and running).
            res = solvers.solve(cost, inst.allowed, capacity,
                                backend=self.backend, soften=True,
                                overrun=inst.overrun, tol=tol,
                                sigma=self.sigma)
        else:
            res = solvers.solve(cost_x, allowed_x, cap_x,
                                backend=self.backend, soften=False,
                                overrun=overrun_x, tol=tol, sigma=self.sigma)
            if not res.feasible:                             # lines 10-11
                softened = True
                res = solvers.solve(cost, inst.allowed, capacity,
                                    backend=self.backend, soften=True,
                                    overrun=inst.overrun, tol=tol,
                                    sigma=self.sigma)
        self.solve_times.append(res.solve_time_s)

        placed = (res.assign >= 0) & (res.assign < N)
        scheduled = [j for j, p in zip(jobs, placed) if p]
        deferred += [j for j, p in zip(jobs, placed) if not p]
        assign = res.assign[placed]
        for j, n in zip(scheduled, assign):
            j.region = int(n)
        return Decision(scheduled, assign, deferred, res, softened)
