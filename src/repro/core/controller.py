"""WaterWise Optimization Decision Controller — compatibility surface.

The controller now lives in ``repro.policy.pipeline`` as ONE composable
``PolicyPipeline`` (Pricer × DeferralPolicy × solver backend) instead of a
``Controller`` / ``ForecastController`` subclass pair; every scheduler
variant is a declarative ``PolicySpec`` over that pipeline (see
``repro.policy``). This module keeps the historical names importable:

  ``Controller(tele, **kw)``          -> ``reactive_pipeline`` (Algorithm 1:
                                         snapshot pricing + defer arc)
  ``ForecastController(tele, **kw)``  -> ``forecast_pipeline`` (forecast-
                                         grid pricing + deferral queue)

Both return a ``PolicyPipeline`` with the same attributes and the same
``schedule(jobs, now_s, capacity) -> Decision`` protocol as before.
"""
from __future__ import annotations

from repro.policy.pipeline import (Decision, HistoryLearner, PolicyPipeline,
                                   forecast_pipeline, reactive_pipeline)

# Historical constructor names (still used by tests and downstream code).
Controller = reactive_pipeline
ForecastController = forecast_pipeline

__all__ = ["Controller", "Decision", "ForecastController", "HistoryLearner",
           "PolicyPipeline", "forecast_pipeline", "reactive_pipeline"]
