"""WaterWise Optimization Decision Controller — paper §4, Algorithm 1.

Ties together: problem construction (Eq 8 costs, Eq 11 arc filter), the
slack manager (Eq 14), the MILP solver with hard→soft fallback (Eqs 8-13),
and the history learner (the λ_ref·(λ_CO2·CO2_ref + λ_H2O·H2O_ref) term).

The controller is deliberately *myopic* (paper: "the scheduler cannot have
futuristic information") — it prices every job at the current telemetry
snapshot and lets delay tolerance + temporal variation create savings.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import footprint, problem, slack, solvers, telemetry


@dataclasses.dataclass
class Decision:
    """One scheduling-round outcome."""
    scheduled: List[problem.Job]       # jobs with .region set by this round
    assign: np.ndarray                 # [len(scheduled)] region index
    deferred: List[problem.Job]        # jobs pushed to the next round
    solver: solvers.SolveResult
    softened: bool
    # Earliest instant the scheduler plans to act on a held job. The engine
    # fast-forwards to it instead of stalling out when the fleet is idle and
    # no arrivals remain (temporal shifting holds jobs *on purpose*).
    wake_s: Optional[float] = None


class HistoryLearner:
    """Trailing-window mean of regional carbon/water intensity.

    Two uses: (a) the normalized CO2_ref / H2O_ref of Eq (8) — regions that
    have *recently* been dirty/thirsty are discouraged even if momentarily
    attractive; (b) the raw trailing means price the *defer* arc — the
    expected cost of waiting for a more typical hour (window=10, λ_ref=0.1
    per §5)."""

    def __init__(self, num_regions: int, window: int = 10,
                 raw_window: int = 240):
        self.window = window
        self.ci = collections.deque(maxlen=window)
        self.wi = collections.deque(maxlen=window)
        # "Typical conditions" need a longer horizon than the Eq-8 ref term:
        # 240 rounds ≈ 2 h at the default 30 s scheduling period. Stored as a
        # ring buffer ([raw_window, 3, R]) — the per-round mean is one
        # vectorized reduction instead of rebuilding arrays from a deque of
        # dicts (this is on the simulator's per-round hot path).
        self.raw_window = raw_window
        self._raw = np.zeros((raw_window, 3, num_regions))
        self._raw_n = 0          # total observations so far
        self.num_regions = num_regions

    def observe(self, snap) -> None:
        ci, wi = snap["ci"], snap["water_intensity"]
        self.ci.append(ci / max(ci.max(), 1e-9))
        self.wi.append(wi / max(wi.max(), 1e-9))
        self._raw[self._raw_n % self.raw_window, 0] = ci
        self._raw[self._raw_n % self.raw_window, 1] = snap["ewif"]
        self._raw[self._raw_n % self.raw_window, 2] = snap["wue"]
        self._raw_n += 1

    @property
    def co2_ref(self) -> Optional[np.ndarray]:
        return np.mean(self.ci, axis=0) if self.ci else None

    @property
    def h2o_ref(self) -> Optional[np.ndarray]:
        return np.mean(self.wi, axis=0) if self.wi else None

    def mean_raw(self) -> Optional[dict]:
        if self._raw_n < 2:
            return None
        m = self._raw[:min(self._raw_n, self.raw_window)].mean(axis=0)
        return dict(ci=m[0], ewif=m[1], wue=m[2])


class Controller:
    """Algorithm 1. ``schedule()`` is one controller invocation."""

    def __init__(self, tele: telemetry.Telemetry,
                 server: footprint.ServerSpec = None,
                 lam_co2: float = 0.5, lam_h2o: float = 0.5,
                 lam_ref: float = 0.1, window: int = 10,
                 sigma: float = 10.0, backend: str = "flow",
                 defer_margin: float = 0.02, defer_slack_s: float = 120.0,
                 record_windows: bool = False):
        assert abs(lam_co2 + lam_h2o - 1.0) < 1e-9, "weights must sum to 1"
        self.tele = tele
        self.server = server or footprint.m5_metal()
        self.lam_co2, self.lam_h2o, self.lam_ref = lam_co2, lam_h2o, lam_ref
        self.sigma = sigma
        self.backend = backend
        # Defer arc: waiting is priced at the trailing-mean cost plus a
        # margin; only jobs with > defer_slack_s of remaining TOL budget may
        # take it (they must still fit a later round + transfer).
        self.defer_margin = defer_margin
        self.defer_slack_s = defer_slack_s
        self.history = HistoryLearner(tele.num_regions, window)
        self.solve_times: List[float] = []
        # Offline queued-window replay: when enabled, every solved instance
        # (the one that produced the round's decision) is captured so the
        # whole run can be re-solved in bulk through ``solvers.solve_many``
        # (bucketed + vmapped Sinkhorn — one device dispatch per bucket).
        self.record_windows = record_windows
        self.recorded: List[dict] = []

    def _record(self, cost, allowed, capacity, overrun, tol, soften) -> None:
        if self.record_windows:
            self.recorded.append(dict(
                cost=np.array(cost), allowed=np.array(allowed),
                capacity=np.array(capacity), overrun=np.array(overrun),
                tol=np.array(tol), soften=bool(soften)))

    def replay_recorded(self, backend: str = "jax") -> List[solvers.SolveResult]:
        """Re-solve every recorded scheduling window through the batched
        ``solvers.solve_many`` path; results come back in round order.

        Hard and soft rounds are batched separately (``soften`` is a batch-
        level flag); with the default ``jax`` backend each group buckets by
        padded shape and runs one vmapped Sinkhorn dispatch per bucket.
        """
        out: List[Optional[solvers.SolveResult]] = [None] * len(self.recorded)
        for soften in (False, True):
            idx = [i for i, w in enumerate(self.recorded)
                   if w["soften"] == soften]
            if not idx:
                continue
            res = solvers.solve_many(
                [self.recorded[i]["cost"] for i in idx],
                [self.recorded[i]["allowed"] for i in idx],
                [self.recorded[i]["capacity"] for i in idx],
                backend=backend, soften=soften,
                overruns=[self.recorded[i]["overrun"] for i in idx],
                tols=[self.recorded[i]["tol"] for i in idx],
                sigma=self.sigma)
            for i, r in zip(idx, res):
                out[i] = r
        return out

    # -- Algorithm 1 ---------------------------------------------------------

    def schedule(self, jobs: Sequence[problem.Job], now_s: float,
                 capacity: np.ndarray) -> Decision:
        jobs = list(jobs)                                    # J_all (line 3)
        if not jobs:
            return Decision([], np.zeros(0, np.int64), [], None, False)

        total_cap = int(capacity.sum())
        deferred: List[problem.Job] = []
        if len(jobs) > total_cap:                            # lines 5-7
            jobs, deferred = slack.pick_most_urgent(jobs, now_s, total_cap)
        if not jobs:
            return Decision([], np.zeros(0, np.int64), deferred, None, False)

        snap = self.tele.at(now_s)
        inst = problem.build(jobs, self.tele, now_s, capacity, self.server,
                             snap=snap)
        self.history.observe(snap)
        cost = inst.objective_matrix(self.lam_co2, self.lam_h2o, self.lam_ref,
                                     self.history.co2_ref,
                                     self.history.h2o_ref)
        tol = np.array([j.tolerance for j in jobs])

        # Temporal deferral arc (the delay-tolerance exploitation of paper
        # Fig 5): one virtual column priced at the trailing-mean cost + a
        # margin. The MILP sends a job there exactly when *now* is a worse-
        # than-typical hour everywhere it could run — it then waits for the
        # next round. Arc-filtered by remaining slack so tolerance is never
        # risked.
        N = self.tele.num_regions
        hist = self.history.mean_raw()
        cost_x, allowed_x, cap_x = cost, inst.allowed, np.asarray(capacity)
        overrun_x = inst.overrun
        if hist is not None:
            h_co2 = footprint.job_carbon(
                np.array([j.energy_kwh for j in jobs])[:, None],
                np.array([j.exec_time_s for j in jobs])[:, None],
                hist["ci"][None, :], self.server)
            h_h2o = footprint.job_water(
                np.array([j.energy_kwh for j in jobs])[:, None],
                np.array([j.exec_time_s for j in jobs])[:, None],
                snap["pue"][None, :], hist["ewif"][None, :],
                hist["wue"][None, :], snap["wsf"][None, :], self.server)
            h_obj = (self.lam_co2 * h_co2 / inst.co2_max[:, None]
                     + self.lam_h2o * h_h2o / inst.h2o_max[:, None])
            # Same λ_ref history term as the real arcs — the defer arc must
            # be compared apples-to-apples or it is uniformly cheaper and
            # every job waits unconditionally (no temporal signal).
            if self.history.co2_ref is not None:
                h_obj = h_obj + self.lam_ref * (
                    self.lam_co2 * self.history.co2_ref
                    + self.lam_h2o * self.history.h2o_ref)[None, :]
            defer_cost = h_obj.min(axis=1) + self.defer_margin
            slack_left = np.array([j.slack_budget_s(now_s) for j in jobs])
            can_wait = slack_left > self.defer_slack_s
            cost_x = np.concatenate([cost, defer_cost[:, None]], axis=1)
            allowed_x = np.concatenate([inst.allowed, can_wait[:, None]],
                                       axis=1)
            overrun_x = np.concatenate(
                [inst.overrun, np.zeros((len(jobs), 1))], axis=1)
            cap_x = np.concatenate([cap_x, [len(jobs)]])

        softened = len(jobs) > total_cap                     # line 7 path
        if softened:
            # Soft mode drops arc filters — the defer column must not be
            # offered there (a tolerance-violating job would "wait" forever
            # instead of paying its penalty and running).
            res = solvers.solve(cost, inst.allowed, capacity,
                                backend=self.backend, soften=True,
                                overrun=inst.overrun, tol=tol,
                                sigma=self.sigma)
        else:
            res = solvers.solve(cost_x, allowed_x, cap_x,
                                backend=self.backend, soften=False,
                                overrun=overrun_x, tol=tol, sigma=self.sigma)
            if not res.feasible:                             # lines 10-11
                softened = True
                res = solvers.solve(cost, inst.allowed, capacity,
                                    backend=self.backend, soften=True,
                                    overrun=inst.overrun, tol=tol,
                                    sigma=self.sigma)
        if softened:
            self._record(cost, inst.allowed, capacity, inst.overrun, tol,
                         True)
        else:
            self._record(cost_x, allowed_x, cap_x, overrun_x, tol, False)
        self.solve_times.append(res.solve_time_s)

        placed = (res.assign >= 0) & (res.assign < N)
        scheduled = [j for j, p in zip(jobs, placed) if p]
        deferred += [j for j, p in zip(jobs, placed) if not p]
        assign = res.assign[placed]
        for j, n in zip(scheduled, assign):
            j.region = int(n)
        return Decision(scheduled, assign, deferred, res, softened)


class ForecastController(Controller):
    """Predictive spatio-temporal controller (beyond-paper subsystem).

    Replaces the reactive defer *arc* with a forecast-priced defer *grid*:
    every round solves ``jobs × (regions × horizon-slots)`` where slot 0 is
    "run now" at the live snapshot and slots 1..S−1 are "hold until t+s·Δ"
    priced at a forecast of (ci, ewif, wue) — Holt–Winters by default, the
    true-future ``oracle`` for upper-bound studies. Jobs assigned a future
    slot enter a ``DeferralQueue`` and are re-offered when their slot (or a
    slack guard) arrives; deadline feasibility is masked, never penalized,
    so deferral cannot cause a tolerance miss (see ``forecast.planner``).

    The flattened problem is the same capacitated transportation polytope,
    solved by the bucketed/padded Sinkhorn backend (``backend="jax"``) that
    already amortizes compiles across rounds.

    ``risk`` shades future-slot prices toward the upper quantile band
    (risk-averse deferral under forecast uncertainty); ``forecast_bias`` /
    ``forecast_noise`` inject systematic error for the ``forecast-error``
    scenario regime.
    """

    def __init__(self, tele: telemetry.Telemetry, *,
                 forecaster: str = "holtwinters", horizon_slots: int = 8,
                 slot_s: float = 1800.0, risk: float = 0.25,
                 defer_eps: float = 1e-3, guard_s: float = 240.0,
                 warmup_hours: int = 96,
                 forecast_bias: float = 1.0, forecast_noise: float = 0.0,
                 forecast_seed: int = 0, backend: str = "jax", **kw):
        super().__init__(tele, backend=backend, **kw)
        from repro import forecast as fcast
        self._fcast = fcast
        self.forecaster_name = forecaster
        self.horizon_slots = int(horizon_slots)
        self.slot_s = float(slot_s)
        # Pre-run telemetry archive: production forecasters are warm-started
        # on months of history, but a simulation starts at t=0. The synthetic
        # telemetry is the single period of a periodic environment
        # (``Telemetry.at`` wraps), so its cyclic extension *is* the
        # environment's past — the archive at simulated hour h is the
        # ``warmup_hours`` wrapped hours ending at h. Set 0 for a cold start.
        self.warmup_hours = int(warmup_hours)
        self.risk = float(risk)
        self.defer_eps = float(defer_eps)
        self.queue = fcast.DeferralQueue(guard_s)
        self.forecast_bias = float(forecast_bias)
        self.forecast_noise = float(forecast_noise)
        self.forecast_seed = int(forecast_seed)
        # Ground truth, stacked [T, 3R]: columns [ci | ewif | wue] — one
        # forecaster fit covers all three signals at once.
        self._truth = np.concatenate([tele.ci, tele.ewif, tele.wue], axis=1)
        self._fit_hour = -1
        self._forecast = None
        self._fitted = None
        # Online forecast-accuracy bookkeeping (the sweep's accuracy column):
        # each refit scores the previous forecast against the hours that have
        # since realized.
        self._ape_sum = 0.0
        self._ape_n = 0

    # -- forecasting ---------------------------------------------------------

    def _make_forecaster(self):
        if self.forecaster_name == "oracle":
            f = self._fcast.Oracle(self._truth)
        else:
            f = self._fcast.make_forecaster(self.forecaster_name)
        if self.forecast_bias != 1.0 or self.forecast_noise > 0.0:
            f = self._fcast.Perturbed(f, self.forecast_bias,
                                      self.forecast_noise,
                                      self.forecast_seed)
        return f

    @property
    def forecast_mape(self) -> float:
        """Realized 1..H-hour-ahead MAPE (%) of the forecasts actually used."""
        return 100.0 * self._ape_sum / self._ape_n if self._ape_n else 0.0

    @property
    def mean_defer_s(self) -> float:
        return self.queue.mean_defer_s

    @property
    def deferred_jobs(self) -> int:
        """Distinct jobs ever time-shifted (re-deferrals don't double-count)."""
        return len(self.queue.unique_held)

    def _refresh_forecast(self, now_s: float) -> None:
        h = min(int(now_s // telemetry.HOUR), self.tele.num_hours - 1)
        if h <= self._fit_hour:
            return
        if self._forecast is not None:
            fc = self._forecast
            for k in range(self._fit_hour + 1, h + 1):
                lead = k - fc.issue_hour - 1
                if 0 <= lead < fc.horizon:
                    truth = self._truth[k % self._truth.shape[0]]
                    pred = fc.mean[lead]
                    self._ape_sum += float(np.mean(
                        np.abs(pred - truth)
                        / np.maximum(np.abs(truth), 1e-9)))
                    self._ape_n += 1
        T = self._truth.shape[0]
        if self.forecaster_name == "oracle" or self.warmup_hours <= 0:
            hist = self._truth[:h + 1]       # oracle indexes truth absolutely
        else:
            idx = np.arange(h - self.warmup_hours + 1, h + 1) % T
            hist = self._truth[idx]
        self._fitted = self._make_forecaster().fit(hist)
        self._fit_hour = h
        horizon_h = int(np.ceil(self.horizon_slots * self.slot_s
                                / telemetry.HOUR)) + 1
        self._forecast = self._predict(horizon_h)

    def _predict(self, horizon_h: int):
        fc = self._fitted.predict(horizon_h)
        if fc.issue_hour != self._fit_hour:
            # Re-anchor from archive-relative to absolute hours (wrapped
            # warm-start histories end at hour ``_fit_hour`` by construction).
            fc = dataclasses.replace(fc, issue_hour=self._fit_hour)
        return fc

    def _ensure_horizon(self, now_s: float, max_exec_s: float,
                        last_offset_s: float) -> None:
        """Grow the cached forecast so every execution window it will price
        — up to [last slot start, + longest exec] — lies inside the horizon
        (beyond it the forecast extrapolates flat, which would silently
        de-calibrate the pricing, oracle included)."""
        t_end = now_s + last_offset_s + max_exec_s
        needed = int(np.ceil(t_end / telemetry.HOUR)) - self._fit_hour + 1
        if needed > self._forecast.horizon:
            self._forecast = self._predict(needed)

    def _slot_signal_tensors(self, jobs: Sequence[problem.Job], now_s: float,
                             offsets: np.ndarray):
        """(ci, ewif, wue) estimates per (job, slot), each [M, S, R].

        Every cell is priced at the forecast's exact time-mean over the
        job's would-be execution window [slot_start, slot_start + exec] —
        the simulator accounts with the integrated telemetry over the same
        window, so "run now" and "run later" are compared on the accounting
        footing (with the oracle forecaster planned and accounted signal
        means coincide exactly). Future slots are shaded toward the upper
        quantile band by ``risk`` — deferring on an uncertain forecast must
        price the uncertainty in.
        """
        R = self.tele.num_regions
        M, S = len(jobs), len(offsets)
        exec_t = np.array([j.exec_time_s for j in jobs])
        self._ensure_horizon(now_s, float(exec_t.max()), float(offsets[-1]))
        t0 = np.broadcast_to(now_s + offsets[None, :], (M, S)).ravel()
        t1 = (now_s + offsets[None, :] + exec_t[:, None]).ravel()
        rows = self._forecast.mean_many(t0, t1)
        if self.risk > 0.0:
            hi = self._forecast.mean_many(t0, t1, "hi")
            shade = self.risk * (hi - rows)
            shade[np.arange(t0.size) % S == 0] = 0.0      # slot 0 is observed
            rows = rows + shade
        rows = np.maximum(rows, 1e-6)          # physical signals are positive
        rows = rows.reshape(M, S, 3 * R)
        return rows[..., :R], rows[..., R:2 * R], rows[..., 2 * R:]

    # -- scheduling ----------------------------------------------------------

    def schedule(self, jobs: Sequence[problem.Job], now_s: float,
                 capacity: np.ndarray) -> Decision:
        jobs = list(jobs)
        if not jobs:
            return Decision([], np.zeros(0, np.int64), [], None, False)

        due, held = self.queue.partition(jobs, now_s)
        if not due:
            return Decision([], np.zeros(0, np.int64), held, None, False,
                            wake_s=self.queue.next_release_s())

        total_cap = int(capacity.sum())
        deferred: List[problem.Job] = []
        if len(due) > total_cap:                             # lines 5-7
            due, deferred = slack.pick_most_urgent(due, now_s, total_cap)
        if not due:
            return Decision([], np.zeros(0, np.int64), deferred + held, None,
                            False, wake_s=self.queue.next_release_s())

        snap = self.tele.at(now_s)
        self.history.observe(snap)
        self._refresh_forecast(now_s)
        inst = problem.build(due, self.tele, now_s, capacity, self.server,
                             snap=snap)
        tol = np.array([j.tolerance for j in due])

        offsets = np.arange(self.horizon_slots) * self.slot_s
        ci, ewif, wue = self._slot_signal_tensors(due, now_s, offsets)
        plan = self._fcast.build_temporal_plan(
            inst, now_s, ci, ewif, wue, snap["pue"], snap["wsf"], offsets,
            self.server, self.lam_co2, self.lam_h2o, self.lam_ref,
            self.history.co2_ref, self.history.h2o_ref,
            defer_eps=self.defer_eps, guard_s=self.queue.guard_s)

        softened = False
        res = solvers.solve(plan.cost, plan.allowed, plan.capacity,
                            backend=self.backend, soften=False,
                            sigma=self.sigma)
        if res.feasible:
            self._record(plan.cost, plan.allowed, plan.capacity,
                         np.tile(inst.overrun, (1, plan.num_slots)), tol,
                         False)
        else:
            # Soft fallback is slot-0 only: a job that must overrun its
            # tolerance should pay the Eq 12-13 penalty and run *now*, not
            # hide in a future slot.
            softened = True
            cost0 = inst.objective_matrix(self.lam_co2, self.lam_h2o,
                                          self.lam_ref, self.history.co2_ref,
                                          self.history.h2o_ref)
            res = solvers.solve(cost0, inst.allowed, capacity,
                                backend=self.backend, soften=True,
                                overrun=inst.overrun, tol=tol,
                                sigma=self.sigma)
            self._record(cost0, inst.allowed, capacity, inst.overrun, tol,
                         True)
        self.solve_times.append(res.solve_time_s)

        N = plan.num_regions
        scheduled: List[problem.Job] = []
        assign: List[int] = []
        for j, col in zip(due, res.assign):
            col = int(col)
            if col < 0:
                deferred.append(j)
                continue
            s, n = (0, col) if softened else plan.decode(col)
            if s == 0:
                j.region = n
                scheduled.append(j)
                assign.append(n)
            else:
                self.queue.hold(j, now_s + float(plan.slot_offsets[s]),
                                now_s)
                deferred.append(j)
        deferred += held
        return Decision(scheduled, np.asarray(assign, np.int64), deferred,
                        res, softened, wake_s=self.queue.next_release_s())
