"""Comparison schedulers — paper §5 "Relevant Techniques".

All expose ``schedule(jobs, now_s, capacity) -> Decision`` (same contract as
``controller.Controller``) so the simulator treats them interchangeably.

  Baseline          home region, carbon/water-unaware (paper's reference).
  Round-Robin       cyclic region placement, sustainability-unaware.
  Least-Load        most-free-capacity region, sustainability-unaware.
  CarbonGreedyOpt   infeasible oracle: knows future carbon intensity, delays/
  WaterGreedyOpt    moves each job (within TOL) to its per-job best slot.
  Ecovisor          home-region carbon scaler (customized re-implementation
                    of [50] per paper §5): resource-scales jobs against a
                    trailing carbon-intensity target; carbon-only, no
                    cross-region moves, embodied carbon grows with runtime.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core import footprint, telemetry
from repro.core.controller import Decision
from repro.core.problem import Job


def _dummy_solver_result():
    from repro.core import solvers
    return solvers.SolveResult(assign=np.zeros(0, np.int64), objective=0.0,
                               status="optimal", solve_time_s=0.0,
                               penalties=np.zeros(0), backend="rule")


class _RuleScheduler:
    """Shared capacity bookkeeping for the rule-based schemes."""

    name = "rule"

    def __init__(self, tele: telemetry.Telemetry):
        self.tele = tele
        self.solve_times: List[float] = []

    def _pick(self, job: Job, free: np.ndarray, now_s: float) -> int:
        raise NotImplementedError

    def schedule(self, jobs: Sequence[Job], now_s: float,
                 capacity: np.ndarray) -> Decision:
        free = capacity.astype(np.int64).copy()
        scheduled, assign, deferred = [], [], []
        for j in jobs:
            n = self._pick(j, free, now_s)
            if n is not None and free[n] > 0:
                free[n] -= 1
                j.region = n
                scheduled.append(j)
                assign.append(n)
            else:
                deferred.append(j)
        self.solve_times.append(0.0)
        return Decision(scheduled, np.asarray(assign, np.int64), deferred,
                        _dummy_solver_result(), False)


class Baseline(_RuleScheduler):
    name = "baseline"

    def _pick(self, job, free, now_s):
        return job.home_region if free[job.home_region] > 0 else None


class RoundRobin(_RuleScheduler):
    name = "round-robin"

    def __init__(self, tele):
        super().__init__(tele)
        self._next = 0

    def _pick(self, job, free, now_s):
        N = len(free)
        for k in range(N):
            n = (self._next + k) % N
            if free[n] > 0:
                self._next = (n + 1) % N
                return n
        return None


class LeastLoad(_RuleScheduler):
    name = "least-load"

    def _pick(self, job, free, now_s):
        n = int(np.argmax(free))
        return n if free[n] > 0 else None


class GreedyOpt(_RuleScheduler):
    """Carbon-/Water-Greedy-Opt oracle (paper §5, infeasible in practice).

    Has *future* telemetry: for each job it enumerates every (region,
    hourly start slot) that respects Eq 11 — start ≥ submit + L(home, n),
    start ≤ submit + TOL·t — and picks the single-metric minimum, integrating
    the true intensity over the execution window. Greedy in arrival order
    (the paper: "not truly optimal since they make the scheduling decision
    without knowing the characteristics of future job arrivals").

    Sets ``job.planned_start_s`` so the simulator can honor intentional
    delays.
    """

    def __init__(self, tele, metric: str = "carbon",
                 server: footprint.ServerSpec = None):
        super().__init__(tele)
        assert metric in ("carbon", "water")
        self.metric = metric
        self.server = server or footprint.m5_metal()
        self.name = f"{metric}-greedy-opt"

    def _objective(self, job: Job, n: int, start_s: float) -> float:
        te = self.tele
        m = te.mean_between(start_s, start_s + job.exec_time_s)
        if self.metric == "carbon":
            return float(footprint.job_carbon(job.energy_kwh,
                                              job.exec_time_s,
                                              float(m["ci"][n]),
                                              self.server))
        return float(footprint.job_water(job.energy_kwh, job.exec_time_s,
                                         te.pue[n], float(m["ewif"][n]),
                                         float(m["wue"][n]), te.wsf[n],
                                         self.server))

    def _pick(self, job, free, now_s):
        best, best_n, best_start = np.inf, None, now_s
        max_start = job.submit_time_s + job.tolerance * job.exec_time_s
        for n in range(self.tele.num_regions):
            if free[n] <= 0:
                continue
            lat = self.tele.transfer_latency_s(job.package_bytes,
                                               job.home_region, n)
            earliest = now_s + lat
            if earliest > max_start + 1e-9:
                continue                       # Eq 11 arc-infeasible
            starts = np.arange(earliest, max_start + 1e-9, telemetry.HOUR)
            for s in starts:
                obj = self._objective(job, n, float(s))
                if obj < best:
                    best, best_n, best_start = obj, n, float(s)
        if best_n is not None:
            job.planned_start_s = best_start
            return best_n
        # Delay budget exhausted (or every candidate region full): run at home
        # as soon as possible — a job must execute somewhere (the remaining
        # overrun is counted as a violation, exactly like the paper's Table 2
        # oracle rows).
        return job.home_region if free[job.home_region] > 0 else None


class Ecovisor(_RuleScheduler):
    """Customized Ecovisor [50]: home-region execution with a carbon scaler.

    Maintains a trailing carbon-intensity target per region; when the grid is
    dirtier than target, the job's resources are scaled down by
    s = target/ci (floored so the runtime extension stays inside the delay
    tolerance). Work is conserved: runtime ×1/s; energy picks up a static-
    power tax  E' = E·(α + (1−α)/s)  with α=0.7 dynamic fraction. Carbon-only
    (water-unaware), no cross-region moves — the paper's §6 comparison.
    """

    name = "ecovisor"
    alpha = 0.7

    def __init__(self, tele, window: int = 24):
        super().__init__(tele)
        self.window = window

    def _pick(self, job, free, now_s):
        n = job.home_region
        if free[n] <= 0:
            return None
        te = self.tele
        h = te.index(now_s)
        lo = max(h - self.window, 0)
        target = float(te.ci[lo:h + 1, n].mean()) if h > lo else te.ci[h, n]
        ci_now = float(te.ci[h, n])
        if ci_now > target > 0:
            s = max(target / ci_now, 1.0 / (1.0 + job.tolerance))
            job.time_scale = 1.0 / s
            job.energy_scale = self.alpha + (1.0 - self.alpha) / s
        return n


def make_scheduler(name: str, tele, **kw):
    """Deprecated shim over the ``repro.policy`` registry.

    The old lambda table (plus the ``TUNABLE_SCHEDULERS`` /
    ``FORECAST_SCHEDULERS`` frozensets that silently dropped kwargs for
    everything else) is replaced by the declarative ``PolicySpec`` API::

        from repro import policy
        sched = policy.build("waterwise[lam_h2o=0.7,backend=jax]", tele)

    This shim parses ``name`` as a spec string (bracketed params work too)
    and applies ``kw`` as validated overrides, so it produces bit-identical
    schedulers to the registry path — and now *raises* on unknown names or
    params instead of ignoring them.
    """
    from repro import policy
    return policy.build(name, tele, **kw)
