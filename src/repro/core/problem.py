"""Scheduling-problem construction: jobs, cost matrices, arc filtering.

Builds the inputs of the paper's MILP (Eqs 8-11) for a batch of M jobs over N
regions at decision time T:

  CO2[m, n]   Eq (1) carbon footprint of job m executed in region n *now*
  H2O[m, n]   Eq (5) water footprint (incl. WSF scaling per Eqs 2-3)
  L[m, n]     transfer latency from job m's home region to region n
  allowed[m,n]  Eq (11) arc filter: L[m,n]/t_m + queue-wait <= TOL%·t_m

Key structural observation (exploited by every solver backend): because each
job is assigned to exactly ONE region (Eq 9), the delay-tolerance constraint
Eq (11) — a sum over n of x[m,n]·L[m,n]/t[m,n] — degenerates to a per-arc
bound. The MILP is therefore a capacitated transportation problem with
forbidden arcs, whose constraint matrix is totally unimodular: the LP
relaxation has integral vertices. The soft-constrained variant (Eqs 12-13)
similarly folds the penalty sigma·P[m,n] into the arc cost, because the
optimal P[m,n] is max(0, L/t - TOL) on the chosen arc and 0 elsewhere.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import footprint, telemetry


@dataclasses.dataclass
class Job:
    """One schedulable unit (paper: a PARSEC/CloudSuite batch job; ours: also
    a JAX train/serve job of an assigned architecture)."""
    job_id: int
    home_region: int
    submit_time_s: float
    exec_time_s: float              # t_j: pure execution time (region-invariant)
    energy_kwh: float               # E_j: mean estimate from previous executions
    package_bytes: float = 2e9      # .tar / checkpoint size to move
    tolerance: float = 0.25         # TOL%: allowed service-time slack fraction
    servers: int = 1                # capacity units consumed
    arch: Optional[str] = None      # workload-side tag (assigned architecture)
    # Mutable bookkeeping (simulator-owned):
    start_time_s: Optional[float] = None
    finish_time_s: Optional[float] = None
    region: Optional[int] = None
    planned_start_s: Optional[float] = None  # oracle-intended delayed start
    time_scale: float = 1.0                  # Ecovisor carbon-scaler effects
    energy_scale: float = 1.0
    # Workflow (DAG) extensions — plain batch jobs leave all three at their
    # defaults and keep their exact pre-DAG semantics (bit-for-bit):
    deps: Tuple[int, ...] = ()               # predecessor job_ids (must finish
    #                                          before this task may start)
    workflow_id: Optional[int] = None        # owning WorkflowSpec, if any
    deadline_override_s: Optional[float] = None  # absolute critical-path
    #                                          deadline (repro.workflows.cpath)

    @property
    def deadline_s(self) -> float:
        """Latest completion compatible with the delay tolerance: the job may
        spend at most (1+TOL)·t_j in the system. Workflow tasks instead carry
        an absolute critical-path deadline (latest finish such that the
        longest remaining path still meets the workflow deadline)."""
        if self.deadline_override_s is not None:
            return self.deadline_override_s
        return self.submit_time_s + (1.0 + self.tolerance) * self.exec_time_s

    def slack_budget_s(self, now_s: float) -> float:
        """Remaining tolerance budget at ``now_s``: TOL·t_j minus the queue
        wait already burnt. The single definition shared by the slack
        manager, the deferral queue, and the temporal feasibility mask —
        they must agree or deferral could cause a deadline miss. For
        workflow tasks the budget derives from the critical-path deadline:
        how long the task can still wait and start no later than
        deadline − t_j."""
        if self.deadline_override_s is not None:
            return self.deadline_override_s - now_s - self.exec_time_s
        return (self.tolerance * self.exec_time_s
                - max(now_s - self.submit_time_s, 0.0))


def slack_budget(jobs: Sequence[Job], now_s: float) -> np.ndarray:
    """Vectorized ``Job.slack_budget_s`` over a batch — ONE array expression
    instead of a per-job Python loop on the hot per-round path.

    Bit-identical to the scalar method: the non-override lane evaluates the
    exact same elementwise expression (``tol·t − max(now − submit, 0)``), so
    pinned decisions cannot drift. The pricers, the temporal planner, and
    the fused round all price slack through this one definition.
    """
    n = len(jobs)
    if n == 0:
        return np.zeros(0)
    tol = np.fromiter((j.tolerance for j in jobs), float, n)
    t = np.fromiter((j.exec_time_s for j in jobs), float, n)
    submit = np.fromiter((j.submit_time_s for j in jobs), float, n)
    override = np.fromiter(
        (np.nan if j.deadline_override_s is None else j.deadline_override_s
         for j in jobs), float, n)
    plain = tol * t - np.maximum(now_s - submit, 0.0)
    return np.where(np.isnan(override), plain, override - now_s - t)


@dataclasses.dataclass
class ProblemInstance:
    """Cost matrices + constraints for one solver invocation."""
    co2: np.ndarray          # [M, N] gCO2
    h2o: np.ndarray          # [M, N] effective liters
    latency: np.ndarray      # [M, N] transfer latency seconds
    overrun: np.ndarray      # [M, N] L/t - already-waited slack, as TOL fraction
    allowed: np.ndarray      # [M, N] bool, Eq (11) arc filter
    capacity: np.ndarray     # [N] free capacity units
    jobs: Sequence[Job]
    co2_max: np.ndarray      # [M] normalizers (paper Eq 7)
    h2o_max: np.ndarray      # [M]
    emb: Optional[np.ndarray] = None      # [M, N] embodied gCO2e (amortized)
    emb_max: Optional[np.ndarray] = None  # [M] embodied normalizers

    @property
    def shape(self):
        return self.co2.shape

    def objective_matrix(self, lam_co2: float = 0.5, lam_h2o: float = 0.5,
                         lam_ref: float = 0.1,
                         co2_ref: Optional[np.ndarray] = None,
                         h2o_ref: Optional[np.ndarray] = None,
                         lam_emb: float = 0.0) -> np.ndarray:
        """Per-arc objective coefficients of Eq (8):
        lam_co2·CO2/CO2_max + lam_h2o·H2O/H2O_max + lam_ref·history term,
        optionally extended with a third (embodied-carbon) footprint
        dimension — ``lam_emb·EMB/EMB_max`` — the axis the source paper
        does not cover."""
        obj = (lam_co2 * self.co2 / self.co2_max[:, None]
               + lam_h2o * self.h2o / self.h2o_max[:, None])
        if lam_emb and self.emb is not None:
            obj = obj + lam_emb * self.emb / self.emb_max[:, None]
        if co2_ref is not None and h2o_ref is not None:
            obj = obj + lam_ref * (lam_co2 * co2_ref + lam_h2o * h2o_ref)[None, :]
        return obj


def latency_matrix(home: np.ndarray, size_bytes: np.ndarray,
                   bw_gbps: Optional[np.ndarray] = None,
                   rtt_s: Optional[np.ndarray] = None) -> np.ndarray:
    """[M, N] transfer latency from each job's home to every region.

    Vectorized equivalent of ``telemetry.transfer_latency_s`` over a job
    batch (zero on the home arc). Shared by the cost-matrix builder, the
    slack manager, and the temporal planner. Callers holding a
    ``Telemetry`` should pass its identity-mapped ``wan_bw_gbps`` /
    ``wan_rtt_s`` tables; the defaults are the full global tables.
    """
    if bw_gbps is None:
        bw_gbps = telemetry.WAN_BW_GBPS
    if rtt_s is None:
        rtt_s = telemetry.WAN_RTT_S
    home = np.asarray(home)
    bw = np.maximum(bw_gbps[home] * 1e9, 1.0)               # [M, N]
    lat = 2.0 + rtt_s[home] + np.asarray(size_bytes)[:, None] / bw
    lat[np.arange(len(home)), home] = 0.0
    return lat


def build(jobs: Sequence[Job], tele: telemetry.Telemetry, now_s: float,
          capacity: np.ndarray, server: footprint.ServerSpec,
          bw_gbps: Optional[np.ndarray] = None,
          snap: Optional[dict] = None) -> ProblemInstance:
    """Construct the cost matrices for ``jobs`` at decision time ``now_s``.

    The scheduler sees only *current* intensities (paper §4: "the scheduler
    cannot have futuristic information") — footprints are priced at time
    ``now_s`` even though execution extends beyond it. Callers that already
    hold the ``tele.at(now_s)`` snapshot may pass it to avoid recomputing.
    """
    if snap is None:
        snap = tele.at(now_s)
    M, N = len(jobs), tele.num_regions

    E = np.array([j.energy_kwh for j in jobs])          # [M]
    t = np.array([j.exec_time_s for j in jobs])         # [M]
    home = np.array([j.home_region for j in jobs])      # [M]
    size = np.array([j.package_bytes for j in jobs])    # [M]
    tol = np.array([j.tolerance for j in jobs])         # [M]
    srv = np.array([j.servers for j in jobs])           # [M]
    waited = np.maximum(now_s - np.array([j.submit_time_s for j in jobs]), 0.0)
    # Workflow tasks carry an absolute critical-path deadline; express their
    # burnt slack in the same TOL-fraction space so Eq (11) and the soft
    # penalty flow through one formula. For plain jobs ``tol·t − slack``
    # equals ``waited`` mathematically but not bitwise — the np.where keeps
    # the original expression on the plain lane (pinned decisions).
    override = np.fromiter(
        (np.nan if j.deadline_override_s is None else 1.0 for j in jobs),
        float, M)
    if not np.isnan(override).all():
        slack = slack_budget(jobs, now_s)
        waited = np.where(np.isnan(override), waited, tol * t - slack)

    co2 = footprint.job_carbon(E[:, None], t[:, None], snap["ci"][None, :],
                               server)
    h2o = footprint.job_water(E[:, None], t[:, None], snap["pue"][None, :],
                              snap["ewif"][None, :], snap["wue"][None, :],
                              snap["wsf"][None, :], server)

    lat = latency_matrix(home, size,
                         bw_gbps if bw_gbps is not None else tele.wan_bw_gbps,
                         tele.wan_rtt_s)

    # Eq (11) with slack accounting: the fraction of tolerance already burnt
    # by queue-waiting plus what the transfer would burn.
    overrun = (lat + waited[:, None]) / np.maximum(t[:, None], 1e-9)
    allowed = overrun <= tol[:, None] + 1e-12

    # Embodied-carbon amortization (gCO2e per server-second, scaled by the
    # per-region fleet factor) — the third accounting dimension.
    emb = footprint.job_embodied(t[:, None], server,
                                 region_scale=footprint.region_embodied_scale(
                                     N)[None, :],
                                 servers=srv[:, None])

    # Normalizers (Eq 7): footprint in the worst (highest-intensity) region.
    co2_max = np.maximum(co2.max(axis=1), 1e-9)
    h2o_max = np.maximum(h2o.max(axis=1), 1e-9)
    emb_max = np.maximum(emb.max(axis=1), 1e-9)

    return ProblemInstance(co2=co2, h2o=h2o, latency=lat, overrun=overrun,
                           allowed=allowed, capacity=np.asarray(capacity),
                           jobs=jobs, co2_max=co2_max, h2o_max=h2o_max,
                           emb=emb, emb_max=emb_max)
