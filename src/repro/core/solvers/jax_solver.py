"""JAX-native entropic-OT solver — the beyond-paper, TPU-idiomatic backend.

The paper solves Eq 8-11 with CBC branch-and-cut on a CPU head node. On a TPU
fleet the natural formulation is entropic-regularized optimal transport over
the same transportation polytope:

    min ⟨C, X⟩ − ε·H(X)   s.t.  X·1 = a,  Xᵀ·1 = b

with forbidden arcs priced at +BIG. Capacity inequalities become equalities
by appending one dummy supply row (supply = Σcap − M, zero cost) — the
classic balanced-OT reduction. Log-domain Sinkhorn iterations with
ε-annealing drive X toward a vertex of the polytope; as ε→0 the entropic
optimum converges to the LP optimum, which is integral (total unimodularity).
A final greedy confidence rounding + min-cost repair produces the integral
assignment; the integrality gap vs the exact ``flow``/``scipy`` backends is
measured in tests (typically 0 on non-degenerate instances).

Why this exists: the Sinkhorn inner loop is two batched row/col logsumexp
reductions — MXU/VPU-friendly, jittable, vmappable over scheduling windows,
and served by the Pallas kernel in ``repro/kernels/sinkhorn`` on TPU. This is
the TPU-native equivalent of the paper's branch-and-cut (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.core import solvers

BIG = 1e4          # forbidden-arc cost after normalization to ~unit scale
_NEG = -1e9        # log-domain mask value / zero-mass row marginal

# Row-count buckets: cost matrices are padded up to the next bucket (with
# zero-mass rows) before hitting the jitted Sinkhorn, so a whole simulation
# run — thousands of scheduling rounds with jittery window sizes — compiles
# the solver once per bucket instead of once per distinct M. Extends through
# 16384 so the 1M-jobs/day storm regime (multi-thousand-row admission
# windows) stays on tabled buckets instead of the ad-hoc overflow path.
BUCKETS = (4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384)

# The annealed-Sinkhorn schedule baked into ``sinkhorn_log``'s defaults;
# solver spans annotate these so traces record the effective iteration
# budget (iters × anneal_stages) per solve.
SINKHORN_EPS0 = 0.5
SINKHORN_ITERS = 60
SINKHORN_STAGES = 6


# Ad-hoc overflow bucket sizes already warned about: the overflow warning
# fires once per *size*, not once per solve — a storm that overflows into
# bucket 32768 ten thousand times is one actionable signal, not ten
# thousand identical RuntimeWarnings.
_OVERFLOW_WARNED: set = set()


def bucket_for(rows: int) -> int:
    """Smallest bucket ≥ rows (next power of two beyond the table)."""
    for b in BUCKETS:
        if rows <= b:
            return b
    b = BUCKETS[-1]
    while b < rows:
        b *= 2
    if b not in _OVERFLOW_WARNED:
        _OVERFLOW_WARNED.add(b)
        obs.warn("solver.bucket_overflow",
                 f"instance with {rows} rows exceeds the largest padded "
                 f"bucket {BUCKETS[-1]}; falling back to ad-hoc bucket {b} "
                 f"(fresh JIT compile per new size)")
    return b


def _sinkhorn_log_impl(C: jnp.ndarray, log_a: jnp.ndarray, log_b: jnp.ndarray,
                       eps0: float = 0.5, eps_min: float = 0.01,
                       iters: int = 60, anneal_stages: int = 6):
    """Log-stabilized Sinkhorn with geometric ε-annealing.

    Args:
      C: [M, N] cost (forbidden arcs already priced at BIG).
      log_a: [M] log row marginals; log_b: [N] log col marginals. Rows with
        log_a ≈ _NEG carry no mass — padding rows are exact no-ops.
    Returns:
      (f, g, eps): dual potentials and the final ε. The primal plan is
      X = exp((f[:,None] + g[None,:] − C) / ε).
    """
    def col_update(f, eps):
        # g_j = ε·(log b_j − logsumexp_i (f_i − C_ij)/ε)
        return eps * (log_b - jax.nn.logsumexp(
            (f[:, None] - C) / eps, axis=0))

    def row_update(g, eps):
        return eps * (log_a - jax.nn.logsumexp(
            (g[None, :] - C) / eps, axis=1))

    def stage(carry, eps):
        f, g = carry

        def body(_, fg):
            f, g = fg
            g = col_update(f, eps)
            f = row_update(g, eps)
            return (f, g)

        f, g = jax.lax.fori_loop(0, iters, body, (f, g))
        return (f, g), None

    decay = (eps_min / eps0) ** (1.0 / max(anneal_stages - 1, 1))
    eps_sched = eps0 * decay ** jnp.arange(anneal_stages)
    f0 = jnp.zeros_like(log_a)
    g0 = jnp.zeros_like(log_b)
    (f, g), _ = jax.lax.scan(stage, (f0, g0), eps_sched)
    return f, g, eps_sched[-1]


# Convergence tolerance of the adaptive (warm-startable) Sinkhorn: a stage
# exits once the sup-norm change of the column potentials per iteration
# drops below this. Small enough that the rounded assignment matches the
# fixed-budget schedule; reached in a handful of iterations from a warm
# start (see ``repro.core.round.SinkhornWarmStart``).
SINKHORN_TOL = 1e-5


def _sinkhorn_log_adaptive_impl(C: jnp.ndarray, log_a: jnp.ndarray,
                                log_b: jnp.ndarray, g0: jnp.ndarray,
                                tol: jnp.ndarray, eps0: float = 0.5,
                                eps_min: float = 0.01, iters: int = 60,
                                anneal_stages: int = 6):
    """Warm-startable annealed Sinkhorn with per-stage convergence exit.

    Same fixed point as ``_sinkhorn_log_impl`` (Sinkhorn at fixed ε has a
    unique fixed point up to a constant shift, which cancels in the primal
    plan), but (a) iterations start from caller-supplied column potentials
    ``g0`` — the update order is (f ← row, g ← col) so a warm ``g0`` is
    honored instead of being overwritten — and (b) each annealing stage
    exits as soon as the per-iteration sup-norm change of ``g`` drops
    below ``tol``, with the total inner-iteration count reported.

    A *cold* call passes ``g0 = 0`` and the full annealing schedule; a
    *warm* call passes the previous round's converged potentials with
    ``anneal_stages=1, eps0=eps_min`` — near a drifted optimum, the single
    final-ε stage converges in a handful of iterations where the cold
    schedule spends hundreds (recorded via ``repro.obs`` in
    ``repro.core.round``).

    Returns ``(f, g, eps, iters_used)``.
    """
    def col_update(f, eps):
        return eps * (log_b - jax.nn.logsumexp(
            (f[:, None] - C) / eps, axis=0))

    def row_update(g, eps):
        return eps * (log_a - jax.nn.logsumexp(
            (g[None, :] - C) / eps, axis=1))

    def stage(carry, eps):
        f, g, total = carry

        def cond(state):
            _, _, k, delta = state
            return jnp.logical_and(k < iters, delta > tol)

        def body(state):
            _, g, k, _ = state
            f = row_update(g, eps)
            g_new = col_update(f, eps)
            delta = jnp.max(jnp.abs(g_new - g))
            return (f, g_new, k + 1, delta)

        f, g, k, _ = jax.lax.while_loop(
            cond, body, (f, g, jnp.int32(0), jnp.float32(jnp.inf)))
        return (f, g, total + k), None

    decay = (eps_min / eps0) ** (1.0 / max(anneal_stages - 1, 1))
    eps_sched = eps0 * decay ** jnp.arange(anneal_stages)
    f0 = jnp.zeros_like(log_a)
    (f, g, used), _ = jax.lax.scan(stage, (f0, g0, jnp.int32(0)), eps_sched)
    return f, g, eps_sched[-1], used


sinkhorn_log_adaptive = functools.partial(jax.jit, static_argnames=(
    "iters", "anneal_stages"))(_sinkhorn_log_adaptive_impl)


# Single-instance and window-batched entry points. The batched variant vmaps
# over a stack of same-bucket instances (queued scheduling windows solved in
# one device dispatch); both share one implementation and therefore one
# compile cache keyed on (bucket, N, iters, stages).
sinkhorn_log = functools.partial(jax.jit, static_argnames=(
    "iters", "anneal_stages"))(_sinkhorn_log_impl)


def _sinkhorn_batched_impl(C, log_a, log_b, eps0: float = 0.5,
                           eps_min: float = 0.01, iters: int = 60,
                           anneal_stages: int = 6):
    def one(c, la, lb):
        return _sinkhorn_log_impl(c, la, lb, eps0, eps_min, iters,
                                  anneal_stages)
    return jax.vmap(one)(C, log_a, log_b)


sinkhorn_log_batched = functools.partial(jax.jit, static_argnames=(
    "iters", "anneal_stages"))(_sinkhorn_batched_impl)


@jax.jit
def plan_from_duals(C, f, g, eps):
    return jnp.exp((f[:, None] + g[None, :] - C) / eps)


def _round_to_vertex(X: np.ndarray, cost: np.ndarray, mask: np.ndarray,
                     capacity: np.ndarray) -> np.ndarray:
    """Greedy confidence rounding + cheapest-feasible repair.

    Jobs are committed in decreasing order of plan confidence (max row prob);
    each takes its argmax column if capacity remains, else its cheapest
    allowed column with spare capacity.
    """
    M, N = cost.shape
    assign = np.full(M, -1, dtype=np.int64)
    left = capacity.astype(np.int64).copy()
    Xm = np.where(mask, X, -np.inf)
    conf = Xm.max(axis=1)
    for m in np.argsort(-conf):
        if not mask[m].any():
            continue
        prefs = np.argsort(np.where(mask[m], cost[m] - 2.0 * BIG * Xm[m],
                                    np.inf))
        for n in prefs:
            if mask[m, n] and left[n] > 0:
                assign[m] = n
                left[n] -= 1
                break
    return assign


def _improve_2swap(assign: np.ndarray, cost: np.ndarray, mask: np.ndarray,
                   capacity: np.ndarray, rounds: int = 3) -> np.ndarray:
    """Local search: single-job moves + pairwise swaps until no improvement.

    Polishes the rounded vertex; with the Sinkhorn duals already near-optimal
    this usually closes the (small) remaining gap to the exact optimum.
    """
    M, N = cost.shape
    used = np.bincount(assign[assign >= 0], minlength=N)
    for _ in range(rounds):
        improved = False
        # Single moves into spare capacity.
        for m in range(M):
            if assign[m] < 0:
                continue
            cur = assign[m]
            deltas = np.where(mask[m] & (used < capacity),
                              cost[m] - cost[m, cur], np.inf)
            deltas[cur] = np.inf
            n = int(np.argmin(deltas))
            if deltas[n] < -1e-12:
                used[cur] -= 1
                used[n] += 1
                assign[m] = n
                improved = True
        # Pairwise swaps (vectorized over the job×job delta matrix).
        a = assign
        ok = a >= 0
        cm = cost[np.arange(M), np.where(ok, a, 0)]
        # delta of swapping m1<->m2: c[m1,a2]+c[m2,a1]-c[m1,a1]-c[m2,a2]
        c_m1_a2 = np.where(mask[:, a] & ok[None, :], cost[:, a], np.inf)
        delta = c_m1_a2 + c_m1_a2.T - cm[:, None] - cm[None, :]
        delta[~ok] = np.inf
        delta[:, ~ok] = np.inf
        np.fill_diagonal(delta, np.inf)
        m1, m2 = np.unravel_index(np.argmin(delta), delta.shape)
        if delta[m1, m2] < -1e-12:
            assign[m1], assign[m2] = assign[m2], assign[m1]
            improved = True
        if not improved:
            break
    return assign


def _effective(cost, allowed, soften, overrun, tol, sigma):
    if soften:
        assert overrun is not None and tol is not None
        c_eff = solvers.soft_cost(cost, allowed, overrun, tol, sigma)
        mask = np.ones_like(allowed, dtype=bool)
    else:
        c_eff = cost.astype(np.float64)
        mask = allowed.astype(bool)
    return c_eff, mask


def _infeasible(M):
    return solvers.SolveResult(assign=np.full(M, -1), objective=float("inf"),
                               status="infeasible", solve_time_s=0.0,
                               penalties=np.zeros(M), backend="jax")


def _prepare(c_eff, mask, cap, pad_rows: int):
    """Padded OT inputs: [M real rows | dummy slack row | pad_rows zero-mass
    rows]. Zero-mass rows (log marginal = _NEG) are exact no-ops in the
    log-domain updates, so padding changes nothing but the compiled shape."""
    M, N = c_eff.shape
    # Normalize costs to ~unit scale so ε has a universal meaning.
    scale = max(float(np.abs(c_eff[mask]).max()), 1e-9)
    Cn = np.where(mask, c_eff / scale, BIG)
    slack = int(cap.sum()) - M
    # Dummy row absorbs spare capacity (zero cost everywhere).
    C = np.vstack([Cn, np.zeros((1 + pad_rows, N))]).astype(np.float32)
    a = np.concatenate([np.ones(M), [max(slack, 1e-9)]])
    total = a.sum()
    log_a = np.concatenate([np.log(a / total),
                            np.full(pad_rows, _NEG)]).astype(np.float32)
    log_b = np.log(np.maximum(cap.astype(np.float64), 1e-12)
                   / total).astype(np.float32)
    return C, log_a, log_b, Cn


def _finalize(X, Cn, c_eff, mask, cap, soften, overrun, tol):
    """Round the (real-row) plan to an integral vertex + polish + price."""
    M = Cn.shape[0]
    X = X / np.maximum(X.sum(axis=1, keepdims=True), 1e-30)
    assign = _round_to_vertex(X, Cn, mask, cap)
    if (assign < 0).any():
        # Greedy rounding stranded a job (capacity-tight instance): repair
        # with the exact successive-shortest-path solver on the same
        # normalized costs. Only genuinely infeasible instances survive this.
        from repro.core.solvers import flow_solver
        assign = flow_solver._ssp_assign(Cn, mask, cap)
    if (assign >= 0).all():
        assign = _improve_2swap(assign, Cn, mask, cap)
    penalties = np.zeros(M)
    if (assign < 0).any():
        return solvers.SolveResult(assign=assign, objective=float("inf"),
                                   status="infeasible", solve_time_s=0.0,
                                   penalties=penalties, backend="jax")
    obj = float(c_eff[np.arange(M), assign].sum())
    if soften:
        excess = np.maximum(overrun - tol[:, None], 0.0)
        penalties = excess[np.arange(M), assign]
    return solvers.SolveResult(assign=assign, objective=obj,
                               status="rounded", solve_time_s=0.0,
                               penalties=penalties, backend="jax")


@solvers.register("jax")
def solve(cost: np.ndarray, allowed: np.ndarray, capacity: np.ndarray, *,
          soften: bool = False, overrun: Optional[np.ndarray] = None,
          tol: Optional[np.ndarray] = None, sigma: float = 10.0,
          eps_min: float = 0.005,
          pad_to_bucket: bool = True) -> solvers.SolveResult:
    def run() -> solvers.SolveResult:
        M, N = cost.shape
        c_eff, mask = _effective(cost, allowed, soften, overrun, tol, sigma)
        cap = capacity.astype(np.int64)
        if int(cap.sum()) < M or not mask.any(axis=1).all():
            return _infeasible(M)
        rows = M + 1
        pad = (bucket_for(rows) - rows) if pad_to_bucket else 0
        C, log_a, log_b, Cn = _prepare(c_eff, mask, cap, pad)
        f, g, eps = sinkhorn_log(jnp.asarray(C), jnp.asarray(log_a),
                                 jnp.asarray(log_b), eps_min=eps_min)
        X = np.asarray(plan_from_duals(jnp.asarray(C), f, g, eps))[:M]
        if obs.enabled():
            # row-marginal residual: each real row targets mass 1/Σcap
            total = max(float(cap.sum()), 1e-9)
            residual = float(np.abs(X.sum(axis=1) * total - 1.0).max())
            obs.annotate(bucket=rows + pad, pad=pad,
                         occupancy=rows / (rows + pad),
                         sinkhorn_iters=SINKHORN_ITERS * SINKHORN_STAGES,
                         eps0=SINKHORN_EPS0, eps_min=eps_min,
                         anneal_stages=SINKHORN_STAGES, residual=residual)
        return _finalize(X, Cn, c_eff, mask, cap, soften, overrun, tol)
    return solvers._timed(run)


def solve_many(costs, alloweds, capacities, *, soften: bool = False,
               overruns=None, tols=None, sigma: float = 10.0,
               eps_min: float = 0.005):
    """Batched entry point: solve K instances, vmapping the Sinkhorn loop
    over groups of same-bucket instances.

    Queued scheduling windows (a scenario sweep's backlog, a replayed
    multi-round trace, a Monte-Carlo ensemble) usually have jittery row
    counts; bucketing pads them to a handful of compiled shapes and each
    group runs as ONE device dispatch. Returns a list of SolveResults in
    input order.
    """
    K = len(costs)
    overruns = overruns if overruns is not None else [None] * K
    tols = tols if tols is not None else [None] * K
    results: list = [None] * K
    groups: dict = {}
    with obs.timed("solver.solve_many", K=K) as t:
        for k in range(K):
            cost = np.asarray(costs[k], np.float64)
            allowed = np.asarray(alloweds[k], bool)
            cap = np.asarray(capacities[k]).astype(np.int64)
            M, N = cost.shape
            c_eff, mask = _effective(cost, allowed, soften, overruns[k],
                                     tols[k], sigma)
            if int(cap.sum()) < M or not mask.any(axis=1).all():
                results[k] = _infeasible(M)
                continue
            rows = M + 1
            pad = bucket_for(rows) - rows
            C, log_a, log_b, Cn = _prepare(c_eff, mask, cap, pad)
            groups.setdefault((bucket_for(rows), N), []).append(
                (k, C, log_a, log_b, Cn, c_eff, mask, cap))
        for (_, _), items in groups.items():
            Cb = jnp.asarray(np.stack([it[1] for it in items]))
            la = jnp.asarray(np.stack([it[2] for it in items]))
            lb = jnp.asarray(np.stack([it[3] for it in items]))
            fb, gb, eps = sinkhorn_log_batched(Cb, la, lb, eps_min=eps_min)
            plans = np.asarray(jnp.exp(
                (fb[:, :, None] + gb[:, None, :] - Cb) / eps[:, None, None]))
            for it, X in zip(items, plans):
                k, _, _, _, Cn, c_eff, mask, cap = it
                M = Cn.shape[0]
                results[k] = _finalize(X[:M], Cn, c_eff, mask, cap, soften,
                                       overruns[k], tols[k])
        t.set(buckets=len(groups),
              sinkhorn_iters=SINKHORN_ITERS * SINKHORN_STAGES)
    per = t.elapsed_s / max(K, 1)
    for r in results:
        r.solve_time_s = per
    return results
