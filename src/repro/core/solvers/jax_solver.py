"""JAX-native entropic-OT solver — the beyond-paper, TPU-idiomatic backend.

The paper solves Eq 8-11 with CBC branch-and-cut on a CPU head node. On a TPU
fleet the natural formulation is entropic-regularized optimal transport over
the same transportation polytope:

    min ⟨C, X⟩ − ε·H(X)   s.t.  X·1 = a,  Xᵀ·1 = b

with forbidden arcs priced at +BIG. Capacity inequalities become equalities
by appending one dummy supply row (supply = Σcap − M, zero cost) — the
classic balanced-OT reduction. Log-domain Sinkhorn iterations with
ε-annealing drive X toward a vertex of the polytope; as ε→0 the entropic
optimum converges to the LP optimum, which is integral (total unimodularity).
A final greedy confidence rounding + min-cost repair produces the integral
assignment; the integrality gap vs the exact ``flow``/``scipy`` backends is
measured in tests (typically 0 on non-degenerate instances).

Why this exists: the Sinkhorn inner loop is two batched row/col logsumexp
reductions — MXU/VPU-friendly, jittable, vmappable over scheduling windows,
and served by the Pallas kernel in ``repro/kernels/sinkhorn`` on TPU. This is
the TPU-native equivalent of the paper's branch-and-cut (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solvers

BIG = 1e4          # forbidden-arc cost after normalization to ~unit scale
_NEG = -1e9        # log-domain mask value


@functools.partial(jax.jit, static_argnames=("iters", "anneal_stages"))
def sinkhorn_log(C: jnp.ndarray, log_a: jnp.ndarray, log_b: jnp.ndarray,
                 eps0: float = 0.5, eps_min: float = 0.01,
                 iters: int = 60, anneal_stages: int = 6):
    """Log-stabilized Sinkhorn with geometric ε-annealing.

    Args:
      C: [M, N] cost (forbidden arcs already priced at BIG).
      log_a: [M] log row marginals; log_b: [N] log col marginals.
    Returns:
      (f, g, eps): dual potentials and the final ε. The primal plan is
      X = exp((f[:,None] + g[None,:] − C) / ε).
    """
    def col_update(f, eps):
        # g_j = ε·(log b_j − logsumexp_i (f_i − C_ij)/ε)
        return eps * (log_b - jax.nn.logsumexp(
            (f[:, None] - C) / eps, axis=0))

    def row_update(g, eps):
        return eps * (log_a - jax.nn.logsumexp(
            (g[None, :] - C) / eps, axis=1))

    def stage(carry, eps):
        f, g = carry

        def body(_, fg):
            f, g = fg
            g = col_update(f, eps)
            f = row_update(g, eps)
            return (f, g)

        f, g = jax.lax.fori_loop(0, iters, body, (f, g))
        return (f, g), None

    decay = (eps_min / eps0) ** (1.0 / max(anneal_stages - 1, 1))
    eps_sched = eps0 * decay ** jnp.arange(anneal_stages)
    f0 = jnp.zeros_like(log_a)
    g0 = jnp.zeros_like(log_b)
    (f, g), _ = jax.lax.scan(stage, (f0, g0), eps_sched)
    return f, g, eps_sched[-1]


@jax.jit
def plan_from_duals(C, f, g, eps):
    return jnp.exp((f[:, None] + g[None, :] - C) / eps)


def _round_to_vertex(X: np.ndarray, cost: np.ndarray, mask: np.ndarray,
                     capacity: np.ndarray) -> np.ndarray:
    """Greedy confidence rounding + cheapest-feasible repair.

    Jobs are committed in decreasing order of plan confidence (max row prob);
    each takes its argmax column if capacity remains, else its cheapest
    allowed column with spare capacity.
    """
    M, N = cost.shape
    assign = np.full(M, -1, dtype=np.int64)
    left = capacity.astype(np.int64).copy()
    Xm = np.where(mask, X, -np.inf)
    conf = Xm.max(axis=1)
    for m in np.argsort(-conf):
        if not mask[m].any():
            continue
        prefs = np.argsort(np.where(mask[m], cost[m] - 2.0 * BIG * Xm[m],
                                    np.inf))
        for n in prefs:
            if mask[m, n] and left[n] > 0:
                assign[m] = n
                left[n] -= 1
                break
    return assign


def _improve_2swap(assign: np.ndarray, cost: np.ndarray, mask: np.ndarray,
                   capacity: np.ndarray, rounds: int = 3) -> np.ndarray:
    """Local search: single-job moves + pairwise swaps until no improvement.

    Polishes the rounded vertex; with the Sinkhorn duals already near-optimal
    this usually closes the (small) remaining gap to the exact optimum.
    """
    M, N = cost.shape
    used = np.bincount(assign[assign >= 0], minlength=N)
    for _ in range(rounds):
        improved = False
        # Single moves into spare capacity.
        for m in range(M):
            if assign[m] < 0:
                continue
            cur = assign[m]
            deltas = np.where(mask[m] & (used < capacity),
                              cost[m] - cost[m, cur], np.inf)
            deltas[cur] = np.inf
            n = int(np.argmin(deltas))
            if deltas[n] < -1e-12:
                used[cur] -= 1
                used[n] += 1
                assign[m] = n
                improved = True
        # Pairwise swaps (vectorized over the job×job delta matrix).
        a = assign
        ok = a >= 0
        cm = cost[np.arange(M), np.where(ok, a, 0)]
        # delta of swapping m1<->m2: c[m1,a2]+c[m2,a1]-c[m1,a1]-c[m2,a2]
        c_m1_a2 = np.where(mask[:, a] & ok[None, :], cost[:, a], np.inf)
        delta = c_m1_a2 + c_m1_a2.T - cm[:, None] - cm[None, :]
        delta[~ok] = np.inf
        delta[:, ~ok] = np.inf
        np.fill_diagonal(delta, np.inf)
        m1, m2 = np.unravel_index(np.argmin(delta), delta.shape)
        if delta[m1, m2] < -1e-12:
            assign[m1], assign[m2] = assign[m2], assign[m1]
            improved = True
        if not improved:
            break
    return assign


@solvers.register("jax")
def solve(cost: np.ndarray, allowed: np.ndarray, capacity: np.ndarray, *,
          soften: bool = False, overrun: Optional[np.ndarray] = None,
          tol: Optional[np.ndarray] = None, sigma: float = 10.0,
          eps_min: float = 0.005) -> solvers.SolveResult:
    def run() -> solvers.SolveResult:
        M, N = cost.shape
        if soften:
            assert overrun is not None and tol is not None
            c_eff = solvers.soft_cost(cost, allowed, overrun, tol, sigma)
            mask = np.ones_like(allowed, dtype=bool)
        else:
            c_eff = cost.astype(np.float64)
            mask = allowed.astype(bool)

        cap = capacity.astype(np.int64)
        slack = int(cap.sum()) - M
        if slack < 0 or not mask.any(axis=1).all():
            return solvers.SolveResult(
                assign=np.full(M, -1), objective=float("inf"),
                status="infeasible", solve_time_s=0.0,
                penalties=np.zeros(M), backend="jax")

        # Normalize costs to ~unit scale so ε has a universal meaning.
        scale = max(float(np.abs(c_eff[mask]).max()), 1e-9)
        Cn = np.where(mask, c_eff / scale, BIG)
        # Dummy row absorbs spare capacity (zero cost everywhere).
        C = np.vstack([Cn, np.zeros((1, N))]).astype(np.float32)
        a = np.concatenate([np.ones(M), [max(slack, 1e-9)]])
        b = cap.astype(np.float64)
        log_a = np.log(a / a.sum())
        log_b = np.log(np.maximum(b, 1e-12) / a.sum())

        f, g, eps = sinkhorn_log(jnp.asarray(C), jnp.asarray(log_a, jnp.float32),
                                 jnp.asarray(log_b, jnp.float32),
                                 eps_min=eps_min)
        X = np.asarray(plan_from_duals(jnp.asarray(C), f, g, eps))[:M]
        X = X / np.maximum(X.sum(axis=1, keepdims=True), 1e-30)

        assign = _round_to_vertex(X, Cn, mask, cap)
        if (assign >= 0).all():
            assign = _improve_2swap(assign, Cn, mask, cap)
        penalties = np.zeros(M)
        if (assign < 0).any():
            return solvers.SolveResult(assign=assign, objective=float("inf"),
                                       status="infeasible", solve_time_s=0.0,
                                       penalties=penalties, backend="jax")
        obj = float(c_eff[np.arange(M), assign].sum())
        if soften:
            excess = np.maximum(overrun - tol[:, None], 0.0)
            penalties = excess[np.arange(M), assign]
        return solvers.SolveResult(assign=assign, objective=obj,
                                   status="rounded", solve_time_s=0.0,
                                   penalties=penalties, backend="jax")
    return solvers._timed(run)
