"""HiGHS MILP backend via scipy.optimize.milp (sparse formulation).

Same mathematical problem as pulp_solver, built as sparse LP data. The soft
variant uses the folded-cost reduction (see solvers.soft_cost): optimal
penalties are recovered per-arc afterwards. Exactness of the fold vs the
literal Eq 12-13 formulation is asserted in tests/test_solvers.py.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import scipy.optimize as sopt
import scipy.sparse as sp

from repro.core import solvers


@solvers.register("scipy")
def solve(cost: np.ndarray, allowed: np.ndarray, capacity: np.ndarray, *,
          soften: bool = False, overrun: Optional[np.ndarray] = None,
          tol: Optional[np.ndarray] = None,
          sigma: float = 10.0) -> solvers.SolveResult:
    def run() -> solvers.SolveResult:
        M, N = cost.shape
        if soften:
            assert overrun is not None and tol is not None
            c_eff = solvers.soft_cost(cost, allowed, overrun, tol, sigma)
            mask = np.ones_like(allowed, dtype=bool)
        else:
            c_eff = cost
            mask = allowed

        mm, nn = np.nonzero(mask)
        A = len(mm)
        if A == 0 or np.unique(mm).size < M:
            return solvers.SolveResult(
                assign=np.full(M, -1), objective=float("inf"),
                status="infeasible", solve_time_s=0.0,
                penalties=np.zeros(M), backend="scipy")

        c = c_eff[mm, nn]
        # Rows 0..M-1: assignment (== 1). Rows M..M+N-1: capacity (<= cap).
        rows = np.concatenate([mm, M + nn])
        cols = np.concatenate([np.arange(A), np.arange(A)])
        vals = np.ones(2 * A)
        Acon = sp.csr_matrix((vals, (rows, cols)), shape=(M + N, A))
        lb = np.concatenate([np.ones(M), np.zeros(N)])
        ub = np.concatenate([np.ones(M), capacity.astype(np.float64)])
        constraints = sopt.LinearConstraint(Acon, lb, ub)
        res = sopt.milp(c=c, constraints=constraints,
                        integrality=np.ones(A),
                        bounds=sopt.Bounds(0, 1))

        assign = np.full(M, -1, dtype=np.int64)
        penalties = np.zeros(M)
        if res.success:
            chosen = res.x > 0.5
            assign[mm[chosen]] = nn[chosen]
            if soften:
                excess = np.maximum(overrun - tol[:, None], 0.0)
                sel = assign >= 0
                penalties[sel] = excess[np.nonzero(sel)[0], assign[sel]]
            return solvers.SolveResult(assign=assign, objective=float(res.fun),
                                       status="optimal", solve_time_s=0.0,
                                       penalties=penalties, backend="scipy")
        return solvers.SolveResult(assign=assign, objective=float("inf"),
                                   status="infeasible", solve_time_s=0.0,
                                   penalties=penalties, backend="scipy")
    return solvers._timed(run)
