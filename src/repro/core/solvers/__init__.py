"""Solver backends for the WaterWise MILP (paper Eqs 8-13).

Interchangeable backends behind one interface:

  ``pulp``   paper-faithful PuLP + CBC branch-and-cut, literal Eq 8-13
             formulation with explicit binary x[m,n] and penalty P[m,n].
             Registered only when PuLP is importable (optional dependency);
             this offline container ships without it, so the literal-MILP
             cross-checks use ``scipy`` instead.
  ``scipy``  HiGHS via scipy.optimize.milp, same formulation in sparse form.
  ``flow``   our own exact solver: successive-shortest-path min-cost flow
             with Johnson potentials, specialized to the capacitated
             assignment structure. Exact because the constraint matrix is
             totally unimodular (DESIGN.md §4) — no LP library needed.
  ``jax``    jittable entropic-OT (log-space Sinkhorn) + vertex rounding —
             the beyond-paper TPU-native solver (see kernels/sinkhorn for the
             Pallas row/col-reduction kernel).
  ``fused``  the ``jax`` backend with every device stage (soft-cost fold,
             masking, normalization, OT padding, annealed Sinkhorn, plan
             extraction) fused into ONE jitted program — one dispatch and
             one host transfer per round (see ``repro.core.round``).

All backends consume a cost matrix + arc filter + capacities and return a
``SolveResult``. ``soften=True`` activates the paper's penalty method
(Eqs 12-13): forbidden arcs become allowed at cost ``+ sigma * overrun_excess``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable, Dict, Optional

import numpy as np

import repro.obs as obs

BIG = 1e6  # cost assigned to structurally-forbidden arcs in dense backends


@dataclasses.dataclass
class SolveResult:
    assign: np.ndarray          # [M] region index, or -1 if unassigned
    objective: float
    status: str                 # "optimal" | "infeasible" | "rounded"
    solve_time_s: float
    penalties: np.ndarray       # [M] tolerance-overrun P value on chosen arc
    backend: str

    @property
    def feasible(self) -> bool:
        return self.status in ("optimal", "rounded") and (self.assign >= 0).all()


def soft_cost(cost: np.ndarray, allowed: np.ndarray, overrun: np.ndarray,
              tol: np.ndarray, sigma: float) -> np.ndarray:
    """Fold the Eq 12-13 penalty into per-arc costs.

    Because each job takes exactly one arc, the optimal penalty variable is
    P[m,n] = max(0, overrun[m,n] - tol[m]) on the chosen arc — so the soft
    MILP is exactly the hard transportation problem with modified costs.
    """
    excess = np.maximum(overrun - tol[:, None], 0.0)
    del allowed  # every arc becomes allowed under the soft relaxation
    return cost + sigma * excess


def _timed(fn: Callable[[], SolveResult],
           name: str = "solver.solve") -> SolveResult:
    """Time one backend solve via an obs span. ``solve_time_s`` is the
    span's wall time — identical semantics (one perf_counter pair) to
    the old inline timing whether obs is enabled or not."""
    with obs.timed(name) as t:
        res = fn()
        obs.annotate(backend=res.backend, status=res.status,
                     jobs=int(res.assign.shape[0]))
    res.solve_time_s = t.elapsed_s
    return res


_REGISTRY: Dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_solver(name: str) -> Callable:
    if name not in _REGISTRY:
        # Import side-effect registration. PuLP is optional (absent in the
        # offline container); its module import is a no-op when unavailable.
        from repro.core.solvers import (  # noqa: F401
            flow_solver, jax_solver, pulp_solver, scipy_solver)
        from repro.core import round  # noqa: F401  (registers "fused")
    if name not in _REGISTRY:
        raise KeyError(f"solver backend {name!r} unavailable; "
                       f"have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_backends() -> list:
    get_solver("flow")  # trigger registration
    return sorted(_REGISTRY)


# Thread-local solve interception: a batching driver (the ``device``
# executor) installs a per-thread hook around a cell's whole run; every
# ``solve()`` the cell issues is offered to the hook first, which may
# return a SolveResult computed elsewhere (e.g. a device-parallel batch
# shared with other cells' threads) or ``None`` to decline — declined
# solves run the normal backend in-thread. Thread-local by design: cells
# running concurrently each carry their own hook, and code outside an
# ``intercepted`` block is never affected.
_LOCAL = threading.local()


@contextlib.contextmanager
def intercepted(hook: Callable):
    """Install ``hook(cost, allowed, capacity, *, backend, soften, overrun,
    tol, sigma) -> Optional[SolveResult]`` for ``solve()`` calls on the
    current thread. Nests: the innermost hook wins; ``None`` restores."""
    prev = getattr(_LOCAL, "hook", None)
    _LOCAL.hook = hook
    try:
        yield
    finally:
        _LOCAL.hook = prev


def solve(cost: np.ndarray, allowed: np.ndarray, capacity: np.ndarray,
          *, backend: str = "scipy", soften: bool = False,
          overrun: Optional[np.ndarray] = None,
          tol: Optional[np.ndarray] = None, sigma: float = 10.0) -> SolveResult:
    """Unified entry point. See module docstring."""
    cost = np.asarray(cost, dtype=np.float64)
    allowed = np.asarray(allowed, bool)
    capacity = np.asarray(capacity)
    overrun = None if overrun is None else np.asarray(overrun)
    tol = None if tol is None else np.asarray(tol)
    hook = getattr(_LOCAL, "hook", None)
    if hook is not None:
        res = hook(cost, allowed, capacity, backend=backend, soften=soften,
                   overrun=overrun, tol=tol, sigma=sigma)
        if res is not None:
            return res
    fn = get_solver(backend)
    return fn(cost, allowed, capacity, soften=soften, overrun=overrun,
              tol=tol, sigma=sigma)


def solve_many(costs, alloweds, capacities, *, backend: str = "jax",
               soften: bool = False, overruns=None, tols=None,
               sigma: float = 10.0) -> list:
    """Solve K independent instances; returns SolveResults in input order.

    The ``jax`` backend buckets instances by padded shape and runs each
    bucket's Sinkhorn as one vmapped device dispatch (see
    ``jax_solver.solve_many``) — the amortized path for queued scheduling
    windows. Every other backend falls back to a per-instance loop.
    """
    get_solver(backend)  # trigger registration / validate name
    if backend == "jax":
        from repro.core.solvers import jax_solver
        return jax_solver.solve_many(costs, alloweds, capacities,
                                     soften=soften, overruns=overruns,
                                     tols=tols, sigma=sigma)
    K = len(costs)
    overruns = overruns if overruns is not None else [None] * K
    tols = tols if tols is not None else [None] * K
    return [solve(costs[k], alloweds[k], capacities[k], backend=backend,
                  soften=soften, overrun=overruns[k], tol=tols[k],
                  sigma=sigma)
            for k in range(K)]
