"""Paper-faithful MILP backend: PuLP + CBC (paper §4 "MILP Optimization").

Implements Eq (8) objective with Eq (9) assignment, Eq (10) capacity and
Eq (11) delay-tolerance constraints as a *literal* MILP over binary x[m,n];
``soften=True`` adds the Eq (12)-(13) penalty variables P[m,n] >= 0 exactly
as published (not the folded-cost shortcut — that equivalence is *tested*
against this literal formulation in tests/test_solvers.py).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

try:  # optional dependency — absent in the offline container
    import pulp
    PULP_AVAILABLE = True
except ImportError:  # pragma: no cover - environment dependent
    pulp = None
    PULP_AVAILABLE = False

from repro.core import solvers


def _register(fn):
    return solvers.register("pulp")(fn) if PULP_AVAILABLE else fn


@_register
def solve(cost: np.ndarray, allowed: np.ndarray, capacity: np.ndarray, *,
          soften: bool = False, overrun: Optional[np.ndarray] = None,
          tol: Optional[np.ndarray] = None,
          sigma: float = 10.0) -> solvers.SolveResult:
    def run() -> solvers.SolveResult:
        M, N = cost.shape
        prob = pulp.LpProblem("waterwise", pulp.LpMinimize)
        x = {}
        for m in range(M):
            for n in range(N):
                if allowed[m, n] or soften:
                    x[m, n] = pulp.LpVariable(f"x_{m}_{n}", cat="Binary")

        terms = [cost[m, n] * v for (m, n), v in x.items()]
        p = {}
        if soften:
            # Eq (12)-(13): relaxed constraint sum_n x·(L/t) <= TOL + P,
            # with sigma·sum P added to the objective. P only needs to exist
            # where the arc can actually overrun.
            assert overrun is not None and tol is not None
            for m in range(M):
                for n in range(N):
                    if overrun[m, n] > tol[m]:
                        p[m, n] = pulp.LpVariable(f"p_{m}_{n}", lowBound=0.0)
            terms += [sigma * v for v in p.values()]
            for m in range(M):
                # sum_n x[m,n]·overrun[m,n] <= TOL% + sum_n P[m,n]  (Eq 13)
                lhs = pulp.lpSum(overrun[m, n] * x[m, n] for n in range(N)
                                 if (m, n) in x)
                rhs = tol[m] + pulp.lpSum(p[m, n] for n in range(N)
                                          if (m, n) in p)
                prob += lhs <= rhs
        prob += pulp.lpSum(terms)

        for m in range(M):                                   # Eq (9)
            prob += pulp.lpSum(x[m, n] for n in range(N) if (m, n) in x) == 1
        for n in range(N):                                   # Eq (10)
            arcs = [x[m, n] for m in range(M) if (m, n) in x]
            if arcs:
                prob += pulp.lpSum(arcs) <= float(capacity[n])

        status = prob.solve(pulp.PULP_CBC_CMD(msg=False))
        assign = np.full(M, -1, dtype=np.int64)
        penalties = np.zeros(M)
        if pulp.LpStatus[status] == "Optimal":
            for (m, n), v in x.items():
                if v.value() is not None and v.value() > 0.5:
                    assign[m] = n
            for (m, n), v in p.items():
                if assign[m] == n and v.value() is not None:
                    penalties[m] = v.value()
            obj = float(pulp.value(prob.objective))
            st = "optimal"
        else:
            obj = float("inf")
            st = "infeasible"
        return solvers.SolveResult(assign=assign, objective=obj, status=st,
                                   solve_time_s=0.0, penalties=penalties,
                                   backend="pulp")
    return solvers._timed(run)
