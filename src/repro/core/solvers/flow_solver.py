"""Exact min-cost-flow backend — our own solver, numpy only.

The WaterWise MILP (Eqs 8-10 with the Eq 11 arc filter) is a capacitated
assignment problem: unit-supply jobs, capacity-bounded regions, forbidden
arcs. Its LP relaxation lives on a transportation polytope whose constraint
matrix is totally unimodular, so the LP optimum is integral — an exact MILP
solution is obtainable with successive-shortest-path (SSP) min-cost flow.

Structure exploited: region count N is tiny (5 in the paper; ≤ dozens in any
geo-distributed fleet), so the residual graph collapses to N region nodes.
A residual "reroute" arc n→n' costs  min_{j matched to n, allowed (j,n')}
(c[j,n'] − c[j,n])  — moving the cheapest-to-move job. Each augmentation is
then a Bellman-Ford over N nodes (N³ ≪ anything) plus an O(M·N) group-min to
build the arc matrix. SSP invariant (flow is min-cost at every prefix) ⇒ the
residual graph never contains a negative cycle ⇒ Bellman-Ford is exact.

Complexity: O(M·(M·N + N³)) worst case — ~10⁷ flops for M=2000 windows, well
under the paper's Fig 13 overhead budget. The ``soften=True`` variant folds
the Eq 12-13 penalty into arc costs via ``solvers.soft_cost`` (the fold is
exact — proven in tests against the literal MILP formulation).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import solvers

_INF = np.inf


def _reroute_arcs(c: np.ndarray, mask: np.ndarray, assign: np.ndarray,
                  N: int):
    """Build the N×N residual arc matrix R and the argmin job per arc.

    R[n, n2] = min over jobs j currently on n (and allowed on n2) of
    c[j, n2] - c[j, n]; job_pick[n, n2] = that argmin job (or -1).
    """
    R = np.full((N, N), _INF)
    job_pick = np.full((N, N), -1, dtype=np.int64)
    for n in range(N):
        js = np.nonzero(assign == n)[0]
        if js.size == 0:
            continue
        delta = np.where(mask[js], c[js] - c[js, n][:, None], _INF)  # [J, N]
        k = np.argmin(delta, axis=0)
        best = delta[k, np.arange(N)]
        has = np.isfinite(best)
        R[n, has] = best[has]
        job_pick[n, has] = js[k[has]]
        R[n, n] = _INF
    return R, job_pick


def _ssp_assign(cost: np.ndarray, mask: np.ndarray,
                capacity: np.ndarray) -> np.ndarray:
    """Successive-shortest-path assignment over the collapsed region graph.

    Returns assign[M] with region index, or -1 where no augmenting path
    exists (infeasible job under the hard constraints).
    """
    M, N = cost.shape
    c = np.where(mask, cost, _INF)

    # Fast path: when every job's unconstrained argmin column fits within
    # capacity (the common case in a low-utilization fleet, and the case the
    # event-driven engine hits tens of thousands of times per trace), the
    # greedy per-job minimum is a per-job lower bound that is jointly
    # feasible — hence exactly optimal. One vectorized shot, no SSP.
    if M > 0:
        best = np.argmin(c, axis=1)
        if np.isfinite(c[np.arange(M), best]).all():
            counts = np.bincount(best, minlength=N)
            if (counts <= capacity).all():
                return best

    assign = np.full(M, -1, dtype=np.int64)
    used = np.zeros(N, dtype=np.int64)

    # Cheapest-first source order speeds convergence (not needed for
    # correctness — SSP is exact under any source order).
    best_c = np.where(mask, cost, np.nan)
    order = np.argsort(np.nanmin(np.where(mask.any(axis=1)[:, None],
                                          best_c, np.inf), axis=1))
    for m in order:
        if not mask[m].any():
            continue
        dist = c[m].copy()                       # source job m -> each region
        prev = np.full(N, -1, dtype=np.int64)    # predecessor region (-1=src)
        R, job_pick = _reroute_arcs(c, mask, assign, N)
        # Bellman-Ford: N-1 rounds of full relaxation over the N×N arcs.
        for _ in range(N - 1):
            cand = dist[:, None] + R             # via-n cost to each n2
            via = np.argmin(cand, axis=0)
            better = cand[via, np.arange(N)] < dist - 1e-15
            if not better.any():
                break
            dist = np.where(better, cand[via, np.arange(N)], dist)
            prev = np.where(better, via, prev)

        free = used < capacity
        if not (free & np.isfinite(dist)).any():
            continue                              # no augmenting path
        tgt = int(np.argmin(np.where(free, dist, _INF)))

        # Retrace: reroute the picked job along every edge, then place m.
        # Guard against zero-cost cycles in the predecessor pointers (possible
        # only under exact float ties): fall back to direct placement.
        n2, hops, moves = tgt, 0, []
        while prev[n2] >= 0 and hops <= N:
            n1 = int(prev[n2])
            moves.append((int(job_pick[n1, n2]), n2))
            n2, hops = n1, hops + 1
        if hops > N:
            assign[m] = tgt
        else:
            for j, dst in moves:
                assign[j] = dst
            assign[m] = n2
        used[tgt] += 1
    return assign


@solvers.register("flow")
def solve(cost: np.ndarray, allowed: np.ndarray, capacity: np.ndarray, *,
          soften: bool = False, overrun: Optional[np.ndarray] = None,
          tol: Optional[np.ndarray] = None,
          sigma: float = 10.0) -> solvers.SolveResult:
    def run() -> solvers.SolveResult:
        M, N = cost.shape
        if soften:
            assert overrun is not None and tol is not None
            c_eff = solvers.soft_cost(cost, allowed, overrun, tol, sigma)
            mask = np.ones_like(allowed, dtype=bool)
        else:
            c_eff = cost.astype(np.float64)
            mask = allowed.astype(bool)

        assign = _ssp_assign(np.asarray(c_eff, np.float64), mask,
                             capacity.astype(np.int64))
        penalties = np.zeros(M)
        if (assign < 0).any():
            status = "infeasible"
            obj = float("inf")
        else:
            status = "optimal"
            obj = float(c_eff[np.arange(M), assign].sum())
            if soften:
                excess = np.maximum(overrun - tol[:, None], 0.0)
                penalties = excess[np.arange(M), assign]
        return solvers.SolveResult(assign=assign, objective=obj,
                                   status=status, solve_time_s=0.0,
                                   penalties=penalties, backend="flow")
    return solvers._timed(run)
