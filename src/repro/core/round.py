"""One fused scheduling round: pricing → masking → Sinkhorn → extraction
as a SINGLE jitted XLA program.

The hot path of a WaterWise scheduling round used to be several separately-
jitted pieces with host round-trips between them: ``problem.build`` /
``forecast.planner.build_temporal_plan`` priced the (jobs × regions × slots)
grid in numpy, ``jax_solver._prepare`` normalized and padded on the host,
``sinkhorn_log`` ran on device, the duals came back to the host, went *back*
to the device for ``plan_from_duals``, and the plan returned once more for
rounding. This module fuses everything between the raw per-round tensors and
the (host-side, inherently sequential) greedy vertex rounding into one XLA
computation:

  ``_assignment_program``   soft-cost folding → arc masking → cost
                            normalization → balanced-OT reduction → annealed
                            log-domain Sinkhorn → plan extraction, one jit.
                            Registered as solver backend ``"fused"`` — a
                            drop-in for ``"jax"`` everywhere a backend name
                            is accepted (``waterwise[backend=fused]``).
  ``_temporal_program``     additionally fuses the *pricing* of the
                            jobs × (regions × slots) decision grid (paper
                            Eqs 1-8 via ``core.footprint``, which is pure
                            arithmetic and traces transparently) and the
                            deadline-feasibility masking (Eq 11 + guard)
                            into the same program. Driven by
                            ``ForecastPricer`` when the pipeline backend is
                            ``"fused"`` (``waterwise-forecast[backend=fused]``).

Round-trip discipline — the actual perf content of the fusion:

  * everything that varies per round is packed into one contiguous per-job
    blob plus one small region-attribute array, so a temporal round costs
    TWO host→device copies instead of ~20 small ones;
  * per-pipeline constants (λ weights, guard, slot offsets, server spec)
    are compile-time static — zero per-round transfer;
  * inputs are padded on the HOST to the row buckets of
    ``jax_solver.BUCKETS`` and the true job count rides along inside a
    traced array, so a whole simulation — thousands of rounds with jittery
    window sizes — compiles each program once per bucket, exactly like the
    unfused path (padding rows carry zero log-domain mass and are exact
    no-ops in every Sinkhorn update);
  * only the normalized costs and the extracted plan return to the host
    (one transfer); the priced cost/mask tensors stay on device unless the
    caller records windows for offline replay.

The Sinkhorn inner loop runs the XLA scan of ``jax_solver`` by default and
can run the fused Pallas row/col-reduction kernel
(``repro.kernels.sinkhorn``) instead where shapes allow — auto-selected on
TPU, opt-in elsewhere (interpret mode is for validation, not speed).

Parity contract (pinned in tests/test_round.py): for identical inputs the
fused and unfused paths produce **bit-identical scheduling decisions** —
the same assignment vector, hold/defer split, and feasibility status per
round, and therefore bit-identical engine records end-to-end. Dual
potentials may differ in low-order bits (the fused program normalizes in
float32 on device where the unfused path staged through float64 numpy), but
the decisions they round to are pinned equal per dtype/shape bucket.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.core import footprint, problem, solvers
from repro.core.solvers import jax_solver
from repro.core.solvers.jax_solver import BIG, _NEG, bucket_for
from repro.runtime import platform as runtime_platform

__all__ = ["fused_solve", "fused_temporal_round", "fused_round_batch",
           "sinkhorn_impl_default", "SinkhornWarmStart", "SolveRequest",
           "group_requests"]


def sinkhorn_impl_default() -> str:
    """``pallas`` on TPU (the fused row/col-reduction kernel), ``xla``
    elsewhere (interpret-mode Pallas is a validation path, not a fast one)."""
    return "pallas" if runtime_platform.on_tpu() else "xla"


def _pad_rows(rows: int):
    """(bucket, job-row pad): job tensors are padded to ``bucket − 1`` rows
    so that [padded jobs | dummy slack row] fills the bucket exactly."""
    bucket = bucket_for(rows + 1)
    return bucket, bucket - 1 - rows


def _pad0(x, pad: int, value=0):
    """Pad job-axis tensors with ``pad`` constant rows."""
    if pad == 0:
        return x
    width = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return np.pad(x, width, constant_values=value)


def _interpret(impl: str, interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return bool(interpret)
    return impl == "pallas" and not runtime_platform.on_tpu()


# ---------------------------------------------------------------------------
# Fused inner stages (traced pieces shared by both programs)
# ---------------------------------------------------------------------------

def _prepare_device(c_eff, mask, cap, valid):
    """Traced equivalent of ``jax_solver._prepare``: normalize costs to
    ~unit scale, price forbidden arcs at BIG, append the balanced-OT dummy
    supply row. ``valid`` marks real job rows; padding rows get zero mass
    (log marginal ``_NEG``) and are exact no-ops in the log-domain updates."""
    Mb, N = c_eff.shape
    scale = jnp.maximum(jnp.max(jnp.where(mask, jnp.abs(c_eff), 0.0)), 1e-9)
    Cn = jnp.where(mask, c_eff / scale, BIG).astype(jnp.float32)
    C = jnp.concatenate([Cn, jnp.zeros((1, N), jnp.float32)], axis=0)
    m_true = valid.sum()
    slack = jnp.maximum(cap.sum() - m_true, 1e-9)
    total = m_true + slack
    log_a = jnp.concatenate([
        jnp.where(valid, -jnp.log(total), _NEG),
        jnp.log(slack / total)[None]]).astype(jnp.float32)
    log_b = jnp.log(jnp.maximum(cap, 1e-12) / total).astype(jnp.float32)
    return C, log_a, log_b, Cn, scale


def _sinkhorn_pallas(C, log_a, log_b, *, eps0: float, eps_min: float,
                     iters: int, anneal_stages: int, interpret: bool):
    """ε-annealed Sinkhorn with the fused Pallas iteration kernel as the
    inner loop. The kernel's ε is a compile-time constant, so the anneal
    schedule is unrolled in Python (``anneal_stages`` is static and small)
    with one ``fori_loop`` per stage. The kernel updates (f ← g, then
    g ← f) where the XLA path updates (g ← f, then f ← g); both converge
    to the same transport polytope vertex as ε → 0."""
    from repro.kernels.sinkhorn.ops import sinkhorn_iteration
    decay = (eps_min / eps0) ** (1.0 / max(anneal_stages - 1, 1))
    f = jnp.zeros(C.shape[0], jnp.float32)
    g = jnp.zeros(C.shape[1], jnp.float32)
    eps = eps0
    for s in range(anneal_stages):
        eps = eps0 * decay ** s

        def body(_, fg, _eps=eps):
            return sinkhorn_iteration(C, fg[0], fg[1], log_a, log_b, _eps,
                                      interpret=interpret)

        f, g = jax.lax.fori_loop(0, iters, body, (f, g))
    return f, g, eps


def _solve_core(c_eff, mask, cap, valid, *, impl: str, eps0: float,
                eps_min: float, iters: int, anneal_stages: int,
                interpret: bool):
    """prepare → annealed Sinkhorn → plan extraction, all traced. Returns
    the (padded-row) normalized cost matrix, row-normalized plan, and the
    normalization scale; the host slices off the padding."""
    C, log_a, log_b, Cn, scale = _prepare_device(c_eff, mask, cap, valid)
    if impl == "pallas":
        f, g, eps = _sinkhorn_pallas(C, log_a, log_b, eps0=eps0,
                                     eps_min=eps_min, iters=iters,
                                     anneal_stages=anneal_stages,
                                     interpret=interpret)
    else:
        f, g, eps = jax_solver._sinkhorn_log_impl(
            C, log_a, log_b, eps0, eps_min, iters, anneal_stages)
    X = jnp.exp((f[:, None] + g[None, :] - C) / eps)[:Cn.shape[0]]
    X = X / jnp.maximum(X.sum(axis=1, keepdims=True), 1e-30)
    return Cn, X, scale


# ---------------------------------------------------------------------------
# Program 1: the fused assignment solve (solver backend "fused")
# ---------------------------------------------------------------------------

def _assignment_body(arcs, tolv, cap, *, soften: bool, sigma: float,
                     impl: str, eps0: float = 0.5, eps_min: float = 0.005,
                     iters: int = 60, anneal_stages: int = 6,
                     interpret: bool = False):
    """Soft-cost folding + masking + prepare + Sinkhorn + extraction as one
    XLA computation (the device half of the ``"fused"`` backend).

    ``arcs`` packs [cost | allowed(0/1) | overrun] as one [3, Mb, C] upload;
    ``tolv`` packs [tol | row-validity] as [Mb, 2] — bucket-padded, with the
    true job count implied by the validity column.

    Unjitted on purpose: the single-cell program jits it directly
    (``_assignment_program``) and the device-parallel batch path vmaps /
    shard_maps the *same traced body* over a leading cell axis
    (``fused_round_batch``) — per-cell results are bitwise identical by
    construction (pinned in tests/test_device_executor.py).
    """
    cost, allowed, overrun = arcs[0], arcs[1] > 0.5, arcs[2]
    tol, valid = tolv[:, 0], tolv[:, 1] > 0.5
    if soften:
        excess = jnp.maximum(overrun - tol[:, None], 0.0)
        c_eff = cost + sigma * excess
        mask = valid[:, None] & jnp.ones_like(allowed)
    else:
        c_eff = cost
        mask = valid[:, None] & allowed
    Cn, X, _ = _solve_core(c_eff, mask, cap, valid, impl=impl, eps0=eps0,
                           eps_min=eps_min, iters=iters,
                           anneal_stages=anneal_stages, interpret=interpret)
    return Cn, X


_assignment_program = functools.partial(jax.jit, static_argnames=(
    "soften", "sigma", "impl", "eps0", "eps_min", "iters", "anneal_stages",
    "interpret"))(_assignment_body)


@solvers.register("fused")
def fused_solve(cost: np.ndarray, allowed: np.ndarray, capacity: np.ndarray,
                *, soften: bool = False,
                overrun: Optional[np.ndarray] = None,
                tol: Optional[np.ndarray] = None, sigma: float = 10.0,
                eps_min: float = 0.005,
                sinkhorn_impl: Optional[str] = None,
                interpret: Optional[bool] = None) -> solvers.SolveResult:
    """Drop-in ``"jax"``-backend replacement with the device work fused
    into one program: ONE dispatch and ONE host transfer per round instead
    of host prepare → Sinkhorn → host → plan extraction → host. The greedy
    vertex rounding + exact SSP repair + 2-swap polish stay on the host
    (inherently sequential, microseconds at scheduling sizes)."""
    def run() -> solvers.SolveResult:
        M, N = cost.shape
        cap = capacity.astype(np.int64)
        if int(cap.sum()) < M or \
                not (soften or allowed.any(axis=1).all()):
            return _infeasible(M)
        _, pad = _pad_rows(M)
        impl = sinkhorn_impl or sinkhorn_impl_default()
        arcs = np.stack([
            _pad0(cost, pad),
            _pad0(allowed.astype(np.float64), pad),
            _pad0(overrun if overrun is not None else np.zeros((M, N)),
                  pad)]).astype(np.float32)
        tolv = np.stack([
            _pad0(tol if tol is not None else np.zeros(M), pad),
            _pad0(np.ones(M), pad)], axis=1).astype(np.float32)
        Cn, X = _assignment_program(
            jnp.asarray(arcs), jnp.asarray(tolv),
            jnp.asarray(cap, jnp.float32),
            soften=bool(soften), sigma=float(sigma), impl=impl,
            eps_min=float(eps_min), interpret=_interpret(impl, interpret))
        Cn, X = jax.device_get((Cn, X))
        if obs.enabled():
            bucket = M + 1 + pad
            obs.annotate(
                bucket=bucket, pad=pad, occupancy=(M + 1) / bucket,
                sinkhorn_iters=jax_solver.SINKHORN_ITERS
                * jax_solver.SINKHORN_STAGES,
                eps0=jax_solver.SINKHORN_EPS0, eps_min=eps_min,
                anneal_stages=jax_solver.SINKHORN_STAGES, impl=impl)
        c_eff, mask = jax_solver._effective(cost, allowed, soften, overrun,
                                            tol, sigma)
        res = jax_solver._finalize(np.asarray(X[:M], np.float64),
                                   np.asarray(Cn[:M], np.float64), c_eff,
                                   mask, cap, soften, overrun, tol)
        res.backend = "fused"
        return res
    return solvers._timed(run)


def _infeasible(M: int) -> solvers.SolveResult:
    res = jax_solver._infeasible(M)
    res.backend = "fused"
    return res


# ---------------------------------------------------------------------------
# Program 1b: the device-parallel batched assignment solve
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SolveRequest:
    """One cell's assignment-round solve, queued for device-parallel
    batching (``fused_round_batch``). Fields mirror ``fused_solve``'s
    signature — a request is exactly one deferred call."""
    cost: np.ndarray                       # [M, C]
    allowed: np.ndarray                    # [M, C]
    capacity: np.ndarray                   # [C]
    soften: bool = False
    overrun: Optional[np.ndarray] = None
    tol: Optional[np.ndarray] = None
    sigma: float = 10.0
    eps_min: float = 0.005
    sinkhorn_impl: Optional[str] = None
    interpret: Optional[bool] = None


def group_requests(requests) -> dict:
    """Group request *indices* by compile signature: (row bucket, columns,
    cost dtype, soften, sigma, impl, eps_min, interpret).

    Pure bookkeeping (property-tested): a group never mixes row buckets,
    column counts, dtypes, or solver statics — each group maps onto exactly
    one compiled batch program, and one compile serves every batch that
    shares the signature.
    """
    groups: dict = {}
    for i, r in enumerate(requests):
        M, C = np.asarray(r.cost).shape
        key = (bucket_for(M + 1), C, np.dtype(np.asarray(r.cost).dtype).str,
               bool(r.soften), float(r.sigma), r.sinkhorn_impl,
               float(r.eps_min), r.interpret)
        groups.setdefault(key, []).append(i)
    return groups


def _request_statics(req: SolveRequest) -> dict:
    """The resolved static (compile-time) solver constants of one request —
    identical across a group by construction of the group key."""
    impl = req.sinkhorn_impl or sinkhorn_impl_default()
    return dict(soften=bool(req.soften), sigma=float(req.sigma), impl=impl,
                eps_min=float(req.eps_min),
                interpret=_interpret(impl, req.interpret))


@functools.lru_cache(maxsize=None)
def _batch_callable(devices: int, *, soften: bool, sigma: float, impl: str,
                    eps_min: float, interpret: bool):
    """The compiled device-parallel batch program for one static signature:
    ``vmap`` of the single-cell ``_assignment_body`` over a leading cell
    axis, ``shard_map``-split across ``devices`` XLA devices when more than
    one is available. Cached per (devices, statics) — jitted shapes cache
    underneath as usual."""
    one = functools.partial(_assignment_body, soften=soften, sigma=sigma,
                            impl=impl, eps_min=eps_min, interpret=interpret)
    fn = jax.vmap(one)
    if devices > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P
        mesh = Mesh(np.asarray(jax.devices()[:devices]), ("cells",))
        fn = shard_map(fn, mesh=mesh,
                       in_specs=(P("cells"), P("cells"), P("cells")),
                       out_specs=(P("cells"), P("cells")), check_rep=False)
    return jax.jit(fn)


def _batch_size(n: int, devices: int) -> int:
    """Compiled batch size for ``n`` cells: next power of two per device
    shard × the device count, so jittery group sizes reuse a handful of
    compiled batch shapes (the cell-axis analogue of the row buckets)."""
    per = -(-n // devices)
    p = 1
    while p < per:
        p *= 2
    return devices * p


def fused_round_batch(requests, devices: int = 1) -> list:
    """Solve many independent cells' assignment rounds as device-parallel
    jitted programs — ONE dispatch per (bucket, dtype, statics) group
    instead of one per cell.

    The batch path vmaps (and, with ``devices > 1``, shard_maps over a
    host-device mesh) the exact traced body the single-cell ``"fused"``
    backend jits, with identical per-cell bucket padding — so every cell's
    normalized costs and transport plan are **bitwise identical** to a
    per-cell ``fused_solve`` call (pinned in tests/test_device_executor.py),
    and the host-side vertex rounding consumes identical inputs. Groups are
    padded to ``_batch_size`` by repeating the last cell (results sliced
    off), keeping compiled batch shapes few and device shards equal-sized.

    Returns ``SolveResult``s in request order; per-request infeasibility
    (capacity shortfall / fully masked row) short-circuits exactly like
    ``fused_solve``. ``obs`` counters: ``round.batch_compile`` counts fresh
    program compiles (retrace accounting for the bench gate),
    ``round.batch_solves`` counts cells served.
    """
    devices = max(1, int(devices))
    n_avail = len(jax.devices())
    if devices > n_avail:
        raise ValueError(f"devices={devices} exceeds the {n_avail} "
                         f"available XLA device(s)")
    results: list = [None] * len(requests)
    live: list = []
    for i, r in enumerate(requests):
        M, C = r.cost.shape
        cap = np.asarray(r.capacity).astype(np.int64)
        allowed = np.asarray(r.allowed, bool)
        if int(cap.sum()) < M or \
                not (r.soften or allowed.any(axis=1).all()):
            results[i] = _infeasible(M)
        else:
            live.append(i)
    if not live:
        return results
    groups = group_requests([requests[i] for i in live])
    with obs.timed("solver.round_batch", requests=len(requests),
                   groups=len(groups), devices=devices) as t:
        for key, local in groups.items():
            idxs = [live[j] for j in local]
            bucket = key[0]
            statics = _request_statics(requests[idxs[0]])
            arcs_l, tolv_l, cap_l = [], [], []
            for i in idxs:
                r = requests[i]
                M, C = r.cost.shape
                pad = bucket - 1 - M
                arcs_l.append(np.stack([
                    _pad0(r.cost, pad),
                    _pad0(np.asarray(r.allowed).astype(np.float64), pad),
                    _pad0(r.overrun if r.overrun is not None
                          else np.zeros((M, C)), pad)]).astype(np.float32))
                tolv_l.append(np.stack([
                    _pad0(r.tol if r.tol is not None else np.zeros(M), pad),
                    _pad0(np.ones(M), pad)], axis=1).astype(np.float32))
                cap_l.append(np.asarray(r.capacity).astype(np.int64)
                             .astype(np.float32))
            B = len(idxs)
            for _ in range(_batch_size(B, devices) - B):
                arcs_l.append(arcs_l[-1])
                tolv_l.append(tolv_l[-1])
                cap_l.append(cap_l[-1])
            fn = _batch_callable(devices, **statics)
            before = fn._cache_size()
            out = fn(jnp.asarray(np.stack(arcs_l)),
                     jnp.asarray(np.stack(tolv_l)),
                     jnp.asarray(np.stack(cap_l)))
            compiles = fn._cache_size() - before
            if compiles:
                obs.counter("round.batch_compile", compiles)
            Cnb, Xb = jax.device_get(out)
            for b, i in enumerate(idxs):
                r = requests[i]
                M = r.cost.shape[0]
                cap = np.asarray(r.capacity).astype(np.int64)
                c_eff, mask = jax_solver._effective(
                    np.asarray(r.cost, np.float64),
                    np.asarray(r.allowed, bool), r.soften, r.overrun,
                    r.tol, r.sigma)
                res = jax_solver._finalize(
                    np.asarray(Xb[b][:M], np.float64),
                    np.asarray(Cnb[b][:M], np.float64), c_eff, mask, cap,
                    r.soften, r.overrun, r.tol)
                res.backend = "fused"
                results[i] = res
        obs.counter("round.batch_solves", len(live))
    per = t.elapsed_s / max(len(requests), 1)
    for r in results:
        r.solve_time_s = per
    return results


# ---------------------------------------------------------------------------
# Program 2: the fused temporal round (pricing + masking + solve)
# ---------------------------------------------------------------------------

def _price_temporal(blob, rattrs, *, offsets: tuple, lam_co2: float,
                    lam_h2o: float, defer_eps: float, guard_s: float,
                    lifetime_s: float, embodied_gco2: float,
                    embodied_water_l: float):
    """Traced pricing + masking of the (jobs × slots × regions) grid —
    the device half shared by the fixed-budget and warm-startable temporal
    programs. Returns ``(cost, mask, cap_t, valid)`` flattened to
    ``[Mb, S·R]`` columns."""
    Mb = blob.shape[0]
    S = len(offsets)
    R = rattrs.shape[1]
    E, t = blob[:, 0, None, None], blob[:, 1, None, None]
    budget, valid = blob[:, 2], blob[:, 3] > 0.5
    signals = blob[:, 4:4 + 3 * S * R].reshape(Mb, S, 3 * R)
    latency = blob[:, 4 + 3 * S * R:4 + 3 * S * R + R]
    allowed0 = blob[:, 4 + 3 * S * R + R:]
    ci = signals[..., :R]
    ewif = signals[..., R:2 * R]
    wue = signals[..., 2 * R:]
    pue, wsf, ref_row, cap = rattrs[0], rattrs[1], rattrs[2], rattrs[3]

    co2 = footprint.total_carbon(E, ci, t, lifetime_s, embodied_gco2)
    h2o = footprint.total_water(E, pue[None, None, :], ewif, wue,
                                wsf[None, None, :], t, lifetime_s,
                                embodied_water_l)
    co2_max = jnp.maximum(co2.max(axis=(1, 2)), 1e-9)
    h2o_max = jnp.maximum(h2o.max(axis=(1, 2)), 1e-9)
    obj = (lam_co2 * co2 / co2_max[:, None, None]
           + lam_h2o * h2o / h2o_max[:, None, None])
    obj = obj + ref_row[None, None, :]
    obj = obj + defer_eps * jnp.arange(S)[None, :, None]

    need = jnp.asarray(offsets)[None, :, None] + latency[:, None, :]
    allowed = need + guard_s <= budget[:, None, None] + 1e-9
    allowed = allowed.at[:, 0, :].set(allowed0 > 0.5)

    cost = obj.reshape(Mb, S * R)
    mask = valid[:, None] & allowed.reshape(Mb, S * R)
    cap_t = jnp.tile(cap, S)
    return cost, mask, cap_t, valid


@functools.partial(jax.jit, static_argnames=(
    "offsets", "lam_co2", "lam_h2o", "defer_eps", "guard_s", "lifetime_s",
    "embodied_gco2", "embodied_water_l", "want_plan", "impl", "eps0",
    "eps_min", "iters", "anneal_stages", "interpret"))
def _temporal_program(blob, rattrs, *,
                      offsets: tuple, lam_co2: float, lam_h2o: float,
                      defer_eps: float, guard_s: float, lifetime_s: float,
                      embodied_gco2: float, embodied_water_l: float,
                      want_plan: bool, impl: str,
                      eps0: float = 0.5, eps_min: float = 0.005,
                      iters: int = 60, anneal_stages: int = 6,
                      interpret: bool = False):
    """The whole forecast-driven round on device: Eq 1/5 footprint pricing
    over the (jobs × slots × regions) grid, Eq-7 normalization, the λ-mixed
    Eq-8 objective + per-slot deferral ramp, the Eq-11 deadline/guard
    feasibility mask, and the fused prepare/Sinkhorn/extraction.

    Mirrors ``forecast.planner.build_temporal_plan`` exactly (the parity
    tests pin the decisions); ``core.footprint`` is pure arithmetic, so the
    same Eq 1-6 implementations trace unchanged.

    Packed inputs (host→device copies, not semantics) — everything that
    varies per round rides in TWO arrays, so a round costs two host→device
    copies total:
      blob    [Mb, 4 + 3SR + 2R]  per-job columns:
                [E | exec_t | slack budget | row-validity    (4)
                 | ci, ewif, wue forecast rows, slot-major   (3SR)
                 | latency | slot-0 Eq-11 mask (0/1)         (2R)]
      rattrs  [4, R]              pue | wsf | λ_ref history row | capacity
    Per-pipeline constants are static: compiled straight into the program.
    """
    cost, mask, cap_t, valid = _price_temporal(
        blob, rattrs, offsets=offsets, lam_co2=lam_co2, lam_h2o=lam_h2o,
        defer_eps=defer_eps, guard_s=guard_s, lifetime_s=lifetime_s,
        embodied_gco2=embodied_gco2, embodied_water_l=embodied_water_l)
    Cn, X, scale = _solve_core(cost, mask, cap_t, valid, impl=impl,
                               eps0=eps0, eps_min=eps_min, iters=iters,
                               anneal_stages=anneal_stages,
                               interpret=interpret)
    if want_plan:
        return Cn, X, scale, cost, mask
    return Cn, X, scale


@functools.partial(jax.jit, static_argnames=(
    "offsets", "lam_co2", "lam_h2o", "defer_eps", "guard_s", "lifetime_s",
    "embodied_gco2", "embodied_water_l", "eps0", "eps_min", "iters",
    "anneal_stages"))
def _temporal_adaptive_program(blob, rattrs, g0, tol, *,
                               offsets: tuple, lam_co2: float,
                               lam_h2o: float, defer_eps: float,
                               guard_s: float, lifetime_s: float,
                               embodied_gco2: float, embodied_water_l: float,
                               eps0: float, eps_min: float, iters: int,
                               anneal_stages: int):
    """``_temporal_program`` with the adaptive warm-startable Sinkhorn
    (convergence-exit ``while_loop``, XLA impl only): the caller supplies
    initial column potentials ``g0`` ([S·R], zeros for a cold start) and
    gets back the converged potentials plus the inner-iteration count —
    the live-serving path that carries duals between consecutive rounds
    (``SinkhornWarmStart``)."""
    cost, mask, cap_t, valid = _price_temporal(
        blob, rattrs, offsets=offsets, lam_co2=lam_co2, lam_h2o=lam_h2o,
        defer_eps=defer_eps, guard_s=guard_s, lifetime_s=lifetime_s,
        embodied_gco2=embodied_gco2, embodied_water_l=embodied_water_l)
    C, log_a, log_b, Cn, scale = _prepare_device(cost, mask, cap_t, valid)
    f, g, eps, used = jax_solver._sinkhorn_log_adaptive_impl(
        C, log_a, log_b, g0, tol, eps0=eps0, eps_min=eps_min, iters=iters,
        anneal_stages=anneal_stages)
    X = jnp.exp((f[:, None] + g[None, :] - C) / eps)[:Cn.shape[0]]
    X = X / jnp.maximum(X.sum(axis=1, keepdims=True), 1e-30)
    return Cn, X, scale, g, used


@dataclasses.dataclass
class SinkhornWarmStart:
    """Column-potential carry between consecutive fused temporal rounds.

    The temporal OT's column space — (region, slot) cells — is fixed per
    pipeline while the row space (jobs) changes every round, so the column
    potentials ``g`` are the part of the duals worth carrying: passed as
    the next round's ``g0``, a drifted-telemetry round converges in a
    handful of final-ε iterations instead of the full annealed schedule.
    The first round (or any column-shape change) runs cold: zeros init +
    the full schedule. Cold and warm iteration counts are recorded via
    ``repro.obs`` (``solver.sinkhorn_iters_cold`` / ``_warm``) and kept on
    the object for reporting (``repro.serve`` folds them into the BENCH
    round-latency fields).
    """
    tol: float = jax_solver.SINKHORN_TOL
    g: Optional[np.ndarray] = None
    cold_iters: list = dataclasses.field(default_factory=list)
    warm_iters: list = dataclasses.field(default_factory=list)

    def reset(self) -> None:
        self.g = None

    @property
    def mean_cold_iters(self) -> float:
        return float(np.mean(self.cold_iters)) if self.cold_iters else 0.0

    @property
    def mean_warm_iters(self) -> float:
        return float(np.mean(self.warm_iters)) if self.warm_iters else 0.0


def fused_temporal_round(inst, now_s: float, ci, ewif, wue, pue, wsf,
                         slot_offsets, server, lam_co2: float,
                         lam_h2o: float, lam_ref: float = 0.0,
                         co2_ref=None, h2o_ref=None,
                         defer_eps: float = 1e-3, guard_s: float = 240.0,
                         want_plan: bool = False,
                         sinkhorn_impl: Optional[str] = None,
                         interpret: Optional[bool] = None,
                         eps_min: float = 0.005,
                         warm_start: Optional[SinkhornWarmStart] = None):
    """Price, mask, and solve one forecast round in a single device dispatch.

    Same signature family as ``forecast.planner.build_temporal_plan`` (the
    unfused path), plus the solve. Returns ``(cost, allowed, capacity,
    SolveResult)``. With ``want_plan`` (offline window recording) the raw
    priced tensors leave the device; otherwise the returned cost/allowed
    are re-derived host-side from the normalized costs that come back
    anyway (identical to the priced tensor on every allowed arc; forbidden
    arcs carry ``solvers.BIG``) — no extra device transfer either way.

    ``warm_start`` switches to the adaptive Sinkhorn (convergence-exit
    loop, XLA impl): the object's carried column potentials seed the solve
    — zeros + the full annealed schedule when empty (cold) — and the
    converged potentials plus iteration counts are written back, so
    consecutive calls with the same object warm-start each other
    (the ``repro.serve`` decision loop's between-round carry).
    """
    jobs = inst.jobs
    M, N = inst.shape
    S = len(slot_offsets)
    assert slot_offsets[0] == 0.0 and ci.shape == (M, S, N)
    if co2_ref is not None and h2o_ref is not None:
        ref_row = lam_ref * (lam_co2 * np.asarray(co2_ref)
                             + lam_h2o * np.asarray(h2o_ref))
    else:
        ref_row = np.zeros(N)

    cap = np.asarray(inst.capacity, np.int64)
    bucket, _ = _pad_rows(M)
    impl = sinkhorn_impl or sinkhorn_impl_default()

    with obs.timed("solver.fused_round", jobs=M, slots=S, regions=N,
                  bucket=bucket, occupancy=(M + 1) / bucket,
                  sinkhorn_iters=jax_solver.SINKHORN_ITERS
                  * jax_solver.SINKHORN_STAGES,
                  eps0=jax_solver.SINKHORN_EPS0, eps_min=eps_min,
                  anneal_stages=jax_solver.SINKHORN_STAGES, impl=impl) as t:
        # One zero-initialized padded blob, filled in place: padding rows fall
        # out as zero-mass (validity 0) rows and the whole round uploads as two
        # contiguous copies (blob + rattrs).
        W = 4 + 3 * S * N + 2 * N
        blob = np.zeros((bucket - 1, W), np.float32)
        for i, j in enumerate(jobs):
            blob[i, 0] = j.energy_kwh
            blob[i, 1] = j.exec_time_s
            blob[i, 3] = 1.0
        # One shared vectorized slack definition (critical-path aware for
        # workflow tasks) — same expression the planner/pricers mask with.
        blob[:M, 2] = problem.slack_budget(jobs, now_s)
        # slot-major [ci | ewif | wue] per slot — [S, 3R] blocks flattened
        blob[:M, 4:4 + 3 * S * N] = np.concatenate(
            [ci, ewif, wue], axis=2).reshape(M, 3 * S * N)
        blob[:M, 4 + 3 * S * N:4 + 3 * S * N + N] = inst.latency
        blob[:M, 4 + 3 * S * N + N:] = inst.allowed
        rattrs = np.stack([pue, wsf, ref_row, cap]).astype(np.float32)
        statics = dict(
            offsets=tuple(float(o) for o in slot_offsets),
            lam_co2=float(lam_co2), lam_h2o=float(lam_h2o),
            defer_eps=float(defer_eps), guard_s=float(guard_s),
            lifetime_s=float(server.lifetime_s),
            embodied_gco2=float(server.embodied_gco2),
            embodied_water_l=float(server.embodied_water_l))
        if warm_start is not None:
            assert not want_plan, \
                "warm_start and want_plan are mutually exclusive"
            cols = S * N
            cold = warm_start.g is None or warm_start.g.shape != (cols,)
            g0 = (np.zeros(cols, np.float32) if cold
                  else warm_start.g.astype(np.float32))
            # Cold: the full annealed schedule with per-stage early exit.
            # Warm: one final-ε stage from the carried potentials, with the
            # whole fixed budget available as the iteration cap (the cap
            # should never bind when the carry is any good).
            budget = jax_solver.SINKHORN_ITERS * jax_solver.SINKHORN_STAGES
            out = _temporal_adaptive_program(
                jnp.asarray(blob), jnp.asarray(rattrs), jnp.asarray(g0),
                jnp.float32(warm_start.tol), **statics,
                eps0=float(eps_min) if not cold else jax_solver.SINKHORN_EPS0,
                eps_min=float(eps_min),
                iters=budget if not cold else jax_solver.SINKHORN_ITERS,
                anneal_stages=1 if not cold else jax_solver.SINKHORN_STAGES)
            out = jax.device_get(out)
            warm_start.g = np.asarray(out[3], np.float32)
            used = int(out[4])
            (warm_start.cold_iters if cold
             else warm_start.warm_iters).append(used)
            obs.observe("solver.sinkhorn_iters_cold" if cold
                        else "solver.sinkhorn_iters_warm", float(used))
            t.set(warm=not cold, adaptive_iters=used)
        else:
            out = _temporal_program(
                jnp.asarray(blob), jnp.asarray(rattrs), **statics,
                want_plan=bool(want_plan), impl=impl, eps_min=float(eps_min),
                interpret=_interpret(impl, interpret))
            out = jax.device_get(out)
        Cn = np.asarray(out[0][:M], np.float64)
        X = np.asarray(out[1][:M], np.float64)
        scale = float(out[2])
        mask = Cn < BIG * 0.5          # forbidden arcs are exactly BIG
        # De-normalized costs price the objective; identical to the priced
        # tensor on every allowed arc (forbidden arcs never enter objectives).
        c_eff = np.where(mask, Cn * scale, solvers.BIG)
        cap_t = np.tile(cap, S)

        if int(cap_t.sum()) < M or not mask.any(axis=1).all():
            res = _infeasible(M)
        else:
            res = jax_solver._finalize(X, Cn, c_eff, mask, cap_t,
                                       False, None, None)
            res.backend = "fused"
        t.set(status=res.status)
    res.solve_time_s = t.elapsed_s
    if want_plan:
        cost = np.asarray(out[3][:M], np.float64)
        allowed = np.asarray(out[4][:M], bool)
        return cost, allowed, cap_t, res
    return c_eff, mask, cap_t, res
