"""Carbon- and water-footprint models — paper §2, Eqs (1)-(6), implemented exactly.

All functions are pure and vectorize transparently over numpy arrays, so the
same code path serves (a) the discrete-event simulator (scalar per job), (b) the
MILP cost-matrix construction (jobs × regions matrices), and (c) the JAX solver
(the arrays are duck-typed; jnp arrays pass through unchanged).

Units
-----
energy_kwh     kWh   — job IT-equipment energy E_j
carbon         gCO2
water          L     (scaled by (1+WSF) => "effective liters", per paper Eq 2/3)
ci             gCO2/kWh  — grid carbon intensity
ewif           L/kWh     — energy-water-intensity factor of the grid mix
wue            L/kWh     — water usage effectiveness (cooling, onsite)
pue            (dimensionless) power usage effectiveness
wsf            (dimensionless) water scarcity factor, >= 0
"""
from __future__ import annotations

import dataclasses
from typing import Any

Array = Any  # np.ndarray | jnp.ndarray | float


# ---------------------------------------------------------------------------
# Eq (1): total carbon = operational + embodied
# ---------------------------------------------------------------------------

def operational_carbon(energy_kwh: Array, ci: Array) -> Array:
    """E_j · CO2^Intensity  [gCO2]."""
    return energy_kwh * ci


def embodied_carbon(exec_time_s: Array, lifetime_s: Array,
                    server_embodied_gco2: Array) -> Array:
    """(t_j / T_lifetime) · CO2_server^embodied  [gCO2]."""
    return (exec_time_s / lifetime_s) * server_embodied_gco2


def total_carbon(energy_kwh: Array, ci: Array, exec_time_s: Array,
                 lifetime_s: Array, server_embodied_gco2: Array) -> Array:
    """Eq (1)."""
    return (operational_carbon(energy_kwh, ci)
            + embodied_carbon(exec_time_s, lifetime_s, server_embodied_gco2))


# ---------------------------------------------------------------------------
# Eqs (2)-(5): water footprint
# ---------------------------------------------------------------------------

def offsite_water(energy_kwh: Array, pue: Array, ewif: Array,
                  wsf_dc: Array) -> Array:
    """Eq (2): PUE · E_j · EWIF · (1 + WSF_r^dc)  [L]."""
    return pue * energy_kwh * ewif * (1.0 + wsf_dc)


def onsite_water(energy_kwh: Array, wue: Array, wsf_dc: Array) -> Array:
    """Eq (3): E_j · WUE · (1 + WSF_r^dc)  [L]."""
    return energy_kwh * wue * (1.0 + wsf_dc)


def embodied_water_server(manufacturing_energy_kwh: Array, ewif_mfg: Array,
                          wsf_server: Array) -> Array:
    """Eq (4): E_manufacturing · EWIF · (1 + WSF_r^server)  [L]."""
    return manufacturing_energy_kwh * ewif_mfg * (1.0 + wsf_server)


def embodied_water(exec_time_s: Array, lifetime_s: Array,
                   server_embodied_water_l: Array) -> Array:
    """Job share of the server's embodied water (same amortization as carbon)."""
    return (exec_time_s / lifetime_s) * server_embodied_water_l


def total_water(energy_kwh: Array, pue: Array, ewif: Array, wue: Array,
                wsf_dc: Array, exec_time_s: Array, lifetime_s: Array,
                server_embodied_water_l: Array) -> Array:
    """Eq (5)."""
    return (offsite_water(energy_kwh, pue, ewif, wsf_dc)
            + onsite_water(energy_kwh, wue, wsf_dc)
            + embodied_water(exec_time_s, lifetime_s, server_embodied_water_l))


# ---------------------------------------------------------------------------
# Eq (6): water intensity (the paper's proposed metric)
# ---------------------------------------------------------------------------

def water_intensity(wue: Array, pue: Array, ewif: Array, wsf_dc: Array) -> Array:
    """Eq (6): (WUE + PUE·EWIF) · (1 + WSF_r^dc)  [L/kWh]."""
    return (wue + pue * ewif) * (1.0 + wsf_dc)


# ---------------------------------------------------------------------------
# Server hardware constants (embodied footprints)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """Embodied footprint + power parameters of one server/accelerator node.

    Defaults follow the paper's m5.metal setup (Teads/Davy dataset [13]):
    ~1,344 kgCO2 embodied per m5.metal server, 4-year lifetime. The embodied
    water is derived per Eq (4): embodied carbon / CI_mfg gives manufacturing
    energy; × EWIF_mfg × (1+WSF_mfg) gives liters. For the TPU-adaptation,
    ``tpu_v5e_tray()`` models an 8-chip v5e tray.
    """
    name: str = "m5.metal"
    embodied_gco2: float = 1_344_000.0          # 1,344 kgCO2 -> g
    lifetime_s: float = 4 * 365 * 24 * 3600.0    # 4 years
    ci_mfg_g_per_kwh: float = 550.0              # Taiwan/Korea fab grid mix
    ewif_mfg_l_per_kwh: float = 1.8
    wsf_mfg: float = 0.40                        # fab regions are water-stressed
    idle_power_w: float = 150.0
    peak_power_w: float = 720.0                  # 4-socket Xeon 8175 node

    @property
    def manufacturing_energy_kwh(self) -> float:
        """Back out E_manufacturing from embodied carbon (paper §2.2 method)."""
        return self.embodied_gco2 / self.ci_mfg_g_per_kwh

    @property
    def embodied_water_l(self) -> float:
        """Eq (4) applied to this server."""
        return embodied_water_server(self.manufacturing_energy_kwh,
                                     self.ewif_mfg_l_per_kwh, self.wsf_mfg)


def m5_metal() -> ServerSpec:
    return ServerSpec()


def tpu_v5e_tray() -> ServerSpec:
    """An 8-chip TPU v5e tray (the migration/scheduling unit in our adaptation)."""
    return ServerSpec(
        name="tpu-v5e-8",
        embodied_gco2=2_600_000.0,       # ~325 kgCO2/chip accel-class estimate
        lifetime_s=4 * 365 * 24 * 3600.0,
        ci_mfg_g_per_kwh=550.0,
        ewif_mfg_l_per_kwh=1.8,
        wsf_mfg=0.40,
        idle_power_w=8 * 60.0,
        peak_power_w=8 * 250.0,          # ~197 TFLOP/s bf16 chip at ~250 W
    )


# ---------------------------------------------------------------------------
# Per-node power model (ichnos-style idle/peak utilization curve)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Linear idle/peak utilization power curve of one server node:

        P(u) = P_idle + (P_peak − P_idle) · u        [W],  u ∈ [0, 1]

    Replaces the flat per-job ``energy_kwh`` estimate at trace-generation
    time: a task's energy is its utilization-dependent draw integrated over
    its execution window. Pure and array-transparent like the Eq (1)-(6)
    functions.
    """
    idle_w: float
    peak_w: float

    @classmethod
    def from_server(cls, server: "ServerSpec") -> "PowerModel":
        return cls(idle_w=server.idle_power_w, peak_w=server.peak_power_w)

    def power_w(self, utilization: Array) -> Array:
        import numpy as np
        u = np.clip(utilization, 0.0, 1.0)
        return self.idle_w + (self.peak_w - self.idle_w) * u

    def energy_kwh(self, utilization: Array, exec_time_s: Array,
                   servers: Array = 1) -> Array:
        """Energy of ``servers`` nodes running ``exec_time_s`` at
        ``utilization``  [kWh]."""
        return self.power_w(utilization) * exec_time_s * servers / 3.6e6


# ---------------------------------------------------------------------------
# Per-region embodied-carbon amortization (the third accounting dimension)
# ---------------------------------------------------------------------------

#: Relative embodied-carbon factor of each region's server fleet. The
#: structure encodes a fleet-age tension: regions that decarbonized their
#: grid early also run the oldest, lifetime-extended fleets (depreciated
#: hardware amortizes little embodied carbon per job), while regions in
#: the middle of a build-out boom run freshly manufactured servers that
#: carry the most *unamortized* embodied carbon. So the cleanest-grid
#: region sits LOW here and the boom region sits high — which is what
#: makes the three-way objective a genuine trade: the embodied-cheap
#: region is operationally cheap on carbon but expensive on water.
#: Applied multiplicatively to the server's amortization rate; regions
#: beyond the table cycle through it. Deterministic and documented so
#: accounting is reproducible — a telemetry-side table can replace it
#: later.
REGION_EMBODIED_SCALE = (0.70, 1.00, 1.30, 1.20, 1.10)


def region_embodied_scale(num_regions: int):
    """[num_regions] per-region embodied amortization factors."""
    import numpy as np
    base = np.asarray(REGION_EMBODIED_SCALE)
    return base[np.arange(num_regions) % len(base)]


def embodied_rate_g_per_s(server: "ServerSpec") -> float:
    """Amortized embodied-carbon rate of one server: gCO2e per server-second
    (ichnos ``EmbodiedCarbon`` style — total embodied CO2 spread uniformly
    over the hardware lifetime)."""
    return server.embodied_gco2 / server.lifetime_s


def job_embodied(exec_time_s: Array, server: "ServerSpec",
                 region_scale: Array = 1.0, servers: Array = 1) -> Array:
    """Embodied gCO2e a job's execution amortizes: rate · t_j · servers,
    scaled by the per-region fleet factor. This is the NEW accounting
    column — it is *not* folded into the Eq (1) carbon the pricers already
    report (that keeps the original embodied term for backward parity)."""
    return embodied_rate_g_per_s(server) * exec_time_s * servers * region_scale


# ---------------------------------------------------------------------------
# Convenience: footprints of a (job, region, time) triple
# ---------------------------------------------------------------------------

def job_carbon(energy_kwh: Array, exec_time_s: Array, ci: Array,
               server: ServerSpec) -> Array:
    return total_carbon(energy_kwh, ci, exec_time_s, server.lifetime_s,
                        server.embodied_gco2)


def job_water(energy_kwh: Array, exec_time_s: Array, pue: Array, ewif: Array,
              wue: Array, wsf_dc: Array, server: ServerSpec) -> Array:
    return total_water(energy_kwh, pue, ewif, wue, wsf_dc, exec_time_s,
                       server.lifetime_s, server.embodied_water_l)
