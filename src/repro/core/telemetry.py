"""Per-region sustainability telemetry — calibrated synthetic time series.

The paper feeds WaterWise live data (Electricity Maps carbon intensity + energy
mix, Meteologix wet-bulb temperature -> WUE, ourworldindata WSF, Macknick EWIF
per energy source). This container is offline, so we generate the same signals
from a *physical* model that is calibrated to the paper's published numbers:

* Fig 1 per-source constants: coal CI=1050 gCO2/kWh (62x hydro's 17);
  hydro EWIF=17 L/kWh (11x coal's ~1.5).
* Fig 2 per-region orderings: Zurich lowest CI / highest EWIF; Mumbai highest
  CI / low EWIF; Madrid & Mumbai & Oregon high WSF, Zurich low.
* Fig 2(e) temporal structure: diurnal solar swing + synoptic (multi-day)
  weather noise => periods of high-CI/low-WI and vice versa.

The generator works by evolving each region's *energy mix shares* hourly and
deriving CI(t) = sum share_s * CI_s and EWIF(t) = sum share_s * EWIF_s — so the
carbon/water tension emerges from the physics (hydro & biomass are low-carbon
but water-thirsty) rather than being painted on. WUE(t) is a cooling-tower
model of wet-bulb temperature. Two EWIF tables are shipped: ``MACKNICK``
(Electricity-Maps-era, used by paper Fig 5) and ``WRI`` (paper Fig 6
sensitivity study).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

import repro.obs as obs
from repro.core import footprint

HOUR = 3600.0

# ---------------------------------------------------------------------------
# Per-source constants (paper Fig 1; Macknick et al. + IPCC Annex III)
# CI in gCO2/kWh; EWIF in L/kWh.
# ---------------------------------------------------------------------------

SOURCE_CI: Dict[str, float] = {
    "coal": 1050.0,
    "oil": 720.0,
    "gas": 490.0,
    "biomass": 230.0,
    "solar": 45.0,
    "hydro": 17.0,
    "nuclear": 12.0,
    "wind": 11.0,
}

# Macknick operational-consumption factors (tower-cooled medians), the dataset
# the paper uses with Electricity Maps mixes.
EWIF_MACKNICK: Dict[str, float] = {
    "coal": 1.55,      # paper: hydro 17 is "11x" coal
    "oil": 1.60,
    "gas": 1.00,
    "biomass": 25.0,   # feedstock irrigation + cooling
    "solar": 0.30,     # PV wash water
    "hydro": 17.0,     # paper Fig 1
    "nuclear": 2.30,
    "wind": 0.01,
}

# WRI "Guidance for calculating water use embedded in purchased electricity"
# (paper Fig 6 sensitivity): same ordering, different magnitudes.
EWIF_WRI: Dict[str, float] = {
    "coal": 1.90,
    "oil": 1.75,
    "gas": 0.75,
    "biomass": 32.0,
    "solar": 0.10,
    "hydro": 9.0,
    "nuclear": 2.70,
    "wind": 0.005,
}

EWIF_TABLES = {"macknick": EWIF_MACKNICK, "wri": EWIF_WRI}


# ---------------------------------------------------------------------------
# Regions (paper §5: five AWS regions)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RegionSpec:
    name: str
    aws: str
    # Mean energy-mix shares (sum to 1); solar share swings diurnally.
    mix: Dict[str, float]
    wsf: float                      # water scarcity factor (Fig 2d)
    pue: float                      # §5: PUE = 1.2 everywhere by default
    wb_mean_c: float                # mean wet-bulb temperature, deg C
    wb_diurnal_c: float             # diurnal wet-bulb amplitude
    wb_synoptic_c: float            # multi-day weather amplitude
    utc_offset_h: float             # phase of local solar noon
    mix_volatility: float = 0.10    # synoptic share-shuffle magnitude


REGIONS: List[RegionSpec] = [
    # Zurich: hydro+nuclear+biomass -> lowest CI, highest EWIF (paper Fig 2a/2b)
    RegionSpec("Zurich", "eu-central-2",
               {"hydro": 0.48, "nuclear": 0.28, "biomass": 0.12,
                "solar": 0.07, "gas": 0.05},
               wsf=0.10, pue=1.2, wb_mean_c=9.0, wb_diurnal_c=3.5,
               wb_synoptic_c=4.0, utc_offset_h=1.0),
    # Oregon: hydro-heavy + gas; low-ish CI, mid EWIF, HIGH WSF (paper Fig 2d)
    RegionSpec("Oregon", "us-west-2",
               {"hydro": 0.42, "gas": 0.28, "wind": 0.14, "solar": 0.07,
                "coal": 0.05, "nuclear": 0.04},
               wsf=0.55, pue=1.2, wb_mean_c=12.0, wb_diurnal_c=5.0,
               wb_synoptic_c=5.0, utc_offset_h=-8.0),
    # Madrid: renewables-forward but water stressed (paper's key example)
    RegionSpec("Madrid", "eu-south-2",
               {"wind": 0.24, "solar": 0.19, "nuclear": 0.21, "gas": 0.24,
                "hydro": 0.10, "coal": 0.02},
               wsf=0.80, pue=1.2, wb_mean_c=14.0, wb_diurnal_c=5.5,
               wb_synoptic_c=4.5, utc_offset_h=1.0),
    # Milan: gas-dominated
    RegionSpec("Milan", "eu-south-1",
               {"gas": 0.46, "hydro": 0.18, "solar": 0.10, "wind": 0.05,
                "nuclear": 0.11, "coal": 0.06, "biomass": 0.04},
               wsf=0.35, pue=1.2, wb_mean_c=15.0, wb_diurnal_c=4.5,
               wb_synoptic_c=4.0, utc_offset_h=1.0),
    # Mumbai: coal-dominated -> highest CI, LOW EWIF, high WSF (Fig 2)
    RegionSpec("Mumbai", "ap-south-1",
               {"coal": 0.68, "gas": 0.12, "hydro": 0.06, "wind": 0.07,
                "solar": 0.06, "oil": 0.01},
               wsf=0.90, pue=1.2, wb_mean_c=24.0, wb_diurnal_c=2.5,
               wb_synoptic_c=2.0, utc_offset_h=5.5),
]

REGION_NAMES = [r.name for r in REGIONS]
REGION_INDEX = {r.name: i for i, r in enumerate(REGIONS)}


# ---------------------------------------------------------------------------
# Inter-region WAN model (paper Table 3: transfer latency dominates the
# communication cost; home Oregon -> {Zurich, Madrid, Milan, Mumbai}).
# Effective long-haul throughput per transfer stream, plus RTT.
# ---------------------------------------------------------------------------

WAN_BW_GBPS = np.array([
    #  Zur   Ore   Mad   Mil   Mum
    [0.0, 0.9, 2.4, 2.8, 0.7],   # Zurich
    [0.9, 0.0, 1.0, 0.9, 0.5],   # Oregon
    [2.4, 1.0, 0.0, 2.2, 0.6],   # Madrid
    [2.8, 0.9, 2.2, 0.0, 0.7],   # Milan
    [0.7, 0.5, 0.6, 0.7, 0.0],   # Mumbai
])  # GB/s effective; diagonal unused

WAN_RTT_S = np.array([
    [0.000, 0.140, 0.030, 0.012, 0.110],
    [0.140, 0.000, 0.150, 0.155, 0.220],
    [0.030, 0.150, 0.000, 0.028, 0.125],
    [0.012, 0.155, 0.028, 0.000, 0.105],
    [0.110, 0.220, 0.125, 0.105, 0.000],
])


def transfer_latency_s(bytes_: float, src: int, dst: int,
                       fixed_overhead_s: float = 2.0) -> float:
    """Job-package / checkpoint transfer time between regions (paper: SCP .tar;
    ours: sharded checkpoint). ``src == dst`` -> 0."""
    if src == dst:
        return 0.0
    bw = WAN_BW_GBPS[src, dst] * 1e9
    return fixed_overhead_s + WAN_RTT_S[src, dst] + bytes_ / bw


# ---------------------------------------------------------------------------
# WUE model: counterflow cooling-tower water evaporation as a function of
# wet-bulb temperature (deg C) -> L/kWh. Piecewise-smooth fit used by
# Li et al. ("Making AI less thirsty" [32]), clipped to physical range.
# ---------------------------------------------------------------------------

def wue_from_wetbulb(t_wb_c: np.ndarray) -> np.ndarray:
    t = np.asarray(t_wb_c, dtype=np.float64)
    wue = 6e-5 * t**3 - 0.01 * t**2 + 0.61 * t - 10.4
    return np.clip(wue / 3.6, 0.05, 9.0)  # /3.6: MJ->kWh units of the fit


# ---------------------------------------------------------------------------
# Time-series generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Telemetry:
    """Hourly telemetry for all regions over a horizon.

    Attributes (all np.ndarray, shape [T, R] unless noted):
      ci          gCO2/kWh grid carbon intensity
      ewif        L/kWh grid energy-water-intensity
      wue         L/kWh onsite cooling water usage effectiveness
      wsf         [R] water scarcity factor (static)
      pue         [R] power usage effectiveness (static)
      water_int   Eq (6) water intensity, L/kWh
      hours       [T] hour index
      wb_c        [T, R] wet-bulb temperature, °C (the raw weather driving
                  WUE; kept because WUE clips at its physical floor, so heat
                  extremes are only visible in the wet-bulb series itself)
    """
    ci: np.ndarray
    ewif: np.ndarray
    wue: np.ndarray
    wsf: np.ndarray
    pue: np.ndarray
    hours: np.ndarray
    wb_c: Optional[np.ndarray] = None
    # [R, R] WAN tables for *this* telemetry's regions. ``generate`` slices
    # the global tables by region identity (name lookup), so ablation runs
    # on a non-prefix subset — e.g. {Zurich, Milan, Mumbai} — price a
    # Zurich→Mumbai transfer with Mumbai's bandwidth/RTT, not whatever
    # region happens to occupy the same local index. None falls back to the
    # leading-N slice of the global tables.
    bw_gbps: Optional[np.ndarray] = None
    rtt_s: Optional[np.ndarray] = None

    @property
    def num_hours(self) -> int:
        return self.ci.shape[0]

    @property
    def num_regions(self) -> int:
        return self.ci.shape[1]

    @property
    def wan_bw_gbps(self) -> np.ndarray:
        if self.bw_gbps is not None:
            return self.bw_gbps
        return WAN_BW_GBPS[:self.num_regions, :self.num_regions]

    @property
    def wan_rtt_s(self) -> np.ndarray:
        if self.rtt_s is not None:
            return self.rtt_s
        return WAN_RTT_S[:self.num_regions, :self.num_regions]

    def transfer_latency_s(self, bytes_: float, src: int, dst: int,
                           fixed_overhead_s: float = 2.0) -> float:
        """Region-identity-aware variant of module-level
        ``transfer_latency_s`` — schedulers and engines must price transfers
        with *this* telemetry's WAN tables so subset runs stay consistent."""
        if src == dst:
            return 0.0
        bw = max(self.wan_bw_gbps[src, dst] * 1e9, 1.0)
        return fixed_overhead_s + self.wan_rtt_s[src, dst] + bytes_ / bw

    @property
    def water_intensity(self) -> np.ndarray:
        return footprint.water_intensity(self.wue, self.pue[None, :],
                                         self.ewif, self.wsf[None, :])

    def at(self, t_s: float) -> Dict[str, np.ndarray]:
        """Telemetry snapshot at absolute time ``t_s`` (linear interpolation
        between hourly samples — grid signals vary continuously; wraps
        around the horizon so long simulations never run off the end)."""
        h = int(t_s // HOUR) % self.num_hours
        h2 = (h + 1) % self.num_hours
        w = (t_s % HOUR) / HOUR
        mix = lambda a: (1 - w) * a[h] + w * a[h2]
        ci, ewif, wue = mix(self.ci), mix(self.ewif), mix(self.wue)
        return dict(ci=ci, ewif=ewif, wue=wue, wsf=self.wsf, pue=self.pue,
                    water_intensity=footprint.water_intensity(
                        wue, self.pue, ewif, self.wsf))

    def mean_between(self, t0_s: float, t1_s: float) -> Dict[str, np.ndarray]:
        """Time-mean of (ci, ewif, wue) over [t0, t1] on the interpolated
        signal (trapezoid over ≤10-minute sub-samples)."""
        n = max(int((t1_s - t0_s) // 600), 1) + 1
        ts = np.linspace(t0_s, max(t1_s, t0_s + 1.0), n + 1)
        snaps = [self.at(float(t)) for t in ts]
        out = {}
        for k in ("ci", "ewif", "wue"):
            vals = np.stack([s[k] for s in snaps])
            out[k] = (0.5 * (vals[:-1] + vals[1:])).mean(axis=0)
        return out

    def index(self, t_s: float) -> int:
        return int(t_s // HOUR) % self.num_hours

    # -- vectorized exact integration (event-driven engine hot path) --------

    def _cumulative(self) -> Dict[str, np.ndarray]:
        """Lazily built per-signal cumulative trapezoid integrals.

        ``cum[k]`` is ∫ over the first k hourly segments of the interpolated
        (piecewise-linear, periodic) signal, in value·hours, shape [T+1, R].
        The signal wraps (segment T-1 interpolates toward sample 0), matching
        ``at``.
        """
        cache = getattr(self, "_cum_cache", None)
        if cache is None:
            cache = {}
            for key in ("ci", "ewif", "wue"):
                x = getattr(self, key)
                xw = np.vstack([x, x[:1]])                    # wrap sample
                seg = 0.5 * (xw[:-1] + xw[1:])                # [T, R]
                cache[key] = np.vstack([np.zeros((1, x.shape[1])),
                                        np.cumsum(seg, axis=0)])
            self._cum_cache = cache
        return cache

    def _antiderivative(self, key: str, t_s: np.ndarray) -> np.ndarray:
        """F(t) = ∫_0^t x(τ) dτ on the periodic interpolated signal,
        vectorized: t_s [K] → [K, R] in value·seconds."""
        x = getattr(self, key)
        cum = self._cumulative()[key]
        T = self.num_hours
        period_s = T * HOUR
        t = np.asarray(t_s, np.float64)
        m = np.floor(t / period_s)
        h = (t - m * period_s) / HOUR
        k = np.minimum(h.astype(np.int64), T - 1)
        frac = (h - k)[..., None]
        xw = np.vstack([x, x[:1]])
        x0, x1 = xw[k], xw[k + 1]
        part = cum[k] + x0 * frac + 0.5 * (x1 - x0) * frac ** 2
        return (m[..., None] * cum[T] + part) * HOUR

    def mean_over(self, t0_s: np.ndarray, t1_s: np.ndarray
                  ) -> Dict[str, np.ndarray]:
        """Exact closed-form time-means of (ci, ewif, wue) over [t0, t1],
        vectorized over K intervals → dict of [K, R] arrays.

        This is the batch counterpart of ``mean_between``: that method
        approximates the integral with ≤10-minute trapezoid sub-samples per
        call; this one integrates the piecewise-linear signal exactly and
        amortizes across all intervals at once (the event-driven engine
        accounts every job of a run in a single call)."""
        t0 = np.asarray(t0_s, np.float64)
        t1 = np.maximum(np.asarray(t1_s, np.float64), t0 + 1.0)
        dt = (t1 - t0)[..., None]
        return {key: (self._antiderivative(key, t1)
                      - self._antiderivative(key, t0)) / dt
                for key in ("ci", "ewif", "wue")}

    def at_many(self, t_s: np.ndarray) -> Dict[str, np.ndarray]:
        """Vectorized ``at``: snapshots at K times → dict of [K, R]."""
        T = self.num_hours
        t = np.asarray(t_s, np.float64)
        h = (t // HOUR).astype(np.int64) % T
        h2 = (h + 1) % T
        w = ((t % HOUR) / HOUR)[..., None]
        out = {}
        for key in ("ci", "ewif", "wue"):
            x = getattr(self, key)
            out[key] = (1 - w) * x[h] + w * x[h2]
        return out


def _solar_profile(hours_utc: np.ndarray, utc_offset_h: float) -> np.ndarray:
    """Daylight factor in [0, 1]: 0 at night, peak at local solar noon."""
    local = (hours_utc + utc_offset_h) % 24.0
    return np.clip(np.sin((local - 6.0) / 12.0 * np.pi), 0.0, None)


def _smooth_noise(rng: np.random.Generator, n: int, corr_hours: float,
                  amp: float) -> np.ndarray:
    """Ornstein-Uhlenbeck-ish smooth noise with given correlation time."""
    alpha = 1.0 / max(corr_hours, 1.0)
    x = np.zeros(n)
    w = rng.standard_normal(n)
    for i in range(1, n):
        x[i] = (1 - alpha) * x[i - 1] + np.sqrt(2 * alpha) * w[i] * amp
    return x


def generate(days: int = 10, seed: int = 0, ewif_table: str = "macknick",
             regions: Sequence[RegionSpec] = tuple(REGIONS)) -> Telemetry:
    """Generate hourly telemetry for ``days`` days across ``regions``."""
    table = EWIF_TABLES[ewif_table]
    rng = np.random.default_rng(seed)
    T = days * 24
    R = len(regions)
    hours = np.arange(T, dtype=np.float64)

    ci = np.zeros((T, R))
    ewif = np.zeros((T, R))
    wue = np.zeros((T, R))
    wb = np.zeros((T, R))
    wsf = np.array([r.wsf for r in regions])
    pue = np.array([r.pue for r in regions])

    sources = sorted(SOURCE_CI)
    for ri, reg in enumerate(regions):
        base = np.array([reg.mix.get(s, 0.0) for s in sources])
        solar_ix = sources.index("solar")
        gas_ix = sources.index("gas")
        hydro_ix = sources.index("hydro")

        solar = _solar_profile(hours, reg.utc_offset_h)
        # Synoptic share noise: hydro/wind availability drifts over days.
        drift = _smooth_noise(rng, T, corr_hours=36.0, amp=reg.mix_volatility)

        shares = np.tile(base, (T, 1))
        # Solar swings with daylight: night solar -> displaced by gas.
        solar_gain = base[solar_ix] * (1.6 * solar - 0.8)
        shares[:, solar_ix] = np.clip(base[solar_ix] + solar_gain, 0.0, None)
        shares[:, gas_ix] = np.clip(base[gas_ix] - solar_gain, 0.02, None)
        # Hydro drifts synoptically; compensated by gas.
        hydro_d = base[hydro_ix] * drift
        shares[:, hydro_ix] = np.clip(base[hydro_ix] + hydro_d, 0.0, None)
        shares[:, gas_ix] = np.clip(shares[:, gas_ix] - hydro_d, 0.02, None)
        shares /= shares.sum(axis=1, keepdims=True)

        ci_src = np.array([SOURCE_CI[s] for s in sources])
        ewif_src = np.array([table[s] for s in sources])
        ci[:, ri] = shares @ ci_src
        ewif[:, ri] = shares @ ewif_src

        # Wet-bulb temperature -> WUE.
        t_wb = (reg.wb_mean_c
                + reg.wb_diurnal_c * np.sin((hours + reg.utc_offset_h - 9.0)
                                            / 24.0 * 2 * np.pi)
                + _smooth_noise(rng, T, corr_hours=48.0, amp=reg.wb_synoptic_c))
        wue[:, ri] = wue_from_wetbulb(t_wb)
        wb[:, ri] = t_wb

    # WAN tables by region *identity*: known region names map to their rows
    # in the global tables (so non-prefix subsets keep the right pairs);
    # unknown/custom regions borrow a not-yet-used global row as a proxy.
    # Any off-diagonal cell two regions end up sharing (only possible with
    # > len(REGIONS) custom regions) would land on the unused zero diagonal,
    # so those cells are patched to the fleet-typical link instead.
    used = {REGION_INDEX[r.name] for r in regions if r.name in REGION_INDEX}
    free = iter(i for i in range(len(REGIONS)) if i not in used)
    ids = np.array([REGION_INDEX[r.name] if r.name in REGION_INDEX
                    else next(free, i % len(REGIONS))
                    for i, r in enumerate(regions)])
    bw_sub = WAN_BW_GBPS[np.ix_(ids, ids)].copy()
    rtt_sub = WAN_RTT_S[np.ix_(ids, ids)].copy()
    off_diag = ~np.eye(len(ids), dtype=bool)
    degenerate = off_diag & (bw_sub <= 0.0)
    if degenerate.any():
        bw_sub[degenerate] = float(WAN_BW_GBPS[WAN_BW_GBPS > 0].mean())
        rtt_sub[degenerate] = float(WAN_RTT_S[WAN_RTT_S > 0].mean())
        obs.warn("telemetry.degenerate_wan",
                 f"{int(degenerate.sum())} region-pair WAN cells had no "
                 "bandwidth entry; patched to the fleet-typical link")
    return Telemetry(ci=ci, ewif=ewif, wue=wue, wsf=wsf, pue=pue, hours=hours,
                     wb_c=wb, bw_gbps=bw_sub, rtt_s=rtt_sub)
