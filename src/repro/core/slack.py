"""Job slack management — paper §4 Eq (14).

The MILP is stateless w.r.t. how long a job has already waited; the slack
manager restores that state. When demand exceeds fleet capacity, jobs are
ranked by urgency (ascending — least slack first) and only the top Σcap(n)
enter the solver; the rest wait for the next round (Algorithm 1, lines 5-7).

    Urgency_m = TOL%·t_m − L_m^avg − waited_m                       (Eq 14)

where waited_m = T^current − T_m^start. (The paper prints the last term as
(T_m^start − T^current) but describes it as "how long the job has been
waiting" and ranks ascending-urgent; we implement the described semantics —
waiting *consumes* slack.)
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.problem import Job, latency_matrix, slack_budget

__all__ = ["urgency", "pick_most_urgent", "slack_budget"]


def urgency(jobs: Sequence[Job], now_s: float,
            bw_gbps: np.ndarray = None,
            rtt_s: np.ndarray = None) -> np.ndarray:
    """Eq (14) urgency score per job (seconds of remaining slack).

    One vectorized latency-matrix evaluation instead of a per-job Python
    loop — this runs on every congested scheduling round (Algorithm 1
    lines 5-7), where the pending set is by definition large. Pass the
    telemetry's identity-mapped WAN tables (``tele.wan_bw_gbps`` /
    ``tele.wan_rtt_s``) so region-subset runs rank with the right links.

    Workflow tasks rank by their critical-path slack (``problem.
    slack_budget``) minus the average transfer latency — the same shared
    slack definition the deferral queue and the Eq (11) mask use. Plain
    jobs keep the exact original expression (op order preserved for
    bit-stable rankings).
    """
    if not jobs:
        return np.zeros(0)
    home = np.array([j.home_region for j in jobs])
    size = np.array([j.package_bytes for j in jobs])
    l_avg = latency_matrix(home, size, bw_gbps, rtt_s).mean(axis=1)
    waited = np.maximum(
        now_s - np.array([j.submit_time_s for j in jobs]), 0.0)
    tol_budget = np.array([j.tolerance * j.exec_time_s for j in jobs])
    plain = tol_budget - l_avg - waited
    if all(j.deadline_override_s is None for j in jobs):
        return plain
    return np.where(
        np.fromiter((j.deadline_override_s is None for j in jobs),
                    bool, len(jobs)),
        plain, slack_budget(jobs, now_s) - l_avg)


def pick_most_urgent(jobs: Sequence[Job], now_s: float, k: int,
                     bw_gbps: np.ndarray = None,
                     rtt_s: np.ndarray = None):
    """Split ``jobs`` into (top-k most urgent, deferred) per Eq 14 ranking."""
    if len(jobs) <= k:
        return list(jobs), []
    u = urgency(jobs, now_s, bw_gbps, rtt_s)
    order = np.argsort(u, kind="stable")      # ascending = most urgent first
    take = set(order[:k].tolist())
    chosen = [j for i, j in enumerate(jobs) if i in take]
    deferred = [j for i, j in enumerate(jobs) if i not in take]
    return chosen, deferred
