"""Precedence-constrained (DAG) workloads — ``repro.workflows``.

Makes workflows first-class across the whole pipeline: a validated
task-graph model (``WorkflowSpec``), vectorized critical-path slack
(``cpath`` — the shared deadline definition the urgency ranking, the
deferral queue, and the Eq-11 temporal mask all derive from), deterministic
synthetic DAG trace generators (``generators`` — chain / fan-out / diamond /
Montage-like mixes in ``sim.trace`` style), and an ichnos-style converter
for Nextflow/Spark-shaped workflow trace CSVs (``ingest``).

The engine side lives in ``repro.sim.engine``: a task becomes schedulable
only when every predecessor has finished, in batch replay and ``repro.serve``
streaming alike (same code path, so batch/stream bit parity holds by
construction).
"""
from repro.workflows.cpath import (CycleError, assign_deadlines,
                                   critical_path_s, longest_path_to_sink,
                                   topological_order)
from repro.workflows.generators import workflow_trace
from repro.workflows.ingest import load_workflow_csv
from repro.workflows.spec import (WorkflowSpec, group_records_by_workflow,
                                  precedence_violations, workflow_miss_rate)

__all__ = [
    "CycleError",
    "WorkflowSpec",
    "assign_deadlines",
    "critical_path_s",
    "group_records_by_workflow",
    "load_workflow_csv",
    "longest_path_to_sink",
    "precedence_violations",
    "topological_order",
    "workflow_miss_rate",
    "workflow_trace",
]
