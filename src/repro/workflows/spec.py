"""``WorkflowSpec`` — the validated task-graph model.

A workflow is a set of ``core.problem.Job`` tasks plus precedence edges
(``Job.deps`` — predecessor job_ids). ``WorkflowSpec.finalize()`` validates
the graph (acyclic, closed, unique ids), computes the vectorized
critical-path deadlines (``cpath.assign_deadlines``), and stamps each task
with ``workflow_id`` / ``deadline_override_s`` — after which the tasks flow
through every existing surface (batch replay, ``repro.serve`` streaming,
the sharded executor) as ordinary jobs with precedence-release semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.problem import Job
from repro.workflows import cpath


@dataclasses.dataclass(frozen=True)
class WorkflowSpec:
    """One precedence-constrained workflow: tasks + the DAG over them.

    ``tolerance`` is workflow-level: the whole graph may take
    ``(1+tolerance)·critical_path`` from its submit instant. Task-level
    ``Job.tolerance`` values are kept (they parameterize the shared
    slack/overrun algebra) but the binding deadline is the critical-path
    one.
    """
    workflow_id: int
    tasks: Tuple[Job, ...]
    tolerance: float = 0.5

    def __post_init__(self):
        # Validation is part of construction: an unvalidated spec never
        # exists. Raises cpath.CycleError on cycles/dangling/duplicate ids.
        self.edges()

    def job_ids(self) -> List[int]:
        return [t.job_id for t in self.tasks]

    def edges(self) -> np.ndarray:
        """(E, 2) local-index edge array (parent, child); validates the
        graph is closed over this task set and acyclic."""
        e = cpath.edges_from_deps(self.job_ids(),
                                  [t.deps for t in self.tasks])
        cpath.topological_order(len(self.tasks), e)      # acyclicity check
        return e

    @property
    def submit_s(self) -> float:
        return min(t.submit_time_s for t in self.tasks)

    @property
    def critical_path_s(self) -> float:
        return cpath.critical_path_s(
            np.array([t.exec_time_s for t in self.tasks]), self.edges())

    @property
    def deadline_s(self) -> float:
        return self.submit_s + (1.0 + self.tolerance) * self.critical_path_s

    def topological_tasks(self) -> List[Job]:
        order = cpath.topological_order(len(self.tasks), self.edges())
        return [self.tasks[i] for i in order]

    def finalize(self) -> List[Job]:
        """Stamp critical-path deadlines + workflow_id onto the tasks and
        return them (submit order). This is the handoff point into the
        ordinary trace/scheduling machinery."""
        exec_s = np.array([t.exec_time_s for t in self.tasks])
        deadlines, _ = cpath.assign_deadlines(exec_s, self.edges(),
                                              self.submit_s, self.tolerance)
        out = []
        for t, d in zip(self.tasks, deadlines):
            out.append(dataclasses.replace(
                t, workflow_id=self.workflow_id, deadline_override_s=float(d)))
        out.sort(key=lambda j: j.submit_time_s)
        return out


# ---------------------------------------------------------------------------
# Record-side helpers (metrics / benches / invariant checks)
# ---------------------------------------------------------------------------

def group_records_by_workflow(records: Iterable) -> Dict[int, list]:
    """Engine ``JobRecord``s grouped by owning workflow (DAG tasks only)."""
    groups: Dict[int, list] = {}
    for r in records:
        wid = r.job.workflow_id
        if wid is not None:
            groups.setdefault(wid, []).append(r)
    return groups


def precedence_violations(records: Sequence) -> int:
    """Number of (task, dep) pairs where a task started before a
    predecessor finished — MUST be zero (the engine's release invariant)."""
    finish = {r.job.job_id: r.finish_s for r in records}
    bad = 0
    for r in records:
        for d in r.job.deps:
            if d not in finish or finish[d] > r.start_s + 1e-6:
                bad += 1
    return bad


def workflow_miss_rate(records: Sequence) -> Tuple[float, int]:
    """(critical-path miss rate, workflows observed): the fraction of
    workflows whose last task finished past the workflow deadline
    (``max deadline_override_s`` over the workflow's tasks — the sinks
    carry exactly the workflow deadline)."""
    groups = group_records_by_workflow(records)
    if not groups:
        return 0.0, 0
    missed = 0
    for recs in groups.values():
        deadline = max(r.job.deadline_override_s for r in recs)
        if max(r.finish_s for r in recs) > deadline + 1e-6:
            missed += 1
    return missed / len(groups), len(groups)
