"""Vectorized critical-path slack over a task DAG.

The per-task ``tolerance·t`` slack of independent jobs is replaced, for
workflow tasks, by a *workflow-deadline-derived* budget: the workflow as a
whole may take ``(1+TOL)·critical_path`` from its submit, and each task's
latest feasible finish is

    deadline(v) = wf_deadline − (L(v) − t_v)

where ``L(v)`` is the longest path from ``v`` to any sink *including* v's
own execution time. A task finishing by ``deadline(v)`` leaves the longest
remaining downstream chain exactly enough room to meet the workflow
deadline; the slack the schedulers mask with is then
``deadline(v) − now − t_v`` (``problem.slack_budget`` — ONE shared
definition feeding the Eq-14 urgency ranking, the deferral queue, and the
Eq-11 temporal feasibility mask; they must agree or deferral cascades into
downstream misses).

All graph passes are vectorized over edge arrays (``np.maximum.at`` per
topological layer), not per-node Python loops — traces carry tens of
thousands of tasks.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


class CycleError(ValueError):
    """The task graph is not acyclic (or has dangling dependencies)."""


def _layered_depths(n: int, edges: np.ndarray) -> np.ndarray:
    """Longest-path depth (in hops) of every node from the sources.

    Vectorized Kahn: each layer's outgoing edges are processed with one
    boolean gather + ``np.maximum.at`` / ``np.subtract.at``; every edge is
    touched exactly once across the whole sweep. Raises ``CycleError`` when
    the graph has a directed cycle.
    """
    depth = np.zeros(n, np.int64)
    if n == 0:
        return depth
    indeg = np.zeros(n, np.int64)
    if len(edges):
        np.add.at(indeg, edges[:, 1], 1)
    frontier = np.flatnonzero(indeg == 0)
    seen = 0
    in_frontier = np.zeros(n, bool)
    while frontier.size:
        seen += int(frontier.size)
        if not len(edges):
            break
        in_frontier[:] = False
        in_frontier[frontier] = True
        m = in_frontier[edges[:, 0]]
        src, dst = edges[m, 0], edges[m, 1]
        np.maximum.at(depth, dst, depth[src] + 1)
        np.subtract.at(indeg, dst, 1)
        frontier = np.unique(dst[indeg[dst] == 0])
    if seen < n:
        raise CycleError(
            f"task graph is not a DAG: {n - seen} of {n} tasks lie on a "
            "directed cycle")
    return depth


def topological_order(n: int, edges: np.ndarray) -> np.ndarray:
    """A deterministic topological order (parents before children):
    stable sort by (layer depth, node index). Raises ``CycleError``."""
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    depth = _layered_depths(n, edges)
    return np.lexsort((np.arange(n), depth))


def longest_path_to_sink(exec_s: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """``L[v]`` = weight of the longest path from ``v`` to any sink,
    *including* ``exec_s[v]`` itself. ``L.max()`` is the critical path.

    Processed sink-up by reversed-graph layers: a node at height ``h`` has
    every child final at heights ``< h``, so each layer is one vectorized
    ``np.maximum.at`` over its outgoing edges.
    """
    exec_s = np.asarray(exec_s, float)
    n = len(exec_s)
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    L = exec_s.copy()
    if n == 0 or not len(edges):
        return L
    height = _layered_depths(n, edges[:, ::-1])    # hops up from the sinks
    eh = height[edges[:, 0]]
    for h in range(1, int(height.max()) + 1):
        m = eh == h
        src, dst = edges[m, 0], edges[m, 1]
        np.maximum.at(L, src, exec_s[src] + L[dst])
    return L


def critical_path_s(exec_s: np.ndarray, edges: np.ndarray) -> float:
    """Length (seconds of execution) of the workflow's critical path."""
    L = longest_path_to_sink(exec_s, edges)
    return float(L.max()) if len(L) else 0.0


def assign_deadlines(exec_s: np.ndarray, edges: np.ndarray,
                     submit_s: float, tolerance: float
                     ) -> Tuple[np.ndarray, float]:
    """Per-task absolute deadlines from one workflow-level tolerance.

    Returns ``(deadline[v], wf_deadline)`` with
    ``wf_deadline = submit + (1+tolerance)·critical_path`` and
    ``deadline[v] = wf_deadline − L[v] + t_v``. For a single-task workflow
    this degenerates to the plain-job deadline
    ``submit + (1+TOL)·t`` exactly.
    """
    L = longest_path_to_sink(exec_s, edges)
    cp = float(L.max()) if len(L) else 0.0
    wf_deadline = submit_s + (1.0 + tolerance) * cp
    return wf_deadline - L + np.asarray(exec_s, float), wf_deadline


def edges_from_deps(job_ids: Sequence[int],
                    deps: Sequence[Sequence[int]]) -> np.ndarray:
    """(E, 2) local-index edge array from per-task predecessor job_id lists.
    Raises ``CycleError`` on dependencies outside the task set."""
    index = {jid: i for i, jid in enumerate(job_ids)}
    if len(index) != len(job_ids):
        raise CycleError("duplicate task ids in one workflow")
    out = []
    for i, dd in enumerate(deps):
        for d in dd:
            if d not in index:
                raise CycleError(f"task {job_ids[i]} depends on unknown "
                                 f"task {d}")
            out.append((index[d], i))
    return (np.asarray(out, np.int64).reshape(-1, 2) if out
            else np.zeros((0, 2), np.int64))
