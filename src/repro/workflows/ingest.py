"""Ichnos-style workflow trace converter (Nextflow/Spark-shaped CSVs).

Carbon-footprint tooling for scientific workflows (e.g. ichnos for Nextflow
traces) exports per-task rows: a workflow/run id, a task id, submission and
runtime, a CPU-utilization or energy figure, and the task's predecessor
list. ``load_workflow_csv`` reads that shape into validated
``WorkflowSpec``s and returns finalized ``Job``s (deps + critical-path
deadlines stamped), ready for any scenario/engine surface.

Canonical columns::

    workflow_id, task_id, submit_s, duration_s, energy_kwh, home_region, deps

``deps`` is a ``;``-separated list of predecessor task_ids *within the same
workflow* (empty for source tasks). Real exports name columns differently —
``column_map`` maps canonical -> CSV header and ``unit_scale`` rescales
numeric columns after mapping (e.g. ``{"duration_s": 1e-3}`` for millisecond
runtimes), mirroring ``sim.trace.load_csv``. When the export carries
``cpu_util`` (0..1) instead of energy, map it via
``column_map={"energy_kwh": "cpu_util"}`` and pass ``util_to_energy=True``
to convert through the per-node power model.
"""
from __future__ import annotations

import csv
from typing import Dict, List, Optional, Tuple

from repro.core import footprint
from repro.core.problem import Job
from repro.workflows.spec import WorkflowSpec

_CSV_CANONICAL = ("workflow_id", "task_id", "submit_s", "duration_s",
                  "energy_kwh", "home_region", "deps")


def load_workflow_csv(path: str, tolerance: float = 0.5,
                      column_map: Optional[dict] = None,
                      unit_scale: Optional[dict] = None,
                      package_bytes: float = 2e9,
                      util_to_energy: bool = False,
                      server: footprint.ServerSpec = None) -> List[Job]:
    """Read an ichnos-style per-task workflow CSV into finalized ``Job``s.

    Task ids are remapped to globally unique sequential job_ids (the CSV's
    ids are only unique per workflow); ``deps`` are remapped alongside.
    Graphs are validated per workflow (``cpath.CycleError`` on cycles or
    dangling predecessors). All tasks of a workflow share the workflow's
    submit instant — the earliest ``submit_s`` among its rows — since
    release is gated by precedence, not by per-task submission.
    """
    cmap = {c: c for c in _CSV_CANONICAL}
    cmap.update(column_map or {})
    scale = unit_scale or {}
    server = server or footprint.m5_metal()

    with open(path, newline="") as fh:
        reader = csv.DictReader(fh)
        headers = reader.fieldnames or []
        missing = [c for c in _CSV_CANONICAL if cmap[c] not in headers]
        if missing:
            raise ValueError(f"workflow trace {path!r} lacks columns for "
                             f"{missing}; available: {headers}")
        rows = list(reader)

    def num(row, c):
        return float(row[cmap[c]]) * float(scale.get(c, 1.0))

    # Group rows per workflow, preserving file order within each.
    by_wf: Dict[int, List[dict]] = {}
    for row in rows:
        by_wf.setdefault(int(float(row[cmap["workflow_id"]])), []).append(row)

    power = footprint.PowerModel.from_server(server)
    jobs: List[Job] = []
    next_id = 0
    for wf_id in sorted(by_wf):
        group = by_wf[wf_id]
        local: Dict[int, int] = {}               # CSV task_id -> job_id
        for row in group:
            local[int(float(row[cmap["task_id"]]))] = next_id
            next_id += 1
        submit = min(num(r, "submit_s") for r in group)
        tasks: List[Job] = []
        for row in group:
            dur = num(row, "duration_s")
            energy = num(row, "energy_kwh")
            if util_to_energy:
                energy = float(power.energy_kwh(energy, dur))
            dep_field = (row[cmap["deps"]] or "").strip()
            deps: Tuple[int, ...] = tuple(
                local.get(int(float(d)), -1)
                for d in dep_field.split(";") if d.strip())
            tasks.append(Job(
                job_id=local[int(float(row[cmap["task_id"]]))],
                home_region=int(float(row[cmap["home_region"]])),
                submit_time_s=submit, exec_time_s=dur, energy_kwh=energy,
                package_bytes=package_bytes, tolerance=tolerance,
                deps=deps))
        spec = WorkflowSpec(workflow_id=wf_id, tasks=tuple(tasks),
                            tolerance=tolerance)
        jobs.extend(spec.finalize())
    jobs.sort(key=lambda j: (j.submit_time_s, j.job_id))
    return jobs
