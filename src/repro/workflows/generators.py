"""Deterministic synthetic DAG workload generators (``sim.trace`` style).

Workflow *arrivals* reuse the inhomogeneous-Poisson machinery of
``sim.trace._arrivals`` (diurnal / burst-train modulation); each arrival
instantiates one workflow from a template mix:

* ``chain``    — linear stage pipeline (ETL-like);
* ``fanout``   — one splitter feeding K parallel shards joined by a reducer
                 (MapReduce-like);
* ``diamond``  — split into two branches that re-join (A/B preprocessing);
* ``montage``  — the classic astronomy mosaicking shape: wide projection
                 fan-out → pairwise overlap fitting → concat/background →
                 final mosaic (Montage-like, the standard DAG benchmark).

Task durations are drawn from the paper's PARSEC/CloudSuite profile mix and
task *energy* comes from the per-node power model
(``footprint.PowerModel`` — idle/peak utilization curve) instead of a fixed
per-benchmark wattage, so DAG tasks exercise the utilization-dependent
accounting path. Generators are deterministic given (seed, days, rate).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core import footprint
from repro.core.problem import Job
from repro.sim import trace
from repro.workflows.spec import WorkflowSpec

DAY = trace.DAY

# Template mix: (name, weight). Montage-like graphs are the heavyweight
# "real workflow" shape; the simple shapes keep the mix varied.
TEMPLATES: Tuple[Tuple[str, float], ...] = (
    ("chain", 0.30),
    ("fanout", 0.25),
    ("diamond", 0.25),
    ("montage", 0.20),
)


def _template_deps(name: str, rng: np.random.Generator
                   ) -> List[Tuple[int, ...]]:
    """Local-index predecessor lists for one workflow instance. Index i's
    entry lists the indices that must finish before task i may start."""
    if name == "chain":
        n = int(rng.integers(3, 7))
        return [() if i == 0 else (i - 1,) for i in range(n)]
    if name == "fanout":
        k = int(rng.integers(3, 8))
        deps: List[Tuple[int, ...]] = [()]                  # splitter
        deps += [(0,) for _ in range(k)]                    # shards
        deps.append(tuple(range(1, k + 1)))                 # reducer
        return deps
    if name == "diamond":
        return [(), (0,), (0,), (1, 2)]
    if name == "montage":
        # mProject ×k → mDiffFit (pairwise) → mConcatFit → mBackground ×k
        # → mAdd: the canonical Montage skeleton at small scale.
        k = int(rng.integers(3, 6))
        deps = [() for _ in range(k)]                       # mProject fan
        proj = tuple(range(k))
        diff = []
        for i in range(k - 1):
            deps.append((i, i + 1))                         # mDiffFit pairs
            diff.append(k + i)
        deps.append(tuple(diff))                            # mConcatFit
        concat = len(deps) - 1
        bg = []
        for i in range(k):
            deps.append((i, concat))                        # mBackground fan
            bg.append(len(deps) - 1)
        deps.append(tuple(bg))                              # mAdd
        return deps
    raise ValueError(f"unknown workflow template {name!r}")


def _pick_templates(rng: np.random.Generator, n: int) -> np.ndarray:
    w = np.array([w for _, w in TEMPLATES])
    return rng.choice(len(TEMPLATES), size=n, p=w / w.sum())


def workflow_trace(days: float = 1.0, seed: int = 0, num_regions: int = 5,
                   tolerance: float = 0.5,
                   workflows_per_day: float = 400.0,
                   burst: float = 0.0,
                   diurnal_depth: float = 0.45,
                   duration_jitter: float = 0.35,
                   server: footprint.ServerSpec = None) -> List[Job]:
    """Generate a finalized DAG trace: a flat ``List[Job]`` (submit order)
    whose tasks carry ``deps`` / ``workflow_id`` / critical-path deadlines.

    Every task of a workflow shares the workflow's submit instant (the DAG
    is known at submission; *release* is what precedence gates). job_ids are
    globally unique and sequential, so the trace drops into every existing
    scenario/engine surface unchanged.
    """
    rng = np.random.default_rng(seed)
    server = server or footprint.m5_metal()
    power = footprint.PowerModel.from_server(server)
    arrivals = trace._arrivals(rng, days, workflows_per_day / DAY,
                               diurnal_depth=diurnal_depth, burst=burst)
    picks = _pick_templates(rng, arrivals.size)
    region_w = np.array([0.25, 0.30, 0.15, 0.15, 0.15])[:num_regions]
    region_w = region_w / region_w.sum()
    profiles = trace.BENCHMARK_PROFILES

    jobs: List[Job] = []
    next_id = 0
    for wf_i, (ts, tmpl_k) in enumerate(zip(arrivals, picks)):
        name = TEMPLATES[tmpl_k][0]
        deps_local = _template_deps(name, rng)
        n = len(deps_local)
        home = int(rng.choice(num_regions, p=region_w))
        pk = rng.integers(0, len(profiles), n)
        jitter = rng.lognormal(mean=0.0, sigma=duration_jitter, size=n)
        util = rng.uniform(0.35, 0.95, n)
        base = next_id
        tasks = []
        for i in range(n):
            p = profiles[pk[i]]
            t_exec = float(p.exec_s * jitter[i])
            tasks.append(Job(
                job_id=base + i, home_region=home,
                submit_time_s=float(ts), exec_time_s=t_exec,
                energy_kwh=float(power.energy_kwh(util[i], t_exec)),
                package_bytes=p.tar_bytes, tolerance=tolerance,
                arch=f"{name}:{p.name}",
                deps=tuple(base + d for d in deps_local[i])))
        next_id += n
        spec = WorkflowSpec(workflow_id=wf_i, tasks=tuple(tasks),
                            tolerance=tolerance)
        jobs.extend(spec.finalize())
    jobs.sort(key=lambda j: (j.submit_time_s, j.job_id))
    return jobs


def mixed_trace(days: float = 1.0, seed: int = 0, num_regions: int = 5,
                tolerance: float = 0.5,
                workflows_per_day: float = 400.0,
                plain_jobs_per_day: float = 0.0,
                burst: float = 0.0) -> List[Job]:
    """DAG trace optionally blended with plain (independent) Borg-like jobs
    — exercises the mixed plain/workflow scheduling path. job_ids stay
    globally unique (plain jobs are offset past the DAG id range)."""
    jobs = workflow_trace(days=days, seed=seed, num_regions=num_regions,
                          tolerance=tolerance,
                          workflows_per_day=workflows_per_day, burst=burst)
    if plain_jobs_per_day > 0:
        plain = trace.borg_trace(days=days, seed=seed + 1,
                                 num_regions=num_regions, tolerance=0.25,
                                 target_jobs_per_day=plain_jobs_per_day)
        offset = (max(j.job_id for j in jobs) + 1) if jobs else 0
        for p in plain:
            p.job_id += offset
        jobs = sorted(jobs + plain,
                      key=lambda j: (j.submit_time_s, j.job_id))
    return jobs
