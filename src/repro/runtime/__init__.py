"""Distributed runtime: sharding rules, train/serve step factories,
elastic restore."""
