"""Elastic execution: failure detection, straggler mitigation, re-mesh
restore.

On a real fleet the runtime watches per-step heartbeats; when a host dies
(or a pod is reclaimed by the WaterWise scheduler for migration), training
restarts from the latest atomic checkpoint on whatever mesh is available —
``restore_checkpoint`` re-shards every leaf, so an 8-device job can resume
on 4 or 16 devices. This module provides the control-plane pieces that are
hardware-independent and therefore fully testable on CPU:

  StepWatchdog      deadline per step; a straggling/hung step raises and
                    triggers restart-from-checkpoint (synchronous SPMD makes
                    one straggler everyone's straggler — detect & evict).
  FailureInjector   deterministic fault schedule for tests/simulations.
  run_elastic       the restart loop: run → (maybe) crash → restore → rerun,
                    preserving exactly-once step accounting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint)


class StepWatchdog:
    """Flags steps that exceed ``deadline_s`` (straggler mitigation)."""

    def __init__(self, deadline_s: float):
        self.deadline_s = deadline_s
        self.history: List[float] = []

    def observe(self, step_time_s: float) -> bool:
        self.history.append(step_time_s)
        return step_time_s > self.deadline_s

    @property
    def p50(self) -> float:
        h = sorted(self.history)
        return h[len(h) // 2] if h else 0.0


@dataclasses.dataclass
class FailureInjector:
    """Deterministic crash schedule: fail right after the listed steps."""
    fail_after_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_after_steps and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_elastic(state, step_fn: Callable, batch_fn: Callable, *,
                num_steps: int, ckpt_dir: str, ckpt_every: int = 10,
                shardings=None, injector: Optional[FailureInjector] = None,
                watchdog: Optional[StepWatchdog] = None,
                max_restarts: int = 10) -> Dict:
    """Run ``num_steps`` of ``state = step_fn(state, batch, step)`` with
    checkpoint/restart. Returns dict(state, restarts, steps_run)."""
    ckpt = AsyncCheckpointer(ckpt_dir, every=ckpt_every)
    restarts = 0
    step = 0
    steps_run = 0
    while step < num_steps:
        try:
            t0 = time.perf_counter()
            state = step_fn(state, batch_fn(step), step)
            dt = time.perf_counter() - t0
            if watchdog is not None and watchdog.observe(dt):
                raise TimeoutError(f"straggling step {step}: {dt:.3f}s")
            steps_run += 1
            step += 1
            ckpt.maybe_save(step, state)
            if injector is not None:
                injector.check(step)
        except (RuntimeError, TimeoutError):
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt.wait()
            last = latest_step(ckpt_dir)
            if last is not None:
                state = restore_checkpoint(ckpt_dir, last, state, shardings)
                step = last
            else:
                step = 0
    ckpt.wait()
    return dict(state=state, restarts=restarts, steps_run=steps_run)
