"""Logical-axis → PartitionSpec resolution (MaxText-style rules).

Every parameter / activation / cache leaf carries a tuple of *logical* axis
names (models/common.P). Rules map logical names to (ordered) mesh-axis
candidates. Resolution is left-to-right per tensor with two safeguards:

  * divisibility — a mesh assignment is dropped (progressively, from the
    left of the candidate tuple) until the dimension divides evenly;
  * no-reuse — a mesh axis already consumed by an earlier dimension of the
    same tensor is skipped.

The no-reuse rule gives context-dependent sharding for free: the cache rules
put ``cache_batch → (pod, data)`` before ``cache_seq → data``, so batched
decode shards the cache over batch, while long-context decode (batch=1,
indivisible) automatically falls through to sequence sharding — the SP
layout — with no per-cell special-casing.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# Ordered logical rules. Values are mesh-axis candidate tuples (sharded over
# the product of the surviving axes).
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # parameters
    "layers": (),
    "embed": ("data",),              # FSDP: params sharded over data, TP over model
    "embed_nosplit": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "mlp_in": (),
    "vocab": ("model",),
    "experts": ("model",),
    "mla_latent": (),
    "rope_dim": (),
    "conv": (),
    "conv_channels": ("model",),
    "ssm_state": (),
    "heads_nosplit": (),
    "scalar": (),
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": ("data",),
    "act_embed": (),
    "act_img": (),
    "act_vocab": ("model",),
    # caches (ordering + no-reuse ⇒ batch-sharded OR sequence-sharded)
    "cache_batch": ("pod", "data"),
    "cache_seq": ("data",),
    "cache_img": (),
}


import contextlib

_ACTIVE_RULES: Dict[str, Tuple[str, ...]] = dict(DEFAULT_RULES)


def active_rules() -> Dict[str, Tuple[str, ...]]:
    return _ACTIVE_RULES


@contextlib.contextmanager
def rule_overrides(overrides: Optional[Dict] = None):
    """Temporarily replace the process-wide rule set (hillclimb variants
    plumb their sharding changes into in-model ``constrain`` calls here)."""
    global _ACTIVE_RULES
    prev = _ACTIVE_RULES
    _ACTIVE_RULES = dict(DEFAULT_RULES, **(overrides or {}))
    try:
        yield _ACTIVE_RULES
    finally:
        _ACTIVE_RULES = prev


def resolve_axis(name: str, dim: int, mesh: Mesh, used: set,
                 rules: Dict[str, Tuple[str, ...]]):
    """Mesh assignment for one tensor dimension (None / str / tuple)."""
    cand = [a for a in rules.get(name, ())
            if a in mesh.shape and a not in used]
    while cand:
        total = int(np.prod([mesh.shape[a] for a in cand]))
        if dim % total == 0 and total > 1:
            used.update(cand)
            return tuple(cand) if len(cand) > 1 else cand[0]
        cand = cand[1:]          # drop the leading (largest-scope) axis
    return None


def spec_for(axes: Sequence[str], shape: Sequence[int], mesh: Mesh,
             rules: Optional[Dict] = None) -> PartitionSpec:
    rules = rules or active_rules()
    used: set = set()
    assert len(axes) == len(shape), (axes, shape)
    return PartitionSpec(*(resolve_axis(a, d, mesh, used, rules)
                           for a, d in zip(axes, shape)))


def tree_shardings(spec_tree, shape_tree, mesh: Mesh,
                   rules: Optional[Dict] = None):
    """NamedSharding tree from (logical-axes tree, ShapeDtypeStruct tree)."""
    return jax.tree.map(
        lambda axes, sds: NamedSharding(
            mesh, spec_for(axes, sds.shape, mesh, rules)),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def abstract_with_sharding(shape_tree, spec_tree, mesh: Mesh,
                           rules: Optional[Dict] = None):
    """ShapeDtypeStructs with NamedShardings attached (dry-run inputs)."""
    sh = tree_shardings(spec_tree, shape_tree, mesh, rules)
    return jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=s),
        shape_tree, sh)


def constraint(x, axes: Sequence[str], mesh: Mesh, rules=None):
    """with_sharding_constraint by logical axes (hillclimb hook)."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes, x.shape, mesh, rules)))
