"""train_step / serve_step factories (the functions the dry-run lowers).

``make_train_step`` builds the full production step: loss → grad (with remat
per config) → optional microbatch accumulation → optional cross-pod gradient
compression → AdamW update. All state (params + optimizer) stays sharded;
buffers are donated.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw as adamw_mod
from repro.optim import compression


def make_train_step(model, opt, *, grad_accum: int = 1,
                    compress: Optional[str] = None):
    """Returns train_step(params, opt_state, batch, step_key) →
    (params, opt_state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: model.loss(p, batch))(params)

    def train_step(params, opt_state, batch, step_key):
        if grad_accum > 1:
            # Microbatch over the leading batch axis via scan (sequential
            # accumulation — each microbatch's backprop overlaps the next
            # microbatch's collectives under XLA pipelining).
            def micro(c, mb):
                acc_loss, acc_g = c
                loss, g = grads_of(params, mb)
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_g, g)), None

            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            # Accumulators seeded from params (data dependence) so they
            # inherit the FSDP sharding instead of being replicated.
            zero = jax.tree.map(
                lambda p: (p * 0).astype(jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zero), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = grads_of(params, batch)

        if compress == "int8":
            grads = compression.int8_roundtrip(grads, step_key)

        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, dict(loss=loss, grad_norm=gnorm)

    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, tokens, pos):
        logits, cache = model.decode(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache
    return decode_step
