"""Serving loop: batched prefill + greedy decode with sharded caches."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import split_tree
from repro.runtime.train_loop import make_decode_step, make_prefill_step


class Server:
    """Minimal batched server: prefill a batch of prompts, then decode
    greedily to ``max_new``. Caches are padded to prompt_len + max_new."""

    def __init__(self, model, params, mesh=None):
        self.model = model
        self.params = params
        self.prefill_step = jax.jit(make_prefill_step(model))
        self.decode_step = jax.jit(make_decode_step(model),
                                   donate_argnums=(1,))

    def generate(self, batch: Dict, max_new: int = 16) -> np.ndarray:
        tokens = batch["tokens"]
        B, S = tokens.shape
        cfg = self.model.cfg
        total = S + max_new
        # Build a full-length cache, then prefill writes [0, S).
        ctree = self.model.init_cache(
            B, total,
            src_len=batch.get("frames", np.zeros((0, 0))).shape[1]
            if cfg.family == "encdec" else 0,
            n_img=cfg.n_img_tokens)
        cache, _ = split_tree(ctree)
        # Prefill: run full forward and splice the produced KV into cache.
        last_logits, built = self.prefill_step(self.params, batch)
        cache = _splice(cache, built, S)
        out = [jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]]
        tok = out[-1]
        for i in range(max_new - 1):
            tok, _, cache = self.decode_step(self.params, cache, tok, S + i)
            out.append(tok)
        return np.concatenate([np.asarray(t) for t in out], axis=1)


def _splice(cache, built, length: int):
    """Copy prefill-built KV/state (length ``length``) into the zero-padded
    decode cache. Leaves whose shapes already match (recurrent states, conv
    tails) are taken as-is."""
    def one(c, b):
        if c.shape == b.shape:
            return b.astype(c.dtype)
        # Cache is longer along the sequence axis — find it and splice.
        for ax, (cs, bs) in enumerate(zip(c.shape, b.shape)):
            if cs != bs:
                return jax.lax.dynamic_update_slice_in_dim(
                    c, b.astype(c.dtype), 0, axis=ax)
        return b.astype(c.dtype)
    return jax.tree.map(one, cache, built)
