"""ONE cached accelerator-platform probe.

``jax.devices()[0].platform == "tpu"`` used to be copy-pasted across every
kernel wrapper and the fused round. Each call is (a) a backend-init trigger
— innocuous-looking module code could lock the device topology before
``launch.devices`` had a chance to configure it — and (b) a per-call device
query on hot paths. The probe below initializes the backend exactly once,
on first *use* (never at import), and caches the answer for the life of the
process; everything platform-conditional goes through it.

The cache is correct because a JAX process cannot change platform after
backend init — the first ``jax.devices()`` call pins it. Tests that fake a
platform can ``platform.cache_clear()``.
"""
from __future__ import annotations

import functools

__all__ = ["platform", "on_tpu"]


@functools.lru_cache(maxsize=None)
def platform() -> str:
    """The default JAX backend's platform name ("cpu" / "gpu" / "tpu").

    First call initializes the JAX backend (by design: callers are already
    about to dispatch); later calls are a dict lookup.
    """
    import jax

    return jax.devices()[0].platform


def on_tpu() -> bool:
    """True when the default backend is a real TPU (the Pallas fast path)."""
    return platform() == "tpu"
