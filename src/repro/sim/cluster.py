"""Region/cluster state: capacities, reservations, in-flight transfers."""
from __future__ import annotations

import heapq
from typing import List

import numpy as np


class Cluster:
    """Server bookkeeping for N regions.

    A scheduled job holds one server from dispatch until completion (the
    transfer window is included in the hold — a deliberate, conservative
    simplification: the destination server is pinned once the move starts,
    mirroring how checkpoint-restore targets are reserved in practice).

    Capacity may change mid-run (``set_capacity``, scenario outage events).
    Running jobs are never evicted: ``busy`` can transiently exceed a
    *reduced* capacity, but ``free()`` clamps at zero so no new dispatch ever
    lands on a lost server.
    """

    def __init__(self, capacity: np.ndarray):
        self.capacity = np.asarray(capacity, dtype=np.int64).copy()
        self.busy = np.zeros_like(self.capacity)
        self._completions: List = []      # heap of (finish_s, region)
        self.busy_integral_s = 0.0        # server-seconds actually busy
        self.cap_integral_s = 0.0         # server-seconds provisioned
        self._last_t = 0.0
        self._busy_total = 0
        self._cap_total = int(self.capacity.sum())
        self._max_finish = 0.0            # time the fleet fully drains
        self.peak_busy = np.zeros_like(self.capacity)

    @property
    def num_regions(self) -> int:
        return len(self.capacity)

    def free(self) -> np.ndarray:
        return np.maximum(self.capacity - self.busy, 0)

    def busy_any(self) -> bool:
        return self._busy_total > 0

    def set_capacity(self, capacity: np.ndarray) -> None:
        self.capacity = np.asarray(capacity, dtype=np.int64).copy()
        self._cap_total = int(self.capacity.sum())

    def drain_time(self) -> float:
        """Time at which every in-flight job has finished."""
        return self._max_finish

    def advance(self, now_s: float) -> int:
        """Release servers whose jobs finished by ``now_s``.

        The busy-time integral is accumulated piecewise at each completion,
        so utilization is exact regardless of how far apart the engine's
        events are (the windowed engine over-counted by up to one window per
        completion)."""
        released = 0
        comp = self._completions
        while comp and comp[0][0] <= now_s:
            t, region = heapq.heappop(comp)
            self.busy_integral_s += self._busy_total * (t - self._last_t)
            self.cap_integral_s += self._cap_total * (t - self._last_t)
            self._last_t = t
            self.busy[region] -= 1
            self._busy_total -= 1
            released += 1
        self.busy_integral_s += self._busy_total * (now_s - self._last_t)
        self.cap_integral_s += self._cap_total * (now_s - self._last_t)
        self._last_t = now_s
        return released

    def dispatch(self, region: int, finish_s: float) -> None:
        assert self.busy[region] < self.capacity[region], "over-capacity"
        self.busy[region] += 1
        self._busy_total += 1
        if self.busy[region] > self.peak_busy[region]:
            self.peak_busy[region] = self.busy[region]
        if finish_s > self._max_finish:
            self._max_finish = finish_s
        heapq.heappush(self._completions, (finish_s, region))

    # -- state handoff (sharded execution, repro.experiments.shard) ---------

    def export_state(self) -> dict:
        """Snapshot everything a later engine run needs to continue this
        cluster mid-flight: occupancy, the completion heap, and the exact
        utilization integrals (so a chained run reports the same cumulative
        utilization as an unsharded one)."""
        return dict(capacity=self.capacity.copy(), busy=self.busy.copy(),
                    completions=list(self._completions),
                    busy_integral_s=self.busy_integral_s,
                    cap_integral_s=self.cap_integral_s,
                    last_t=self._last_t, max_finish=self._max_finish,
                    peak_busy=self.peak_busy.copy())

    def restore_state(self, state: dict) -> None:
        """Inverse of ``export_state`` (overwrites this cluster's state)."""
        self.capacity = np.asarray(state["capacity"], np.int64).copy()
        self.busy = np.asarray(state["busy"], np.int64).copy()
        self._completions = list(state["completions"])
        heapq.heapify(self._completions)
        self.busy_integral_s = float(state["busy_integral_s"])
        self.cap_integral_s = float(state["cap_integral_s"])
        self._last_t = float(state["last_t"])
        self._busy_total = int(self.busy.sum())
        self._cap_total = int(self.capacity.sum())
        self._max_finish = float(state["max_finish"])
        self.peak_busy = np.asarray(state["peak_busy"], np.int64).copy()

    def utilization(self, horizon_s: float) -> float:
        """Busy server-seconds over *provisioned* server-seconds — the
        denominator is the time-integral of capacity, so runs with capacity
        events (outages) report a meaningful, finite utilization."""
        denom = self.cap_integral_s + self._cap_total * max(
            horizon_s - self._last_t, 0.0)
        return self.busy_integral_s / max(denom, 1e-9)
