"""Region/cluster state: capacities, reservations, in-flight transfers."""
from __future__ import annotations

import heapq
from typing import List

import numpy as np


class Cluster:
    """Server bookkeeping for N regions.

    A scheduled job holds one server from dispatch until completion (the
    transfer window is included in the hold — a deliberate, conservative
    simplification: the destination server is pinned once the move starts,
    mirroring how checkpoint-restore targets are reserved in practice).
    """

    def __init__(self, capacity: np.ndarray):
        self.capacity = np.asarray(capacity, dtype=np.int64)
        self.busy = np.zeros_like(self.capacity)
        self._completions: List = []      # heap of (finish_s, region)
        self.busy_integral_s = 0.0        # server-seconds actually busy
        self._last_t = 0.0

    @property
    def num_regions(self) -> int:
        return len(self.capacity)

    def free(self) -> np.ndarray:
        return self.capacity - self.busy

    def advance(self, now_s: float) -> int:
        """Release servers whose jobs finished by ``now_s``."""
        self.busy_integral_s += float(self.busy.sum()) * (now_s - self._last_t)
        self._last_t = now_s
        released = 0
        while self._completions and self._completions[0][0] <= now_s:
            _, region = heapq.heappop(self._completions)
            self.busy[region] -= 1
            released += 1
        return released

    def dispatch(self, region: int, finish_s: float) -> None:
        assert self.busy[region] < self.capacity[region], "over-capacity"
        self.busy[region] += 1
        heapq.heappush(self._completions, (finish_s, region))

    def utilization(self, horizon_s: float) -> float:
        return self.busy_integral_s / (float(self.capacity.sum()) * horizon_s)
