"""Figures of merit (paper §5): carbon/water totals & savings, service time,
delay-tolerance violations, decision overhead."""
from __future__ import annotations

from typing import Dict

import numpy as np


def summarize(result: Dict) -> Dict[str, float]:
    recs = result["records"]
    if not recs:
        return dict(carbon_kg=0.0, water_kl=0.0, mean_service_ratio=1.0,
                    violation_pct=0.0, jobs=0, mean_solve_ms=0.0,
                    p99_service_ratio=1.0, moved_pct=0.0,
                    utilization=result.get("utilization", 0.0))
    carbon = sum(r.carbon_g for r in recs) / 1e3
    water = sum(r.water_l for r in recs) / 1e3
    ratios = np.array([r.service_ratio for r in recs])
    viol = np.mean([r.violated for r in recs]) * 100.0
    moved = np.mean([r.region != r.job.home_region for r in recs]) * 100.0
    st = result["solve_times"]
    return dict(carbon_kg=float(carbon), water_kl=float(water),
                mean_service_ratio=float(ratios.mean()),
                p99_service_ratio=float(np.percentile(ratios, 99)),
                violation_pct=float(viol), jobs=len(recs),
                mean_solve_ms=float(st.mean() * 1e3) if st.size else 0.0,
                moved_pct=float(moved),
                utilization=float(result.get("utilization", 0.0)))


def savings_vs(baseline: Dict[str, float], other: Dict[str, float]) -> Dict:
    """% carbon/water savings of ``other`` relative to ``baseline``
    (positive = better, the paper's primary metric)."""
    def pct(key):
        b = baseline[key]
        return 100.0 * (b - other[key]) / b if b else 0.0
    return dict(carbon_savings_pct=pct("carbon_kg"),
                water_savings_pct=pct("water_kl"))


def region_distribution(result: Dict, num_regions: int) -> np.ndarray:
    """Fig 3(b): % of jobs executed per region."""
    recs = result["records"]
    counts = np.bincount([r.region for r in recs], minlength=num_regions)
    return 100.0 * counts / max(len(recs), 1)
