"""Figures of merit (paper §5): carbon/water totals & savings, service time,
delay-tolerance violations, decision overhead."""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


def _empty_summary(result: Dict) -> Dict[str, float]:
    return dict(carbon_kg=0.0, water_kl=0.0, embodied_kg=0.0,
                mean_service_ratio=1.0,
                violation_pct=0.0, jobs=0, mean_solve_ms=0.0,
                p99_service_ratio=1.0, moved_pct=0.0,
                utilization=result.get("utilization", 0.0))


def _frame_of(result: Dict) -> Optional[Dict[str, np.ndarray]]:
    """The columnar per-job frame, if the engine attached one (the
    event-driven engine always does; the windowed oracle and hand-built
    results fall back to the record-object loop)."""
    return result.get("frame")


def summarize(result: Dict) -> Dict[str, float]:
    frame = _frame_of(result)
    if frame is not None:
        n = int(frame["region"].size)
        if n == 0:
            return _empty_summary(result)
        service = frame["finish_s"] - frame["submit_s"]
        ratios = service / np.maximum(frame["exec_s"], 1e-9)
        violated = service > ((1.0 + frame["tolerance"]) * frame["exec_s"]
                              + 1e-6)
        deadline = frame.get("deadline_s")
        if deadline is not None and deadline.size:
            # Workflow tasks carry an absolute critical-path deadline
            # (NaN = plain job, which keeps the tolerance-based test).
            violated = np.where(np.isnan(deadline), violated,
                                frame["finish_s"] > deadline + 1e-6)
        moved = frame["region"] != frame["home_region"]
        st = result["solve_times"]
        embodied = frame.get("embodied_g")
        return dict(carbon_kg=float(np.sum(frame["carbon_g"]) / 1e3),
                    water_kl=float(np.sum(frame["water_l"]) / 1e3),
                    embodied_kg=(float(np.sum(embodied) / 1e3)
                                 if embodied is not None else 0.0),
                    mean_service_ratio=float(ratios.mean()),
                    p99_service_ratio=float(np.percentile(ratios, 99)),
                    violation_pct=float(np.mean(violated) * 100.0),
                    jobs=n,
                    mean_solve_ms=float(st.mean() * 1e3) if st.size else 0.0,
                    moved_pct=float(np.mean(moved) * 100.0),
                    utilization=float(result.get("utilization", 0.0)))
    recs = result["records"]
    if not recs:
        return _empty_summary(result)
    carbon = sum(r.carbon_g for r in recs) / 1e3
    water = sum(r.water_l for r in recs) / 1e3
    embodied = sum(r.embodied_g for r in recs) / 1e3
    ratios = np.array([r.service_ratio for r in recs])
    viol = np.mean([r.violated for r in recs]) * 100.0
    moved = np.mean([r.region != r.job.home_region for r in recs]) * 100.0
    st = result["solve_times"]
    return dict(carbon_kg=float(carbon), water_kl=float(water),
                embodied_kg=float(embodied),
                mean_service_ratio=float(ratios.mean()),
                p99_service_ratio=float(np.percentile(ratios, 99)),
                violation_pct=float(viol), jobs=len(recs),
                mean_solve_ms=float(st.mean() * 1e3) if st.size else 0.0,
                moved_pct=float(moved),
                utilization=float(result.get("utilization", 0.0)))


def stress_water_kl(result: Dict, weight: np.ndarray) -> float:
    """Scarcity-weighted water total (Wu et al. accounting view) in kl."""
    frame = _frame_of(result)
    if frame is not None:
        if frame["region"].size == 0:
            return 0.0
        w = np.asarray(weight, np.float64)
        return float(np.sum(frame["water_l"]
                            * w[frame["region"].astype(np.int64)]) / 1e3)
    return float(sum(r.water_l * weight[r.region]
                     for r in result["records"]) / 1e3)


def savings_vs(baseline: Dict[str, float], other: Dict[str, float]) -> Dict:
    """% carbon/water savings of ``other`` relative to ``baseline``
    (positive = better, the paper's primary metric)."""
    def pct(key):
        b = baseline[key]
        return 100.0 * (b - other[key]) / b if b else 0.0
    return dict(carbon_savings_pct=pct("carbon_kg"),
                water_savings_pct=pct("water_kl"))


def region_distribution(result: Dict, num_regions: int) -> np.ndarray:
    """Fig 3(b): % of jobs executed per region."""
    frame = _frame_of(result)
    if frame is not None:
        counts = np.bincount(frame["region"].astype(np.int64),
                             minlength=num_regions)
        return 100.0 * counts / max(int(frame["region"].size), 1)
    recs = result["records"]
    counts = np.bincount([r.region for r in recs], minlength=num_regions)
    return 100.0 * counts / max(len(recs), 1)
