"""Workload traces: Borg-like and Alibaba-like synthetic generators.

The evaluated Google Borg slice (paper §5) is ~230,000 jobs over 10 days at
~15% fleet utilization on 175 servers; Alibaba runs at 8.5× the invocation
rate with a burstier pattern. Neither trace is redistributable inside this
offline image, so we generate statistically matched processes:

* arrivals: inhomogeneous Poisson with diurnal modulation (Borg) or
  diurnal × burst-train modulation (Alibaba);
* durations & energy: drawn from per-benchmark profiles of the paper's
  PARSEC/CloudSuite mix (Table 1) — heavy-tailed across the mix;
* home regions: categorical, weighted toward the larger regions;
* real traces can be substituted via ``load_csv`` (job_id, submit_s,
  duration_s, energy_kwh, home_region columns).

The generators are deterministic given (seed, days, rate multiplier).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.problem import Job

DAY = 86400.0


@dataclasses.dataclass(frozen=True)
class BenchProfile:
    """Measured-style profile of one benchmark (paper Table 1 mix).

    Calibrated to plausible m5.metal numbers: exec time in seconds, mean IT
    power draw in watts while running, package (.tar) size to transfer.
    """
    name: str
    suite: str
    exec_s: float
    power_w: float
    tar_bytes: float

    @property
    def energy_kwh(self) -> float:
        return self.power_w * self.exec_s / 3.6e6


BENCHMARK_PROFILES: List[BenchProfile] = [
    # PARSEC-3.0 (paper Table 1)
    BenchProfile("dedup", "parsec", 210.0, 340.0, 1.8e9),
    BenchProfile("netdedup", "parsec", 260.0, 350.0, 1.9e9),
    BenchProfile("canneal", "parsec", 680.0, 290.0, 0.9e9),
    BenchProfile("blackscholes", "parsec", 380.0, 310.0, 0.6e9),
    BenchProfile("swaptions", "parsec", 420.0, 330.0, 0.5e9),
    # CloudSuite
    BenchProfile("data-caching", "cloudsuite", 900.0, 260.0, 2.5e9),
    BenchProfile("graph-analytics", "cloudsuite", 1500.0, 380.0, 3.2e9),
    BenchProfile("web-serving", "cloudsuite", 1100.0, 240.0, 2.8e9),
    BenchProfile("memory-analytics", "cloudsuite", 1300.0, 360.0, 3.0e9),
    BenchProfile("media-streaming", "cloudsuite", 800.0, 270.0, 4.5e9),
]


def _arrivals(rng: np.random.Generator, days: float, rate_per_s: float,
              diurnal_depth: float = 0.45, burst: float = 0.0) -> np.ndarray:
    """Inhomogeneous Poisson arrivals via thinning."""
    horizon = days * DAY
    lam_max = rate_per_s * (1 + diurnal_depth) * (1 + burst * 4)
    n_cand = rng.poisson(lam_max * horizon)
    t = np.sort(rng.uniform(0, horizon, n_cand))
    lam = rate_per_s * (1 + diurnal_depth * np.sin(t / DAY * 2 * np.pi))
    if burst > 0:
        # Burst trains: 30-minute hot windows every ~4h (Alibaba-like).
        phase = (t % (4 * 3600.0)) < 1800.0
        lam = lam * np.where(phase, 1 + 4 * burst, 1.0)
    keep = rng.uniform(0, lam_max, n_cand) < lam
    return t[keep]


def _make_jobs(rng: np.random.Generator, arrivals: np.ndarray,
               num_regions: int, tolerance: float,
               duration_jitter: float = 0.35) -> List[Job]:
    profiles = BENCHMARK_PROFILES
    picks = rng.integers(0, len(profiles), arrivals.size)
    # Region weights: larger regions receive more submissions.
    w = np.array([0.25, 0.30, 0.15, 0.15, 0.15])[:num_regions]
    w = w / w.sum()
    homes = rng.choice(num_regions, size=arrivals.size, p=w)
    jitter = rng.lognormal(mean=0.0, sigma=duration_jitter, size=arrivals.size)
    jobs = []
    for i, (ts, k, h, jt) in enumerate(zip(arrivals, picks, homes, jitter)):
        p = profiles[k]
        t_exec = float(p.exec_s * jt)
        jobs.append(Job(job_id=i, home_region=int(h), submit_time_s=float(ts),
                        exec_time_s=t_exec,
                        energy_kwh=float(p.energy_kwh * jt),
                        package_bytes=p.tar_bytes, tolerance=tolerance,
                        arch=p.name))
    return jobs


def borg_trace(days: float = 10.0, seed: int = 0, num_regions: int = 5,
               tolerance: float = 0.25, rate_multiplier: float = 1.0,
               target_jobs_per_day: float = 23000.0) -> List[Job]:
    """Borg-like trace: ~23k jobs/day (≈230k over 10 days, paper §5)."""
    rng = np.random.default_rng(seed)
    rate = target_jobs_per_day / DAY * rate_multiplier
    t = _arrivals(rng, days, rate, diurnal_depth=0.45, burst=0.0)
    return _make_jobs(rng, t, num_regions, tolerance)


def alibaba_trace(days: float = 10.0, seed: int = 1, num_regions: int = 5,
                  tolerance: float = 0.25,
                  rate_multiplier: float = 1.0) -> List[Job]:
    """Alibaba-like trace: 8.5× Borg invocation rate, bursty (paper §6)."""
    rng = np.random.default_rng(seed)
    rate = 8.5 * 23000.0 / DAY * rate_multiplier
    t = _arrivals(rng, days, rate, diurnal_depth=0.30, burst=0.5)
    # Alibaba VM jobs skew shorter.
    jobs = _make_jobs(rng, t, num_regions, tolerance, duration_jitter=0.5)
    for j in jobs:
        j.exec_time_s *= 0.6
        j.energy_kwh *= 0.6
    return jobs


# Canonical trace columns -> (required, default). Published Borg/Alibaba
# slices name these differently; ``column_map`` translates.
_CSV_CANONICAL = ("job_id", "submit_s", "duration_s", "energy_kwh",
                  "home_region")


def load_csv(path: str, tolerance: float = 0.25,
             column_map: Optional[dict] = None,
             unit_scale: Optional[dict] = None,
             package_bytes: float = 2e9) -> List[Job]:
    """Load a real trace CSV into ``Job`` objects.

    Canonical columns: ``job_id, submit_s, duration_s, energy_kwh,
    home_region``. Published slices rarely match — ``column_map`` maps
    canonical name -> CSV header (e.g. Google Borg:
    ``{"submit_s": "time", "duration_s": "runtime"}``), and ``unit_scale``
    multiplies a canonical column after mapping (e.g.
    ``{"submit_s": 1e-6}`` for microsecond timestamps). ``energy_kwh`` may
    be mapped from a mean-power column via ``unit_scale`` since energy =
    power × duration is not expressible here; absent energy columns can be
    synthesized upstream instead.

    Home regions outside [0, 4] are folded modulo the region count by the
    scenario builder, not here — the loader stays a faithful reader.
    """
    cmap = {c: c for c in _CSV_CANONICAL}
    cmap.update(column_map or {})
    scale = unit_scale or {}
    raw = np.genfromtxt(path, delimiter=",", names=True)
    if raw.shape == ():                       # single-row CSV edge case
        raw = raw.reshape(1)
    missing = [c for c in _CSV_CANONICAL if cmap[c] not in
               (raw.dtype.names or ())]
    if missing:
        raise ValueError(f"trace {path!r} lacks columns for {missing}; "
                         f"available: {raw.dtype.names}")

    def col(c):
        return np.asarray(raw[cmap[c]], np.float64) * float(scale.get(c, 1.0))

    jobs = [Job(job_id=int(i), home_region=int(h), submit_time_s=float(t),
                exec_time_s=float(d), energy_kwh=float(e),
                package_bytes=package_bytes, tolerance=tolerance)
            for i, t, d, e, h in zip(col("job_id"), col("submit_s"),
                                     col("duration_s"), col("energy_kwh"),
                                     col("home_region"))]
    jobs.sort(key=lambda j: j.submit_time_s)
    return jobs


def rescale_arrival_rate(jobs: Sequence[Job], days: float,
                         target_jobs_per_day: float,
                         seed: int = 0) -> List[Job]:
    """Deterministically thin (or keep) a trace to ≈ ``target_jobs_per_day``.

    Real slices rarely match the fleet size under study. Thinning keeps the
    empirical arrival process (burst structure, diurnal shape) intact —
    unlike time-warping, which would move arrivals across telemetry hours.
    Traces *below* the target are returned unchanged (jobs are never
    duplicated; synthetic upsampling belongs to the generators).
    """
    native = len(jobs) / max(days, 1e-9)
    keep_p = target_jobs_per_day / max(native, 1e-9)
    if keep_p >= 1.0:
        return list(jobs)
    rng = np.random.default_rng(seed)
    keep = rng.random(len(jobs)) < keep_p
    return [j for j, k in zip(jobs, keep) if k]


# ---------------------------------------------------------------------------
# Arrival-time sharding (repro.experiments sharded executor)
# ---------------------------------------------------------------------------

def pick_shard_boundaries(jobs: Sequence[Job], shards: int,
                          window_frac: float = 0.1) -> List[float]:
    """Choose ``shards - 1`` boundary times that split ``jobs`` into
    near-equal-count, arrival-contiguous slices.

    Each boundary starts at the exact count quantile, then snaps to the
    *largest arrival gap* within ±``window_frac`` of a slice's worth of
    jobs around it, and lands at the midpoint of that gap. Wide gaps
    maximize the chance that the fleet has fully drained at the boundary —
    the condition under which an optimistically (independently) executed
    slice is bit-identical to its stretch of the unsharded run, so the
    sharded executor keeps its parallel results instead of falling back.

    Deterministic in its arguments; boundaries are strictly increasing and
    never touch an arrival instant (so slicing is unambiguous). Degenerate
    requests (more shards than distinct arrivals) yield fewer boundaries.
    """
    if shards <= 1 or len(jobs) < 2:
        return []
    t = np.sort(np.asarray([j.submit_time_s for j in jobs], np.float64))
    n = t.size
    gaps = np.diff(t)                       # gap g lies between t[g], t[g+1]
    half = max(int(n / shards * window_frac), 1)
    out: List[float] = []
    for k in range(1, shards):
        q = int(round(k * n / shards))      # first index of the next slice
        if q < 1 or q > n - 1:
            continue                        # no arrivals left to split off
        lo = max(q - half, 1)
        hi = min(q + half, n - 1)
        if lo > hi:
            lo = hi = q
        g = lo - 1 + int(np.argmax(gaps[lo - 1:hi]))
        b = 0.5 * (t[g] + t[g + 1])
        if out and b <= out[-1]:
            continue                        # collapsed with previous boundary
        if t[g + 1] <= b or b <= t[g]:
            continue                        # zero-width gap: unusable
        out.append(float(b))
    return out


def slice_by_arrival(jobs: Sequence[Job],
                     boundaries: Sequence[float]) -> List[List[Job]]:
    """Partition ``jobs`` into ``len(boundaries) + 1`` arrival-time slices:
    slice ``k`` holds exactly the jobs with ``B_k <= submit < B_{k+1}``
    (``B_0 = -inf``, ``B_last = +inf``).

    An exact partition — every job lands in exactly one slice (no loss, no
    duplication) and the input's relative order is preserved within each
    slice (hypothesis-property-tested in tests/test_experiments.py).
    """
    bounds = sorted(float(b) for b in boundaries)
    out: List[List[Job]] = [[] for _ in range(len(bounds) + 1)]
    if not bounds:
        out[0] = list(jobs)
        return out
    edges = np.asarray(bounds, np.float64)
    for j in jobs:
        k = int(np.searchsorted(edges, j.submit_time_s, side="right"))
        out[k].append(j)
    return out


def scale_capacity_for_utilization(jobs: Sequence[Job], days: float,
                                   num_regions: int,
                                   utilization: float = 0.15) -> np.ndarray:
    """Servers per region so mean fleet utilization hits ``utilization``
    (paper §5: 175 servers ≈ 15% at Borg rates; §6 sweeps 5%/15%/25%)."""
    busy_s = sum(j.exec_time_s for j in jobs)
    servers = busy_s / (days * DAY) / utilization
    per_region = max(int(np.ceil(servers / num_regions)), 1)
    return np.full(num_regions, per_region, dtype=np.int64)
