"""Discrete-event simulation engine — replays a trace through a scheduler.

Windowed batching: arrivals within ``window_s`` are presented to the
scheduler together (the paper's controller also "co-optimizes jobs that are
invoked together or nearby in time"). Footprints are *accounted* with the
true hourly telemetry integrated over each job's actual execution window —
the scheduler itself only ever sees the current snapshot (no future info).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import footprint, telemetry
from repro.core.problem import Job
from repro.sim.cluster import Cluster


@dataclasses.dataclass
class SimConfig:
    # Scheduling-round period. Small enough that queue wait consumes little
    # of a short job's TOL budget, large enough to batch co-arriving jobs
    # (the MILP co-optimizes whole windows).
    window_s: float = 30.0
    server: footprint.ServerSpec = dataclasses.field(
        default_factory=footprint.m5_metal)
    # Account footprint with hourly integration (True) or at-start snapshot.
    integrate: bool = True


@dataclasses.dataclass
class JobRecord:
    job: Job
    region: int
    start_s: float
    finish_s: float
    carbon_g: float
    water_l: float

    @property
    def service_s(self) -> float:
        return self.finish_s - self.job.submit_time_s

    @property
    def service_ratio(self) -> float:
        return self.service_s / max(self.job.exec_time_s, 1e-9)

    @property
    def violated(self) -> bool:
        return (self.service_s >
                (1.0 + self.job.tolerance) * self.job.exec_time_s + 1e-6)


class Simulator:
    def __init__(self, tele: telemetry.Telemetry, capacity: np.ndarray,
                 config: Optional[SimConfig] = None):
        self.tele = tele
        self.capacity = np.asarray(capacity, np.int64)
        self.cfg = config or SimConfig()

    # -- footprint accounting ------------------------------------------------

    def _account(self, job: Job, region: int, start_s: float):
        t_eff = job.exec_time_s * job.time_scale
        e_eff = job.energy_kwh * job.energy_scale
        te = self.tele
        if self.cfg.integrate:
            m = te.mean_between(start_s, start_s + t_eff)
            ci = float(m["ci"][region])
            ewif = float(m["ewif"][region])
            wue = float(m["wue"][region])
        else:
            snap = te.at(start_s)
            ci, ewif, wue = (snap["ci"][region], snap["ewif"][region],
                             snap["wue"][region])
        server = self.cfg.server
        carbon = float(footprint.job_carbon(e_eff, t_eff, ci, server))
        water = float(footprint.job_water(e_eff, t_eff, te.pue[region], ewif,
                                          wue, te.wsf[region], server))
        return carbon, water

    # -- main loop -----------------------------------------------------------

    def run(self, jobs: Sequence[Job], scheduler) -> Dict:
        jobs = sorted(jobs, key=lambda j: j.submit_time_s)
        horizon = max(j.submit_time_s for j in jobs) + 1.0 if jobs else 1.0
        cluster = Cluster(self.capacity)
        records: List[JobRecord] = []
        pending: List[Job] = []
        i = 0
        now = 0.0
        windows = 0
        stalls = 0
        while i < len(jobs) or pending or cluster.busy.any():
            cluster.advance(now)
            while i < len(jobs) and jobs[i].submit_time_s <= now:
                pending.append(jobs[i])
                i += 1
            progressed = False
            if pending:
                dec = scheduler.schedule(pending, now, cluster.free())
                progressed = bool(dec.scheduled)
                for job, n in zip(dec.scheduled, dec.assign):
                    n = int(n)
                    lat = telemetry.transfer_latency_s(job.package_bytes,
                                                       job.home_region, n)
                    start = now + lat
                    if job.planned_start_s is not None:
                        start = max(start, job.planned_start_s)
                    finish = start + job.exec_time_s * job.time_scale
                    cluster.dispatch(n, finish)
                    job.start_time_s, job.finish_time_s = start, finish
                    carbon, water = self._account(job, n, start)
                    records.append(JobRecord(job, n, start, finish, carbon,
                                             water))
                pending = list(dec.deferred)
            windows += 1
            if i < len(jobs) and not pending and not cluster.busy.any():
                now = jobs[i].submit_time_s      # fast-forward idle gaps
            else:
                now += self.cfg.window_s
            # Deadlock guard: pending jobs that no scheduler round can place
            # and no running job will ever release capacity for.
            if pending and not progressed and not cluster.busy.any() \
                    and i >= len(jobs):
                stalls += 1
                if stalls > 2:
                    break
            else:
                stalls = 0
        return dict(records=records, windows=windows,
                    solve_times=np.asarray(getattr(scheduler, "solve_times",
                                                   [])),
                    utilization=cluster.utilization(max(now, 1.0)),
                    unfinished=len(pending))
