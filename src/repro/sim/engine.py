"""Discrete-event simulation engine — replays a trace through a scheduler.

Schedulers are anything satisfying the uniform ``repro.policy.Scheduler``
protocol — ``schedule(jobs, now_s, capacity) -> Decision`` — which every
registry policy (rule baselines, the reactive pipeline, the forecast
pipeline) implements. ``run()`` also accepts a declarative policy spec
(``"waterwise[lam_h2o=0.7,backend=jax]"`` or a ``repro.policy.PolicySpec``)
and builds it against the engine's telemetry.

Two engines share one contract (``run(jobs, scheduler) -> result dict``):

``EventSimulator`` (the default ``Simulator``) is event-driven: it holds a
completion heap plus a sorted arrival cursor and only materializes the
instants where something can happen — a scheduling round with pending jobs,
a completion, a capacity event, the next arrival. Idle stretches are skipped
in O(1), per-job footprint accounting is batched into one vectorized
closed-form telemetry integration at the end of the run, and time-varying
capacity (scenario outages) is supported. Multi-day, 100k+-job traces run
in seconds.

``WindowedSimulator`` is the original fixed-window loop, kept verbatim as
the fidelity oracle: it ticks every ``window_s`` whether or not anything
happens and prices each job with per-job sub-sampled integration. The golden
parity test (tests/test_engine.py) pins the event engine's per-job records
to it.

Round-time semantics are identical by construction: rounds happen on the
same ``window_s`` grid (re-anchored at each fully-idle fast-forward), the
scheduler sees the same pending set and free capacities at the same decision
times, so both engines produce the same placements for any scheduler.

Windowed batching rationale: arrivals within ``window_s`` are presented to
the scheduler together (the paper's controller also "co-optimizes jobs that
are invoked together or nearby in time"). Footprints are *accounted* with
the true hourly telemetry integrated over each job's actual execution
window — the scheduler itself only ever sees the current snapshot (no future
info).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import repro.obs as obs
from repro.core import footprint, telemetry
from repro.core.problem import Job
from repro.sim.cluster import Cluster


@dataclasses.dataclass
class SimConfig:
    # Scheduling-round period. Small enough that queue wait consumes little
    # of a short job's TOL budget, large enough to batch co-arriving jobs
    # (the MILP co-optimizes whole windows).
    window_s: float = 30.0
    server: footprint.ServerSpec = dataclasses.field(
        default_factory=footprint.m5_metal)
    # Account footprint with hourly integration (True) or at-start snapshot.
    integrate: bool = True


@dataclasses.dataclass
class JobRecord:
    job: Job
    region: int
    start_s: float
    finish_s: float
    carbon_g: float
    water_l: float
    # Per-region-amortized embodied carbon — a separate accounting column
    # (``carbon_g`` keeps its original operational+lifetime-share definition
    # so every pre-existing parity pin holds unchanged).
    embodied_g: float = 0.0

    @property
    def service_s(self) -> float:
        return self.finish_s - self.job.submit_time_s

    @property
    def service_ratio(self) -> float:
        return self.service_s / max(self.job.exec_time_s, 1e-9)

    @property
    def violated(self) -> bool:
        if self.job.deadline_override_s is not None:
            # Workflow task: the binding deadline is the critical-path one.
            return self.finish_s > self.job.deadline_override_s + 1e-6
        return (self.service_s >
                (1.0 + self.job.tolerance) * self.job.exec_time_s + 1e-6)


# Capacity event: at time t_s the fleet's per-region capacity becomes `cap`.
# The payload is either an absolute per-region vector, or a *relative* profile
# ``("scale", fracs)`` applied to the run's base capacity — ``fracs`` may be a
# scalar ("-30% everywhere at peak heat" == 0.7) or a per-region array.
CapacityEvent = Tuple[float, object]


@dataclasses.dataclass
class EngineState:
    """Everything a follow-on ``EventSimulator.run`` needs to continue a
    run mid-flight — the boundary-stitching handoff of sharded execution
    (``repro.experiments.shard``).

    Exported by ``run(..., stop_at=B, export_state=True)`` at the first
    loop instant at-or-past ``B`` and consumed by the next slice's
    ``run(slice_jobs, sched, state=...)``. A chained sequence of runs over
    an arrival-time partition of a trace reproduces the single unsharded
    run *exactly* — same rounds at the same instants, same placements,
    same per-job footprints — provided the scheduler object itself is
    carried across the chain (the state here covers only the engine:
    clock, grid phase, pending queue, in-flight completions, capacity and
    its event cursor, and the utilization integrals). Everything is
    plain data (floats, ``Job`` dataclasses, small arrays), so the state
    also crosses process boundaries via pickle.
    """
    now: float                      # engine clock == current grid instant
    pending: List[Job]              # arrived but not yet placed, queue order
    applied_events: int             # capacity-event cursor
    cluster: Dict                   # Cluster.export_state() payload
    rounds: int = 0                 # cumulative scheduler rounds so far
    # Workflow (DAG) carry-over. ``blocked`` holds arrived tasks whose
    # predecessors have not all finished; ``finished`` maps job_id ->
    # finish_s for every dispatched job (in-flight finishes included — the
    # release check compares against the clock, so a finish beyond ``now``
    # never releases early). Defaults keep pre-DAG states loadable.
    blocked: List[Job] = dataclasses.field(default_factory=list)
    finished: Dict[int, float] = dataclasses.field(default_factory=dict)


def resolve_scheduler(scheduler, tele):
    """Materialize ``scheduler`` against ``tele``: policy-spec strings and
    ``PolicySpec`` objects are built through the registry; anything already
    satisfying the ``schedule()`` protocol passes through untouched."""
    from repro import policy
    if isinstance(scheduler, (str, policy.PolicySpec)):
        return policy.build(scheduler, tele)
    return scheduler


def resolve_capacity(payload, base: np.ndarray) -> np.ndarray:
    """Materialize a capacity-event payload against the base capacity."""
    if isinstance(payload, tuple) and len(payload) == 2 \
            and payload[0] == "scale":
        frac = np.asarray(payload[1], np.float64)
        return np.maximum(np.round(base * frac), 0).astype(np.int64)
    return np.asarray(payload, np.int64)


class EventSimulator:
    """Event-driven engine (see module docstring)."""

    def __init__(self, tele: telemetry.Telemetry, capacity: np.ndarray,
                 config: Optional[SimConfig] = None,
                 capacity_events: Optional[Sequence[CapacityEvent]] = None):
        self.tele = tele
        self.capacity = np.asarray(capacity, np.int64)
        self.cfg = config or SimConfig()
        self.capacity_events = sorted(capacity_events or [],
                                      key=lambda e: e[0])

    # -- batched footprint accounting ---------------------------------------

    def _account_all(self, placed: List[Tuple[Job, int, float, float]]
                     ) -> Tuple[List[JobRecord], Dict[str, np.ndarray]]:
        """One vectorized accounting pass over every placed job.

        Returns the per-job records plus a columnar *frame* of the same
        data (placement order preserved): metrics aggregation
        (``sim.metrics.summarize``, stress-weighted water) runs on the
        arrays instead of looping over 100k+ record objects, and sharded
        workers ship the frame across process boundaries instead of
        pickling record lists. Frames from an arrival-time-sharded run
        concatenate into exactly the serial run's frame, so array
        reductions over them are bit-identical.
        """
        n = len(placed)
        if not placed:
            return [], {k: np.zeros(0) for k in
                        ("job_id", "region", "home_region", "start_s",
                         "finish_s", "submit_s", "exec_s", "tolerance",
                         "carbon_g", "water_l", "embodied_g", "deadline_s")}
        te = self.tele
        region = np.fromiter((p[1] for p in placed), np.int64, n)
        start = np.fromiter((p[2] for p in placed), np.float64, n)
        t_eff = np.fromiter(
            (p[0].exec_time_s * p[0].time_scale for p in placed),
            np.float64, n)
        e_eff = np.fromiter(
            (p[0].energy_kwh * p[0].energy_scale for p in placed),
            np.float64, n)
        if self.cfg.integrate:
            m = te.mean_over(start, start + t_eff)
        else:
            m = te.at_many(start)
        rows = np.arange(n)
        ci = m["ci"][rows, region]
        ewif = m["ewif"][rows, region]
        wue = m["wue"][rows, region]
        server = self.cfg.server
        carbon = footprint.job_carbon(e_eff, t_eff, ci, server)
        water = footprint.job_water(e_eff, t_eff, te.pue[region], ewif, wue,
                                    te.wsf[region], server)
        servers = np.fromiter((p[0].servers for p in placed), np.float64, n)
        embodied = footprint.job_embodied(
            t_eff, server,
            region_scale=footprint.region_embodied_scale(te.num_regions)[
                region],
            servers=servers)
        frame = dict(
            job_id=np.fromiter((p[0].job_id for p in placed), np.int64, n),
            region=region,
            home_region=np.fromiter((p[0].home_region for p in placed),
                                    np.int64, n),
            start_s=start,
            finish_s=np.fromiter((p[3] for p in placed), np.float64, n),
            submit_s=np.fromiter((p[0].submit_time_s for p in placed),
                                 np.float64, n),
            exec_s=np.fromiter((p[0].exec_time_s for p in placed),
                               np.float64, n),
            tolerance=np.fromiter((p[0].tolerance for p in placed),
                                  np.float64, n),
            carbon_g=np.asarray(carbon, np.float64),
            water_l=np.asarray(water, np.float64),
            embodied_g=np.asarray(embodied, np.float64),
            # Critical-path deadline (NaN for plain jobs) — lets metrics
            # compute override-aware violation rates on the frame alone.
            deadline_s=np.fromiter(
                (np.nan if p[0].deadline_override_s is None
                 else p[0].deadline_override_s for p in placed),
                np.float64, n))
        records = [JobRecord(job, int(nn), float(s), float(f), float(c),
                             float(w), float(g))
                   for (job, nn, s, f), c, w, g in zip(placed, carbon, water,
                                                       embodied)]
        return records, frame

    # -- trace series --------------------------------------------------------

    def _emit_series(self, tr, frame: Dict[str, np.ndarray],
                     horizon: float) -> None:
        """Retroactive simulated-time counter tracks: hourly per-region
        carbon/water (accounted footprints bucketed by start hour) plus the
        WUE truth series — rendered by ``repro.obs.report`` and shown on
        their own Perfetto track (``pid = obs.SIM_PID``, sim-hours as the
        time axis)."""
        H = int(np.ceil(horizon / telemetry.HOUR))
        if H <= 0 or not len(frame["start_s"]):
            return
        R = self.tele.num_regions
        hr = np.minimum((frame["start_s"] // telemetry.HOUR).astype(np.int64),
                        H - 1)
        region = frame["region"].astype(np.int64)
        carbon = np.zeros((H, R))
        water = np.zeros((H, R))
        np.add.at(carbon, (hr, region), frame["carbon_g"])
        np.add.at(water, (hr, region), frame["water_l"])
        labels = [f"R{j}" for j in range(R)]
        for h in range(H):
            ts = h * telemetry.HOUR * 1e6
            wue = self.tele.at(h * telemetry.HOUR)["wue"]
            tr.counter("sim/carbon_g",
                       {lb: float(v) for lb, v in zip(labels, carbon[h])},
                       ts_us=ts, pid=obs.SIM_PID)
            tr.counter("sim/water_L",
                       {lb: float(v) for lb, v in zip(labels, water[h])},
                       ts_us=ts, pid=obs.SIM_PID)
            tr.counter("sim/wue",
                       {lb: float(v) for lb, v in zip(labels, wue)},
                       ts_us=ts, pid=obs.SIM_PID)

    # -- main loop -----------------------------------------------------------

    def stepper(self, scheduler, jobs: Sequence[Job] = (), *,
                state: Optional[EngineState] = None,
                hold_grid: bool = False) -> "EngineStepper":
        """A stepable handle on this engine: the same loop as ``run()`` held
        open between ``step(until_s)`` calls, with ``inject()`` feeding live
        arrivals. See :class:`EngineStepper`."""
        return EngineStepper(self, scheduler, jobs, state=state,
                             hold_grid=hold_grid)

    def run(self, jobs: Sequence[Job], scheduler, *,
            state: Optional[EngineState] = None,
            stop_at: Optional[float] = None,
            export_state: bool = False,
            hold_grid: bool = False) -> Dict:
        """Replay ``jobs`` through ``scheduler``.

        ``state`` resumes a previous run's exported ``EngineState`` (the
        sharded-execution handoff); ``stop_at=B`` halts the loop at the
        first instant at-or-past ``B`` — pretending further arrivals exist
        beyond ``B`` rather than draining/stalling, so a later resumed run
        observes exactly the engine a single uninterrupted run would have
        had there; ``export_state=True`` attaches the boundary state as
        ``result["state"]``. Chained ``run(slice, ..., state=prev)`` calls
        over an arrival-time partition reproduce the unsharded run
        bit-for-bit (pinned in tests/test_experiments.py).

        ``hold_grid=True`` ticks the round grid through idle stretches
        instead of re-anchoring at the next arrival. A *speculative*
        warm-up run (sharded execution) starts from an empty fleet that
        the real run would have had busy; holding the grid keeps its round
        instants bit-aligned with the real run's ``now += w``
        accumulation, so the warm-up can converge to the exact engine
        state of the unsharded run at the shard boundary.

        Implemented on top of :class:`EngineStepper` — one ``step(stop_at)``
        to the boundary (or to drain) plus the accounting pass — so batch
        replay and live serving share the loop verbatim.
        """
        st = self.stepper(scheduler, jobs, state=state, hold_grid=hold_grid)
        st.step(stop_at)
        return st.result(export_state=export_state)


class EngineStepper:
    """The event-engine loop as a stepable object (live-serving seam).

    Holds every loop variable of the classic ``EventSimulator.run`` —
    clock, grid phase, arrival cursor, pending queue, cluster, capacity-
    event cursor — between calls, so the same engine powers both execution
    modes:

      * **batch replay**: construct with the whole trace, ``step(None)``
        runs to drain — ``EventSimulator.run`` is exactly this plus the
        accounting pass, so parity is by construction;
      * **live serving** (``repro.serve``): ``inject(new_jobs)`` then
        ``step(t_round)`` per decision round. ``step(until_s)`` uses the
        ``stop_at`` boundary semantics proven bit-exact by the sharded
        chained-handoff tests: the engine behaves as if further arrivals
        exist beyond ``until_s``, so a stream fed chunk-by-chunk at round
        boundaries reproduces the batch replay of the same arrivals
        bit-for-bit (pinned in tests/test_serve.py).

    ``step`` may be called after the loop went idle (everything drained);
    a later ``inject`` + ``step`` resumes exactly like a chained
    ``run(state=...)`` handoff would.
    """

    def __init__(self, sim: "EventSimulator", scheduler,
                 jobs: Sequence[Job] = (), *,
                 state: Optional[EngineState] = None,
                 hold_grid: bool = False):
        self.sim = sim
        self.scheduler = resolve_scheduler(scheduler, sim.tele)
        self.hold_grid = hold_grid
        self.jobs: List[Job] = sorted(jobs, key=lambda j: j.submit_time_s)
        self._submit: List[float] = [j.submit_time_s for j in self.jobs]
        self.cluster = Cluster(sim.capacity)
        self.placed: List[Tuple[Job, int, float, float]] = []
        self.pending: List[Job] = []
        self.blocked: List[Job] = []        # arrived, predecessors unfinished
        self._finish: Dict[int, float] = {}  # job_id -> finish_s at dispatch
        self.i = 0          # arrival cursor
        self.ce = 0         # capacity-event cursor
        self.now = 0.0
        self.prior_rounds = 0
        if state is not None:
            self.cluster.restore_state(state.cluster)
            self.pending = list(state.pending)
            self.blocked = list(state.blocked)
            self._finish = dict(state.finished)
            self.ce = int(state.applied_events)
            self.now = float(state.now)
            self.prior_rounds = int(state.rounds)
        self.rounds = 0
        self.stalls = 0

    def inject(self, jobs: Sequence[Job]) -> int:
        """Feed live arrivals into the un-consumed tail of the trace.

        The tail is re-sorted by submit time (stable), so time-ordered
        chunks — every arrival source in ``repro.serve`` polls in submit
        order — leave the consumption order identical to a single up-front
        sort of the whole trace. Returns the number of injected jobs.
        """
        new = list(jobs)
        if not new:
            return 0
        tail = self.jobs[self.i:] + new
        tail.sort(key=lambda j: j.submit_time_s)
        del self.jobs[self.i:]
        self.jobs.extend(tail)
        del self._submit[self.i:]
        self._submit.extend(j.submit_time_s for j in tail)
        return len(new)

    def next_arrival_s(self) -> Optional[float]:
        """Submit time of the next un-consumed arrival, if any."""
        if self.i < len(self.jobs):
            return self.jobs[self.i].submit_time_s
        return None

    def step(self, until_s: Optional[float] = None) -> float:
        """Advance the engine to the first loop instant at-or-past
        ``until_s`` (the ``stop_at`` boundary semantics), or to full drain
        when ``until_s`` is ``None``. Returns the engine clock."""
        sim = self.sim
        stop_at = until_s
        w = sim.cfg.window_s
        scheduler = self.scheduler
        jobs = self.jobs
        cluster = self.cluster
        cap_events = sim.capacity_events
        placed = self.placed
        pending = self.pending
        blocked = self.blocked
        finished = self._finish
        i = self.i
        ce = self.ce
        now = self.now
        rounds = self.rounds
        stalls = self.stalls
        hold_grid = self.hold_grid
        n_jobs = len(jobs)
        submit = self._submit
        while i < n_jobs or pending or blocked or cluster.busy_any():
            if stop_at is not None and now >= stop_at:
                break
            while ce < len(cap_events) and cap_events[ce][0] <= now:
                t_event, payload = cap_events[ce]
                # Settle busy/provisioned integrals up to the event instant
                # so the capacity change is not billed retroactively.
                cluster.advance(t_event)
                cluster.set_capacity(resolve_capacity(payload, sim.capacity))
                ce += 1
            cluster.advance(now)
            while i < n_jobs and submit[i] <= now:
                # Precedence routing: a DAG task is not *schedulable* until
                # every predecessor has finished — it arrives into ``blocked``
                # and the release pass below moves it to ``pending``. Plain
                # jobs keep their exact pre-DAG path.
                (blocked if jobs[i].deps else pending).append(jobs[i])
                i += 1
            if blocked:
                # Release pass: a task becomes schedulable at the first loop
                # instant at-or-past its last predecessor's finish. Stable
                # order; identical in batch replay and streaming (same code,
                # same instants), so DAG parity holds by construction.
                still: List[Job] = []
                for job in blocked:
                    fins = [finished.get(d) for d in job.deps]
                    if all(f is not None and f <= now + 1e-9 for f in fins):
                        pending.append(job)
                    else:
                        still.append(job)
                blocked = still
            progressed = False
            if pending:
                with obs.span("engine.round", now_s=now,
                              pending=len(pending)) as sp:
                    dec = scheduler.schedule(pending, now, cluster.free())
                    progressed = bool(dec.scheduled)
                    for job, n in zip(dec.scheduled, dec.assign):
                        n = int(n)
                        lat = sim.tele.transfer_latency_s(job.package_bytes,
                                                          job.home_region, n)
                        start = now + lat
                        if job.planned_start_s is not None:
                            start = max(start, job.planned_start_s)
                        finish = start + job.exec_time_s * job.time_scale
                        cluster.dispatch(n, finish)
                        job.start_time_s, job.finish_time_s = start, finish
                        finished[job.job_id] = finish
                        placed.append((job, n, start, finish))
                    sp.set(scheduled=len(dec.scheduled),
                           deferred=len(dec.deferred))
                    pending = list(dec.deferred)
                    rounds += 1
                if obs.enabled():
                    tr = obs.tracer()
                    if tr is not None:
                        tr.counter("engine/queue", {
                            "pending": len(pending),
                            "scheduled": len(dec.scheduled)})
            # Deadlock guard: pending jobs that no scheduler round can place
            # and no running job will ever release capacity for. A future
            # capacity event may still unblock them (outage restoration), and
            # a temporal-shifting scheduler may be holding them *on purpose*
            # (Decision.wake_s names its planned release) — fast-forward to
            # the earlier of the two rather than stalling out. With a
            # ``stop_at`` boundary, later slices hold more arrivals, so a
            # single uninterrupted run would never take this branch here
            # (its arrival cursor is not exhausted) — skip it and keep
            # rounds marching toward the boundary instead.
            if stop_at is None and pending and not progressed \
                    and not cluster.busy_any() and i >= n_jobs:
                wake = getattr(dec, "wake_s", None)
                targets = []
                if ce < len(cap_events):
                    targets.append(max(cap_events[ce][0], now))
                if wake is not None and wake > now + 1e-9:
                    targets.append(wake)
                if targets:
                    stalls = 0
                    now = min(targets)
                    continue
                stalls += 1
                if stalls > 2:
                    break
            else:
                stalls = 0
            # ---- jump to the next instant anything can happen -------------
            if pending:
                now += w                      # next round on the grid
            elif blocked and cluster.busy_any():
                # A completion may release a blocked task; releases happen on
                # the grid, so tick one window (same float accumulation in
                # batch and stream — parity by construction).
                now += w
            elif i < n_jobs:
                nxt = submit[i]
                if cluster.busy_any():
                    # Tick the grid forward (same float accumulation as the
                    # windowed engine) until either the next arrival falls
                    # inside a window or the fleet drains — draining first
                    # re-anchors the grid at the arrival, exactly like the
                    # windowed engine's idle fast-forward.
                    drain = cluster.drain_time()
                    t = now + w
                    while t < nxt and drain > t:
                        t += w
                    now = t if t >= nxt else nxt
                elif hold_grid:
                    # Speculative warm-up: the real fleet would be busy
                    # here, so keep accumulating the grid instead of
                    # re-anchoring at the arrival.
                    t = now + w
                    while t < nxt:
                        t += w
                    now = t
                else:
                    now = nxt                 # fully idle: fast-forward
            elif cluster.busy_any():
                if stop_at is None:
                    now = cluster.drain_time()   # no more work: drain, stop
                else:
                    # Next arrivals live beyond the handoff boundary: tick
                    # the grid toward it exactly as the single run would
                    # tick toward that (>= stop_at) arrival, preserving the
                    # float-accumulated grid phase across the handoff.
                    drain = cluster.drain_time()
                    t = now + w
                    while t < stop_at and drain > t:
                        t += w
                    now = t
            else:
                break
        self.pending = pending
        self.blocked = blocked
        self.i = i
        self.ce = ce
        self.now = now
        self.rounds = rounds
        self.stalls = stalls
        return now

    def result(self, export_state: bool = False) -> Dict:
        """Settle the utilization integrals at the current clock, run the
        batched accounting pass over everything placed so far, and build the
        engine result dict (same shape as ``EventSimulator.run``'s)."""
        sim = self.sim
        cluster = self.cluster
        pending = self.pending
        now = self.now
        cluster.advance(now)
        horizon = max(now, cluster.drain_time(), 1.0)
        records, frame = sim._account_all(self.placed)
        if obs.enabled():
            obs.observe("engine.pending_depth", float(len(pending)))
            tr = obs.tracer()
            if tr is not None:
                sim._emit_series(tr, frame, horizon)
        rounds = self.prior_rounds + self.rounds
        result = dict(records=records, frame=frame,
                      windows=rounds,
                      rounds=rounds,
                      solve_times=np.asarray(getattr(self.scheduler,
                                                     "solve_times", [])),
                      utilization=cluster.utilization(horizon),
                      peak_busy=cluster.peak_busy.copy(),
                      horizon_s=horizon,
                      drain_s=cluster.drain_time(),
                      busy_integral_s=cluster.busy_integral_s,
                      cap_integral_s=cluster.cap_integral_s,
                      unfinished=(len(pending) + len(self.blocked)
                                  + (len(self.jobs) - self.i)))
        if export_state:
            # Arrivals the loop never consumed (all below ``stop_at`` by
            # slicing) join the carried queues in submit order — exactly the
            # order the single run would have appended them in. DAG-tail
            # tasks join ``blocked`` (the single run's arrival pop routes
            # dep-carrying jobs there, and its release pass — which runs
            # *after* the pop — appends the ready ones to pending after the
            # plain arrivals), so the restored run reproduces the single
            # run's queue order exactly.
            tail = self.jobs[self.i:]
            result["state"] = EngineState(
                now=now,
                pending=pending + [j for j in tail if not j.deps],
                applied_events=self.ce,
                cluster=cluster.export_state(),
                rounds=rounds,
                blocked=self.blocked + [j for j in tail if j.deps],
                finished=dict(self._finish))
        return result


class WindowedSimulator:
    """The original fixed-window engine — kept as the golden-parity oracle.

    Spins the ``window_s`` grid through idle time and prices each job with
    per-job sub-sampled integration (``Telemetry.mean_between``). Quadratic
    in trace span; use only for small fidelity checks.
    """

    def __init__(self, tele: telemetry.Telemetry, capacity: np.ndarray,
                 config: Optional[SimConfig] = None):
        self.tele = tele
        self.capacity = np.asarray(capacity, np.int64)
        self.cfg = config or SimConfig()

    # -- footprint accounting ------------------------------------------------

    def _account(self, job: Job, region: int, start_s: float):
        t_eff = job.exec_time_s * job.time_scale
        e_eff = job.energy_kwh * job.energy_scale
        te = self.tele
        if self.cfg.integrate:
            m = te.mean_between(start_s, start_s + t_eff)
            ci = float(m["ci"][region])
            ewif = float(m["ewif"][region])
            wue = float(m["wue"][region])
        else:
            snap = te.at(start_s)
            ci, ewif, wue = (snap["ci"][region], snap["ewif"][region],
                             snap["wue"][region])
        server = self.cfg.server
        carbon = float(footprint.job_carbon(e_eff, t_eff, ci, server))
        water = float(footprint.job_water(e_eff, t_eff, te.pue[region], ewif,
                                          wue, te.wsf[region], server))
        embodied = float(footprint.job_embodied(
            t_eff, server,
            region_scale=float(
                footprint.region_embodied_scale(te.num_regions)[region]),
            servers=job.servers))
        return carbon, water, embodied

    # -- main loop -----------------------------------------------------------

    def run(self, jobs: Sequence[Job], scheduler) -> Dict:
        scheduler = resolve_scheduler(scheduler, self.tele)
        jobs = sorted(jobs, key=lambda j: j.submit_time_s)
        cluster = Cluster(self.capacity)
        records: List[JobRecord] = []
        pending: List[Job] = []
        i = 0
        now = 0.0
        windows = 0
        rounds = 0
        stalls = 0
        while i < len(jobs) or pending or cluster.busy.any():
            cluster.advance(now)
            while i < len(jobs) and jobs[i].submit_time_s <= now:
                pending.append(jobs[i])
                i += 1
            progressed = False
            if pending:
                dec = scheduler.schedule(pending, now, cluster.free())
                progressed = bool(dec.scheduled)
                for job, n in zip(dec.scheduled, dec.assign):
                    n = int(n)
                    lat = self.tele.transfer_latency_s(job.package_bytes,
                                                       job.home_region, n)
                    start = now + lat
                    if job.planned_start_s is not None:
                        start = max(start, job.planned_start_s)
                    finish = start + job.exec_time_s * job.time_scale
                    cluster.dispatch(n, finish)
                    job.start_time_s, job.finish_time_s = start, finish
                    carbon, water, embodied = self._account(job, n, start)
                    records.append(JobRecord(job, n, start, finish, carbon,
                                             water, embodied))
                pending = list(dec.deferred)
                rounds += 1
            windows += 1
            if i < len(jobs) and not pending and not cluster.busy.any():
                now = jobs[i].submit_time_s      # fast-forward idle gaps
            else:
                now += self.cfg.window_s
            # Deadlock guard: pending jobs that no scheduler round can place
            # and no running job will ever release capacity for. A scheduler
            # holding jobs on purpose (Decision.wake_s) keeps ticking — the
            # windowed engine spins the grid rather than jumping.
            if pending and not progressed and not cluster.busy.any() \
                    and i >= len(jobs):
                wake = getattr(dec, "wake_s", None)
                if wake is not None and wake > now:
                    stalls = 0
                else:
                    stalls += 1
                    if stalls > 2:
                        break
            else:
                stalls = 0
        return dict(records=records, windows=windows, rounds=rounds,
                    solve_times=np.asarray(getattr(scheduler, "solve_times",
                                                   [])),
                    utilization=cluster.utilization(max(now, 1.0)),
                    peak_busy=cluster.peak_busy.copy(),
                    horizon_s=max(now, 1.0),
                    unfinished=len(pending))


# The event-driven engine is the default.
Simulator = EventSimulator
