"""Discrete-event geo-distributed cluster simulator (paper §5-§6 testbed).

The paper runs 175 AWS m5.metal nodes across five regions and replays Google
Borg / Alibaba arrival processes over PARSEC/CloudSuite jobs. This package
reproduces that testbed as a simulator: ``trace`` generates statistically
matched arrival/duration/energy processes (real trace files can be loaded
when available), ``cluster``/``engine`` run the event loop with any scheduler
plugged in, and ``metrics`` computes the paper's figures of merit.
"""
from repro.sim.trace import borg_trace, alibaba_trace, BENCHMARK_PROFILES
from repro.sim.engine import (Simulator, EventSimulator, WindowedSimulator,
                              SimConfig)
from repro.sim.metrics import summarize, savings_vs
