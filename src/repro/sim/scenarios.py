"""Scenario registry + fleet-scale sweep runner.

A *scenario* is a named, deterministic composition of

  * a telemetry perturbation  (drought, grid decarbonization, …),
  * a trace generator          (Borg-like steady, Alibaba-like bursty, …),
  * a capacity profile         (static, or timed capacity events — outages),
  * an accounting view         (e.g. Wu et al.-style water-stress weighting).

The paper evaluates WaterWise under one telemetry regime; related work shows
conclusions move with the regime (Attenni et al. sweep spatio-temporal
shifting policies across regions/seasons; Wu et al. show water rankings flip
under water-stress weighting). This module makes those regimes first-class:
``sweep(schedulers, scenarios)`` runs the full cross product on the
event-driven engine — optionally fanned out across worker processes — and
returns one tidy row per (scenario, scheduler) cell. Schedulers are
declarative policy specs (``repro.policy``): strings like
``"waterwise-forecast[horizon_slots=8]"`` work anywhere, and every row's
``spec`` column re-parses to the exact policy that produced it.

Adding a scenario::

    @register("heatwave", "2-week heatwave: +8C wet-bulb everywhere")
    def _heatwave(days, seed, jobs_per_day, utilization):
        inst = _base(days, seed, jobs_per_day, utilization)
        return dataclasses.replace(
            inst, tele=scale_wue(inst.tele, 1.9), name="heatwave")

The builder must be deterministic in its arguments (property-tested).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import telemetry
from repro.core.problem import Job
from repro.sim.engine import EventSimulator, SimConfig
from repro.sim.metrics import savings_vs, summarize
from repro.sim.trace import (DAY, alibaba_trace, borg_trace,
                             scale_capacity_for_utilization)


@dataclasses.dataclass
class ScenarioInstance:
    """Everything one simulation run needs, fully materialized."""
    name: str
    tele: telemetry.Telemetry
    jobs: List[Job]
    capacity: np.ndarray
    capacity_events: List[Tuple[float, object]] = \
        dataclasses.field(default_factory=list)
    # Per-region weights applied to each record's water footprint when
    # reporting `stress_water_kl` (Wu et al.: liters in a water-stressed
    # basin are not interchangeable with liters in a wet one). None = 1.
    water_weight: Optional[np.ndarray] = None
    # Forecast-error regime (systematic over-/under-prediction × noise):
    # injected into forecast-driven schedulers by ``run_cell``. 1.0/0.0 = off.
    forecast_bias: float = 1.0
    forecast_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    build: Callable[..., ScenarioInstance]


_REGISTRY: Dict[str, Scenario] = {}


def register(name: str, description: str):
    """Decorator: register a scenario builder under ``name``."""
    def deco(fn):
        _REGISTRY[name] = Scenario(name=name, description=description,
                                   build=fn)
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Telemetry perturbations (pure: Telemetry -> new Telemetry)
# ---------------------------------------------------------------------------

def scale_wue(tele: telemetry.Telemetry, factor: float) -> telemetry.Telemetry:
    return dataclasses.replace(tele, wue=tele.wue * factor)


def raise_wsf(tele: telemetry.Telemetry, gain: float = 1.5,
              floor: float = 0.1) -> telemetry.Telemetry:
    return dataclasses.replace(
        tele, wsf=np.minimum(tele.wsf * gain + floor, 1.0))


def decarbonize(tele: telemetry.Telemetry, regions: Sequence[int],
                onset_frac: float = 0.4, final_scale: float = 0.55,
                horizon_hours: Optional[float] = None) -> telemetry.Telemetry:
    """Grid-decarbonization event: carbon intensity in ``regions`` ramps
    linearly from 1.0× down to ``final_scale``× starting at ``onset_frac``
    of the *simulated* horizon (coal retirement / renewables buildout).

    ``horizon_hours`` is the simulated span; telemetry is generated with
    headroom beyond it (whole days + 1), so anchoring the ramp to the raw
    array length would push the event past the end of short simulations.
    Hours beyond the horizon hold at ``final_scale``."""
    T = tele.num_hours
    H = min(float(horizon_hours) if horizon_hours is not None else T, T)
    onset = int(H * onset_frac)
    end = min(int(np.ceil(H)), T)
    ramp = np.ones(T)
    if onset < end:
        ramp[onset:end] = np.linspace(1.0, final_scale, end - onset)
    ramp[end:] = final_scale
    ci = tele.ci.copy()
    for r in regions:
        ci[:, r] = ci[:, r] * ramp
    return dataclasses.replace(tele, ci=ci)


# ---------------------------------------------------------------------------
# Built-in scenarios
# ---------------------------------------------------------------------------

def _base(days: float, seed: int, jobs_per_day: float, utilization: float,
          *, trace: str = "borg", tolerance: float = 0.5,
          ewif_table: str = "macknick",
          regions: Optional[Sequence] = None) -> ScenarioInstance:
    tele = telemetry.generate(days=max(int(np.ceil(days)) + 1, 2), seed=seed,
                              ewif_table=ewif_table,
                              regions=regions or tuple(telemetry.REGIONS))
    if trace == "borg":
        jobs = borg_trace(days=days, seed=seed, tolerance=tolerance,
                          num_regions=tele.num_regions,
                          target_jobs_per_day=jobs_per_day)
    else:
        # Alibaba keeps its 8.5× burst shape; the multiplier rescales the
        # absolute rate to the requested jobs/day.
        mult = jobs_per_day / (8.5 * 23000.0)
        jobs = alibaba_trace(days=days, seed=seed, tolerance=tolerance,
                             num_regions=tele.num_regions,
                             rate_multiplier=mult)
    cap = scale_capacity_for_utilization(jobs, days, tele.num_regions,
                                         utilization)
    return ScenarioInstance(name="nominal", tele=tele, jobs=jobs,
                            capacity=cap)


@register("nominal", "Borg-like steady trace, unperturbed telemetry")
def _nominal(days, seed, jobs_per_day, utilization, **kw):
    return _base(days, seed, jobs_per_day, utilization, **kw)


@register("drought-summer",
          "Heatwave + drought: cooling WUE +45%, scarcity factors elevated")
def _drought(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    tele = raise_wsf(scale_wue(inst.tele, 1.45), gain=1.4, floor=0.1)
    return dataclasses.replace(inst, name="drought-summer", tele=tele)


@register("decarbonization",
          "Grid-decarbonization event: dirtiest two grids ramp CI to 0.55x "
          "from 40% of the horizon")
def _decarb(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    dirty = list(np.argsort(inst.tele.ci.mean(axis=0))[-2:])
    tele = decarbonize(inst.tele, dirty, horizon_hours=days * 24.0)
    return dataclasses.replace(inst, name="decarbonization", tele=tele)


@register("capacity-loss",
          "Region outage: the greenest region loses all of its servers for "
          "the middle ~15% of the horizon")
def _outage(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    green = int(np.argmin(inst.tele.ci.mean(axis=0)))
    degraded = inst.capacity.copy()
    degraded[green] = 0
    t0, t1 = 0.40 * days * DAY, 0.55 * days * DAY
    events = [(t0, degraded), (t1, inst.capacity.copy())]
    return dataclasses.replace(inst, name="capacity-loss",
                               capacity_events=events)


@register("burst-storm",
          "Alibaba-style burst storm: bursty short-job trace at 25% target "
          "utilization")
def _burst(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, max(utilization, 0.25),
                 trace="alibaba", **kw)
    return dataclasses.replace(inst, name="burst-storm")


@register("water-stress-weighted",
          "Wu et al. accounting: identical physics, but reported water is "
          "weighted by regional scarcity")
def _stress_weighted(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    # Liters weighted by (1 + WSF)^2 relative to fleet mean: water spent in
    # Madrid/Mumbai counts for more than water spent in Zurich.
    w = (1.0 + inst.tele.wsf) ** 2
    w = w / w.mean()
    return dataclasses.replace(inst, name="water-stress-weighted",
                               water_weight=w)


@register("forecast-error",
          "Nominal physics, but forecast-driven schedulers see a +30% biased "
          "and 15%-noisy forecast (systematic over-prediction)")
def _forecast_error(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    return dataclasses.replace(inst, name="forecast-error",
                               forecast_bias=1.30, forecast_noise=0.15)


def heat_derate_events(tele: telemetry.Telemetry, days: float,
                       frac: float = 0.7, wb_quantile: float = 0.85
                       ) -> List[Tuple[float, object]]:
    """Capacity events derived from the telemetry's wet-bulb extremes.

    The fleet-mean wet-bulb series (``Telemetry.wb_c`` — the raw weather;
    WUE itself clips at its physical floor and hides the extremes) locates
    the heat peak: the longest contiguous run of hours above the
    ``wb_quantile`` quantile becomes a relative derate. Regions whose own
    wet-bulb during that window exceeds their horizon median are scaled to
    ``frac`` of base capacity (cooling-limited); the rest keep full
    capacity — no fixed outage window, no absolute vectors.
    """
    wb = tele.wb_c if tele.wb_c is not None else tele.wue
    H = max(int(days * 24), 1)
    fleet = wb[:H].mean(axis=1)
    thresh = np.quantile(fleet, wb_quantile)
    hot = fleet >= thresh
    if not hot.any() or hot.all():
        return []
    # Longest contiguous hot run.
    best, cur, best_span = 0, 0, (0, 0)
    for h, flag in enumerate(hot):
        if flag:
            cur += 1
            if cur > best:
                best, best_span = cur, (h - cur + 1, h + 1)
        else:
            cur = 0
    h0, h1 = best_span
    med = np.median(wb[:H], axis=0)
    peak_wb = wb[h0:h1].mean(axis=0)
    fracs = np.where(peak_wb > med, frac, 1.0)
    return [(h0 * 3600.0, ("scale", fracs)),
            (h1 * 3600.0, ("scale", np.ones(tele.num_regions)))]


@register("heat-derate",
          "Wet-bulb-extreme derate: during the hottest contiguous hours, "
          "cooling-limited regions drop to 70% capacity (relative profile "
          "derived from telemetry, not fixed fractions)")
def _heat_derate(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    events = heat_derate_events(inst.tele, days)
    return dataclasses.replace(inst, name="heat-derate",
                               capacity_events=events)


def register_csv_scenario(name: str, path: str, *,
                          column_map: Optional[Dict] = None,
                          unit_scale: Optional[Dict] = None,
                          description: str = "") -> Scenario:
    """Register a scenario whose trace is a real CSV slice.

    The builder drops cell-for-cell into the sweep: the CSV replaces the
    synthetic generator (column mapping + deterministic arrival-rate
    thinning to the cell's ``jobs_per_day``), while telemetry, capacity
    scaling, and accounting views stay identical to ``nominal``. Home
    regions are folded modulo the region count.
    """
    from repro.sim.trace import load_csv, rescale_arrival_rate

    def build(days, seed, jobs_per_day, utilization, *, tolerance=0.5):
        tele = telemetry.generate(days=max(int(np.ceil(days)) + 1, 2),
                                  seed=seed)
        jobs = load_csv(path, tolerance=tolerance, column_map=column_map,
                        unit_scale=unit_scale)
        jobs = [j for j in jobs if j.submit_time_s < days * DAY]
        for j in jobs:
            j.home_region = j.home_region % tele.num_regions
        jobs = rescale_arrival_rate(jobs, days, jobs_per_day, seed=seed)
        for i, j in enumerate(jobs):
            j.job_id = i
        cap = scale_capacity_for_utilization(jobs, days, tele.num_regions,
                                             utilization)
        return ScenarioInstance(name=name, tele=tele, jobs=jobs,
                                capacity=cap)

    register(name, description or f"real trace from {path}")(build)
    return _REGISTRY[name]


# ---------------------------------------------------------------------------
# Sweep runner
# ---------------------------------------------------------------------------

def run_cell(scenario: str, scheduler, *, days: float = 0.2,
             seed: int = 0, jobs_per_day: float = 23000.0,
             utilization: float = 0.15, window_s: float = 30.0,
             tolerance: Optional[float] = None,
             sched_kwargs: Optional[Dict] = None,
             build_kwargs: Optional[Dict] = None,
             return_result: bool = False) -> Dict:
    """Build one scenario instance, run one scheduler through it, and return
    a tidy result row. Deterministic in its arguments; safe to run in a
    worker process (everything is rebuilt from primitives).

    ``scheduler`` is a policy spec — a ``repro.policy.PolicySpec`` or its
    string form (``"waterwise[lam_h2o=0.7,backend=jax]"``). ``sched_kwargs``
    are merged into the spec as validated overrides: unknown or ill-typed
    params raise with a did-you-mean message for *every* policy (nothing is
    silently dropped any more). The row's ``spec`` column is the fully
    resolved spec string — re-parsing it reproduces the cell's scheduler
    exactly, so any sweep CSV line is self-describing.

    ``tolerance`` overrides the builders' default delay tolerance (the
    temporal-shifting dimension: TOL×exec_time of slack per job) and
    ``build_kwargs`` forwards further builder kwargs (``trace``,
    ``ewif_table``, ``regions``, ... — whatever the scenario's builder
    accepts). Forecast-driven policies additionally report
    ``forecast_mape`` (realized % error of the forecasts they acted on),
    ``mean_defer_s`` (average intentional hold), and ``deferred_pct``;
    scenarios with a forecast-error regime inject their bias/noise into
    the spec (visible in the ``spec`` column). ``return_result=True``
    attaches the raw engine result dict as ``row["_result"]`` (in-process
    use only; never serialized into sweep CSVs).
    """
    from repro import policy
    from repro.core import solvers

    solvers.available_backends()     # one-time backend imports, off the clock
    spec = policy.as_spec(scheduler)
    if sched_kwargs:
        spec = spec.with_params(**sched_kwargs)
    build_kw = dict(build_kwargs or {})
    if tolerance is not None:
        build_kw["tolerance"] = tolerance
    inst = get_scenario(scenario).build(days, seed, jobs_per_day, utilization,
                                        **build_kw)
    if policy.get_policy(spec.name).forecast_driven \
            and (inst.forecast_bias != 1.0 or inst.forecast_noise > 0.0):
        spec = spec.with_defaults(forecast_bias=inst.forecast_bias,
                                  forecast_noise=inst.forecast_noise,
                                  forecast_seed=seed)
    sched = policy.build(spec, inst.tele)
    sim = EventSimulator(inst.tele, inst.capacity,
                         SimConfig(window_s=window_s),
                         capacity_events=inst.capacity_events)
    t0 = time.perf_counter()
    result = sim.run(inst.jobs, sched)
    wall = time.perf_counter() - t0
    row = dict(scenario=scenario, scheduler=spec.name, spec=str(spec),
               **summarize(result))
    row["wall_s"] = wall
    row["unfinished"] = result["unfinished"]
    weight = (inst.water_weight if inst.water_weight is not None
              else np.ones(inst.tele.num_regions))
    row["stress_water_kl"] = float(
        sum(r.water_l * weight[r.region] for r in result["records"]) / 1e3)
    if hasattr(sched, "forecast_mape"):
        row["forecast_mape"] = float(sched.forecast_mape)
        row["mean_defer_s"] = float(sched.mean_defer_s)
        row["deferred_pct"] = (100.0 * sched.deferred_jobs
                               / max(len(inst.jobs), 1))
    if return_result:
        row["_result"] = result
    return row


def sweep(schedulers: Sequence, scenarios: Optional[Sequence[str]] = None,
          *, days: float = 0.2, seed: int = 0,
          jobs_per_day: float = 23000.0, utilization: float = 0.15,
          window_s: float = 30.0, tolerance: Optional[float] = None,
          sched_kwargs: Optional[Dict] = None,
          max_workers: Optional[int] = None) -> List[Dict]:
    """Run the schedulers × scenarios cross product; one tidy row per cell.

    ``schedulers`` are policy specs — strings like
    ``"waterwise-forecast[horizon_slots=8]"`` or ``PolicySpec`` objects —
    validated up front so a typo'd policy or param fails before any cell
    runs. ``max_workers > 1`` fans cells out over worker processes (each
    cell is independent and deterministic, so parallel and serial sweeps
    produce identical rows). Defaults to the CPU count capped by the cell
    count. Within each scenario, savings percentages are attached relative
    to the ``baseline`` scheduler when it is part of the sweep.
    """
    from repro import policy
    scenarios = list(scenarios) if scenarios is not None else list_scenarios()
    for s in scenarios:
        get_scenario(s)          # fail fast on typos
    specs = [policy.as_spec(s) for s in schedulers]   # fail fast on typos
    cells = [(sc, sd) for sc in scenarios for sd in specs]
    kw = dict(days=days, seed=seed, jobs_per_day=jobs_per_day,
              utilization=utilization, window_s=window_s,
              tolerance=tolerance, sched_kwargs=sched_kwargs)
    if max_workers is None:
        max_workers = min(os.cpu_count() or 1, len(cells))
    rows: List[Dict] = []
    if max_workers > 1 and len(cells) > 1:
        with concurrent.futures.ProcessPoolExecutor(max_workers) as pool:
            futs = [pool.submit(run_cell, sc, sd, **kw) for sc, sd in cells]
            rows = [f.result() for f in futs]
    else:
        rows = [run_cell(sc, sd, **kw) for sc, sd in cells]
    # Savings relative to the in-scenario baseline scheduler.
    by_scenario: Dict[str, Dict] = {}
    for row in rows:
        if row["scheduler"] == "baseline":
            by_scenario[row["scenario"]] = row
    for row in rows:
        base = by_scenario.get(row["scenario"])
        if base is not None:
            row.update(savings_vs(base, row))
            bw = base["stress_water_kl"]
            row["stress_water_savings_pct"] = (
                100.0 * (bw - row["stress_water_kl"]) / bw if bw else 0.0)
    return rows


# "unfinished" stays in the default view: a scheduler that strands jobs
# accrues less footprint than one that ran everything — savings read from a
# row with unfinished > 0 are not comparable to the baseline's.
_TABLE_COLS = ("scenario", "scheduler", "jobs", "unfinished", "carbon_kg",
               "water_kl", "stress_water_kl", "carbon_savings_pct",
               "water_savings_pct", "violation_pct", "mean_service_ratio",
               "wall_s")
_CSV_COLS = _TABLE_COLS + ("stress_water_savings_pct", "p99_service_ratio",
                           "utilization", "mean_solve_ms", "moved_pct",
                           "forecast_mape", "mean_defer_s", "deferred_pct",
                           "spec")


def to_table(rows: Sequence[Dict], cols: Sequence[str] = _TABLE_COLS) -> str:
    """Fixed-width tidy table (one line per sweep cell)."""
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.2f}"
        return str(v)
    table = [[fmt(r.get(c, "")) for c in cols] for r in rows]
    widths = [max(len(c), *(len(t[i]) for t in table)) if table else len(c)
              for i, c in enumerate(cols)]
    lines = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for t in table:
        lines.append("  ".join(v.rjust(w) for v, w in zip(t, widths)))
    return "\n".join(lines)


def to_csv(rows: Sequence[Dict], path: str,
           cols: Sequence[str] = _CSV_COLS) -> None:
    """Write tidy rows as CSV. Uses the stdlib writer so the ``spec`` column
    — whose bracketed params contain commas — is quoted and every row stays
    re-parseable (``policy.parse(row["spec"])`` rebuilds the cell's
    scheduler)."""
    import csv
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(cols)
        for r in rows:
            w.writerow([r.get(c, "") for c in cols])
