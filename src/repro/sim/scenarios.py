"""Scenario registry + fleet-scale sweep runner.

A *scenario* is a named, deterministic composition of

  * a telemetry perturbation  (drought, grid decarbonization, …),
  * a trace generator          (Borg-like steady, Alibaba-like bursty, …),
  * a capacity profile         (static, or timed capacity events — outages),
  * an accounting view         (e.g. Wu et al.-style water-stress weighting).

The paper evaluates WaterWise under one telemetry regime; related work shows
conclusions move with the regime (Attenni et al. sweep spatio-temporal
shifting policies across regions/seasons; Wu et al. show water rankings flip
under water-stress weighting). This module makes those regimes first-class:
``sweep(schedulers, scenarios)`` runs the full cross product on the
event-driven engine — optionally fanned out across worker processes — and
returns one tidy row per (scenario, scheduler) cell. Schedulers are
declarative policy specs (``repro.policy``): strings like
``"waterwise-forecast[horizon_slots=8]"`` work anywhere, and every row's
``spec`` column re-parses to the exact policy that produced it.

Adding a scenario::

    @register("heatwave", "2-week heatwave: +8C wet-bulb everywhere")
    def _heatwave(days, seed, jobs_per_day, utilization):
        inst = _base(days, seed, jobs_per_day, utilization)
        return dataclasses.replace(
            inst, tele=scale_wue(inst.tele, 1.9), name="heatwave")

The builder must be deterministic in its arguments (property-tested).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import telemetry
from repro.core.problem import Job
from repro.sim.trace import (DAY, alibaba_trace, borg_trace,
                             scale_capacity_for_utilization)


@dataclasses.dataclass
class ScenarioInstance:
    """Everything one simulation run needs, fully materialized."""
    name: str
    tele: telemetry.Telemetry
    jobs: List[Job]
    capacity: np.ndarray
    capacity_events: List[Tuple[float, object]] = \
        dataclasses.field(default_factory=list)
    # Per-region weights applied to each record's water footprint when
    # reporting `stress_water_kl` (Wu et al.: liters in a water-stressed
    # basin are not interchangeable with liters in a wet one). None = 1.
    water_weight: Optional[np.ndarray] = None
    # Forecast-error regime (systematic over-/under-prediction × noise):
    # injected into forecast-driven schedulers by ``run_cell``. 1.0/0.0 = off.
    forecast_bias: float = 1.0
    forecast_noise: float = 0.0


#: Help strings for builder params surfaced through the ScenarioSpec
#: grammar (``repro.experiments``); the builder signatures stay the single
#: source of truth for names, types, and defaults.
_PARAM_HELP = {
    "trace": "trace generator (borg / alibaba)",
    "tolerance": "delay tolerance TOL (fraction of exec time of slack)",
    "ewif_table": "water-intensity dataset (macknick / wri)",
}


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    build: Callable[..., ScenarioInstance]

    @property
    def params(self):
        """Builder-specific typed params (beyond the shared cell params of
        ``repro.experiments.scenario.CELL_PARAMS``), introspected from the
        builder signature. Builders that forward ``**kw`` inherit
        ``_base``'s keyword params (``trace``, ``tolerance``,
        ``ewif_table``); non-spec-expressible arguments (``regions``) stay
        build-kwargs-only. Introspection keeps the documented defaults
        from ever drifting from the code."""
        from repro.spec import has_var_keyword, params_from_signature
        ps = params_from_signature(self.build, drop_positional=4,
                                   help_text=_PARAM_HELP)
        if has_var_keyword(self.build):
            seen = {p.name for p in ps}
            ps += [p for p in params_from_signature(_base, drop_positional=4,
                                                    help_text=_PARAM_HELP)
                   if p.name not in seen]
        return {p.name: p for p in ps}


_REGISTRY: Dict[str, Scenario] = {}


def register(name: str, description: str):
    """Decorator: register a scenario builder under ``name``."""
    def deco(fn):
        _REGISTRY[name] = Scenario(name=name, description=description,
                                   build=fn)
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        from repro.spec import unknown_name_error
        raise unknown_name_error("scenario", name, list(_REGISTRY))
    return _REGISTRY[name]


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


def describe(markdown: bool = False) -> str:
    """Human-readable scenario-registry dump (the ``--list-scenarios``
    surface and the source of the README scenario table). Lists each
    scenario's builder-specific params; the shared cell params (``days``,
    ``seed``, ``jobs_per_day``, ``utilization``, ``window_s``) apply to
    every scenario and are documented once by the experiments API."""
    entries = [_REGISTRY[n] for n in sorted(_REGISTRY)]
    if markdown:
        lines = ["| scenario | extra parameters | description |",
                 "|---|---|---|"]
        for e in entries:
            ps = ", ".join(f"`{p.describe()}`" for p in e.params.values()) \
                or "—"
            lines.append(f"| `{e.name}` | {ps} | {e.description} |")
        return "\n".join(lines)
    lines = []
    for e in entries:
        lines.append(f"{e.name:24s} {e.description}")
        for p in e.params.values():
            doc = f"  — {p.help}" if p.help else ""
            lines.append(f"    {p.describe():28s}{doc}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Telemetry perturbations (pure: Telemetry -> new Telemetry)
# ---------------------------------------------------------------------------

def scale_wue(tele: telemetry.Telemetry, factor: float) -> telemetry.Telemetry:
    return dataclasses.replace(tele, wue=tele.wue * factor)


def raise_wsf(tele: telemetry.Telemetry, gain: float = 1.5,
              floor: float = 0.1) -> telemetry.Telemetry:
    return dataclasses.replace(
        tele, wsf=np.minimum(tele.wsf * gain + floor, 1.0))


def decarbonize(tele: telemetry.Telemetry, regions: Sequence[int],
                onset_frac: float = 0.4, final_scale: float = 0.55,
                horizon_hours: Optional[float] = None) -> telemetry.Telemetry:
    """Grid-decarbonization event: carbon intensity in ``regions`` ramps
    linearly from 1.0× down to ``final_scale``× starting at ``onset_frac``
    of the *simulated* horizon (coal retirement / renewables buildout).

    ``horizon_hours`` is the simulated span; telemetry is generated with
    headroom beyond it (whole days + 1), so anchoring the ramp to the raw
    array length would push the event past the end of short simulations.
    Hours beyond the horizon hold at ``final_scale``."""
    T = tele.num_hours
    H = min(float(horizon_hours) if horizon_hours is not None else T, T)
    onset = int(H * onset_frac)
    end = min(int(np.ceil(H)), T)
    ramp = np.ones(T)
    if onset < end:
        ramp[onset:end] = np.linspace(1.0, final_scale, end - onset)
    ramp[end:] = final_scale
    ci = tele.ci.copy()
    for r in regions:
        ci[:, r] = ci[:, r] * ramp
    return dataclasses.replace(tele, ci=ci)


# ---------------------------------------------------------------------------
# Built-in scenarios
# ---------------------------------------------------------------------------

def _base(days: float, seed: int, jobs_per_day: float, utilization: float,
          *, trace: str = "borg", tolerance: float = 0.5,
          ewif_table: str = "macknick",
          regions: Optional[Sequence] = None) -> ScenarioInstance:
    tele = telemetry.generate(days=max(int(np.ceil(days)) + 1, 2), seed=seed,
                              ewif_table=ewif_table,
                              regions=regions or tuple(telemetry.REGIONS))
    if trace == "borg":
        jobs = borg_trace(days=days, seed=seed, tolerance=tolerance,
                          num_regions=tele.num_regions,
                          target_jobs_per_day=jobs_per_day)
    else:
        # Alibaba keeps its 8.5× burst shape; the multiplier rescales the
        # absolute rate to the requested jobs/day.
        mult = jobs_per_day / (8.5 * 23000.0)
        jobs = alibaba_trace(days=days, seed=seed, tolerance=tolerance,
                             num_regions=tele.num_regions,
                             rate_multiplier=mult)
    cap = scale_capacity_for_utilization(jobs, days, tele.num_regions,
                                         utilization)
    return ScenarioInstance(name="nominal", tele=tele, jobs=jobs,
                            capacity=cap)


@register("nominal", "Borg-like steady trace, unperturbed telemetry")
def _nominal(days, seed, jobs_per_day, utilization, **kw):
    return _base(days, seed, jobs_per_day, utilization, **kw)


@register("diurnal",
          "alias of 'nominal': Borg-like diurnally modulated steady trace, "
          "unperturbed telemetry (the sharding examples' canonical cell)")
def _diurnal(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    return dataclasses.replace(inst, name="diurnal")


@register("drought-summer",
          "Heatwave + drought: cooling WUE +45%, scarcity factors elevated")
def _drought(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    tele = raise_wsf(scale_wue(inst.tele, 1.45), gain=1.4, floor=0.1)
    return dataclasses.replace(inst, name="drought-summer", tele=tele)


@register("decarbonization",
          "Grid-decarbonization event: dirtiest two grids ramp CI to 0.55x "
          "from 40% of the horizon")
def _decarb(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    dirty = list(np.argsort(inst.tele.ci.mean(axis=0))[-2:])
    tele = decarbonize(inst.tele, dirty, horizon_hours=days * 24.0)
    return dataclasses.replace(inst, name="decarbonization", tele=tele)


@register("capacity-loss",
          "Region outage: the greenest region loses all of its servers for "
          "the middle ~15% of the horizon")
def _outage(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    green = int(np.argmin(inst.tele.ci.mean(axis=0)))
    degraded = inst.capacity.copy()
    degraded[green] = 0
    t0, t1 = 0.40 * days * DAY, 0.55 * days * DAY
    events = [(t0, degraded), (t1, inst.capacity.copy())]
    return dataclasses.replace(inst, name="capacity-loss",
                               capacity_events=events)


@register("burst-storm",
          "Alibaba-style burst storm: bursty short-job trace at 25% target "
          "utilization")
def _burst(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, max(utilization, 0.25),
                 trace="alibaba", **kw)
    return dataclasses.replace(inst, name="burst-storm")


@register("water-stress-weighted",
          "Wu et al. accounting: identical physics, but reported water is "
          "weighted by regional scarcity")
def _stress_weighted(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    # Liters weighted by (1 + WSF)^2 relative to fleet mean: water spent in
    # Madrid/Mumbai counts for more than water spent in Zurich.
    w = (1.0 + inst.tele.wsf) ** 2
    w = w / w.mean()
    return dataclasses.replace(inst, name="water-stress-weighted",
                               water_weight=w)


@register("forecast-error",
          "Nominal physics, but forecast-driven schedulers see a +30% biased "
          "and 15%-noisy forecast (systematic over-prediction)")
def _forecast_error(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    return dataclasses.replace(inst, name="forecast-error",
                               forecast_bias=1.30, forecast_noise=0.15)


def heat_derate_events(tele: telemetry.Telemetry, days: float,
                       frac: float = 0.7, wb_quantile: float = 0.85
                       ) -> List[Tuple[float, object]]:
    """Capacity events derived from the telemetry's wet-bulb extremes.

    The fleet-mean wet-bulb series (``Telemetry.wb_c`` — the raw weather;
    WUE itself clips at its physical floor and hides the extremes) locates
    the heat peak: the longest contiguous run of hours above the
    ``wb_quantile`` quantile becomes a relative derate. Regions whose own
    wet-bulb during that window exceeds their horizon median are scaled to
    ``frac`` of base capacity (cooling-limited); the rest keep full
    capacity — no fixed outage window, no absolute vectors.
    """
    wb = tele.wb_c if tele.wb_c is not None else tele.wue
    H = max(int(days * 24), 1)
    fleet = wb[:H].mean(axis=1)
    thresh = np.quantile(fleet, wb_quantile)
    hot = fleet >= thresh
    if not hot.any() or hot.all():
        return []
    # Longest contiguous hot run.
    best, cur, best_span = 0, 0, (0, 0)
    for h, flag in enumerate(hot):
        if flag:
            cur += 1
            if cur > best:
                best, best_span = cur, (h - cur + 1, h + 1)
        else:
            cur = 0
    h0, h1 = best_span
    med = np.median(wb[:H], axis=0)
    peak_wb = wb[h0:h1].mean(axis=0)
    fracs = np.where(peak_wb > med, frac, 1.0)
    return [(h0 * 3600.0, ("scale", fracs)),
            (h1 * 3600.0, ("scale", np.ones(tele.num_regions)))]


@register("heat-derate",
          "Wet-bulb-extreme derate: during the hottest contiguous hours, "
          "cooling-limited regions drop to 70% capacity (relative profile "
          "derived from telemetry, not fixed fractions)")
def _heat_derate(days, seed, jobs_per_day, utilization, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    events = heat_derate_events(inst.tele, days)
    return dataclasses.replace(inst, name="heat-derate",
                               capacity_events=events)


@register("regime-shift",
          "Telemetry regime shift: mid-trace step change flips the CI "
          "ranking (cleanest grid x2.2, dirtiest /2.2) and raises the "
          "shifted region's WUE — commit-at-admission plans go stale, "
          "receding-horizon re-planning wins")
def _regime_shift(days, seed, jobs_per_day, utilization, *,
                  onset_frac: float = 0.5, ci_flip: float = 2.2,
                  wue_step: float = 1.35, **kw):
    inst = _base(days, seed, jobs_per_day, utilization, **kw)
    tele = inst.tele
    onset = int(days * 24.0 * onset_frac)
    # The step persists through the simulated horizon (plus the pricing
    # lookahead) but NOT through the rest of the telemetry array: warm-start
    # forecaster archives are the array's cyclic extension, so a step that
    # ran to the end of the array would dominate the wrapped history and the
    # forecaster would "know" the shift before it happens — exactly the
    # staleness this scenario exists to create. Keeping the tail unshifted
    # keeps the shift unforecastable.
    end = min(int(np.ceil(days * 24.0)) + 8, tele.num_hours)
    green = int(np.argmin(tele.ci.mean(axis=0)))
    dirty = int(np.argmax(tele.ci.mean(axis=0)))
    ci = tele.ci.copy()
    wue = tele.wue.copy()
    ci[onset:end, green] *= ci_flip
    ci[onset:end, dirty] /= ci_flip
    wue[onset:end, green] *= wue_step
    # Telemetry memoizes cumulative integrals (_cum_cache) — never mutate
    # in place; replace() builds a fresh instance with fresh caches.
    tele = dataclasses.replace(tele, ci=ci, wue=wue)
    return dataclasses.replace(inst, name="regime-shift", tele=tele)


# Average tasks per workflow under ``repro.workflows.generators.TEMPLATES``
# (chain/fanout/diamond/montage mix) — converts the shared ``jobs_per_day``
# cell param (which counts *tasks*, like every other scenario) into the
# generator's workflow arrival rate.
_TASKS_PER_WORKFLOW = 6.7


def _workflow_base(days, seed, jobs_per_day, utilization, *,
                   tolerance: float = 0.5, ewif_table: str = "macknick",
                   burst: float = 0.0, name: str = "workflow-diurnal"
                   ) -> ScenarioInstance:
    from repro.workflows import generators
    tele = telemetry.generate(days=max(int(np.ceil(days)) + 1, 2), seed=seed,
                              ewif_table=ewif_table)
    jobs = generators.workflow_trace(
        days=days, seed=seed, num_regions=tele.num_regions,
        tolerance=tolerance,
        workflows_per_day=jobs_per_day / _TASKS_PER_WORKFLOW, burst=burst)
    cap = scale_capacity_for_utilization(jobs, days, tele.num_regions,
                                         utilization)
    return ScenarioInstance(name=name, tele=tele, jobs=jobs, capacity=cap)


@register("workflow-diurnal",
          "Precedence-constrained DAG trace (chain/fan-out/diamond/Montage "
          "mix) with diurnal arrivals; jobs_per_day counts tasks")
def _workflow_diurnal(days, seed, jobs_per_day, utilization, *,
                      tolerance: float = 0.5, ewif_table: str = "macknick"):
    return _workflow_base(days, seed, jobs_per_day, utilization,
                          tolerance=tolerance, ewif_table=ewif_table,
                          name="workflow-diurnal")


@register("workflow-burst",
          "DAG trace with burst-train arrivals (Alibaba-like hot windows): "
          "whole workflows co-arrive, stressing precedence release under "
          "queue pressure")
def _workflow_burst(days, seed, jobs_per_day, utilization, *,
                    tolerance: float = 0.5, ewif_table: str = "macknick",
                    burst: float = 0.5):
    return _workflow_base(days, seed, jobs_per_day, utilization,
                          tolerance=tolerance, ewif_table=ewif_table,
                          burst=burst, name="workflow-burst")


def register_csv_scenario(name: str, path: str, *,
                          column_map: Optional[Dict] = None,
                          unit_scale: Optional[Dict] = None,
                          description: str = "") -> Scenario:
    """Register a scenario whose trace is a real CSV slice.

    The builder drops cell-for-cell into the sweep: the CSV replaces the
    synthetic generator (column mapping + deterministic arrival-rate
    thinning to the cell's ``jobs_per_day``), while telemetry, capacity
    scaling, and accounting views stay identical to ``nominal``. Home
    regions are folded modulo the region count.
    """
    from repro.sim.trace import load_csv, rescale_arrival_rate

    def build(days, seed, jobs_per_day, utilization, *, tolerance=0.5):
        tele = telemetry.generate(days=max(int(np.ceil(days)) + 1, 2),
                                  seed=seed)
        jobs = load_csv(path, tolerance=tolerance, column_map=column_map,
                        unit_scale=unit_scale)
        jobs = [j for j in jobs if j.submit_time_s < days * DAY]
        for j in jobs:
            j.home_region = j.home_region % tele.num_regions
        jobs = rescale_arrival_rate(jobs, days, jobs_per_day, seed=seed)
        for i, j in enumerate(jobs):
            j.job_id = i
        cap = scale_capacity_for_utilization(jobs, days, tele.num_regions,
                                             utilization)
        return ScenarioInstance(name=name, tele=tele, jobs=jobs,
                                capacity=cap)

    register(name, description or f"real trace from {path}")(build)
    return _REGISTRY[name]


# ---------------------------------------------------------------------------
# Sweep runner — thin shims over the declarative experiment API
# ---------------------------------------------------------------------------
# The cell/sweep machinery lives in ``repro.experiments`` now: scenarios are
# addressed by ScenarioSpec strings ("diurnal[days=10,jobs_per_day=1e6]"),
# grids by ExperimentPlan, and execution by interchangeable backends
# (serial / process / sharded). These shims keep the established kwargs
# surface working and produce identical rows.


def run_cell(scenario: str, scheduler, *, days: float = 0.2,
             seed: int = 0, jobs_per_day: float = 23000.0,
             utilization: float = 0.15, window_s: float = 30.0,
             tolerance: Optional[float] = None,
             sched_kwargs: Optional[Dict] = None,
             build_kwargs: Optional[Dict] = None,
             return_result: bool = False) -> Dict:
    """Build one scenario instance, run one scheduler through it, and return
    a tidy result row (shim over ``repro.experiments.run_cell``).

    ``scheduler`` is a policy spec — a ``repro.policy.PolicySpec`` or its
    string form (``"waterwise[lam_h2o=0.7,backend=jax]"``). ``sched_kwargs``
    are merged into the spec as validated overrides: unknown or ill-typed
    params raise with a did-you-mean message for *every* policy (nothing is
    silently dropped). The row's ``spec`` column is the fully resolved spec
    string and its ``scenario_spec`` column the fully resolved scenario
    spec — re-parsing either reproduces the cell exactly.

    ``tolerance`` overrides the builders' default delay tolerance and
    ``build_kwargs`` forwards further builder kwargs: spec-expressible ones
    (``trace``, ``ewif_table``, ...) fold into the scenario spec; the rest
    (``regions`` objects) stay in-process extras. ``return_result=True``
    attaches the raw engine result dict as ``row["_result"]`` (in-process
    use only; never serialized into sweep CSVs).
    """
    from repro import experiments, policy

    spec = policy.as_spec(scheduler)
    if sched_kwargs:
        spec = spec.with_params(**sched_kwargs)
    params = dict(days=days, seed=seed, jobs_per_day=jobs_per_day,
                  utilization=utilization, window_s=window_s)
    if tolerance is not None:
        params["tolerance"] = tolerance
    from repro.spec import SPEC_TYPES
    schema = experiments.scenario_schema(scenario)
    extra = {}
    for k, v in (build_kwargs or {}).items():
        if k in schema and k not in params and type(v) in SPEC_TYPES:
            params[k] = v
        else:
            extra[k] = v
    cell = experiments.Cell(
        experiments.make_scenario_spec(scenario, **params), spec)
    return experiments.run_cell(cell, extra_build_kwargs=extra or None,
                                return_result=return_result)


def sweep(schedulers: Sequence, scenarios: Optional[Sequence[str]] = None,
          *, days: float = 0.2, seed: int = 0,
          jobs_per_day: float = 23000.0, utilization: float = 0.15,
          window_s: float = 30.0, tolerance: Optional[float] = None,
          sched_kwargs: Optional[Dict] = None,
          max_workers: Optional[int] = None,
          executor: Optional[str] = None) -> List[Dict]:
    """Run the schedulers × scenarios cross product; one tidy row per cell
    (shim over ``repro.experiments.ExperimentPlan``).

    ``schedulers`` are policy specs and ``scenarios`` scenario names —
    validated up front so a typo'd name or param fails before any cell
    runs. ``executor`` picks the backend (``"serial"``, ``"process"``,
    ``"sharded[shards=4]"``); by default cells fan out over worker
    processes capped at ``max_workers`` (serial and parallel sweeps
    produce identical rows). Within each scenario, savings percentages are
    attached relative to the ``baseline`` scheduler when it is part of the
    sweep.

    A crashed cell no longer aborts the sweep: every other cell finishes,
    the failed cell's row records the failure in its ``error`` column, and
    a ``repro.experiments.CellError`` naming the failing (scenario, spec)
    pair is raised at the end with all rows attached as ``err.rows``.
    """
    from repro import experiments, policy

    names = list(scenarios) if scenarios is not None else list_scenarios()
    specs = []
    for s in schedulers:
        sp = policy.as_spec(s)                       # fail fast on typos
        if sched_kwargs:
            sp = sp.with_params(**sched_kwargs)
        specs.append(sp)
    params = dict(days=days, seed=seed, jobs_per_day=jobs_per_day,
                  utilization=utilization, window_s=window_s)
    if tolerance is not None:
        params["tolerance"] = tolerance
    scen_specs = [experiments.make_scenario_spec(n, **params) for n in names]
    plan = experiments.ExperimentPlan(tuple(scen_specs), tuple(specs))
    n_cells = len(scen_specs) * len(specs)
    if executor is None:
        if max_workers is None:
            max_workers = min(os.cpu_count() or 1, n_cells)
        executor = "process" if (max_workers > 1 and n_cells > 1) \
            else "serial"
    options = {}
    if executor.startswith("process") and max_workers is not None:
        options["max_workers"] = max_workers
    return plan.run(executor=executor, strict=True, **options)


def to_table(rows: Sequence[Dict], cols: Optional[Sequence[str]] = None
             ) -> str:
    """Fixed-width tidy table (shim over ``repro.experiments.to_table``)."""
    from repro import experiments
    return experiments.to_table(rows, cols or experiments.TABLE_COLS)


def to_csv(rows: Sequence[Dict], path: str,
           cols: Optional[Sequence[str]] = None) -> None:
    """Write tidy rows as CSV (shim over ``repro.experiments.to_csv``)."""
    from repro import experiments
    experiments.to_csv(rows, path, cols or experiments.CSV_COLS)
