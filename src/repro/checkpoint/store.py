"""Sharded checkpointing with atomic commit and elastic resharding restore.

Format: one .npz per checkpoint (flattened tree paths → arrays) plus a JSON
manifest. Commit is atomic (write to .tmp dir, fsync, rename) so a failure
mid-write never corrupts the latest checkpoint. ``restore_checkpoint``
re-device_puts every leaf with the *target* sharding — which may belong to a
different mesh shape than the one that saved it (elastic resharding: this is
simultaneously failure recovery and WaterWise's migration mechanism; the
checkpoint bytes are exactly the L[m,n] transfer payload).

``AsyncCheckpointer`` commits in a background thread (training never blocks
on disk) with at-most-one in flight.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def checkpoint_bytes(tree) -> int:
    """Size of the movable state — feeds Job.package_bytes in the scheduler."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def save_checkpoint(directory: str, step: int, tree, extra: Optional[Dict]
                    = None) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp-{step}")
    final = os.path.join(directory, f"step-{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "state.npz"), **flat)
    manifest = dict(step=step, leaves=len(flat),
                    bytes=int(sum(v.nbytes for v in flat.values())),
                    **(extra or {}))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(directory)
             if d.startswith("step-")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree,
                       shardings=None) -> Any:
    """Restore into ``target_tree``'s structure; ``shardings`` (same
    structure) reshards every leaf onto the current mesh — the saved and
    restoring meshes may differ (elastic restore)."""
    path = os.path.join(directory, f"step-{step}", "state.npz")
    data = np.load(path)
    flat_paths = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for (p, leaf) in flat_paths[0]:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    restored = jax.tree_util.tree_unflatten(
        jax.tree.structure(target_tree), leaves)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored


class AsyncCheckpointer:
    def __init__(self, directory: str, every: int = 50):
        self.directory = directory
        self.every = every
        self._thread: Optional[threading.Thread] = None
        self.saved_steps = []

    def maybe_save(self, step: int, tree, extra=None) -> bool:
        if step % self.every:
            return False
        self.wait()                       # at most one in flight
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
