"""Shared declarative-spec grammar: ``name[key=value,key=value]``.

One grammar, many registries. A *spec* is data — a registered name plus a
dict of explicitly overridden, typed parameters — whose textual form
round-trips exactly (``parse(str(spec)) == spec``), so a spec survives CSV
rows, CLI flags, JSON plans, and worker-process boundaries unchanged.

PR 3 introduced the grammar for scheduling policies
(``"waterwise[lam_h2o=0.7,backend=jax]"``); this module is the extraction
that lets *scenarios* (``"diurnal[days=10,jobs_per_day=1e6]"``) and
*executors* (``"sharded[shards=4]"``) speak the same language. Registries
(``repro.policy.registry``, ``repro.experiments.scenario``,
``repro.experiments.executor``) supply the per-name parameter schemas; this
module owns the syntax, the type coercion, and the did-you-mean error
surface.

Grammar (whitespace around tokens is ignored)::

    spec    :=  name [ '[' params ']' ]
    name    :=  [A-Za-z0-9._-]+
    params  :=  kv ( ',' kv )*  |  <empty>
    kv      :=  key '=' value
    key     :=  [A-Za-z0-9_]+
    value   :=  any run of characters except ',' ']' '='

Values are typed against the registered schema, not guessed from their
spelling: ``backend=jax`` stays a string because ``backend`` is declared
``str``, ``lam_h2o=0.7`` becomes a float because ``lam_h2o`` is declared
``float``. Formatting uses ``repr`` for floats, so parse∘format is exact
(floats round-trip bit-for-bit through ``repr``/``float``).
"""
from __future__ import annotations

import dataclasses
import difflib
import re
from typing import Dict, List, Mapping, Optional, Sequence, Tuple


class SpecError(ValueError):
    """Base class for every spec-grammar / registry error."""


class SpecSyntaxError(SpecError):
    """Malformed spec string (bad brackets, missing '=', empty key...)."""


class UnknownNameError(SpecError, KeyError):
    """Spec names something that is not registered (KeyError for backward
    compatibility with plain dict-lookup call sites)."""

    def __str__(self) -> str:        # KeyError would repr() the message
        return self.args[0] if self.args else ""


class UnknownParamError(SpecError):
    """Spec carries a parameter the registered entry does not declare."""


class ParamValueError(SpecError):
    """Parameter value cannot be coerced to its declared type."""


_NAME_RE = re.compile(r"^[A-Za-z0-9._-]+$")
_KEY_RE = re.compile(r"^[A-Za-z0-9_]+$")

#: Parameter types the grammar can express (and round-trip exactly).
SPEC_TYPES = (bool, int, float, str)


@dataclasses.dataclass(frozen=True)
class Spec:
    """A registered name + explicit typed params, as data.

    ``params`` holds only the *overridden* parameters — defaults stay with
    the registry entry, so ``str(spec)`` is terse and two specs compare
    equal exactly when they describe identically configured objects.
    Registries subclass this (``PolicySpec``, ``ScenarioSpec``) to attach
    their validation hooks; the textual form is shared.
    """

    name: str
    params: Mapping[str, object] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "params", dict(self.params))

    def format(self) -> str:
        """Canonical string form (sorted params; omits brackets when empty)."""
        if not self.params:
            return self.name
        kv = ",".join(f"{k}={format_value(self.params[k])}"
                      for k in sorted(self.params))
        return f"{self.name}[{kv}]"

    def __str__(self) -> str:
        return self.format()


@dataclasses.dataclass(frozen=True)
class Param:
    """One typed, documented spec parameter (the default lives here purely
    as documentation — the builder's own signature stays the source of
    truth, and builders receive only explicitly overridden keys)."""
    name: str
    type: type
    default: object
    help: str = ""

    def describe(self) -> str:
        return (f"{self.name}={format_value(self.default)}"
                f":{self.type.__name__}")


def format_value(v: object) -> str:
    """Render one param value so that type-directed parsing recovers it."""
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, float):
        return repr(v)               # repr round-trips floats exactly
    return str(v)


def coerce_value(raw: object, typ: type, *, owner: str, key: str) -> object:
    """Coerce ``raw`` (a grammar string or an already-typed Python value) to
    the declared param type, raising ``ParamValueError`` on mismatch.

    ``owner`` names the registry entry for the error message, e.g.
    ``"policy 'waterwise'"`` or ``"scenario 'diurnal'"``.
    """

    def bad(expected: str):
        return ParamValueError(
            f"{owner}: parameter {key!r} expects {expected}, got {raw!r}")

    if typ is bool:
        if isinstance(raw, bool):
            return raw
        if isinstance(raw, (int, float)) and raw in (0, 1):
            return bool(raw)
        if isinstance(raw, str):
            low = raw.strip().lower()
            if low in ("true", "1", "yes", "on"):
                return True
            if low in ("false", "0", "no", "off"):
                return False
        raise bad("a bool (true/false)")
    if typ is int:
        if isinstance(raw, bool):
            raise bad("an int")
        if isinstance(raw, int):
            return raw
        if isinstance(raw, float) and raw == int(raw):
            return int(raw)
        if isinstance(raw, str):
            try:
                return int(raw.strip())
            except ValueError:
                raise bad("an int") from None
        raise bad("an int")
    if typ is float:
        if isinstance(raw, bool):
            raise bad("a float")
        if isinstance(raw, (int, float)):
            return float(raw)
        if isinstance(raw, str):
            try:
                return float(raw.strip())
            except ValueError:
                raise bad("a float") from None
        raise bad("a float")
    if typ is str:
        if isinstance(raw, str):
            return raw
        raise bad("a string")
    raise ParamValueError(f"{owner}: parameter {key!r} declares "
                          f"unsupported type {typ!r}")


def parse_raw(text: str, kind: str = "spec") -> Tuple[str, Dict[str, str]]:
    """Syntax-level parse: ``text`` -> (name, raw string params).

    Validates the grammar only; the registry layer types the values and
    checks the keys against the entry's schema. ``kind`` labels the error
    messages (``"policy"``, ``"scenario"``, ``"executor"``).
    """
    label = f"{kind} spec" if kind != "spec" else "spec"
    if not isinstance(text, str):
        raise SpecSyntaxError(f"{label} must be a string, got {text!r}")
    s = text.strip()
    if "[" not in s:
        name, body = s, None
    else:
        name, _, rest = s.partition("[")
        if not rest.endswith("]"):
            raise SpecSyntaxError(f"unterminated '[' in {label} {text!r}")
        body = rest[:-1]
        if "[" in body or "]" in body:
            raise SpecSyntaxError(f"nested brackets in {label} {text!r}")
    name = name.strip()
    if not _NAME_RE.match(name):
        raise SpecSyntaxError(f"invalid {kind} name in spec {text!r}")
    params: Dict[str, str] = {}
    if body is not None and body.strip():
        for item in body.split(","):
            key, eq, value = item.partition("=")
            key, value = key.strip(), value.strip()
            if not eq:
                raise SpecSyntaxError(
                    f"expected key=value, got {item.strip()!r} in {text!r}")
            if not _KEY_RE.match(key):
                raise SpecSyntaxError(f"invalid parameter key {key!r} "
                                      f"in {text!r}")
            if not value:
                raise SpecSyntaxError(f"empty value for parameter {key!r} "
                                      f"in {text!r}")
            if key in params:
                raise SpecSyntaxError(f"duplicate parameter {key!r} "
                                      f"in {text!r}")
            params[key] = value
    return name, params


def split_specs(text: str) -> List[str]:
    """Split a comma-separated list of spec strings, honouring brackets:
    ``"a,b[x=1,y=2],c"`` -> ``["a", "b[x=1,y=2]", "c"]`` (the CLI
    list grammar shared by ``--schedulers`` and ``--scenarios``)."""
    out: List[str] = []
    depth, cur = 0, []
    for ch in text:
        if ch == "[":
            depth += 1
        elif ch == "]":
            depth = max(depth - 1, 0)
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    return [s.strip() for s in out if s.strip()]


# ---------------------------------------------------------------------------
# Registry-side helpers (shared did-you-mean surface)
# ---------------------------------------------------------------------------

def unknown_name_error(kind: str, name: str,
                       known: Sequence[str]) -> UnknownNameError:
    """``UnknownNameError`` with a did-you-mean hint against ``known``."""
    hint = difflib.get_close_matches(name, known, n=1)
    did = f" — did you mean {hint[0]!r}?" if hint else ""
    return UnknownNameError(
        f"unknown {kind} {name!r}{did} (have: {', '.join(sorted(known))})")


def unknown_param_error(kind: str, owner: str, key: str,
                        known: Sequence[str]) -> UnknownParamError:
    """``UnknownParamError`` with a did-you-mean hint against ``known``."""
    if not known:
        return UnknownParamError(
            f"{kind} {owner!r} accepts no parameters (got {key!r})")
    hint = difflib.get_close_matches(key, known, n=1)
    did = f" — did you mean {hint[0]!r}?" if hint else ""
    return UnknownParamError(
        f"unknown parameter {key!r} for {kind} {owner!r}{did} "
        f"(accepts: {', '.join(known)})")


def validate_params(kind: str, owner: str, schema: Mapping[str, Param],
                    raw: Mapping[str, object]) -> Dict[str, object]:
    """Type-check ``raw`` against ``schema``: unknown keys raise with a
    did-you-mean, values are coerced to their declared types. Returns the
    validated (typed) param dict — the one a ``Spec`` should carry."""
    out: Dict[str, object] = {}
    for key, value in raw.items():
        p = schema.get(key)
        if p is None:
            raise unknown_param_error(kind, owner, key, list(schema))
        out[key] = coerce_value(value, p.type,
                                owner=f"{kind} {owner!r}", key=key)
    return out


def params_from_signature(fn, *, skip: Sequence[str] = (),
                          drop_positional: int = 0,
                          help_text: Optional[Mapping[str, str]] = None
                          ) -> List[Param]:
    """Derive a ``Param`` list from a builder's signature.

    Takes every parameter with a default whose type is spec-expressible
    (``SPEC_TYPES``), skipping the first ``drop_positional`` positional
    arguments (e.g. a scenario builder's ``(days, seed, jobs_per_day,
    utilization)``) and anything in ``skip``. The signature stays the
    single source of truth — documented defaults can never drift from the
    code.
    """
    import inspect
    out: List[Param] = []
    helps = help_text or {}
    sig = inspect.signature(fn)
    for i, p in enumerate(sig.parameters.values()):
        if i < drop_positional or p.name in skip:
            continue
        if p.kind not in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                          inspect.Parameter.KEYWORD_ONLY):
            continue
        if p.default is inspect.Parameter.empty:
            continue
        if type(p.default) not in SPEC_TYPES:
            continue
        out.append(Param(p.name, type(p.default), p.default,
                         helps.get(p.name, "")))
    return out


def has_var_keyword(fn) -> bool:
    """True when ``fn`` forwards ``**kwargs`` (its schema should inherit
    the forwarding target's params)."""
    import inspect
    return any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in inspect.signature(fn).parameters.values())
