"""Pallas TPU kernels for the compute hot-spots.

Each kernel directory contains:
  <name>.py   pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py      jit'd public wrapper (interpret=True on CPU)
  ref.py      pure-jnp oracle the kernel is asserted against

Kernels: flash_attention (blocked online-softmax attention),
ssd_scan (Mamba-2 chunked SSD), rglru_scan (RG-LRU blocked recurrence),
sinkhorn (the WaterWise scheduler's entropic-OT inner loop).
"""
