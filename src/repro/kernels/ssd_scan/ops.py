"""Public wrapper for the SSD scan kernel (interpret fallback on CPU)."""
from __future__ import annotations

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
from repro.runtime.platform import on_tpu as _on_tpu


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=interpret)
