"""Public wrapper for the SSD scan kernel (interpret fallback on CPU)."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                           interpret=interpret)
