"""Oracle: the models/ssm.py chunked SSD (itself validated against a naive
sequential recurrence in tests/test_kernels.py)."""
from repro.models.ssm import ssd_chunked as ssd_ref


def ssd_naive(x, dt, A, Bm, Cm):
    """O(S·N·P) sequential recurrence — ground truth for tiny shapes."""
    import jax.numpy as jnp
    b, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    a = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))    # [b,S,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    state = jnp.zeros((b, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        state = (state * a[:, t, :, None, None]
                 + jnp.einsum("bhn,bhp->bhpn", Bf[:, t], xdt[:, t]))
        ys.append(jnp.einsum("bhn,bhpn->bhp", Cf[:, t], state))
    return jnp.stack(ys, axis=1).astype(x.dtype), state.astype(x.dtype)
