"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

Grid = (B, H, num_chunks); chunks are the innermost (sequential) grid axis,
so the [P, N] inter-chunk state lives in VMEM scratch and is passed from
chunk to chunk without ever touching HBM — the property that makes SSD
training bandwidth-light on TPU. Per chunk the kernel evaluates the dual
quadratic form on the MXU:

  y_intra = (tril(exp(segsum(a))) ⊙ (C Bᵀ)) · (x·dt)      [L,L]·[L,P]
  y_inter = exp(cumsum a) ⊙ (C · stateᵀ)                   [L,N]·[N,P]
  state'  = exp(Σa)·state + (B·decay_tail)ᵀ (x·dt)          [N,L]·[L,P]

VMEM per step (L=256, P=64, N=128): x,B,C tiles + L×L decay ≈ 0.6 MB f32.
All matmul dims are multiples of 64/128 — MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, st_ref, state_ref, *,
            L: int, P: int, N: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)               # [L, P]
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)       # [L]
    A = A_ref[0, 0]                                      # scalar
    Bm = B_ref[0, 0, 0].astype(jnp.float32)              # [L, N]
    Cm = C_ref[0, 0, 0].astype(jnp.float32)              # [L, N]

    a = dt * A                                           # [L] (negative)
    acs = jnp.cumsum(a)                                  # [L]
    xdt = x * dt[:, None]

    # Intra-chunk dual form.
    seg = acs[:, None] - acs[None, :]                    # segsum
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ()))) * Lmat
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())))

    # Inter-chunk contribution of the carried state [P, N].
    state = state_ref[...]
    y += jnp.exp(acs)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())))

    # State update.
    decay_tail = jnp.exp(acs[-1] - acs)                  # [L]
    state_ref[...] = (state * jnp.exp(acs[-1])
                      + jax.lax.dot_general(
                          xdt, Bm * decay_tail[:, None],
                          (((0,), (0,)), ((), ()))))     # [P, N]

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        st_ref[0, 0] = state_ref[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, Bm, Cm, *, chunk: int = 256,
                    interpret: bool = False):
    """x: [b,S,H,P]; dt: [b,S,H]; A: [H]; Bm/Cm: [b,S,G,N] (G divides H).
    Returns (y [b,S,H,P], state [b,H,P,N])."""
    b, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L

    # Layout: head-major so one grid cell sees one (b, h) stream.
    xh = x.transpose(0, 2, 1, 3).reshape(b, H, nc, L, P)
    dth = dt.transpose(0, 2, 1).reshape(b, H, nc, L, 1)
    Bh = jnp.repeat(Bm, rep, axis=2).transpose(0, 2, 1, 3).reshape(
        b, H, nc, L, N)
    Ch = jnp.repeat(Cm, rep, axis=2).transpose(0, 2, 1, 3).reshape(
        b, H, nc, L, N)
    Ah = jnp.broadcast_to(A[None], (b, H)).astype(jnp.float32)

    kernel = functools.partial(_kernel, L=L, P=P, N=N, nc=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ic: (ib, ih)),
            pl.BlockSpec((1, 1, 1, L, N), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, N), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, nc, L, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dth, Ah, Bh, Ch)
    return y.reshape(b, H, S, P).transpose(0, 2, 1, 3), st
