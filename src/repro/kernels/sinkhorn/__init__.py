from repro.kernels.sinkhorn.ops import sinkhorn_iteration
