"""Pure-jnp oracle for one fused Sinkhorn iteration (log-domain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sinkhorn_iteration_ref(C, f, g, log_a, log_b, eps):
    """One (f, g) update pair. C: [M, N]; f/log_a: [M]; g/log_b: [N]."""
    f_new = eps * (log_a - jax.nn.logsumexp((g[None, :] - C) / eps, axis=1))
    g_new = eps * (log_b - jax.nn.logsumexp((f_new[:, None] - C) / eps,
                                            axis=0))
    return f_new, g_new
