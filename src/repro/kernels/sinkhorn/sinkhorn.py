"""Pallas TPU kernel: one fused Sinkhorn iteration over the cost matrix.

The WaterWise MILP's TPU-native solver (DESIGN.md §4) runs log-domain
Sinkhorn on the [M jobs × N regions] cost matrix. M can reach tens of
thousands in a burst window (Alibaba trace: 8.5× Borg rate), N stays small
(regions). One iteration is

    f_i ← ε·(log aᵢ − LSE_j (g_j − C_ij)/ε)        (row update)
    g_j ← ε·(log b_j − LSE_i (f_i − C_ij)/ε)        (col update)

Fused single pass: grid over M row-blocks (sequential); each step computes
its f tile (row LSE over the in-VMEM [bm, N] cost tile) and accumulates the
column LSE online (running max + rescaled sum in scratch, flash-attention
style), finalizing g on the last block. C is streamed through VMEM exactly
once per iteration — the HBM-optimal schedule.

N is lane-padded to 128; padding columns are masked with −∞ contributions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(C_ref, g_ref, loga_ref, logb_ref, f_ref, gout_ref,
            m_ref, s_ref, *, eps: float, n_true: int, bm: int, nm: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    C = C_ref[...].astype(jnp.float32)                    # [bm, Np]
    Np = C.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, Np), 1)
    valid = lane < n_true

    # Row update: f tile from fixed g.
    z = jnp.where(valid, (g_ref[0] - C) / eps, NEG)
    zmax = z.max(axis=1)
    lse = zmax + jnp.log(jnp.sum(jnp.exp(z - zmax[:, None]), axis=1))
    f = eps * (loga_ref[0, :] - lse)
    f_ref[0, :] = f

    # Column accumulation: online LSE of (f_i − C_ij)/ε over all row blocks.
    w = jnp.where(valid, (f[:, None] - C) / eps, NEG)     # [bm, Np]
    m_prev = m_ref[0, :]
    m_new = jnp.maximum(m_prev, w.max(axis=0))
    s_ref[0, :] = (s_ref[0, :] * jnp.exp(m_prev - m_new)
                   + jnp.sum(jnp.exp(w - m_new[None, :]), axis=0))
    m_ref[0, :] = m_new

    @pl.when(i == nm - 1)
    def _finalize():
        lse_col = m_ref[0, :] + jnp.log(jnp.maximum(s_ref[0, :], 1e-30))
        gout_ref[0, :] = eps * (logb_ref[0, :] - lse_col)


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def sinkhorn_iteration_pallas(C, g, log_a, log_b, *, eps: float,
                              bm: int = 256, interpret: bool = False):
    """C: [M, N]; g/log_b: [N]; log_a: [M]. Returns (f [M], g_new [N])."""
    M, N = C.shape
    Np = 128 * ((N + 127) // 128)
    bm = min(bm, M)
    assert M % bm == 0, (M, bm)
    nm = M // bm
    Cp = jnp.pad(C.astype(jnp.float32), ((0, 0), (0, Np - N)),
                 constant_values=0.0)
    gp = jnp.pad(g.astype(jnp.float32), (0, Np - N), constant_values=NEG)
    lbp = jnp.pad(log_b.astype(jnp.float32), (0, Np - N),
                  constant_values=NEG)

    kernel = functools.partial(_kernel, eps=float(eps), n_true=N, bm=bm,
                               nm=nm)
    f, g_new = pl.pallas_call(
        kernel,
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, Np), lambda i: (i, 0)),
            pl.BlockSpec((1, Np), lambda i: (0, 0)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, Np), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, Np), lambda i: (0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((1, M), jnp.float32),
                   jax.ShapeDtypeStruct((1, Np), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, Np), jnp.float32),
                        pltpu.VMEM((1, Np), jnp.float32)],
        interpret=interpret,
    )(Cp, gp[None], log_a[None].astype(jnp.float32), lbp[None])
    return f[0], g_new[0, :N]
