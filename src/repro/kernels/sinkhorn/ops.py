"""Public wrapper for the fused Sinkhorn-iteration kernel."""
from __future__ import annotations

from repro.kernels.sinkhorn.sinkhorn import sinkhorn_iteration_pallas
from repro.runtime.platform import on_tpu as _on_tpu


def sinkhorn_iteration(C, f, g, log_a, log_b, eps, *, bm=256,
                       interpret=None):
    """One fused (f, g) Sinkhorn update. Drop-in for the jnp reference
    (the ``f`` argument is unused — the fused pass recomputes it from g —
    but kept for signature parity with ref.py)."""
    del f
    interpret = (not _on_tpu()) if interpret is None else interpret
    M = C.shape[0]
    bm = min(bm, M)
    while M % bm:
        bm //= 2
    return sinkhorn_iteration_pallas(C, g, log_a, log_b, eps=float(eps),
                                     bm=max(bm, 1), interpret=interpret)
