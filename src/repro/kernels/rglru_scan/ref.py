"""Oracle: naive sequential RG-LRU recurrence over precomputed gates."""
import jax.numpy as jnp


def rglru_ref(a, bx):
    """a, bx: [B, S, W] (decay / gated input). h_t = a_t·h_{t−1} + bx_t."""
    B, S, W = a.shape
    h = jnp.zeros((B, W), jnp.float32)
    ys = []
    for t in range(S):
        h = a[:, t].astype(jnp.float32) * h + bx[:, t].astype(jnp.float32)
        ys.append(h)
    return jnp.stack(ys, axis=1).astype(a.dtype)
