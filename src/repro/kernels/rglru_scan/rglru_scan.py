"""Pallas TPU kernel: RG-LRU blocked linear recurrence.

Grid = (B, num_chunks) with chunks sequential; the [1, W] hidden state
persists in VMEM scratch. Within a chunk the recurrence is evaluated by the
blocked two-pass form: for lane-width W the chunk does L sequential
vector FMAs (VPU), while the chunk-to-chunk handoff stays in VMEM — HBM
traffic is exactly one read of (a, bx) and one write of y.

The gate matmuls (W×W) stay outside (XLA already MXU-pipelines them);
this kernel owns the part XLA serializes badly: the length-S dependence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, y_ref, h_ref, *, L: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)          # [L, W]
    bx = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t][None, :] * h + bx[t][None, :]
        y_ref[0, t, :] = h[0].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, L, step, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_scan_pallas(a, bx, *, chunk: int = 128, interpret: bool = False):
    """a, bx: [B, S, W] → y [B, S, W] with y_t = a_t·y_{t−1} + bx_t."""
    B, S, W = a.shape
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    kernel = functools.partial(_kernel, L=L)
    return pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[pl.BlockSpec((1, L, W), lambda ib, ic: (ib, ic, 0)),
                  pl.BlockSpec((1, L, W), lambda ib, ic: (ib, ic, 0))],
        out_specs=pl.BlockSpec((1, L, W), lambda ib, ic: (ib, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        interpret=interpret,
    )(a, bx)
