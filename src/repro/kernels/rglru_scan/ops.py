"""Public wrapper for the RG-LRU scan kernel (interpret fallback on CPU).

The kernel entry carries a custom VJP, so learned-forecaster *training* can
run through the Pallas kernel too (``scan_impl="pallas"``) instead of
silently requiring the associative scan. For the linear recurrence

    y_t = a_t · y_{t−1} + b_t,          y_{−1} = 0

the reverse-mode cotangents satisfy the *reverse* linear recurrence

    ğ_t = ȳ_t + a_{t+1} · ğ_{t+1},      ğ_S = 0
    ∂a_t = ğ_t · y_{t−1},               ∂b_t = ğ_t

which is the same recurrence on time-reversed inputs with the gates shifted
by one step — so the backward pass is one more call of the forward kernel
(flip → scan → flip), keeping training HBM-optimal as well. Gradient parity
against the associative scan is pinned in tests/test_round.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas
from repro.runtime.platform import on_tpu as _on_tpu


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _scan(a, bx, chunk, interpret):
    return rglru_scan_pallas(a, bx, chunk=chunk, interpret=interpret)


def _scan_fwd(a, bx, chunk, interpret):
    y = rglru_scan_pallas(a, bx, chunk=chunk, interpret=interpret)
    return y, (a, y)


def _scan_bwd(chunk, interpret, residuals, gy):
    a, y = residuals
    # ğ_t = ȳ_t + a_{t+1}·ğ_{t+1} run as a forward scan on reversed time:
    # gates become flip(a) delayed one step (the final gate never enters).
    a_shift = jnp.concatenate(
        [jnp.zeros_like(a[:, :1]), jnp.flip(a, axis=1)[:, :-1]], axis=1)
    gt = jnp.flip(
        rglru_scan_pallas(a_shift, jnp.flip(gy, axis=1), chunk=chunk,
                          interpret=interpret), axis=1)
    y_prev = jnp.concatenate(
        [jnp.zeros_like(y[:, :1]), y[:, :-1]], axis=1)
    return gt * y_prev, gt


_scan.defvjp(_scan_fwd, _scan_bwd)


def rglru_scan(a, bx, *, chunk=128, interpret=None):
    """a, bx: [B, S, W] → y with y_t = a_t·y_{t−1} + bx_t. Differentiable:
    both the forward and the backward pass run the Pallas kernel."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _scan(a, bx, chunk, interpret)
