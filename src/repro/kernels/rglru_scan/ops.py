"""Public wrapper for the RG-LRU scan kernel (interpret fallback on CPU)."""
from __future__ import annotations

import jax

from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def rglru_scan(a, bx, *, chunk=128, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return rglru_scan_pallas(a, bx, chunk=chunk, interpret=interpret)
