"""Pallas TPU flash attention: blocked online-softmax, causal / sliding.

Grid = (BH, num_q_blocks, num_kv_blocks); the KV axis is the innermost
(sequential) grid dimension, so the f32 accumulator / running-max / running-
sum scratch persists across KV blocks for a fixed (head, q-block) — the
classic TPU formulation. Per-step VMEM footprint:

  q tile  (bq, D)    bf16      k/v tiles (bk, D) bf16
  acc     (bq, D)    f32       m, l      (bq, 128) f32 (lane-padded)

with bq = bk = 512, D = 128: ~0.9 MB — far under the ~128 MB v5e VMEM, and
the (bq, bk) = (512, 512) MXU matmuls are 128-aligned in every dimension.

GQA is expressed in the BlockSpec index maps: the K/V arrays carry kv heads
only; q head ``h`` reads kv head ``h // group``, so grouped queries never
materialize repeated KV in HBM (what ``jnp.repeat`` would do).

Causal/sliding skipping is tile-level: blocks entirely above the diagonal
(or beyond the window) are skipped via pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_first = iq * bq                       # first query position this tile
    k_first = ik * bk
    # Whole-tile liveness (any in-range (q, k) pair?).
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_first <= q_first + bq - 1)
    if window:
        live = jnp.logical_and(live, q_first - (k_first + bk - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        qp = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            valid &= kp <= qp
        if window:
            valid &= qp - kp < window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
        m_ref[:, 0] = m_new
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ()))))

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "group",
                                             "interpret"))
def flash_attention_bh(q, k, v, *, causal=True, window=0, scale=None,
                       bq=512, bk=512, group=1, interpret=False):
    """q: [BHq, Sq, D]; k, v: [BHkv, Skv, D] with BHq = BHkv · group.
    Returns [BHq, Sq, D]. Head ``h`` attends kv head ``h // group``."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = float(scale if scale is not None else 1.0 / np.sqrt(D))

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=int(window), bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, iq, ik: (bh // group, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, iq, ik: (bh // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),     # acc
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lane-padded)
            pltpu.VMEM((bq, 128), jnp.float32),   # running sum
        ],
        interpret=interpret,
    )(q, k, v)
