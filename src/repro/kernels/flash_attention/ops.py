"""Public wrapper: model-layout in, kernel-layout dispatch, CPU fallback.

``flash_attention`` takes the model's [B, S, Kh, G, D] / [B, S, Kh, D]
layout (models/attention.py), flattens heads into the kernel's BH axis, and
runs the Pallas kernel — interpret=True when no TPU is present, so the same
code path is correct (if not fast) everywhere.
"""
from __future__ import annotations

from repro.kernels.flash_attention.flash_attention import flash_attention_bh
from repro.runtime.platform import on_tpu as _on_tpu


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    bq=512, bk=512, interpret=None):
    """q: [B, Sq, Kh, G, D]; k, v: [B, Skv, Kh, D] → [B, Sq, Kh, G, D]."""
    B, Sq, Kh, G, D = q.shape
    Skv = k.shape[1]
    interpret = (not _on_tpu()) if interpret is None else interpret
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * Kh * G, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kh, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kh, Skv, D)
    o = flash_attention_bh(qf, kf, vf, causal=causal, window=window,
                           scale=scale, bq=bq, bk=bk, group=G,
                           interpret=interpret)
    return o.reshape(B, Kh, G, Sq, D).transpose(0, 3, 1, 2, 4)
