"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: [BH, Sq, D]; k, v: [BH, Skv, D] (kv heads already expanded).
    fp32 reference softmax attention."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    valid = jnp.ones((Sq, Skv), bool)
    if causal:
        valid &= kp <= qp
    if window:
        valid &= qp - kp < window
    s = jnp.where(valid[None], s, -2e38)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
