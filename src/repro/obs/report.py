"""Reporting CLI for obs traces.

Usage::

    python -m repro.obs.report run.trace.jsonl            # per-stage table
    python -m repro.obs.report run.trace.jsonl --json     # machine-readable
    python -m repro.obs.report --validate run.trace.jsonl # schema check
    python -m repro.obs.report --diff a.trace.jsonl b.trace.jsonl

The per-stage table gives count / total / p50 / p95 / p99 / max wall
time per span name, plus mean Sinkhorn iteration count and final
residual for solver spans that carry them as args.  If the trace holds
simulated-time counter series (``sim/carbon_g`` etc., emitted by a
traced :class:`~repro.sim.engine.EventSimulator` run), a per-region
carbon/water/WUE time-series table is rendered after the stage table.
``--diff`` compares two traces stage-by-stage (p50/p99 deltas) for
regression triage.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs.trace import read_trace, validate_events

# span args whose mean is worth a column in the stage table
_ARG_COLS = ("sinkhorn_iters", "residual", "occupancy")

_SERIES = ("sim/carbon_g", "sim/water_L", "sim/wue")
_SERIES_LABEL = {"sim/carbon_g": "carbon_g", "sim/water_L": "water_L",
                 "sim/wue": "wue"}


def stage_stats(events: Sequence[Dict]) -> Dict[str, Dict]:
    """Aggregate ``ph == "X"`` events by name."""
    durs: Dict[str, List[float]] = {}
    args_acc: Dict[str, Dict[str, List[float]]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        name = ev["name"]
        durs.setdefault(name, []).append(ev["dur"] / 1e3)  # -> ms
        for k in _ARG_COLS:
            v = ev.get("args", {}).get(k)
            if isinstance(v, (int, float)):
                args_acc.setdefault(name, {}).setdefault(k, []).append(v)
    out: Dict[str, Dict] = {}
    for name, ds in durs.items():
        arr = np.asarray(ds)
        st = {
            "count": int(arr.size),
            "total_ms": float(arr.sum()),
            "p50_ms": float(np.percentile(arr, 50)),
            "p95_ms": float(np.percentile(arr, 95)),
            "p99_ms": float(np.percentile(arr, 99)),
            "max_ms": float(arr.max()),
        }
        for k, vals in args_acc.get(name, {}).items():
            st[f"mean_{k}"] = float(np.mean(vals))
        out[name] = st
    return out


def series_stats(events: Sequence[Dict]) -> Dict[str, Dict[str, List]]:
    """Collect simulated-time counter series: name -> region -> points.
    ``ts`` is sim-microseconds (hour = ts / 3.6e9)."""
    out: Dict[str, Dict[str, List]] = {}
    for ev in events:
        if ev.get("ph") != "C" or ev["name"] not in _SERIES:
            continue
        hour = ev["ts"] / 3.6e9
        for region, v in ev.get("args", {}).items():
            out.setdefault(ev["name"], {}).setdefault(region, []) \
               .append((hour, float(v)))
    return out


def _fmt(v: Optional[float], width: int = 9) -> str:
    if v is None:
        return " " * (width - 1) + "-"
    if v == 0:
        return f"{0:>{width}.0f}"
    if abs(v) >= 1e5 or abs(v) < 1e-3:
        return f"{v:>{width}.2e}"
    return f"{v:>{width}.3f}"


def render_stage_table(stats: Dict[str, Dict]) -> str:
    if not stats:
        return "(no spans in trace)"
    has_iters = any("mean_sinkhorn_iters" in s for s in stats.values())
    head = (f"{'stage':<28}{'count':>7}{'total_ms':>11}{'p50_ms':>10}"
            f"{'p95_ms':>10}{'p99_ms':>10}{'max_ms':>10}")
    if has_iters:
        head += f"{'iters':>8}{'residual':>11}"
    lines = [head, "-" * len(head)]
    for name in sorted(stats, key=lambda n: -stats[n]["total_ms"]):
        s = stats[name]
        row = (f"{name:<28}{s['count']:>7}{_fmt(s['total_ms'], 11)}"
               f"{_fmt(s['p50_ms'], 10)}{_fmt(s['p95_ms'], 10)}"
               f"{_fmt(s['p99_ms'], 10)}{_fmt(s['max_ms'], 10)}")
        if has_iters:
            it = s.get("mean_sinkhorn_iters")
            res = s.get("mean_residual")
            row += (f"{it:>8.0f}" if it is not None else f"{'-':>8}")
            row += (f"{res:>11.2e}" if res is not None else f"{'-':>11}")
        lines.append(row)
    return "\n".join(lines)


def render_series_table(series: Dict[str, Dict[str, List]],
                        max_rows: int = 24) -> str:
    if not series:
        return ""
    regions = sorted({r for by_r in series.values() for r in by_r})
    # union of hours across signals, subsampled to max_rows
    hours = sorted({round(h, 6) for by_r in series.values()
                    for pts in by_r.values() for h, _ in pts})
    step = max(1, len(hours) // max_rows)
    shown = hours[::step]
    lookup = {(n, r): dict((round(h, 6), v) for h, v in pts)
              for n, by_r in series.items() for r, pts in by_r.items()}
    cols = [(n, r) for n in _SERIES if n in series for r in regions
            if r in series[n]]
    head = f"{'hour':>7}" + "".join(
        f"{_SERIES_LABEL[n] + ':' + r:>16}" for n, r in cols)
    lines = ["per-region footprint series (simulated time)", head,
             "-" * len(head)]
    for h in shown:
        row = f"{h:>7.1f}"
        for key in cols:
            row += _fmt(lookup[key].get(h), 16)
        lines.append(row)
    if step > 1:
        lines.append(f"({len(hours)} hourly points, showing every {step})")
    return "\n".join(lines)


def render_diff(a_stats: Dict[str, Dict], b_stats: Dict[str, Dict],
                a_name: str, b_name: str) -> str:
    names = sorted(set(a_stats) | set(b_stats))
    head = (f"{'stage':<28}{'p50_a':>10}{'p50_b':>10}{'Δp50%':>8}"
            f"{'p99_a':>10}{'p99_b':>10}{'Δp99%':>8}")
    lines = [f"diff: a={a_name}  b={b_name}", head, "-" * len(head)]
    for name in names:
        sa, sb = a_stats.get(name), b_stats.get(name)
        if sa is None or sb is None:
            lines.append(f"{name:<28}  only in {'b' if sa is None else 'a'}")
            continue
        def delta(k):
            if sa[k] <= 0:
                return float("nan")
            return 100.0 * (sb[k] - sa[k]) / sa[k]
        lines.append(f"{name:<28}{_fmt(sa['p50_ms'], 10)}"
                     f"{_fmt(sb['p50_ms'], 10)}{delta('p50_ms'):>+8.1f}"
                     f"{_fmt(sa['p99_ms'], 10)}{_fmt(sb['p99_ms'], 10)}"
                     f"{delta('p99_ms'):>+8.1f}")
    return "\n".join(lines)


def summarize(path: str) -> Dict:
    events = read_trace(path)
    return {"path": path, "events": len(events),
            "stages": stage_stats(events),
            "series": {n: {r: len(pts) for r, pts in by_r.items()}
                       for n, by_r in series_stats(events).items()}}


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(prog="repro.obs.report",
                                description=__doc__.splitlines()[0])
    p.add_argument("trace", nargs="*", help="trace file(s)")
    p.add_argument("--diff", nargs=2, metavar=("A", "B"),
                   help="compare two traces stage-by-stage")
    p.add_argument("--validate", action="store_true",
                   help="validate events against the schema; exit 1 on errors")
    p.add_argument("--json", action="store_true",
                   help="emit a machine-readable summary instead of tables")
    args = p.parse_args(argv)

    if args.diff:
        a, b = args.diff
        print(render_diff(stage_stats(read_trace(a)),
                          stage_stats(read_trace(b)), a, b))
        return 0

    if not args.trace:
        p.error("need a trace file (or --diff A B)")
    rc = 0
    for path in args.trace:
        events = read_trace(path)
        if args.validate:
            errors = validate_events(events)
            if errors:
                rc = 1
                print(f"{path}: {len(errors)} schema violation(s)")
                for e in errors[:20]:
                    print(f"  {e}")
            else:
                print(f"{path}: {len(events)} events, schema OK")
            continue
        if args.json:
            print(json.dumps(summarize(path), indent=2, sort_keys=True))
            continue
        print(f"{path}: {len(events)} events")
        print(render_stage_table(stage_stats(events)))
        tbl = render_series_table(series_stats(events))
        if tbl:
            print()
            print(tbl)
    return rc


if __name__ == "__main__":
    sys.exit(main())
