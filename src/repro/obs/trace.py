"""Chrome-trace-event JSONL writer + reader.

The on-disk format is the Chrome trace "JSON array" flavour written
line-orientedly: the first line is ``[``, then one event object per
line, each terminated by ``,``.  The closing ``]`` is deliberately
omitted — the trace-event spec makes it optional so crashed runs stay
loadable — which means the file is simultaneously

* loadable in Perfetto / ``chrome://tracing`` as-is, and
* greppable/streamable: every event is one ``json.loads``-able line
  after stripping the trailing comma.

Timestamps (``ts``/``dur``) are microseconds.  Wall-clock spans use
``time.perf_counter`` relative to the writer's epoch; *simulated-time*
counter series (per-region carbon/water/WUE) are emitted against a
separate ``pid`` so Perfetto renders them on their own track instead of
interleaving sim-seconds with wall-microseconds.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Iterator, List, Optional

# pid used for simulated-time counter tracks (sim seconds -> "us").
SIM_PID = 2

# Event-schema contract (validated by ``validate_events`` and the CI
# smoke job): required keys per phase type.
_REQUIRED = {"name", "ph", "ts", "pid", "tid"}
_PHASES = {"X", "i", "C", "M"}


class TraceWriter:
    """Append-only trace-event writer. Not thread-safe by design — the
    simulator is single-threaded and shard workers each get their own
    process (and would write their own file)."""

    def __init__(self, path: str) -> None:
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(path, "w")
        self._f.write("[\n")
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self.events_written = 0
        self.metadata("process_name", {"name": "repro"})
        self.metadata("process_name", {"name": "simulated-time"}, pid=SIM_PID)

    # -- clock -----------------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    # -- emitters --------------------------------------------------------
    def _emit(self, ev: Dict) -> None:
        self._f.write(json.dumps(ev, separators=(",", ":")) + ",\n")
        self.events_written += 1

    def complete(self, name: str, ts_us: float, dur_us: float,
                 args: Optional[Dict] = None, cat: str = "repro") -> None:
        """A ``ph: "X"`` complete event (a span)."""
        ev = {"name": name, "ph": "X", "cat": cat, "ts": round(ts_us, 3),
              "dur": round(max(dur_us, 0.0), 3), "pid": self._pid, "tid": 1}
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, args: Optional[Dict] = None) -> None:
        ev = {"name": name, "ph": "i", "s": "t", "ts": round(self.now_us(), 3),
              "pid": self._pid, "tid": 1}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, values: Dict[str, float],
                ts_us: Optional[float] = None, pid: Optional[int] = None) -> None:
        """A ``ph: "C"`` counter event. Pass ``pid=SIM_PID`` with a
        simulated-time ``ts_us`` for sim-clock series."""
        self._emit({"name": name, "ph": "C",
                    "ts": round(self.now_us() if ts_us is None else ts_us, 3),
                    "pid": self._pid if pid is None else pid, "tid": 1,
                    "args": values})

    def metadata(self, name: str, args: Dict, pid: Optional[int] = None) -> None:
        self._emit({"name": name, "ph": "M", "ts": 0,
                    "pid": self._pid if pid is None else pid, "tid": 1,
                    "args": args})

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


# ---------------------------------------------------------------------------
# reading / validation
# ---------------------------------------------------------------------------

def read_trace(path: str) -> List[Dict]:
    """Parse a trace file written by :class:`TraceWriter` (tolerates a
    plain JSON array too)."""
    with open(path) as f:
        first = f.readline().strip()
        if not first.startswith("["):
            raise ValueError(f"{path}: not a trace-event file")
        if first != "[":  # whole array on one (or few) line(s)
            text = (first + f.read()).rstrip().rstrip(",")
            if not text.endswith("]"):
                text += "]"
            return json.loads(text)
        events = []
        for line in f:
            line = line.strip().rstrip(",")
            if not line or line == "]":
                continue
            events.append(json.loads(line))
        return events


def iter_spans(events: List[Dict]) -> Iterator[Dict]:
    for ev in events:
        if ev.get("ph") == "X":
            yield ev


def validate_events(events: List[Dict]) -> List[str]:
    """Return a list of schema violations (empty == valid)."""
    errors: List[str] = []
    for i, ev in enumerate(events):
        missing = _REQUIRED - set(ev)
        if missing:
            errors.append(f"event {i}: missing keys {sorted(missing)}")
            continue
        ph = ev["ph"]
        if ph not in _PHASES:
            errors.append(f"event {i} ({ev['name']}): unknown ph {ph!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            errors.append(f"event {i} ({ev['name']}): bad ts {ev['ts']!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({ev['name']}): X event needs "
                              f"non-negative dur, got {dur!r}")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            errors.append(f"event {i} ({ev['name']}): C event needs args")
    return errors
