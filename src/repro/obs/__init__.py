"""``repro.obs`` — zero-overhead-when-disabled observability.

Three pillars:

* a process-global :class:`~repro.obs.metrics.MetricsRegistry`
  (counters / gauges / exact-quantile latency histograms) with
  snapshot + associative merge, so sharded-executor workers ship their
  metrics back to the driver;
* structured **span tracing** with nesting, exported as
  Chrome-trace-event JSONL (Perfetto / ``chrome://tracing``-loadable)
  via :class:`~repro.obs.trace.TraceWriter`;
* a reporting CLI (``python -m repro.obs.report``) rendering per-stage
  p50/p99 tables, per-region carbon/water/WUE series, and run diffs.

Disabled (the default) is the fast path: ``span()`` returns a shared
no-op context manager, ``observe``/``gauge`` return immediately, and no
trace I/O happens — pinned in ``tests/test_obs.py`` by checking engine
records are bit-identical with obs on vs off.  Only plain **counters**
are always live (a dict add), because degenerate-path warning counts
and JIT-retrace accounting must be visible in ordinary runs too.

Typical use::

    import repro.obs as obs

    with obs.capture(trace_path="out/run.trace.jsonl"):
        result = engine.run(...)
        snap = obs.snapshot()          # counters/gauges/histograms
    # trace file closed; report with `python -m repro.obs.report`

Instrumentation sites use::

    with obs.span("policy.solve", jobs=M):
        res = solvers.solve(problem)
        obs.annotate(status=res.status)   # add args to the open span

    with obs.timed("cell.run") as t:      # always measures .elapsed_s
        sim.run()
    row["wall_s"] = t.elapsed_s
"""
from __future__ import annotations

import contextlib
import time
import warnings
from typing import Dict, List, Optional

from repro.obs.metrics import (HIST_BASE, HIST_MAX_SAMPLES, Counter, Gauge,
                               Histogram, MetricsRegistry, merge_snapshots)
from repro.obs.trace import (SIM_PID, TraceWriter, iter_spans, read_trace,
                             validate_events)

__all__ = [
    "enabled", "enable", "disable", "capture", "span", "timed", "annotate",
    "counter", "gauge", "observe", "warn", "snapshot", "merge", "reset",
    "counter_value", "tracer", "registry",
    "MetricsRegistry", "Histogram", "Counter", "Gauge", "merge_snapshots",
    "TraceWriter", "read_trace", "iter_spans", "validate_events",
    "HIST_BASE", "HIST_MAX_SAMPLES", "SIM_PID",
]

_REGISTRY = MetricsRegistry()
_TRACER: Optional[TraceWriter] = None
_ENABLED = False
_STACK: List["_Span"] = []


def enabled() -> bool:
    return _ENABLED


def registry() -> MetricsRegistry:
    return _REGISTRY


def tracer() -> Optional[TraceWriter]:
    return _TRACER


def enable(trace_path: Optional[str] = None) -> None:
    """Turn collection on; if ``trace_path`` is given, also stream
    Chrome-trace events there until :func:`disable`."""
    global _ENABLED, _TRACER
    _ENABLED = True
    if trace_path is not None:
        if _TRACER is not None:
            _TRACER.close()
        _TRACER = TraceWriter(trace_path)


def disable() -> None:
    """Stop collection and close any open trace file. The metrics
    registry is kept (read it with :func:`snapshot`; clear with
    :func:`reset`)."""
    global _ENABLED, _TRACER
    _ENABLED = False
    if _TRACER is not None:
        _TRACER.close()
        _TRACER = None
    _STACK.clear()


@contextlib.contextmanager
def capture(trace_path: Optional[str] = None, fresh: bool = True,
            fold: bool = True):
    """Enable obs for a block, restoring the previous state after.
    Yields the live registry. ``fresh=True`` starts from an empty
    registry so the snapshot covers only this block; ``fold=False``
    discards the block's metrics on exit instead of merging them into
    the outer registry (shard workers ship their snapshot explicitly,
    so the driver must not also receive it by fold)."""
    global _REGISTRY
    prev_enabled, prev_reg = _ENABLED, _REGISTRY
    if fresh:
        _REGISTRY = MetricsRegistry()
    enable(trace_path)
    try:
        yield _REGISTRY
    finally:
        disable()
        if prev_enabled:
            enable()
        if fresh:
            # fold the block's metrics into the outer registry so nested
            # captures don't silently drop observations
            captured = _REGISTRY.snapshot() if fold else None
            _REGISTRY = prev_reg
            if captured is not None:
                _REGISTRY.merge(captured)


def reset() -> None:
    global _REGISTRY
    _REGISTRY = MetricsRegistry()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

class _NullSpan:
    """Shared no-op span: the entire disabled-mode cost of ``span()``."""
    __slots__ = ()
    elapsed_s = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> None:
        pass

    def elapsed(self) -> float:
        return 0.0


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "args", "t0", "elapsed_s", "_measure_only")

    def __init__(self, name: str, args: Dict, measure_only: bool = False):
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.elapsed_s = 0.0
        self._measure_only = measure_only

    def set(self, **args) -> None:
        self.args.update(args)

    def elapsed(self) -> float:
        """Mid-flight wall-clock reading (``elapsed_s`` is only set at
        exit); lets a multi-return function report its wall so far."""
        return time.perf_counter() - self.t0

    def __enter__(self):
        self.t0 = time.perf_counter()
        if not self._measure_only:
            _STACK.append(self)
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self.elapsed_s = t1 - self.t0
        if self._measure_only:
            return False
        if _STACK and _STACK[-1] is self:
            _STACK.pop()
        _REGISTRY.observe(self.name, self.elapsed_s)
        if _TRACER is not None:
            ts0 = (self.t0 - _TRACER._t0) * 1e6
            _TRACER.complete(self.name, ts0, self.elapsed_s * 1e6,
                             args=self.args or None)
        return False


def span(name: str, **args):
    """Context manager timing a named stage.  No-op singleton when obs
    is disabled; when enabled, records a latency-histogram observation
    and (if tracing) a Chrome-trace ``X`` event with ``args``."""
    if not _ENABLED:
        return _NULL_SPAN
    return _Span(name, args)


def timed(name: str, **args):
    """Like :func:`span`, but **always** measures wall time and exposes
    ``.elapsed_s`` — the drop-in replacement for ad-hoc
    ``time.perf_counter()`` pairs whose result feeds a data field
    (``solve_time_s``, ``wall_s``): the field is populated identically
    whether obs is on or off."""
    if not _ENABLED:
        return _Span(name, args, measure_only=True)
    return _Span(name, args)


def annotate(**args) -> None:
    """Attach args to the innermost open (enabled) span, if any."""
    if _STACK:
        _STACK[-1].set(**args)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def counter(name: str, n: float = 1) -> None:
    """Increment a counter. Always live (cheap), even when disabled —
    counters carry degenerate-path and JIT-retrace accounting that must
    not vanish in ordinary runs."""
    _REGISTRY.counter(name, n)


def counter_value(name: str) -> float:
    c = _REGISTRY.counters.get(name)
    return 0.0 if c is None else c.value


def gauge(name: str, value: float, weight: float = 1.0) -> None:
    if _ENABLED:
        _REGISTRY.gauge(name, value, weight)


def observe(name: str, value: float) -> None:
    if _ENABLED:
        _REGISTRY.observe(name, value)


def warn(name: str, message: str, n: float = 1) -> None:
    """Degenerate-path signal: bump ``warn/<name>`` (always) and issue a
    ``RuntimeWarning`` (Python's default filter dedups repeats per
    call site, so hot loops don't spam)."""
    _REGISTRY.counter(f"warn/{name}", n)
    warnings.warn(f"[{name}] {message}", RuntimeWarning, stacklevel=3)


def snapshot() -> Dict:
    return _REGISTRY.snapshot()


def merge(snap: Dict) -> None:
    """Fold a worker's snapshot into this process's registry."""
    _REGISTRY.merge(snap)
