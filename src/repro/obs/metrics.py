"""Metrics registry: counters, gauges, and log-bucketed latency histograms.

Three metric kinds, chosen to match how the scheduler's numbers are
consumed downstream:

* ``Counter`` — monotonically increasing event counts (rounds run, JIT
  retraces, degenerate-path warnings).  Merge = add.
* ``Gauge`` — a last-written value with a *weight*, so that merging
  shard-local gauges job-weights them exactly like
  ``experiments.shard.merge_forecast_stats`` job-weights forecaster
  losses.  Merge = weighted mean over (value, weight) pairs.
* ``Histogram`` — latency distribution with **exact** p50/p95/p99 while
  the raw-sample buffer holds every observation (default 65 536), plus
  log-spaced bucket counts that survive any sample-cap overflow so the
  quantiles degrade gracefully (relative error bounded by the bucket
  base, ~9%/octave-eighth) instead of silently going wrong.  Merge =
  bucket-count addition plus multiset union of the sample buffers.

Snapshots are plain JSON-serialisable dicts; ``merge_snapshots`` is
associative (pinned in tests), so sharded-executor workers can ship
their registries to the driver in any completion order.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

# 2**(1/8): eight buckets per octave -> worst-case relative quantile
# error of ~4.4% once the exact sample buffer overflows.
HIST_BASE = 2.0 ** 0.125
HIST_MAX_SAMPLES = 65536
_LOG_BASE = math.log(HIST_BASE)
_TINY = 1e-12


class Counter:
    __slots__ = ("value",)

    def __init__(self, value: float = 0) -> None:
        self.value = value

    def inc(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value", "weight")

    def __init__(self, value: float = 0.0, weight: float = 0.0) -> None:
        self.value = value
        self.weight = weight

    def set(self, value: float, weight: float = 1.0) -> None:
        """Fold ``value`` in as a weighted observation (not a plain
        overwrite): the gauge keeps the running weighted mean so that a
        merged snapshot equals the mean over every shard's observations."""
        total = self.weight + weight
        if total > 0:
            self.value = (self.value * self.weight + value * weight) / total
        self.weight = total


def bucket_index(v: float) -> int:
    """Log-bucket index of a positive value (values <= 0 clamp to tiny)."""
    return int(math.ceil(math.log(max(v, _TINY)) / _LOG_BASE))


def bucket_bounds(idx: int) -> tuple:
    """(lo, hi] value range covered by bucket ``idx``."""
    return (HIST_BASE ** (idx - 1), HIST_BASE ** idx)


class Histogram:
    __slots__ = ("counts", "samples", "count", "total", "vmin", "vmax",
                 "max_samples")

    def __init__(self, max_samples: int = HIST_MAX_SAMPLES) -> None:
        self.counts: Dict[int, int] = {}
        self.samples: Optional[List[float]] = []
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.max_samples = max_samples

    def observe(self, v: float) -> None:
        v = float(v)
        idx = bucket_index(v)
        self.counts[idx] = self.counts.get(idx, 0) + 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if self.samples is not None:
            if len(self.samples) < self.max_samples:
                self.samples.append(v)
            else:
                self.samples = None  # cap hit: fall back to bucket quantiles

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """``q`` in [0, 100].  Exact (``numpy.percentile``-identical,
        linear interpolation) while the sample buffer is intact; bucket
        geometric-midpoint estimate after overflow."""
        if self.count == 0:
            return 0.0
        if self.samples is not None:
            return float(np.percentile(self.samples, q))
        rank = (q / 100.0) * (self.count - 1)
        seen = 0
        for idx in sorted(self.counts):
            seen += self.counts[idx]
            if seen > rank:
                lo, hi = bucket_bounds(idx)
                return math.sqrt(max(lo, _TINY) * hi)
        return self.vmax

    def percentiles(self, qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
        return {f"p{g:g}": self.quantile(g) for g in qs}


class MetricsRegistry:
    """Named counters/gauges/histograms with snapshot + merge."""

    def __init__(self) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.hists: Dict[str, Histogram] = {}

    # -- write paths -----------------------------------------------------
    def counter(self, name: str, n: float = 1) -> None:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        c.inc(n)

    def gauge(self, name: str, value: float, weight: float = 1.0) -> None:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        g.set(value, weight)

    def observe(self, name: str, value: float) -> None:
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram()
        h.observe(value)

    # -- snapshot / merge ------------------------------------------------
    def snapshot(self) -> Dict:
        return {
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: {"value": g.value, "weight": g.weight}
                       for k, g in self.gauges.items()},
            "hists": {k: {
                "counts": {str(i): n for i, n in h.counts.items()},
                "samples": None if h.samples is None else list(h.samples),
                "count": h.count,
                "total": h.total,
                "min": None if h.count == 0 else h.vmin,
                "max": None if h.count == 0 else h.vmax,
                "max_samples": h.max_samples,
            } for k, h in self.hists.items()},
        }

    def merge(self, snap: Dict) -> None:
        """Fold a snapshot (e.g. shipped back by a shard worker) in."""
        for k, v in snap.get("counters", {}).items():
            self.counter(k, v)
        for k, g in snap.get("gauges", {}).items():
            self.gauge(k, g["value"], g["weight"])
        for k, hs in snap.get("hists", {}).items():
            h = self.hists.get(k)
            if h is None:
                h = self.hists[k] = Histogram(hs.get("max_samples",
                                                     HIST_MAX_SAMPLES))
            for i, n in hs["counts"].items():
                i = int(i)
                h.counts[i] = h.counts.get(i, 0) + n
            h.count += hs["count"]
            h.total += hs["total"]
            if hs["min"] is not None:
                h.vmin = min(h.vmin, hs["min"])
                h.vmax = max(h.vmax, hs["max"])
            other = hs["samples"]
            if h.samples is None or other is None or \
                    len(h.samples) + len(other) > h.max_samples:
                h.samples = None
            else:
                h.samples.extend(other)


def merge_snapshots(snaps: Iterable[Dict]) -> Dict:
    """Merge snapshots into one (associative; order only permutes the
    retained sample multiset, which quantile() sorts anyway)."""
    reg = MetricsRegistry()
    for s in snaps:
        reg.merge(s)
    return reg.snapshot()
