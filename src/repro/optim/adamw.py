"""AdamW with cosine schedule and global-norm clipping (pure JAX, no optax).

Optimizer state mirrors the parameter tree (mu, nu per leaf) so FSDP
sharding applies to it automatically — the specs tree for the state is the
params specs tree reused leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        warm = peak_lr * (step + 1) / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


@dataclasses.dataclass(frozen=True)
class adamw:
    lr: Callable = cosine_schedule(3e-4, 100, 10000)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(z, params),
                          nu=jax.tree.map(z, params))

    def update(self, grads, state: AdamWState, params):
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu), gnorm
