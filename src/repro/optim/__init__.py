from repro.optim.adamw import adamw, cosine_schedule, clip_by_global_norm
from repro.optim.compression import (compress_int8, decompress_int8,
                                     topk_error_feedback)
