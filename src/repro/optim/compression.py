"""Gradient compression for cross-pod sync (distributed-optimization tricks).

Two schemes, both applied on the "pod" axis where inter-pod bandwidth is the
scarce resource (data-center interconnect, not ICI):

  int8 stochastic rounding   8× volume reduction; unbiased; stateless.
  top-k + error feedback     k-sparsification with residual accumulation —
                             the EF state rides in the train loop's carry.

Both are pure-JAX transforms of the gradient tree — they lower to
quantize → all-reduce(int8/sparse) → dequantize patterns the compiler can
overlap with backprop.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(g, key):
    """Per-tensor scale + stochastic-rounded int8 payload."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    scaled = gf / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_roundtrip(grads, key):
    """Quantize-dequantize the whole gradient tree (what crosses pods)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        q, s = compress_int8(g, k)
        out.append(decompress_int8(q, s, g.dtype))
    return jax.tree.unflatten(treedef, out)


def topk_error_feedback(grads, residual, frac: float = 0.01
                        ) -> Tuple[Any, Any]:
    """Keep the top-``frac`` magnitude entries per tensor; the rest
    accumulates into ``residual`` (error feedback, Stich et al.)."""
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        k = max(int(acc.size * frac), 1)
        flat = jnp.abs(acc).reshape(-1)
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = jnp.abs(acc) >= thresh
        sent = jnp.where(mask, acc, 0.0)
        return sent.astype(g.dtype), acc - sent

    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)
    pairs = jax.tree.map(one, grads, residual)
    sent = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return sent, res
