"""ModelConfig: the single declarative description every architecture uses.

``family`` selects the assembly in models/transformer.py:

  decoder   homogeneous decoder-only stack (dense / MoE / MLA per flags)
  gemma3    local:global sliding-window pattern (attn_every-th layer global)
  griffin   RecurrentGemma (rec, rec, attn) pattern
  encdec    encoder-decoder (seamless; encoder fed stub frame embeddings)
  vision    decoder with gated cross-attention groups (llama-3.2-vision)

Shape cells (the assignment's 4 shapes) are ShapeSpec entries; smoke tests
use ``reduced()`` configs of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.models import common


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # decoder | gemma3 | griffin | encdec | vision
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 → d_model // n_heads
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0   # gemma3 dual-base (global layers)
    window: int = 0                  # sliding-window size (local layers)
    attn_every: int = 0              # gemma3: every k-th layer is global
    norm: str = "rmsnorm"
    softmax_scale: Optional[float] = None
    embed_scale: bool = False        # gemma-style sqrt(d_model) embed scaling
    # MLA (deepseek-v2 / minicpm3)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    d_nope: int = 0
    d_rope: int = 0
    d_v: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    first_dense: int = 0             # leading dense layers (deepseek-v2)
    dense_d_ff: int = 0              # d_ff of those dense layers
    # SSM (mamba2)
    ssm: bool = False
    d_inner: int = 0
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    # hybrid (recurrentgemma)
    lru_width: int = 0
    # enc-dec
    enc_layers: int = 0
    # vision
    cross_every: int = 0             # one cross layer leads each group
    n_img_tokens: int = 0
    # numerics / runtime
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # bf16 params + fp32 Adam moments: the FSDP all-gather and the grad
    # all-reduce move half the bytes vs fp32 params; update math runs fp32.
    param_dtype: str = "bfloat16"
    remat: str = "full"              # none | dots | full
    block_kv: int = 1024
    ssd_chunk: int = 256
    moe_capacity_factor: float = 1.25

    # -- derived -------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return common.pad_vocab(self.vocab)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (assignment rule)."""
        return self.family in ("griffin",) or self.ssm or (
            self.family == "gemma3")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_REGISTRY = [
    "dbrx_132b", "deepseek_v2_236b", "seamless_m4t_large_v2", "qwen2_72b",
    "qwen2_1_5b", "gemma3_4b", "minicpm3_4b", "recurrentgemma_2b",
    "llama_3_2_vision_11b", "mamba2_2_7b",
]


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    """Load ``src/repro/configs/<arch>.py`` (dashes normalized)."""
    mod_name = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced() if reduced else mod.config()


def list_archs() -> Tuple[str, ...]:
    return tuple(ARCH_REGISTRY)
