"""Mamba2-2.7B [arXiv:2405.21060]: 64L pure-SSD blocks (attention-free),
d=2560, d_inner=5120 (80 heads × 64), state N=128, vocab 50280."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="decoder", n_layers=64, d_model=2560,
        n_heads=80, n_kv=80, d_ff=0, vocab=50280,
        ssm=True, d_inner=5120, ssm_state=128, ssm_head_dim=64, ssm_groups=1,
        tie_embeddings=True)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv=4,
                            d_inner=128, ssm_state=16, ssm_head_dim=32,
                            vocab=512, ssd_chunk=8, remat="none")
