"""RecurrentGemma-2B [arXiv:2402.19427]: 26L Griffin — (rec, rec, attn)
pattern (RG-LRU width 2560 + local MQA window 2048), d=2560, 10H (kv=1),
head_dim=256, d_ff=7680 (GeGLU), vocab 256000."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="griffin", n_layers=26, d_model=2560,
        n_heads=10, n_kv=1, d_ff=7680, vocab=256000, head_dim=256,
        window=2048, lru_width=2560, embed_scale=True, tie_embeddings=True)


def reduced() -> ModelConfig:
    return config().replace(n_layers=5, d_model=64, n_heads=4, n_kv=1,
                            head_dim=16, d_ff=128, lru_width=64, window=8,
                            vocab=512, remat="none")
