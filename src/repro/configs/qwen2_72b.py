"""Qwen2-72B [arXiv:2407.10671]: 80L, d=8192, 64H (GQA kv=8), d_ff=29568,
vocab 152064, QKV bias."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b", family="decoder", n_layers=80, d_model=8192,
        n_heads=64, n_kv=8, d_ff=29568, vocab=152064, head_dim=128,
        qkv_bias=True, rope_theta=1e6, tie_embeddings=False)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                            head_dim=16, d_ff=160, vocab=512, remat="none")
