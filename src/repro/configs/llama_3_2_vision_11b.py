"""Llama-3.2-Vision-11B [hf:meta-llama/Llama-3.2-11B-Vision]: 40L backbone
(8 gated cross-attention layers leading groups of 5), d=4096, 32H (GQA
kv=8), d_ff=14336, vocab 128256. The vision tower is a stub: ``input_specs``
feeds precomputed patch embeddings [B, 4096, d] (per the assignment)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vision", n_layers=40,
        d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=128256,
        head_dim=128, rope_theta=5e5, cross_every=5, n_img_tokens=4096,
        tie_embeddings=False)


def reduced() -> ModelConfig:
    return config().replace(n_layers=5, d_model=64, n_heads=4, n_kv=2,
                            head_dim=16, d_ff=128, vocab=512, cross_every=5,
                            n_img_tokens=16, remat="none")
