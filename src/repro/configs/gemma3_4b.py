"""Gemma3-4B [hf:google/gemma-3-*]: 34L, d=2560, 8H (GQA kv=4),
head_dim=256, d_ff=10240, vocab 262144. 5:1 local:global sliding-window
pattern (window 1024; every 6th layer global), dual RoPE base
(10k local / 1M global), 128k context."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="gemma3", n_layers=34, d_model=2560,
        n_heads=8, n_kv=4, d_ff=10240, vocab=262144, head_dim=256,
        window=1024, attn_every=6, rope_theta=1e4, rope_theta_global=1e6,
        embed_scale=True, tie_embeddings=True)


def reduced() -> ModelConfig:
    return config().replace(n_layers=6, d_model=64, n_heads=4, n_kv=2,
                            head_dim=16, d_ff=128, vocab=512, window=8,
                            remat="none")
