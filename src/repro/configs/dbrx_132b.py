"""DBRX-132B [hf:databricks/dbrx-base]: 40L, d=6144, 48H (GQA kv=8),
16 experts top-4 (fine-grained), d_ff=10752/expert, vocab 100352."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="decoder", n_layers=40, d_model=6144,
        n_heads=48, n_kv=8, d_ff=10752, vocab=100352, head_dim=128,
        rope_theta=5e5, n_experts=16, top_k=4, tie_embeddings=False)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                            head_dim=16, d_ff=96, vocab=512, n_experts=4,
                            top_k=2, remat="none")
