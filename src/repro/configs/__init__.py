"""Architecture configs: one module per assigned architecture + registry."""
from repro.configs.base import (ModelConfig, ShapeSpec, SHAPES, get_config,
                                list_archs, ARCH_REGISTRY)
