"""Qwen2-1.5B [arXiv:2407.10671]: 28L, d=1536, 12H (GQA kv=2), d_ff=8960,
vocab 151936, QKV bias, tied embeddings."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="decoder", n_layers=28, d_model=1536,
        n_heads=12, n_kv=2, d_ff=8960, vocab=151936, head_dim=128,
        qkv_bias=True, rope_theta=1e6, tie_embeddings=True)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                            head_dim=16, d_ff=128, vocab=512, remat="none")
