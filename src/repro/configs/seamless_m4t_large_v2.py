"""SeamlessM4T-large-v2 [arXiv:2308.11596]: enc-dec, 24L encoder + 24L
decoder, d=1024, 16H, d_ff=8192, vocab 256206. The speech/modality frontend
is a stub: ``input_specs`` feeds precomputed frame embeddings [B, S, d] to
the encoder (per the assignment)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec", n_layers=24,
        enc_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=8192,
        vocab=256206, head_dim=64, norm="layernorm", tie_embeddings=False)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                            n_kv=4, head_dim=16, d_ff=128, vocab=512,
                            remat="none")
