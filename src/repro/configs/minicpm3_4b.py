"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: 62L, d=2560, 40H MLA
(kv_lora=256, q_lora=768, nope 64 / rope 32 / v 64), d_ff=6400,
vocab 73448."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="decoder", n_layers=62, d_model=2560,
        n_heads=40, n_kv=40, d_ff=6400, vocab=73448,
        mla=True, q_lora=768, kv_lora=256, d_nope=64, d_rope=32, d_v=64,
        tie_embeddings=True)


def reduced() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, n_heads=4, n_kv=4,
                            d_ff=128, q_lora=32, kv_lora=16, d_nope=16,
                            d_rope=8, d_v=16, vocab=512, remat="none")
