"""DeepSeek-V2-236B [arXiv:2405.04434]: 60L, d=5120, 128H MLA
(kv_lora=512, q_lora=1536, nope 128 / rope 64 / v 128), MoE 160 routed
top-6 + 2 shared (expert d_ff=1536), first layer dense (d_ff=12288),
vocab 102400."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="decoder", n_layers=60, d_model=5120,
        n_heads=128, n_kv=128, d_ff=1536, vocab=102400,
        mla=True, q_lora=1536, kv_lora=512, d_nope=128, d_rope=64, d_v=128,
        n_experts=160, top_k=6, n_shared=2, first_dense=1, dense_d_ff=12288,
        tie_embeddings=False)


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=48,
        q_lora=32, kv_lora=16, d_nope=16, d_rope=8, d_v=16,
        n_experts=8, top_k=2, n_shared=1, first_dense=1, dense_d_ff=128,
        vocab=512, remat="none")
