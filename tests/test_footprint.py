"""Unit + property tests for the paper's footprint equations (Eqs 1-6)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import footprint as fp

pos = st.floats(min_value=1e-3, max_value=1e3, allow_nan=False)


def test_eq1_carbon_components():
    # 2 kWh at 100 g/kWh + half-lifetime amortization of 1000 g.
    assert fp.operational_carbon(2.0, 100.0) == 200.0
    assert fp.embodied_carbon(50.0, 100.0, 1000.0) == 500.0
    assert fp.total_carbon(2.0, 100.0, 50.0, 100.0, 1000.0) == 700.0


def test_eq2_eq3_water_scaling_by_wsf():
    base = fp.offsite_water(1.0, 1.2, 10.0, 0.0)
    stressed = fp.offsite_water(1.0, 1.2, 10.0, 1.0)
    assert stressed == pytest.approx(2 * base)          # (1+WSF) scaling
    assert fp.onsite_water(2.0, 3.0, 0.0) == 6.0


def test_eq6_water_intensity_consistency():
    """Eq 6 must equal the per-kWh operational water of Eqs 2+3."""
    pue, ewif, wue, wsf = 1.2, 8.0, 2.5, 0.4
    wi = fp.water_intensity(wue, pue, ewif, wsf)
    per_kwh = (fp.offsite_water(1.0, pue, ewif, wsf)
               + fp.onsite_water(1.0, wue, wsf))
    assert wi == pytest.approx(per_kwh)


def test_embodied_water_derivation():
    """Eq 4 back-out: embodied carbon / CI_mfg × EWIF × (1+WSF)."""
    s = fp.ServerSpec(embodied_gco2=550_000.0, ci_mfg_g_per_kwh=550.0,
                      ewif_mfg_l_per_kwh=2.0, wsf_mfg=0.5)
    assert s.manufacturing_energy_kwh == pytest.approx(1000.0)
    assert s.embodied_water_l == pytest.approx(1000.0 * 2.0 * 1.5)


@settings(max_examples=100, deadline=None)
@given(e=pos, ci=pos, t=pos, life=pos, emb=pos)
def test_carbon_monotone_in_energy_and_ci(e, ci, t, life, emb):
    c1 = fp.total_carbon(e, ci, t, life, emb)
    assert fp.total_carbon(2 * e, ci, t, life, emb) > c1
    assert fp.total_carbon(e, 2 * ci, t, life, emb) > c1


@settings(max_examples=100, deadline=None)
@given(e=pos, pue=st.floats(1.0, 3.0), ewif=pos, wue=pos,
       wsf=st.floats(0, 2))
def test_water_linear_in_energy(e, pue, ewif, wue, wsf):
    w1 = fp.offsite_water(e, pue, ewif, wsf) + fp.onsite_water(e, wue, wsf)
    w2 = fp.offsite_water(2 * e, pue, ewif, wsf) + fp.onsite_water(2 * e, wue,
                                                                   wsf)
    assert w2 == pytest.approx(2 * w1, rel=1e-9)
