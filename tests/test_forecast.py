"""Forecasting subsystem: models, backtesting, quantile bands, jit caching."""
import time

import numpy as np
import pytest

from repro import forecast
from repro.core import telemetry
from repro.forecast import holtwinters


@pytest.fixture(scope="module")
def tele():
    return telemetry.generate(days=6, seed=0)


BT_KW = dict(horizon=6, warmup=48, stride=6)


def test_seasonal_naive_beats_persistence_on_diurnal_ci(tele):
    """Carbon intensity is solar-cycle dominated: the period-24 baseline must
    beat the random-walk baseline on it (the subsystem's sanity anchor)."""
    p = forecast.backtest_telemetry(tele, "ci", "persistence", **BT_KW)
    s = forecast.backtest_telemetry(tele, "ci", "seasonal-naive", **BT_KW)
    assert s["mape"] < p["mape"]
    assert s["n_origins"] == p["n_origins"] > 5


def test_holtwinters_beats_persistence_on_diurnal_ci(tele):
    p = forecast.backtest_telemetry(tele, "ci", "persistence", **BT_KW)
    h = forecast.backtest_telemetry(tele, "ci", "holtwinters", **BT_KW)
    assert h["mape"] < p["mape"]


def test_oracle_forecaster_is_exact(tele):
    r = forecast.backtest_telemetry(tele, "ci", "oracle", **BT_KW)
    assert r["mape"] == pytest.approx(0.0, abs=1e-9)
    assert r["pinball"] == pytest.approx(0.0, abs=1e-9)
    assert r["coverage"] == 1.0


def test_quantile_bands_order_and_coverage(tele):
    for name in ("persistence", "seasonal-naive", "holtwinters"):
        f = forecast.make_forecaster(name).fit(tele.ci[:96])
        fc = f.predict(8)
        assert (fc.lo <= fc.mean + 1e-12).all()
        assert (fc.mean <= fc.hi + 1e-12).all()
    s = forecast.backtest_telemetry(tele, "ci", "seasonal-naive", **BT_KW)
    assert 0.5 < s["coverage"] <= 1.0     # 10/90 band should cover most truth


def test_perturbed_wrapper_scales_mean(tele):
    inner = forecast.SeasonalNaive().fit(tele.ci[:72])
    biased = forecast.Perturbed(forecast.SeasonalNaive(), bias=1.3,
                                noise=0.0, seed=0).fit(tele.ci[:72])
    np.testing.assert_allclose(biased.predict(6).mean,
                               1.3 * inner.predict(6).mean)
    noisy_a = forecast.Perturbed(forecast.SeasonalNaive(), bias=1.0,
                                 noise=0.2, seed=7).fit(tele.ci[:72])
    noisy_b = forecast.Perturbed(forecast.SeasonalNaive(), bias=1.0,
                                 noise=0.2, seed=7).fit(tele.ci[:72])
    # Deterministic given (seed, history length); different from the truth.
    np.testing.assert_array_equal(noisy_a.predict(6).mean,
                                  noisy_b.predict(6).mean)
    assert not np.allclose(noisy_a.predict(6).mean, inner.predict(6).mean)


def test_forecast_interpolation_and_window_means(tele):
    f = forecast.SeasonalNaive().fit(tele.ci[:72])
    fc = f.predict(8)
    t_issue = 71 * 3600.0
    # at(): anchors at the last observation, hits the hour grid exactly.
    np.testing.assert_allclose(fc.at(t_issue), fc.anchor)
    np.testing.assert_allclose(fc.at(t_issue + 3600.0), fc.mean[0])
    mid = fc.at(t_issue + 1800.0)
    np.testing.assert_allclose(mid, 0.5 * (fc.anchor + fc.mean[0]))
    # mean_many(): exact integral of the piecewise-linear curve — must match
    # a fine trapezoid on at_many().
    t0 = np.array([t_issue + 600.0, t_issue + 5000.0])
    t1 = t0 + np.array([3600.0, 9000.0])
    exact = fc.mean_many(t0, t1)
    for k in range(2):
        ts = np.linspace(t0[k], t1[k], 2001)
        vals = fc.at_many(ts)
        dt = ts[1] - ts[0]
        approx = (dt * (0.5 * (vals[0] + vals[-1]) + vals[1:-1].sum(axis=0))
                  / (t1[k] - t0[k]))
        np.testing.assert_allclose(exact[k], approx, rtol=1e-6)


def test_holtwinters_fit_is_jit_cached():
    """Acceptance: second fit of the same history shape ≥10× faster than the
    first (the lax.scan filter compiles once per padded shape)."""
    rng = np.random.default_rng(3)
    t = np.arange(61)
    # 7 columns: a shape no other test uses, so the first fit must compile.
    hist = (10.0 + 3.0 * np.sin(t / 24.0 * 2 * np.pi)[:, None]
            + 0.1 * rng.standard_normal((61, 7)))
    t0 = time.perf_counter()
    holtwinters.HoltWinters().fit(hist)
    first = time.perf_counter() - t0
    t0 = time.perf_counter()
    holtwinters.HoltWinters().fit(hist)
    second = time.perf_counter() - t0
    assert first >= 10.0 * second, (first, second)


def test_holtwinters_bucketing_and_fallbacks(tele):
    for rows in (48, 49, 71, 200, 10_000):
        b = holtwinters.fit_bucket_for(rows, 24)
        assert b % 24 == 0
        assert b >= min(rows, holtwinters.MAX_FIT_PERIODS * 24)
    # Short histories degrade gracefully: seasonal-naive then persistence.
    short = holtwinters.HoltWinters().fit(tele.ci[:30])
    assert short.predict(4).mean.shape == (4, 5)
    tiny = holtwinters.HoltWinters().fit(tele.ci[:3])
    assert tiny.predict(4).mean.shape == (4, 5)


def test_backtest_rejects_too_short_series(tele):
    with pytest.raises(ValueError):
        forecast.backtest(tele.ci[:10], forecast.Persistence, horizon=6,
                          warmup=48)


def test_make_forecaster_registry():
    names = forecast.list_forecasters()
    assert {"persistence", "seasonal-naive", "holtwinters"} <= set(names)
    with pytest.raises(KeyError):
        forecast.make_forecaster("no-such-model")
