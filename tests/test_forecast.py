"""Forecasting subsystem: models, backtesting, quantile bands, jit caching,
the learned RG-LRU forecaster, and the registry surface."""
import os
import tempfile
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import forecast
from repro.core import telemetry
from repro.forecast import holtwinters


@pytest.fixture(scope="module")
def tele():
    return telemetry.generate(days=6, seed=0)


BT_KW = dict(horizon=6, warmup=48, stride=6)


def test_seasonal_naive_beats_persistence_on_diurnal_ci(tele):
    """Carbon intensity is solar-cycle dominated: the period-24 baseline must
    beat the random-walk baseline on it (the subsystem's sanity anchor)."""
    p = forecast.backtest_telemetry(tele, "ci", "persistence", **BT_KW)
    s = forecast.backtest_telemetry(tele, "ci", "seasonal-naive", **BT_KW)
    assert s["mape"] < p["mape"]
    assert s["n_origins"] == p["n_origins"] > 5


def test_holtwinters_beats_persistence_on_diurnal_ci(tele):
    p = forecast.backtest_telemetry(tele, "ci", "persistence", **BT_KW)
    h = forecast.backtest_telemetry(tele, "ci", "holtwinters", **BT_KW)
    assert h["mape"] < p["mape"]


def test_oracle_forecaster_is_exact(tele):
    r = forecast.backtest_telemetry(tele, "ci", "oracle", **BT_KW)
    assert r["mape"] == pytest.approx(0.0, abs=1e-9)
    assert r["pinball"] == pytest.approx(0.0, abs=1e-9)
    assert r["coverage"] == 1.0


def test_quantile_bands_order_and_coverage(tele):
    for name in ("persistence", "seasonal-naive", "holtwinters"):
        f = forecast.make_forecaster(name).fit(tele.ci[:96])
        fc = f.predict(8)
        assert (fc.lo <= fc.mean + 1e-12).all()
        assert (fc.mean <= fc.hi + 1e-12).all()
    s = forecast.backtest_telemetry(tele, "ci", "seasonal-naive", **BT_KW)
    assert 0.5 < s["coverage"] <= 1.0     # 10/90 band should cover most truth


def test_perturbed_wrapper_scales_mean(tele):
    inner = forecast.SeasonalNaive().fit(tele.ci[:72])
    biased = forecast.Perturbed(forecast.SeasonalNaive(), bias=1.3,
                                noise=0.0, seed=0).fit(tele.ci[:72])
    np.testing.assert_allclose(biased.predict(6).mean,
                               1.3 * inner.predict(6).mean)
    noisy_a = forecast.Perturbed(forecast.SeasonalNaive(), bias=1.0,
                                 noise=0.2, seed=7).fit(tele.ci[:72])
    noisy_b = forecast.Perturbed(forecast.SeasonalNaive(), bias=1.0,
                                 noise=0.2, seed=7).fit(tele.ci[:72])
    # Deterministic given (seed, history length); different from the truth.
    np.testing.assert_array_equal(noisy_a.predict(6).mean,
                                  noisy_b.predict(6).mean)
    assert not np.allclose(noisy_a.predict(6).mean, inner.predict(6).mean)


def test_forecast_interpolation_and_window_means(tele):
    f = forecast.SeasonalNaive().fit(tele.ci[:72])
    fc = f.predict(8)
    t_issue = 71 * 3600.0
    # at(): anchors at the last observation, hits the hour grid exactly.
    np.testing.assert_allclose(fc.at(t_issue), fc.anchor)
    np.testing.assert_allclose(fc.at(t_issue + 3600.0), fc.mean[0])
    mid = fc.at(t_issue + 1800.0)
    np.testing.assert_allclose(mid, 0.5 * (fc.anchor + fc.mean[0]))
    # mean_many(): exact integral of the piecewise-linear curve — must match
    # a fine trapezoid on at_many().
    t0 = np.array([t_issue + 600.0, t_issue + 5000.0])
    t1 = t0 + np.array([3600.0, 9000.0])
    exact = fc.mean_many(t0, t1)
    for k in range(2):
        ts = np.linspace(t0[k], t1[k], 2001)
        vals = fc.at_many(ts)
        dt = ts[1] - ts[0]
        approx = (dt * (0.5 * (vals[0] + vals[-1]) + vals[1:-1].sum(axis=0))
                  / (t1[k] - t0[k]))
        np.testing.assert_allclose(exact[k], approx, rtol=1e-6)


def test_holtwinters_fit_is_jit_cached():
    """Acceptance: second fit of the same history shape ≥10× faster than the
    first (the lax.scan filter compiles once per padded shape)."""
    rng = np.random.default_rng(3)
    t = np.arange(61)
    # 7 columns: a shape no other test uses, so the first fit must compile.
    hist = (10.0 + 3.0 * np.sin(t / 24.0 * 2 * np.pi)[:, None]
            + 0.1 * rng.standard_normal((61, 7)))
    t0 = time.perf_counter()
    holtwinters.HoltWinters().fit(hist)
    first = time.perf_counter() - t0
    t0 = time.perf_counter()
    holtwinters.HoltWinters().fit(hist)
    second = time.perf_counter() - t0
    assert first >= 10.0 * second, (first, second)


def test_holtwinters_bucketing_and_fallbacks(tele):
    for rows in (48, 49, 71, 200, 10_000):
        b = holtwinters.fit_bucket_for(rows, 24)
        assert b % 24 == 0
        assert b >= min(rows, holtwinters.MAX_FIT_PERIODS * 24)
    # Short histories degrade gracefully: seasonal-naive then persistence.
    short = holtwinters.HoltWinters().fit(tele.ci[:30])
    assert short.predict(4).mean.shape == (4, 5)
    tiny = holtwinters.HoltWinters().fit(tele.ci[:3])
    assert tiny.predict(4).mean.shape == (4, 5)


def test_backtest_rejects_too_short_series(tele):
    with pytest.raises(ValueError):
        forecast.backtest(tele.ci[:10], forecast.Persistence, horizon=6,
                          warmup=48)


def test_make_forecaster_registry():
    names = forecast.list_forecasters()
    assert {"persistence", "seasonal-naive", "holtwinters",
            "learned"} <= set(names)
    with pytest.raises(KeyError):
        forecast.make_forecaster("no-such-model")


# ---------------------------------------------------------------------------
# Registry surface: did-you-mean parity + default-construction round trip
# ---------------------------------------------------------------------------

def test_make_forecaster_did_you_mean_parity():
    """Unknown forecaster names raise the same UnknownNameError surface as
    the policy/scenario registries: KeyError subclass, did-you-mean hint,
    full name list."""
    from repro.spec import UnknownNameError
    with pytest.raises(KeyError) as ei:
        forecast.make_forecaster("hotwinters")
    assert isinstance(ei.value, UnknownNameError)
    msg = str(ei.value)
    assert "did you mean 'holtwinters'" in msg
    assert "seasonal-naive" in msg          # the full list rides along


def test_every_registered_forecaster_round_trips(tele):
    """Every list_forecasters() entry constructs with defaults and
    satisfies the Forecaster interface on a tiny series (the learned model
    falls back to seasonal-naive below its training threshold — still a
    valid Forecast)."""
    for name in forecast.list_forecasters():
        f = forecast.make_forecaster(name)
        assert isinstance(f, forecast.Forecaster)
        fc = f.fit(tele.ci[:60]).predict(4)
        assert isinstance(fc, forecast.Forecast)
        assert fc.mean.shape == (4, 5)
        assert (fc.lo <= fc.mean + 1e-12).all()
        assert (fc.mean <= fc.hi + 1e-12).all()
        np.testing.assert_allclose(fc.anchor, tele.ci[59])
        # update() is part of the shared interface (walk-forward refresh).
        fc2 = f.update(tele.ci[:61]).predict(4)
        assert fc2.mean.shape == (4, 5)


def test_describe_forecasters_schema():
    md = forecast.describe_forecasters(markdown=True)
    for name in forecast.list_forecasters():
        assert f"| `{name}` |" in md
    assert "`period=24:int`" in md
    schema = forecast.forecaster_schema("learned")
    assert schema["train_steps"].type is int
    assert schema["lr"].type is float
    with pytest.raises(KeyError):
        forecast.forecaster_schema("nope")


# ---------------------------------------------------------------------------
# Backtest metric edge cases
# ---------------------------------------------------------------------------

def test_mape_edge_cases():
    const = np.full((5, 2), 3.0)
    assert forecast.mape(const, const) == 0.0
    zeros = np.zeros((4, 1))
    # Exact zero prediction of a zero truth contributes nothing...
    assert forecast.mape(zeros, zeros) == 0.0
    # ...while a nonzero prediction of zero truth is huge but finite (the
    # documented 1e-9 denominator guard), never a ZeroDivision/inf/nan.
    big = forecast.mape(zeros, np.full((4, 1), 1e-3))
    assert np.isfinite(big) and big > 1e6
    # Length-1 series work elementwise.
    assert forecast.mape(np.array([2.0]), np.array([1.0])) == \
        pytest.approx(50.0)


def test_pinball_edge_cases():
    zeros = np.zeros(4)
    assert forecast.pinball_loss(zeros, zeros, 0.1) == 0.0
    const = np.full(6, 2.5)
    assert forecast.pinball_loss(const, const, 0.9) == 0.0
    # Length-1: under-prediction at q charges q·d, over charges (1−q)·|d|.
    assert forecast.pinball_loss(np.array([1.0]), np.array([0.0]), 0.9) == \
        pytest.approx(0.9)
    assert forecast.pinball_loss(np.array([0.0]), np.array([1.0]), 0.9) == \
        pytest.approx(0.1)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.floats(-1e6, 1e6), st.floats(-1e6, 1e6)),
                min_size=1, max_size=30))
def test_pinball_at_median_is_half_mae(pairs):
    y = np.array([p[0] for p in pairs])
    p = np.array([p[1] for p in pairs])
    assert forecast.pinball_loss(y, p, 0.5) == \
        pytest.approx(0.5 * np.mean(np.abs(y - p)), rel=1e-12, abs=1e-12)


# ---------------------------------------------------------------------------
# Learned forecaster (RG-LRU head)
# ---------------------------------------------------------------------------

def test_learned_beats_seasonal_naive_walk_forward():
    """Acceptance: the learned forecaster, trained once on 7 days of
    synthetic diurnal carbon intensity, beats seasonal-naive on the
    held-out tail under the walk-forward protocol (fixed seed, fully
    deterministic)."""
    tele10 = telemetry.generate(days=10, seed=0)
    kw = dict(horizon=6, warmup=168, stride=6)
    s = forecast.backtest_telemetry(tele10, "ci", "seasonal-naive", **kw)
    l = forecast.backtest_telemetry(tele10, "ci", "learned", seed=0,
                                    refit_every=999, **kw)
    assert l["mape"] < s["mape"], (l["mape"], s["mape"])
    assert l["n_origins"] == s["n_origins"] > 5


def test_learned_interface_and_periodic_extension(tele):
    f = forecast.make_forecaster("learned", train_steps=30, seed=0)
    f.fit(tele.ci[:96])
    assert f.train_count == 1
    fc = f.predict(8)
    assert fc.mean.shape == (8, 5)
    assert (fc.lo <= fc.mean + 1e-12).all()
    assert (fc.mean <= fc.hi + 1e-12).all()
    np.testing.assert_allclose(fc.anchor, tele.ci[95])
    # Horizons past the trained 24 extend periodically from the tail.
    fc2 = f.predict(40)
    assert fc2.mean.shape == (40, 5)
    np.testing.assert_allclose(fc2.mean[24:40], fc2.mean[0:16])


def test_learned_fallback_and_refit_policy(tele):
    # Histories below the training threshold degrade to seasonal-naive.
    tiny = forecast.make_forecaster("learned").fit(tele.ci[:30])
    assert tiny.predict(4).mean.shape == (4, 5)
    assert tiny.train_count == 0
    # update() never retrains; fit() retrains on the retrain_every cadence.
    f = forecast.make_forecaster("learned", train_steps=10, retrain_every=2,
                                 seed=0)
    f.fit(tele.ci[:96])
    assert f.train_count == 1
    f.update(tele.ci[:100])
    f.update(tele.ci[:104])
    assert f.train_count == 1
    f.fit(tele.ci[:100])
    f.fit(tele.ci[:104])            # 2nd fit since training → retrain
    assert f.train_count == 2


def test_learned_checkpoint_roundtrip(tele):
    f = forecast.make_forecaster("learned", train_steps=25, seed=3)
    f.fit(tele.ci[:96])
    with tempfile.TemporaryDirectory() as d:
        path = f.save(d, step=7)
        assert os.path.exists(os.path.join(path, "state.npz"))
        g = forecast.LearnedForecaster.load(d)
        assert g.train_count == 0           # restored, not retrained
        f.update(tele.ci[:100])
        g.update(tele.ci[:100])
        np.testing.assert_allclose(g.predict(6).mean, f.predict(6).mean,
                                   rtol=1e-6)
        # The checkpoint= constructor param (the make_forecaster path).
        h = forecast.make_forecaster("learned", checkpoint=d)
        h.update(tele.ci[:100])
        np.testing.assert_allclose(h.predict(6).mean, f.predict(6).mean,
                                   rtol=1e-6)
    unfit = forecast.make_forecaster("learned")
    with pytest.raises(ValueError):
        unfit.save("/tmp/never-written")


def test_learned_pallas_inference_matches_assoc(tele):
    """The scan_impl="pallas" inference path (the repro.kernels.rglru_scan
    kernel, interpret mode on CPU) agrees with the associative scan."""
    fa = forecast.make_forecaster("learned", train_steps=5, seed=0)
    fp = forecast.make_forecaster("learned", train_steps=5, seed=0,
                                  scan_impl="pallas")
    fa.fit(tele.ci[:96])
    fp.fit(tele.ci[:96])
    np.testing.assert_allclose(fp.predict(6).mean, fa.predict(6).mean,
                               rtol=1e-4, atol=1e-4)


def test_backtest_refit_every_updates_between_refits(tele):
    """The walk-forward harness fully refits on the cadence and updates in
    between — for the learned model that means exactly one training run."""
    r = forecast.backtest_telemetry(tele, "ci", "learned", horizon=6,
                                    warmup=96, stride=6, refit_every=999,
                                    train_steps=5, seed=0)
    assert r["n_origins"] > 3          # walked multiple origins, one train
