"""Observability layer: exact histogram quantiles, associative snapshot
merge, span nesting in the exported Chrome trace, warning counters on the
degenerate paths, the report CLI, and the disabled-mode pin (obs off and
obs on produce bit-identical engine records)."""
import json
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.obs as obs
from repro.obs.metrics import (HIST_BASE, Histogram, MetricsRegistry,
                               bucket_bounds, bucket_index, merge_snapshots)


# ---------------------------------------------------------------------------
# histogram quantiles
# ---------------------------------------------------------------------------

def test_quantiles_exact_vs_numpy():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-3.0, sigma=2.0, size=997)
    h = Histogram()
    for v in vals:
        h.observe(v)
    for q in (0, 10, 50, 90, 95, 99, 100):
        assert h.quantile(q) == pytest.approx(np.percentile(vals, q),
                                              rel=0, abs=1e-12)
    assert h.count == len(vals)
    assert h.mean == pytest.approx(vals.mean())


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(min_value=1e-9, max_value=1e6,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=300),
       st.floats(min_value=0.0, max_value=100.0))
def test_quantiles_exact_property(vals, q):
    h = Histogram()
    for v in vals:
        h.observe(v)
    assert h.quantile(q) == pytest.approx(np.percentile(vals, q),
                                          rel=1e-12, abs=1e-15)


def test_quantile_bounded_error_after_overflow():
    """Once the sample buffer drops, bucket quantiles stay within the
    bucket base's relative error of the exact answer."""
    rng = np.random.default_rng(1)
    vals = rng.lognormal(mean=0.0, sigma=1.5, size=2000)
    h = Histogram(max_samples=100)           # force overflow
    for v in vals:
        h.observe(v)
    assert h.samples is None
    for q in (50, 95, 99):
        exact = np.percentile(vals, q)
        assert h.quantile(q) == pytest.approx(exact, rel=HIST_BASE - 1.0)


def test_bucket_geometry():
    for v in (1e-6, 0.37, 1.0, 42.0):
        lo, hi = bucket_bounds(bucket_index(v))
        assert lo < v <= hi or v <= lo  # <=: values clamp at the tiny floor
    assert bucket_index(0.0) == bucket_index(-5.0)   # non-positive clamps


# ---------------------------------------------------------------------------
# snapshot / merge
# ---------------------------------------------------------------------------

def _registry_with(vals, counters=(), gauges=()):
    r = MetricsRegistry()
    for v in vals:
        r.observe("lat", v)
    for name, n in counters:
        r.counter(name, n)
    for name, v, w in gauges:
        r.gauge(name, v, w)
    return r


def test_merge_is_associative():
    rng = np.random.default_rng(2)
    parts = [rng.lognormal(size=40) for _ in range(3)]
    snaps = [_registry_with(p, counters=[("n", len(p))],
                            gauges=[("g", p.mean(), len(p))]).snapshot()
             for p in parts]
    ab_c = merge_snapshots([merge_snapshots(snaps[:2]), snaps[2]])
    a_bc = merge_snapshots([snaps[0], merge_snapshots(snaps[1:])])
    assert ab_c["counters"] == a_bc["counters"]
    assert ab_c["gauges"]["g"]["weight"] == a_bc["gauges"]["g"]["weight"]
    assert ab_c["gauges"]["g"]["value"] == pytest.approx(
        a_bc["gauges"]["g"]["value"])
    ha, hb = ab_c["hists"]["lat"], a_bc["hists"]["lat"]
    assert ha["counts"] == hb["counts"] and ha["count"] == hb["count"]
    assert sorted(ha["samples"]) == sorted(hb["samples"])


def test_merged_quantile_equals_pooled():
    rng = np.random.default_rng(3)
    parts = [rng.lognormal(size=50) for _ in range(4)]
    merged = merge_snapshots(
        [_registry_with(p).snapshot() for p in parts])
    reg = MetricsRegistry()
    reg.merge(merged)
    pooled = np.concatenate(parts)
    assert reg.hists["lat"].quantile(95) == pytest.approx(
        np.percentile(pooled, 95), abs=1e-12)


def test_gauge_merge_is_weighted_mean():
    reg = MetricsRegistry()
    reg.gauge("depth", 10.0, weight=1.0)
    reg.merge({"gauges": {"depth": {"value": 40.0, "weight": 3.0}},
               "counters": {}, "hists": {}})
    g = reg.gauges["depth"]
    assert g.weight == 4.0
    assert g.value == pytest.approx((10.0 * 1 + 40.0 * 3) / 4)


def test_snapshot_is_json_round_trippable():
    snap = _registry_with([0.1, 0.2], counters=[("c", 2)],
                          gauges=[("g", 1.0, 1.0)]).snapshot()
    reg = MetricsRegistry()
    reg.merge(json.loads(json.dumps(snap)))
    assert reg.hists["lat"].count == 2
    assert reg.counters["c"].value == 2


# ---------------------------------------------------------------------------
# spans and the exported trace
# ---------------------------------------------------------------------------

def test_span_nesting_in_exported_trace(tmp_path):
    path = tmp_path / "t.trace.jsonl"
    with obs.capture(trace_path=str(path)):
        with obs.span("outer", kind="round"):
            with obs.span("inner.a"):
                obs.annotate(jobs=3)
            with obs.span("inner.b"):
                pass
    events = obs.read_trace(str(path))
    assert obs.validate_events(events) == []
    spans = {e["name"]: e for e in events if e["ph"] == "X"}
    assert set(spans) == {"outer", "inner.a", "inner.b"}
    out, a, b = spans["outer"], spans["inner.a"], spans["inner.b"]
    # containment: children inside the parent interval, a before b
    assert out["ts"] <= a["ts"] and a["ts"] + a["dur"] <= out["ts"] + out["dur"]
    assert out["ts"] <= b["ts"] and b["ts"] + b["dur"] <= out["ts"] + out["dur"]
    assert a["ts"] + a["dur"] <= b["ts"]
    assert a["args"]["jobs"] == 3            # annotate hit the open span
    assert out["args"]["kind"] == "round"


def test_span_observes_histogram():
    with obs.capture() as reg:
        with obs.span("stage"):
            pass
        with obs.span("stage"):
            pass
        assert reg.hists["stage"].count == 2


def test_timed_measures_when_disabled():
    assert not obs.enabled()
    with obs.timed("anything") as t:
        sum(range(1000))
    assert t.elapsed_s > 0.0
    assert "anything" not in obs.registry().hists   # no metric recorded


def test_disabled_span_is_shared_noop():
    assert not obs.enabled()
    s1, s2 = obs.span("a"), obs.span("b", x=1)
    assert s1 is s2                          # the whole disabled-mode cost


def test_capture_restores_and_folds():
    obs.reset()
    with obs.capture():
        obs.observe("inner", 1.0)
    assert not obs.enabled()
    assert obs.registry().hists["inner"].count == 1   # folded out
    with obs.capture(fold=False):
        obs.observe("dropped", 1.0)
    assert "dropped" not in obs.registry().hists
    obs.reset()


# ---------------------------------------------------------------------------
# warning counters on the degenerate paths
# ---------------------------------------------------------------------------

def test_bucket_overflow_warn_counter():
    from repro.core.solvers import jax_solver
    obs.reset()
    rows = jax_solver.BUCKETS[-1] + 1
    # The overflow warning fires once per ad-hoc size; re-arm in case an
    # earlier test already overflowed into the same bucket.
    jax_solver._OVERFLOW_WARNED.discard(2 * jax_solver.BUCKETS[-1])
    before = obs.counter_value("warn/solver.bucket_overflow")
    with pytest.warns(RuntimeWarning, match="padded bucket"):
        warnings.simplefilter("always")
        b = jax_solver.bucket_for(rows)
    assert b >= rows
    assert obs.counter_value("warn/solver.bucket_overflow") == before + 1
    # ...and is deduplicated on repeat overflows of that size.
    jax_solver.bucket_for(rows)
    assert obs.counter_value("warn/solver.bucket_overflow") == before + 1


def test_forecaster_fallback_warn_counter():
    from repro.forecast import make_forecaster
    obs.reset()
    f = make_forecaster("learned", train_steps=2, seed=0)
    with pytest.warns(RuntimeWarning, match="seasonal-naive"):
        warnings.simplefilter("always")
        f.fit(np.abs(np.random.default_rng(0).normal(size=(6, 3))) + 1.0)
    assert obs.counter_value("warn/forecast.fallback_seasonal_naive") >= 1


def test_degenerate_wan_warn_counter(monkeypatch):
    from repro.core import telemetry
    bw = telemetry.WAN_BW_GBPS.copy()
    bw[0, 1] = bw[1, 0] = 0.0                # knock out one WAN link
    monkeypatch.setattr(telemetry, "WAN_BW_GBPS", bw)
    obs.reset()
    with pytest.warns(RuntimeWarning, match="WAN"):
        warnings.simplefilter("always")
        tele = telemetry.generate(days=1, seed=0)
    assert obs.counter_value("warn/telemetry.degenerate_wan") >= 1
    assert (tele.bw_gbps[0, 1] > 0.0).all()  # patched, not left at zero


# ---------------------------------------------------------------------------
# disabled-mode pin: obs on vs off is bit-identical engine output
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_records_bit_identical_obs_on_vs_off(tmp_path):
    from repro.experiments.plan import Cell
    from repro.experiments.runner import run_cell
    from repro.experiments.scenario import parse_scenario
    from repro import policy

    cell = Cell(parse_scenario("diurnal[days=0.05,jobs_per_day=2000]"),
                policy.as_spec("waterwise[backend=jax]"), 0)
    assert not obs.enabled()
    off = run_cell(cell, return_result=True)
    with obs.capture(trace_path=str(tmp_path / "cell.trace.jsonl")):
        on = run_cell(cell, return_result=True)

    def key(r):
        return (r.job.job_id, r.region, r.start_s, r.finish_s,
                r.carbon_g, r.water_l)

    assert [key(r) for r in off["_result"]["records"]] \
        == [key(r) for r in on["_result"]["records"]]
    for col in ("carbon_kg", "water_kl", "violation_pct", "utilization"):
        assert off[col] == on[col]


# ---------------------------------------------------------------------------
# report CLI
# ---------------------------------------------------------------------------

def _tiny_trace(path):
    with obs.capture(trace_path=str(path)):
        for i in range(6):
            with obs.span("solver.solve", sinkhorn_iters=360,
                          residual=1e-5 * (i + 1)):
                sum(range(200))
        tr = obs.tracer()
        tr.counter("sim/carbon_g", {"R0": 10.0 * (1 + 0)}, ts_us=0.0,
                   pid=obs.SIM_PID)
        tr.counter("sim/carbon_g", {"R0": 20.0}, ts_us=3.6e9,
                   pid=obs.SIM_PID)


def test_report_cli_smoke(tmp_path, capsys):
    from repro.obs import report
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _tiny_trace(a)
    _tiny_trace(b)
    assert report.main([str(a)]) == 0
    out = capsys.readouterr().out
    assert "solver.solve" in out and "p99_ms" in out
    assert "360" in out                       # sinkhorn iters column
    assert report.main([str(a), "--validate"]) == 0
    assert "schema OK" in capsys.readouterr().out
    assert report.main(["--diff", str(a), str(b)]) == 0
    out = capsys.readouterr().out
    assert "solver.solve" in out and "Δp99" in out


def test_report_rejects_bad_schema(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('[\n{"name": "x", "ph": "Q", "ts": 0},\n')
    from repro.obs import report
    assert report.main([str(bad), "--validate"]) == 1
