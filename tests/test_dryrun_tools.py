"""Dry-run tooling: HLO collective parser, artifact detector, grad-accum
sizing. (The heavy compiles themselves run via launch/dryrun.py — their
outputs are asserted in test_dryrun_results.py when present.)"""
import glob
import json
import os

import pytest

from repro.configs import SHAPES, get_config
from repro.launch import dryrun

SAMPLE_HLO = """
  %ag = bf16[16,512,128]{2,1,0} all-gather(bf16[1,512,128]{2,1,0} %p0), replica_groups=...
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %p1), to_apply=%add
  %rs.1 = f32[64,32]{1,0} reduce-scatter(f32[1024,32]{1,0} %p2), dimensions={0}
  %a2a = bf16[8,64]{1,0} all-to-all(bf16[8,64]{1,0} %p3), dimensions={0}
  %cp = bf16[128]{0} collective-permute(bf16[128]{0} %p4), source_target_pairs=...
  %ards = (f32[256]{0}, f32[256]{0}) all-reduce-start(f32[256]{0} %p5, f32[256]{0} %p6)
  %x = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""


def test_collective_parser_kinds_and_bytes():
    out = dryrun.collective_bytes(SAMPLE_HLO)
    assert out["all-gather"] == 16 * 512 * 128 * 2
    assert out["all-reduce"] == 2 * (1024 * 4) + 2 * (256 * 4 * 2)
    assert out["reduce-scatter"] == 64 * 32 * 4
    assert out["all-to-all"] == 8 * 64 * 2
    assert out["collective-permute"] == 128 * 2
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_f32_widened_stack_detector():
    hlo = """
      %d1 = bf16[80,1,4096,8192]{3,2,1,0} dynamic-update-slice(%a, %b, %i)
      %d2 = f32[80,1,4096,8192]{3,2,1,0} dynamic-update-slice(%c, %d, %i)
      %d3 = f32[10,10]{1,0} dynamic-update-slice(%e, %f, %i)
    """
    b = dryrun.f32_widened_stack_bytes(hlo)
    assert b == 80 * 1 * 4096 * 8192 * 4


def test_grad_accum_sizing():
    cfg = get_config("qwen2_72b")
    assert dryrun._grad_accum_for(cfg, SHAPES["train_4k"]) == 16
    assert dryrun._grad_accum_for(cfg, SHAPES["prefill_32k"]) == 2


def test_skip_rule_matches_assignment():
    """long_500k must be buildable exactly for the sub-quadratic archs."""
    sub_q = {"gemma3_4b", "recurrentgemma_2b", "mamba2_2_7b"}
    from repro.configs import list_archs
    for arch in list_archs():
        cfg = get_config(arch)
        assert cfg.sub_quadratic == (arch in sub_q), arch


RESULTS = sorted(glob.glob("results/dryrun/*.baseline.json"))


@pytest.mark.skipif(not RESULTS, reason="dry-run results not generated")
def test_dryrun_results_complete_and_fit():
    """Every runnable (arch × shape × mesh) cell compiled; decode/prefill
    cells fit v5e HBM outright; train cells fit after removing the
    documented CPU-backend f32-stack artifact (see EXPERIMENTS.md)."""
    seen = {}
    for path in RESULTS:
        d = json.load(open(path))
        key = (d["arch"], d["shape"], d.get("multi_pod", False))
        seen[key] = d
    from repro.configs import list_archs
    runnable = 0
    for arch in list_archs():
        for shape in SHAPES:
            for mp in (False, True):
                key = (arch, shape, mp)
                assert key in seen, f"missing cell {key}"
                d = seen[key]
                if d.get("skipped"):
                    assert shape == "long_500k"
                    continue
                runnable += 1
                assert d["roofline"]["t_compute"] > 0
    assert runnable == 66  # 10 archs × 3 shapes × 2 meshes + 3 × long × 2
