"""Declarative experiment API: ScenarioSpec grammar, ExperimentPlan JSON,
executor backend parity (serial == process == sharded, bit-identical
totals), arrival-time trace slicing, engine-state handoff, and sweep
failure handling."""
import copy
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import experiments, policy
from repro.sim import scenarios
from repro.sim.engine import EventSimulator
from repro.sim.trace import (borg_trace, pick_shard_boundaries,
                             slice_by_arrival)
from repro.spec import (ParamValueError, SpecSyntaxError, UnknownNameError,
                        UnknownParamError, split_specs)

CELL = "diurnal[days=0.1,jobs_per_day=20000.0,tolerance=0.5]"


# ---------------------------------------------------------------------------
# ScenarioSpec grammar
# ---------------------------------------------------------------------------

def test_scenario_spec_typed_params_and_round_trip():
    spec = experiments.parse_scenario(
        "diurnal[days=10.0,jobs_per_day=1e6,tolerance=0.5,seed=3]")
    assert spec.name == "diurnal"
    assert spec.params == {"days": 10.0, "jobs_per_day": 1e6,
                           "tolerance": 0.5, "seed": 3}
    assert isinstance(spec.params["seed"], int)
    assert isinstance(spec.params["jobs_per_day"], float)
    assert experiments.parse_scenario(str(spec)) == spec
    assert experiments.parse_scenario("nominal[]") == \
        experiments.parse_scenario("nominal")
    # Builder params come from the builder signature (trace, ewif_table...).
    spec = experiments.parse_scenario("burst-storm[trace=alibaba]")
    assert spec.params == {"trace": "alibaba"}


def test_scenario_spec_errors_have_did_you_mean():
    with pytest.raises(UnknownNameError, match="diurnal"):
        experiments.parse_scenario("diurnl")
    with pytest.raises(KeyError):            # UnknownNameError is a KeyError
        experiments.parse_scenario("no-such-regime")
    with pytest.raises(UnknownParamError, match="jobs_per_day"):
        experiments.parse_scenario("diurnal[jobs_per_da=1.0]")
    with pytest.raises(ParamValueError, match="float"):
        experiments.parse_scenario("diurnal[days=abc]")
    with pytest.raises(ParamValueError, match="int"):
        experiments.parse_scenario("diurnal[seed=1.5]")
    with pytest.raises(SpecSyntaxError):
        experiments.parse_scenario("diurnal[days=1")


def test_scenario_spec_split_and_cell_kwargs():
    spec = experiments.parse_scenario("diurnal[days=0.5,trace=alibaba]")
    cell = spec.cell_kwargs()
    assert cell["days"] == 0.5 and cell["seed"] == 0
    assert cell["jobs_per_day"] == 23000.0 and cell["window_s"] == 30.0
    assert spec.build_kwargs() == {"trace": "alibaba"}
    over = spec.with_params(seed=7)
    assert over.params["seed"] == 7 and over.params["days"] == 0.5
    kept = spec.with_defaults(days=9.0, seed=7)
    assert kept.params["days"] == 0.5 and kept.params["seed"] == 7


def _scenario_spec_strategy():
    def params_for(name):
        schema = experiments.scenario_schema(name)
        by_type = {
            float: st.floats(allow_nan=False, allow_infinity=False,
                             width=64),
            int: st.integers(-10**9, 10**9),
            bool: st.booleans(),
            str: st.text(alphabet="abcdefghijklmnopqrstuvwxyz0123456789_-",
                         min_size=1, max_size=12),
        }
        opts = {k: by_type[p.type] for k, p in schema.items()}
        return st.fixed_dictionaries({}, optional=opts).map(
            lambda d: experiments.ScenarioSpec(name, d))
    return st.sampled_from(scenarios.list_scenarios()).flatmap(params_for)


@settings(max_examples=100, deadline=None)
@given(spec=_scenario_spec_strategy())
def test_scenario_spec_format_parse_round_trip_property(spec):
    text = spec.format()
    back = experiments.parse_scenario(text)
    assert back == spec
    assert back.format() == text


# ---------------------------------------------------------------------------
# ExperimentPlan
# ---------------------------------------------------------------------------

def test_plan_cells_cross_product_and_json_round_trip(tmp_path):
    plan = experiments.ExperimentPlan.build(
        scenarios=["diurnal[days=0.05]", "drought-summer"],
        policies=["baseline", "waterwise[lam_h2o=0.7]"],
        seeds=[0, 1])
    cells = plan.cells()
    assert len(cells) == 8                   # 2 scenarios × 2 seeds × 2 pols
    # Scenario-major, then seed, then policy (the old sweep's row order).
    assert [  (c.scenario.name, c.seed, c.policy.name) for c in cells[:4]] == \
        [("diurnal", 0, "baseline"), ("diurnal", 0, "waterwise"),
         ("diurnal", 1, "baseline"), ("diurnal", 1, "waterwise")]
    assert cells[0].resolved_scenario().params["seed"] == 0
    assert cells[2].resolved_scenario().params["seed"] == 1

    back = experiments.ExperimentPlan.from_json(plan.to_json())
    assert back == plan
    path = tmp_path / "plan.json"
    plan.save(str(path))
    assert experiments.ExperimentPlan.load(str(path)) == plan
    with pytest.raises(ValueError, match="unknown ExperimentPlan keys"):
        experiments.ExperimentPlan.from_json('{"scenarios": [], "pols": []}')


def test_plan_validates_up_front():
    with pytest.raises(UnknownNameError):
        experiments.ExperimentPlan.build(["nominl"], ["baseline"])
    with pytest.raises(UnknownNameError):
        experiments.ExperimentPlan.build(["nominal"], ["baselin"])
    with pytest.raises(UnknownParamError):
        experiments.ExperimentPlan.build(["nominal[dayz=1.0]"], ["baseline"])


def test_executor_specs_share_the_grammar():
    ex = experiments.get_executor("sharded[shards=4,handoff_s=100.0]")
    assert (ex.shards, ex.handoff_s) == (4, 100.0)
    ex = experiments.get_executor("process", max_workers=3)
    assert ex.max_workers == 3
    with pytest.raises(UnknownNameError, match="sharded"):
        experiments.get_executor("sharted")
    with pytest.raises(UnknownParamError, match="shards"):
        experiments.get_executor("sharded[shard=2]")
    assert set(experiments.list_executors()) == \
        {"serial", "process", "sharded", "device"}


# ---------------------------------------------------------------------------
# Arrival-time slicing (the sharded executor's partition)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), shards=st.integers(1, 6))
def test_slice_by_arrival_partitions_exactly(seed, shards):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(0, 200))
    jobs = borg_trace(days=0.05, seed=seed, tolerance=0.5)[:n]
    boundaries = pick_shard_boundaries(jobs, shards)
    assert len(boundaries) <= shards - 1
    assert boundaries == sorted(boundaries)
    slices = slice_by_arrival(jobs, boundaries)
    assert len(slices) == len(boundaries) + 1
    # Exact partition: no loss, no duplication.
    merged = [j.job_id for sl in slices for j in sl]
    assert sorted(merged) == sorted(j.job_id for j in jobs)
    assert len(merged) == len(jobs)
    # Arrival-contiguous: every job in slice k respects the boundaries, and
    # input order is preserved within each slice.
    for k, sl in enumerate(slices):
        lo = boundaries[k - 1] if k > 0 else -np.inf
        hi = boundaries[k] if k < len(boundaries) else np.inf
        for j in sl:
            assert lo <= j.submit_time_s < hi
        ids = [j.job_id for j in sl]
        in_order = [j.job_id for j in jobs if j.job_id in set(ids)]
        assert ids == in_order


# ---------------------------------------------------------------------------
# Engine-state handoff: chained slice runs == one uninterrupted run
# ---------------------------------------------------------------------------

def _record_sig(res):
    return [(r.job.job_id, r.region, r.start_s, r.finish_s, r.carbon_g,
             r.water_l) for r in res["records"]]


@pytest.mark.parametrize("spec", ["round-robin",
                                  "waterwise-forecast[warmup_hours=4]"])
def test_chained_handoff_matches_single_run_bitwise(spec):
    """Stateful schedulers shard exactly through the engine-state handoff:
    stopping/exporting at boundaries and resuming with the same scheduler
    object reproduces the single run's records bit-for-bit."""
    inst = scenarios.get_scenario("nominal").build(0.05, 0, 23000.0, 0.15)
    single = EventSimulator(inst.tele, inst.capacity).run(
        copy.deepcopy(inst.jobs), spec)

    jobs = copy.deepcopy(inst.jobs)
    boundaries = pick_shard_boundaries(jobs, 3)
    slices = slice_by_arrival(jobs, boundaries)
    sched = policy.build(spec, inst.tele)
    sim = EventSimulator(inst.tele, inst.capacity)
    state, merged = None, []
    for k, sl in enumerate(slices):
        stop = boundaries[k] if k < len(boundaries) else None
        res = sim.run(sl, sched, state=state, stop_at=stop,
                      export_state=stop is not None)
        state = res.get("state")
        merged += _record_sig(res)
    assert merged == _record_sig(single)


# ---------------------------------------------------------------------------
# Executor backend parity (acceptance: identical tidy rows)
# ---------------------------------------------------------------------------

# Timing-derived columns can never be bit-stable; merged utilization is
# recomposed from per-slice integrals (equal in value, float association
# differs — compared approximately below).
_NONDET_COLS = ("wall_s", "mean_solve_ms", "utilization")


def _assert_rows_match(a, b):
    assert set(a) - {"_result"} == set(b) - {"_result"}
    for key in a:
        if key in _NONDET_COLS or key.startswith("_"):
            continue
        assert a[key] == b[key], f"column {key!r}: {a[key]} != {b[key]}"
    assert a["utilization"] == pytest.approx(b["utilization"], rel=1e-9)


def test_serial_process_sharded_backends_produce_identical_rows():
    """Acceptance: the three executors are interchangeable — identical
    rows, carbon/water totals bit-identical, on a 2-shard diurnal cell for
    both a stateless policy (speculative parallel path) and a stateful
    one (chained handoff path)."""
    plan = experiments.ExperimentPlan.build(
        scenarios=[CELL], policies=["baseline", "waterwise[backend=flow]"])
    serial = plan.run(executor="serial")
    process = plan.run(executor="process[max_workers=2]")
    sharded = plan.run(executor="sharded[shards=2]")
    assert len(serial) == len(process) == len(sharded) == 2
    for s, p, sh in zip(serial, process, sharded):
        _assert_rows_match(s, p)
        _assert_rows_match(s, sh)
        assert s["carbon_kg"] == p["carbon_kg"] == sh["carbon_kg"]
        assert s["water_kl"] == p["water_kl"] == sh["water_kl"]
        assert s["violation_pct"] == p["violation_pct"] == sh["violation_pct"]
        assert not s["error"]


def test_sharded_rows_reparse_and_seed_axis():
    plan = experiments.ExperimentPlan.build(
        scenarios=["diurnal[days=0.05]"], policies=["baseline"],
        seeds=[0, 1])
    rows = plan.run(executor="sharded[shards=2]")
    assert [r["seed"] for r in rows] == [0, 1]
    assert rows[0]["carbon_kg"] != rows[1]["carbon_kg"]   # seeds differ
    for row in rows:
        sc = experiments.parse_scenario(row["scenario_spec"])
        assert sc.params["seed"] == row["seed"]
        assert policy.parse(row["spec"]).name == row["scheduler"]


# ---------------------------------------------------------------------------
# Failure handling (satellite: one crashed cell never aborts the sweep)
# ---------------------------------------------------------------------------

@pytest.fixture()
def crash_scenario():
    @scenarios.register("crash-test", "always-raising builder (tests only)")
    def _crash(days, seed, jobs_per_day, utilization, **kw):
        raise RuntimeError("builder exploded")
    yield "crash-test"
    scenarios._REGISTRY.pop("crash-test", None)


def test_failed_cell_records_error_row_and_others_finish(crash_scenario):
    plan = experiments.ExperimentPlan.build(
        scenarios=["crash-test", "diurnal[days=0.02]"],
        policies=["baseline"])
    rows = plan.run(executor="serial")
    assert len(rows) == 2
    bad, good = rows
    assert "builder exploded" in bad["error"]
    assert "carbon_kg" not in bad                    # metrics stay empty
    assert good["error"] == "" and good["jobs"] > 0


def test_sweep_raises_enriched_error_after_finishing_other_cells(
        crash_scenario):
    with pytest.raises(experiments.CellError) as ei:
        scenarios.sweep(["baseline"], ["crash-test", "diurnal"], days=0.02,
                        max_workers=1)
    err = ei.value
    assert "crash-test" in err.scenario and err.spec == "baseline"
    assert "builder exploded" in str(err)
    # Every other cell finished; all rows ride on the exception.
    assert len(err.rows) == 2
    good = [r for r in err.rows if not r.get("error")]
    assert len(good) == 1 and good[0]["scenario"] == "diurnal"


def test_process_executor_survives_worker_crash(crash_scenario):
    plan = experiments.ExperimentPlan.build(
        scenarios=["crash-test", "diurnal[days=0.02]"],
        policies=["baseline"])
    rows = plan.run(executor="process[max_workers=2]")
    assert "builder exploded" in rows[0]["error"]
    assert rows[1]["error"] == "" and rows[1]["jobs"] > 0


# ---------------------------------------------------------------------------
# Shard-merged forecast/deferral fields (satellite: job-weighted, never
# dropped when only some shards defer)
# ---------------------------------------------------------------------------

def test_merge_forecast_stats_is_job_weighted():
    merged = experiments.merge_forecast_stats([
        dict(forecast_mape=10.0, mean_defer_s=100.0, deferred_jobs=50,
             jobs=100, deferred_pct=50.0),
        dict(forecast_mape=20.0, mean_defer_s=300.0, deferred_jobs=0,
             jobs=300, deferred_pct=0.0),      # this shard never defers
    ])
    assert merged["jobs"] == 400 and merged["deferred_jobs"] == 50
    assert merged["forecast_mape"] == pytest.approx(
        (10.0 * 100 + 20.0 * 300) / 400)
    # mean_defer_s weights by *deferred* jobs: the non-deferring shard
    # contributes nothing instead of diluting the average.
    assert merged["mean_defer_s"] == pytest.approx(100.0)
    assert merged["deferred_pct"] == pytest.approx(12.5)


def test_merge_forecast_stats_absent_for_non_forecast_policies():
    assert experiments.merge_forecast_stats([None, None]) is None
    one = experiments.merge_forecast_stats(
        [None, dict(forecast_mape=5.0, mean_defer_s=60.0, deferred_jobs=2,
                    jobs=10, deferred_pct=20.0)])
    assert one is not None and one["deferred_jobs"] == 2


def test_sharded_forecast_cell_matches_serial_stats():
    """A forecast policy sharded (chained handoff) reports the same
    deferral telemetry as the serial run — the fields survive the merge."""
    plan = experiments.ExperimentPlan.build(
        scenarios=["diurnal[days=0.05,tolerance=3.0]"],
        policies=["waterwise-forecast[warmup_hours=4]"])
    serial = plan.run(executor="serial")[0]
    sharded = plan.run(executor="sharded[shards=2]")[0]
    assert serial["deferred_pct"] == sharded["deferred_pct"]
    assert serial["forecast_mape"] == sharded["forecast_mape"]
    assert serial["mean_defer_s"] == sharded["mean_defer_s"]


# ---------------------------------------------------------------------------
# Opt-in scale check (acceptance: >=200k-job cell, bit-identical totals;
# >=2.5x wall-clock at 4 shards on machines with >=4 CPUs)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not os.environ.get("REPRO_SHARD_PERF"),
                    reason="set REPRO_SHARD_PERF=1 to run the 200k-job "
                           "sharded parity + speedup check (minutes)")
def test_sharded_200k_cell_parity_and_speedup():
    import time
    plan = experiments.ExperimentPlan.build(
        scenarios=["diurnal[days=2.0,jobs_per_day=1.05e5,tolerance=0.5]"],
        policies=["water-greedy-opt"])
    t0 = time.perf_counter()
    serial = plan.run(executor="serial")[0]
    t_serial = time.perf_counter() - t0
    assert serial["jobs"] >= 200_000
    t0 = time.perf_counter()
    sharded = plan.run(executor="sharded[shards=4]")[0]
    t_sharded = time.perf_counter() - t0
    assert sharded["carbon_kg"] == serial["carbon_kg"]
    assert sharded["water_kl"] == serial["water_kl"]
    assert sharded["violation_pct"] == serial["violation_pct"]
    assert sharded["jobs"] == serial["jobs"]
    speedup = t_serial / t_sharded
    print(f"\n# sharded 200k cell: serial {t_serial:.1f}s, "
          f"4-shard {t_sharded:.1f}s, speedup {speedup:.2f}x "
          f"({os.cpu_count()} CPUs)")
    if (os.cpu_count() or 1) >= 4:
        assert speedup >= 2.5


def test_more_shards_than_arrivals_degrades_gracefully():
    """Degenerate shard counts yield fewer boundaries instead of crashing
    (and the sharded executor still produces the exact row)."""
    jobs = borg_trace(days=0.01, seed=0, tolerance=0.5)[:4]
    bounds = pick_shard_boundaries(jobs, 10)
    assert len(bounds) <= 3
    plan = experiments.ExperimentPlan.build(
        scenarios=["diurnal[days=0.01]"], policies=["baseline"])
    rows = plan.run(executor="sharded[shards=64,max_workers=1]")
    assert rows[0]["error"] == "" and rows[0]["jobs"] > 0


def test_savings_group_by_scenario_spec_not_name():
    """Two param-variants of one scenario each get their own baseline."""
    small = "diurnal[days=0.03,jobs_per_day=10000.0]"
    big = "diurnal[days=0.03,jobs_per_day=40000.0]"
    rows = experiments.ExperimentPlan.build(
        scenarios=[small, big],
        policies=["baseline", "least-load"]).run(executor="serial")
    by = {(r["scenario_spec"], r["scheduler"]): r for r in rows}
    for spec in (small, big):
        base = by[(spec, "baseline")]
        other = by[(spec, "least-load")]
        assert base["carbon_savings_pct"] == 0.0
        expected = 100.0 * (base["carbon_kg"] - other["carbon_kg"]) \
            / base["carbon_kg"]
        assert other["carbon_savings_pct"] == pytest.approx(expected)


def test_split_specs_reexported_for_scenario_lists():
    assert split_specs("a[x=1,y=2], b ,c[z=3]") == \
        ["a[x=1,y=2]", "b", "c[z=3]"]


# ---------------------------------------------------------------------------
# Multi-seed confidence intervals (ROADMAP: rolling multi-seed studies)
# ---------------------------------------------------------------------------

def test_aggregate_seeds_ci_math_pinned():
    """CI math on a fixed 3-seed cell: mean ± t_{0.975,2}·s/√3 with the
    sample std (ddof=1), exactly."""
    rows = [dict(scenario="nominal", scheduler="baseline", spec="baseline",
                 scenario_spec=f"nominal[days=0.2,seed={s}]", seed=s,
                 error="", carbon_kg=v, jobs=100)
            for s, v in zip((0, 1, 2), (10.0, 12.0, 14.0))]
    agg = experiments.aggregate_seeds(rows)
    assert len(agg) == 1
    a = agg[0]
    assert a["n_seeds"] == 3 and a["seed"] == "0,1,2"
    # The aggregated row's spec columns are the seed-stripped group
    # identity, not the first replicate's seed-bearing spec.
    assert a["scenario_spec"] == "nominal[days=0.2]"
    assert a["carbon_kg"] == pytest.approx(12.0)
    # sample std of (10, 12, 14) is 2.0; t_{0.975, df=2} = 4.302652729911275
    assert a["carbon_kg_ci95"] == pytest.approx(
        4.302652729911275 * 2.0 / np.sqrt(3.0), rel=1e-12)
    assert experiments.t95(2) == pytest.approx(4.302652729911275)
    assert experiments.t95(1000) == pytest.approx(1.959963984540054)
    # Zero-variance metrics aggregate to ±0.00.
    assert a["jobs"] == pytest.approx(100.0)
    assert a["jobs_ci95"] == pytest.approx(0.0)


def test_to_table_emits_ci_columns_for_multi_seed_rows():
    rows = [dict(scenario="nominal", scheduler="baseline", spec="baseline",
                 scenario_spec=f"nominal[days=0.2,seed={s}]", seed=s,
                 error="", carbon_kg=v)
            for s, v in zip((0, 1, 2), (10.0, 12.0, 14.0))]
    table = experiments.to_table(rows, ("scenario", "scheduler",
                                        "carbon_kg"))
    assert "12.00±4.97" in table
    assert table.count("baseline") == 1          # collapsed to one line
    # Single-seed rows render unchanged, and ci=False disables aggregation.
    assert "±" not in experiments.to_table(rows[:1],
                                           ("scenario", "carbon_kg"))
    assert "±" not in experiments.to_table(rows, ("scenario", "carbon_kg"),
                                           ci=False)


def test_seed_group_key_strips_seed_and_forecast_seed():
    a = dict(scenario_spec="nominal[days=0.2,seed=0]",
             spec="waterwise-forecast[forecast_bias=1.3,forecast_seed=0]")
    b = dict(scenario_spec="nominal[days=0.2,seed=1]",
             spec="waterwise-forecast[forecast_bias=1.3,forecast_seed=1]")
    assert experiments.seed_group_key(a) == experiments.seed_group_key(b)
    c = dict(scenario_spec="nominal[days=0.5,seed=1]", spec="waterwise")
    assert experiments.seed_group_key(a) != experiments.seed_group_key(c)


def test_multi_seed_plan_end_to_end_ci():
    """A real 3-seed plan: one aggregated row per cell, CI columns on the
    metrics, error-free."""
    plan = experiments.ExperimentPlan.build(
        scenarios=["nominal[days=0.02]"], policies=["baseline"],
        seeds=[0, 1, 2])
    rows = plan.run(executor="serial")
    assert len(rows) == 3
    assert sorted(r["seed"] for r in rows) == [0, 1, 2]
    agg = experiments.aggregate_seeds(rows)
    assert len(agg) == 1
    assert agg[0]["n_seeds"] == 3
    assert agg[0]["carbon_kg_ci95"] >= 0.0
    assert "±" in experiments.to_table(rows)


def test_aggregate_seeds_keeps_error_rows_unaggregated():
    ok = [dict(scenario="nominal", scheduler="baseline", spec="baseline",
               scenario_spec=f"nominal[seed={s}]", seed=s, error="",
               carbon_kg=1.0 * s) for s in (0, 1)]
    bad = dict(scenario="nominal", scheduler="waterwise", spec="waterwise",
               scenario_spec="nominal[seed=0]", seed=0,
               error="RuntimeError: boom")
    agg = experiments.aggregate_seeds(ok + [bad])
    assert len(agg) == 2
    assert agg[0]["n_seeds"] == 2
    assert agg[1]["error"].startswith("RuntimeError")
