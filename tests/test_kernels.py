"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
all in interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash_attention import flash_attention_bh
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_ref
from repro.kernels.sinkhorn.ops import sinkhorn_iteration
from repro.kernels.sinkhorn.ref import sinkhorn_iteration_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_naive, ssd_ref


@pytest.mark.parametrize("BH,S,D,causal,window,bq,bk,dtype", [
    (4, 256, 64, True, 0, 128, 128, jnp.float32),
    (2, 512, 128, True, 0, 256, 128, jnp.float32),
    (2, 256, 64, False, 0, 128, 64, jnp.float32),
    (2, 512, 64, True, 100, 128, 128, jnp.float32),
    (2, 256, 128, True, 0, 128, 128, jnp.bfloat16),
    (1, 128, 256, True, 64, 64, 64, jnp.float32),
])
def test_flash_attention_sweep(BH, S, D, causal, window, bq, bk, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((BH, S, D)), dtype)
    k = jnp.asarray(rng.standard_normal((BH, S, D)), dtype)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), dtype)
    out = flash_attention_bh(q, k, v, causal=causal, window=window,
                             bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("G", [1, 2, 4])
def test_flash_attention_gqa(G):
    rng = np.random.default_rng(1)
    B, S, Kh, D = 2, 256, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, Kh, G, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kh, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kh, D)), jnp.float32)
    out = flash_attention(q, k, v, bq=128, bk=128, interpret=True)
    from repro.models.attention import blocked_attention
    ref = blocked_attention(q, k, v, jnp.arange(S), jnp.arange(S),
                            kind="causal", block_kv=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-5)


@pytest.mark.parametrize("S,H,P,G,N,chunk", [
    (64, 4, 16, 2, 8, 16),
    (128, 2, 32, 1, 16, 32),
    (64, 8, 64, 8, 8, 64),
])
def test_ssd_scan_sweep(S, H, P, G, N, chunk):
    rng = np.random.default_rng(2)
    b = 2
    x = jnp.asarray(rng.standard_normal((b, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((b, S, H)) * 0.5 + 0.1, jnp.float32)
    A = jnp.asarray(-rng.random(H) - 0.2, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, S, G, N)), jnp.float32)
    yk, sk = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yn, sn = ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yn), atol=2e-3)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sn), atol=2e-3)


def test_ssd_chunked_model_path_matches_naive():
    """models/ssm.ssd_chunked (the train path) vs sequential recurrence."""
    rng = np.random.default_rng(3)
    b, S, H, P, G, N = 1, 48, 2, 8, 1, 4
    x = jnp.asarray(rng.standard_normal((b, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((b, S, H)) + 0.05, jnp.float32)
    A = jnp.asarray(-rng.random(H) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, S, G, N)), jnp.float32)
    yr, sr = ssd_ref(x, dt, A, Bm, Cm, chunk=16)
    yn, sn = ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yn), atol=2e-3)


@pytest.mark.parametrize("S,W,chunk", [(64, 32, 16), (128, 128, 64),
                                       (32, 256, 32)])
def test_rglru_scan_sweep(S, W, chunk):
    rng = np.random.default_rng(4)
    B = 2
    a = jnp.asarray(rng.random((B, S, W)) * 0.9, jnp.float32)
    bx = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    yk = rglru_scan(a, bx, chunk=chunk, interpret=True)
    yr = rglru_ref(a, bx)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)


@pytest.mark.parametrize("B,S,W", [
    (15, 48, 16),     # the learned forecaster's shape: batch = stacked
                      # signal×region columns, window-length sequences
    (5, 29, 16),      # batch = regions, odd non-padded length
    (2, 7, 15),       # short odd sequence, odd (non-lane-aligned) width
])
def test_rglru_scan_forecast_shapes(B, S, W):
    """Forecast-shaped inputs through the kernel entry (default chunk, so
    odd lengths hit the L=S single-chunk path with no padding) — pins the
    learned forecaster's pallas inference path independently of the model
    tests."""
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.random((B, S, W)) * 0.95, jnp.float32)
    bx = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    yk = rglru_scan(a, bx, interpret=True)
    yr = rglru_ref(a, bx)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)


def test_rglru_model_assoc_scan_matches_naive():
    """models/rglru associative scan == sequential recurrence."""
    import repro.models.rglru as rg
    rng = np.random.default_rng(5)
    B, S, W = 2, 32, 16
    x = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    p, _ = __import__("repro.models.common", fromlist=["split_tree"]) \
        .split_tree(rg.block_init(jax.random.PRNGKey(0), W, lru_width=W))
    a, bx = rg._gates(x, p)
    y, _ = rg.rglru_scan(x, p)
    yn = rglru_ref(a, bx)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yn),
                               atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), m_blocks=st.integers(1, 4),
       n=st.integers(2, 9))
def test_sinkhorn_kernel_property(seed, m_blocks, n):
    """Fused kernel == reference iteration for random instances; the g
    update keeps the column marginals consistent."""
    rng = np.random.default_rng(seed)
    M = 128 * m_blocks
    C = jnp.asarray(rng.random((M, n)), jnp.float32)
    g = jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)
    log_a = jnp.full((M,), -np.log(M), jnp.float32)
    b = rng.random(n) + 0.5
    log_b = jnp.asarray(np.log(b / b.sum()), jnp.float32)
    eps = float(rng.choice([0.05, 0.2, 1.0]))
    f_k, g_k = sinkhorn_iteration(C, None, g, log_a, log_b, eps,
                                  interpret=True)
    f_r, g_r = sinkhorn_iteration_ref(C, None, g, log_a, log_b, eps)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r), atol=2e-4)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), atol=2e-4)
    # after the g update, column marginals of the implied plan match b
    X = np.exp((np.asarray(f_k)[:, None] + np.asarray(g_k)[None, :]
                - np.asarray(C)) / eps)
    np.testing.assert_allclose(X.sum(0), b / b.sum(), rtol=5e-3)
