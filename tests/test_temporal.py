"""Temporal shifting: deferral-queue invariants, spatio-temporal planning,
engine wake support, forecast scheduler wiring, and the end-to-end savings
ordering (acceptance criteria)."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import footprint, problem, telemetry
from repro.core.controller import Controller, Decision, ForecastController
from repro.forecast import DeferralQueue, build_temporal_plan
from repro.sim import scenarios
from repro.sim.engine import EventSimulator, SimConfig, resolve_capacity
from repro.sim.trace import (borg_trace, load_csv, rescale_arrival_rate,
                             scale_capacity_for_utilization)


@pytest.fixture(scope="module")
def tele():
    return telemetry.generate(days=2, seed=0)


def _job(jid, submit=0.0, t=600.0, tol=2.0, home=0):
    return problem.Job(job_id=jid, home_region=home, submit_time_s=submit,
                       exec_time_s=t, energy_kwh=0.05, tolerance=tol)


# ---------------------------------------------------------------------------
# Deferral queue invariants
# ---------------------------------------------------------------------------

def test_queue_releases_at_planned_slot():
    q = DeferralQueue(guard_s=100.0)
    a = _job(0, t=10_000.0)
    q.hold(a, release_s=1000.0, now_s=0.0)
    due, held = q.partition([a], 500.0)
    assert due == [] and held == [a]
    due, held = q.partition([a], 1000.0)
    assert due == [a] and held == [] and len(q) == 0
    assert q.mean_defer_s == pytest.approx(1000.0)


def test_queue_force_releases_on_slack_guard():
    """A held job is released the moment its remaining tolerance budget
    drops to the guard — deferral can never run a job out of slack."""
    q = DeferralQueue(guard_s=300.0)
    a = _job(0, t=1000.0, tol=0.5)          # budget 500 s
    q.hold(a, release_s=10_000.0, now_s=0.0)
    _, held = q.partition([a], 100.0)       # slack 400 > guard: still held
    assert held == [a]
    due, held = q.partition([a], 250.0)     # slack 250 <= guard: released
    assert due == [a] and held == []


def test_queue_fifo_within_equal_slack():
    q = DeferralQueue(guard_s=0.0)
    jobs = [_job(i, t=10_000.0) for i in range(5)]
    for j in jobs:
        q.hold(j, release_s=100.0, now_s=0.0)
    due, held = q.partition(list(reversed(jobs)), 100.0)
    assert held == []
    assert [j.job_id for j in due] == [0, 1, 2, 3, 4]   # insertion, not input


def test_queue_re_deferral_counts_jobs_once():
    """A job held, released, and held again is one time-shifted job (the
    sweep's deferred_pct must never exceed 100%), while its hold episodes
    accumulate into the deferral latency."""
    q = DeferralQueue(guard_s=0.0)
    a = _job(0, t=100_000.0)
    q.hold(a, release_s=100.0, now_s=0.0)
    q.partition([a], 100.0)
    q.hold(a, release_s=300.0, now_s=100.0)
    q.partition([a], 300.0)
    assert q.released == 2
    assert len(q.unique_held) == 1
    assert q.mean_defer_s == pytest.approx(300.0)   # 100 + 200 for one job


def test_queue_drain_on_horizon_end():
    q = DeferralQueue()
    jobs = [_job(i, t=10_000.0) for i in range(3)]
    for j in jobs:
        q.hold(j, release_s=1e9, now_s=0.0)
    out = q.drain(500.0)
    assert [j.job_id for j in out] == [0, 1, 2]
    assert len(q) == 0 and q.released == 3


# ---------------------------------------------------------------------------
# Spatio-temporal plan
# ---------------------------------------------------------------------------

def test_temporal_plan_deadline_masking(tele):
    now = 3600.0
    jobs = [_job(0, submit=now, t=400.0, tol=0.5),     # budget 200 s: no defer
            _job(1, submit=now, t=4000.0, tol=2.0)]    # budget 8000 s
    snap = tele.at(now)
    cap = np.array([3, 3, 3, 3, 3])
    server = footprint.m5_metal()
    inst = problem.build(jobs, tele, now, cap, server, snap=snap)
    S, R = 4, tele.num_regions
    offsets = np.arange(S) * 1800.0
    ci = np.stack([np.stack([snap["ci"]] * S)] * 2)
    ewif = np.stack([np.stack([snap["ewif"]] * S)] * 2)
    wue = np.stack([np.stack([snap["wue"]] * S)] * 2)
    plan = build_temporal_plan(inst, now, ci, ewif, wue, snap["pue"],
                               snap["wsf"], offsets, server, 0.5, 0.5,
                               guard_s=240.0)
    al = plan.allowed.reshape(2, S, R)
    np.testing.assert_array_equal(al[:, 0, :], inst.allowed)  # slot 0 = Eq 11
    assert not al[0, 1:, :].any()          # 200 s budget cannot reach slot 1
    assert al[1, 1:4, :].any()             # big job can
    # Every allowed future cell leaves >= guard budget at the slot start.
    waited = 0.0
    for s in range(1, S):
        need = offsets[s] + inst.latency[1] + 240.0
        np.testing.assert_array_equal(
            al[1, s], need <= 2.0 * 4000.0 - waited + 1e-9)
    # Capacity is tiled per slot; defer_eps makes later slots strictly pricier
    # when signals are identical.
    assert plan.capacity.sum() == S * cap.sum()
    c = plan.cost.reshape(2, S, R)
    assert (np.diff(c, axis=1) > 0).all()


def test_resolve_capacity_relative_and_absolute():
    base = np.array([10, 10, 4])
    np.testing.assert_array_equal(resolve_capacity(("scale", 0.7), base),
                                  [7, 7, 3])
    np.testing.assert_array_equal(
        resolve_capacity(("scale", np.array([0.5, 1.0, 0.0])), base),
        [5, 10, 0])
    np.testing.assert_array_equal(resolve_capacity(np.array([1, 2, 3]), base),
                                  [1, 2, 3])


def test_heat_derate_scenario_derived_from_wetbulb():
    inst = scenarios.get_scenario("heat-derate").build(1.0, 0, 23000.0, 0.15)
    assert len(inst.capacity_events) == 2
    (t0, p0), (t1, p1) = inst.capacity_events
    assert 0.0 <= t0 < t1 <= 86400.0
    assert p0[0] == "scale" and (np.asarray(p0[1]) < 1.0).any()
    assert (np.asarray(p1[1]) == 1.0).all()


def test_engine_wakes_for_held_jobs(tele):
    """A scheduler that intentionally holds every job (wake_s set) must not
    be killed by the deadlock guard; jobs run after the planned hold."""

    class Holder:
        def __init__(self):
            self.solve_times = []
            self.release = 5000.0

        def schedule(self, jobs, now_s, capacity):
            if now_s < self.release:
                return Decision([], np.zeros(0, np.int64), list(jobs), None,
                                False, wake_s=self.release)
            sched = list(jobs)
            for j in sched:
                j.region = j.home_region
            return Decision(sched,
                            np.array([j.home_region for j in sched]),
                            [], None, False)

    jobs = [_job(i, submit=0.0, t=300.0, tol=100.0, home=i % 5)
            for i in range(4)]
    sim = EventSimulator(tele, np.array([2] * 5), SimConfig())
    res = sim.run(jobs, Holder())
    assert res["unfinished"] == 0
    assert len(res["records"]) == 4
    assert all(r.start_s >= 5000.0 for r in res["records"])


def test_forecast_controller_no_deadline_miss_when_deferring(tele):
    """Deferral invariant end-to-end: with ample slack the forecast planner
    shifts jobs in time yet violates no tolerance and strands no job."""
    jobs = borg_trace(days=0.05, seed=3, tolerance=4.0,
                      target_jobs_per_day=23000.0)
    cap = scale_capacity_for_utilization(jobs, 0.05, 5, 0.15)
    ctl = ForecastController(tele, forecaster="oracle", slot_s=1800.0,
                             risk=0.0, defer_eps=1e-4)
    res = EventSimulator(tele, cap, SimConfig()).run(jobs, ctl)
    assert res["unfinished"] == 0
    assert ctl.deferred_jobs > 0                       # it did shift
    assert not any(r.violated for r in res["records"])
    assert len(ctl.queue) == 0                         # drained by run end


# ---------------------------------------------------------------------------
# Offline queued-window replay through solve_many
# ---------------------------------------------------------------------------

def test_replay_recorded_windows_matches_live(tele):
    jobs = borg_trace(days=0.03, seed=1, tolerance=0.5,
                      target_jobs_per_day=23000.0)
    cap = scale_capacity_for_utilization(jobs, 0.03, 5, 0.15)
    ctl = Controller(tele, record_windows=True)
    res = EventSimulator(tele, cap, SimConfig()).run(jobs, ctl)
    assert len(ctl.recorded) > 10
    replayed = ctl.replay_recorded(backend="jax")
    assert len(replayed) == len(ctl.recorded)
    assert all(r is not None and r.feasible for r in replayed)
    total = sum(int((r.assign >= 0).sum()) for r in replayed)
    assert total == len(res["records"])


# ---------------------------------------------------------------------------
# Real-trace CSV scenario builder
# ---------------------------------------------------------------------------

def test_csv_scenario_cell_for_cell():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "slice.csv")
        with open(path, "w") as f:
            f.write("jid,t_us,runtime,energy,dc\n")
            for i in range(200):
                f.write(f"{i},{i * 30 * 1e6},{200 + 5 * i},0.03,{i % 7}\n")
        cmap = dict(job_id="jid", submit_s="t_us", duration_s="runtime",
                    energy_kwh="energy", home_region="dc")
        jobs = load_csv(path, column_map=cmap, unit_scale=dict(submit_s=1e-6))
        assert len(jobs) == 200
        assert jobs[1].submit_time_s == pytest.approx(30.0)
        assert jobs[7].home_region == 0     # not yet folded by the loader
        try:
            scenarios.register_csv_scenario("csv-test", path,
                                            column_map=cmap,
                                            unit_scale=dict(submit_s=1e-6))
            a = scenarios.get_scenario("csv-test").build(0.05, 0, 1e5, 0.15)
            b = scenarios.get_scenario("csv-test").build(0.05, 0, 1e5, 0.15)
            assert [j.job_id for j in a.jobs] == [j.job_id for j in b.jobs]
            assert all(j.home_region < 5 for j in a.jobs)
            assert all(j.submit_time_s < 0.05 * 86400.0 for j in a.jobs)
            row = scenarios.run_cell("csv-test", "baseline", days=0.05)
            assert row["jobs"] == len(a.jobs) > 0
        finally:
            scenarios._REGISTRY.pop("csv-test", None)


def test_rescale_arrival_rate_thins_deterministically():
    jobs = [_job(i, submit=i * 10.0) for i in range(1000)]
    thin_a = rescale_arrival_rate(jobs, days=1.0, target_jobs_per_day=300,
                                  seed=5)
    thin_b = rescale_arrival_rate(jobs, days=1.0, target_jobs_per_day=300,
                                  seed=5)
    assert [j.job_id for j in thin_a] == [j.job_id for j in thin_b]
    assert 150 < len(thin_a) < 450
    # Below-target traces pass through untouched.
    assert rescale_arrival_rate(jobs, 1.0, 1e6) == jobs


# ---------------------------------------------------------------------------
# Forecast-error regime wiring
# ---------------------------------------------------------------------------

def test_forecast_error_scenario_injects_bias(tele):
    inst = scenarios.get_scenario("forecast-error").build(0.05, 0, 23000.0,
                                                          0.15)
    assert inst.forecast_bias > 1.0 and inst.forecast_noise > 0.0
    ctl = ForecastController(tele, forecaster="oracle",
                             forecast_bias=inst.forecast_bias,
                             forecast_noise=inst.forecast_noise)
    f = ctl._make_forecaster()
    from repro.forecast import Perturbed
    assert isinstance(f, Perturbed) and f.bias == inst.forecast_bias
    # An unbiased cell wraps nothing.
    assert not isinstance(
        ForecastController(tele, forecaster="oracle")._make_forecaster(),
        Perturbed)


# ---------------------------------------------------------------------------
# Acceptance: savings ordering on the nominal 0.2-day cell
# ---------------------------------------------------------------------------

def _joint(row, base):
    return 0.5 * (row["carbon_kg"] / base["carbon_kg"]
                  + row["water_kl"] / base["water_kl"])


NOMINAL_KW = dict(days=0.2, seed=0, tolerance=3.0)


@pytest.fixture(scope="module")
def nominal_cells():
    """The nominal 0.2-day delay-tolerant cell under the reactive
    controller and the forecast/oracle planners (shared by the ordering
    tests — these are the expensive rows)."""
    return {name: scenarios.run_cell("nominal", name, **NOMINAL_KW)
            for name in ("waterwise", "waterwise-forecast",
                         "waterwise-oracle")}


@pytest.mark.slow
def test_forecast_shifting_savings_ordering(nominal_cells):
    """On the nominal 0.2-day cell (delay-tolerant regime, TOL=3.0 so jobs
    have slack to shift), forecast-driven temporal shifting must reduce the
    joint carbon+water cost vs the reactive controller with zero deadline
    misses, and the oracle upper bound must confirm the ordering
    oracle ≥ forecast ≥ reactive up to solver/decision noise."""
    ww = nominal_cells["waterwise"]
    fc = nominal_cells["waterwise-forecast"]
    oc = nominal_cells["waterwise-oracle"]
    for row in (ww, fc, oc):
        assert row["violation_pct"] == 0.0
        assert row["unfinished"] == 0
    assert fc["deferred_pct"] > 1.0        # shifting actually happened
    j_fc, j_oc = _joint(fc, ww), _joint(oc, ww)
    assert j_fc < 0.999                    # real joint-cost reduction
    assert j_oc < 0.999
    # Oracle >= forecast in savings, up to decision noise (the risk-shaded
    # forecast policy can edge out the risk-neutral oracle by conservatism).
    assert j_oc <= j_fc + 4e-3
    # Forecast accuracy column: oracle exact, Holt-Winters small but nonzero.
    assert oc["forecast_mape"] == pytest.approx(0.0, abs=1e-9)
    assert 0.0 < fc["forecast_mape"] < 15.0


@pytest.mark.slow
def test_learned_forecaster_savings_ordering(nominal_cells):
    """Acceptance: the learned RG-LRU forecaster drops into the forecast
    pipeline via its spec (``forecaster=learned``) and preserves the
    oracle ≥ forecast ≥ reactive ordering on the same cell — it trains
    inside the pricer (on the warm-start telemetry archive) and then
    re-conditions on each hourly refit."""
    ww = nominal_cells["waterwise"]
    oc = nominal_cells["waterwise-oracle"]
    lf = scenarios.run_cell("nominal",
                            "waterwise-forecast[forecaster=learned]",
                            **NOMINAL_KW)
    assert lf["violation_pct"] == 0.0
    assert lf["unfinished"] == 0
    assert lf["deferred_pct"] > 1.0        # it shifted jobs
    j_lf = _joint(lf, ww)
    assert j_lf < 0.999                    # real joint-cost reduction
    assert _joint(oc, ww) <= j_lf + 4e-3   # oracle still the upper bound
    assert 0.0 < lf["forecast_mape"] < 15.0
