"""Streaming service: batch/stream bit parity, arrival-stream determinism,
bounded-admission invariants (property-tested), the Sinkhorn warm-start
pin, receding-horizon re-plan semantics, and the service smoke."""
import copy
import json
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import footprint, problem, telemetry
from repro.core.round import SinkhornWarmStart, fused_temporal_round
from repro.policy.pipeline import (HOLD, RUN, PricedPlan, QueueDeferral,
                                   ReplanQueueDeferral, forecast_pipeline)
from repro.serve import (DROP_OLDEST, REJECT_NEW, AdmissionQueue,
                         DecisionLoop, FileTailArrivals,
                         PoissonBurstArrivals, ReplayArrivals, ServeConfig)
from repro.sim.engine import EventSimulator, SimConfig
from repro.sim.trace import borg_trace, scale_capacity_for_utilization


@pytest.fixture(scope="module")
def tele():
    return telemetry.generate(days=2, seed=0)


def _job(i, submit=0.0, region=0, exec_s=600.0, tol=4.0):
    return problem.Job(job_id=i, home_region=region, submit_time_s=submit,
                       exec_time_s=exec_s, energy_kwh=0.05, tolerance=tol)


def _key(r):
    return (r.job.job_id, r.region, r.start_s, r.finish_s,
            r.carbon_g, r.water_l)


# ---------------------------------------------------------------------------
# The one-engine contract: streamed replay ≡ batch replay, bit for bit
# ---------------------------------------------------------------------------

class TestStreamBatchParity:

    def test_records_bit_identical(self, tele):
        days = 0.03
        jobs = borg_trace(days=days, seed=3, tolerance=4.0,
                          target_jobs_per_day=23000.0)
        cap = scale_capacity_for_utilization(jobs, days, tele.num_regions,
                                             0.15)

        def pipeline():
            return forecast_pipeline(tele, forecaster="oracle", risk=0.0,
                                     defer_eps=1e-4, backend="fused")

        batch = EventSimulator(tele, cap, SimConfig()).run(
            copy.deepcopy(jobs), pipeline())
        loop = DecisionLoop(EventSimulator(tele, cap, SimConfig()),
                            pipeline(), ReplayArrivals(copy.deepcopy(jobs)),
                            ServeConfig(round_s=300.0, queue_bound=1 << 30))
        rep = loop.run(days * 86400.0)
        stream = loop.stepper.result()
        assert rep.shed == 0 and rep.jobs_in == len(jobs)
        assert len(stream["records"]) == len(batch["records"])
        assert ([_key(r) for r in stream["records"]]
                == [_key(r) for r in batch["records"]])


# ---------------------------------------------------------------------------
# Arrival sources
# ---------------------------------------------------------------------------

class TestArrivals:

    def test_replay_chunked_equals_whole(self):
        jobs = [_job(i, submit=float(i * 7 % 100)) for i in range(40)]
        whole = ReplayArrivals(jobs).poll(1e9)
        chunked, src = [], ReplayArrivals(jobs)
        for t in np.arange(0.0, 120.0, 11.0):
            chunked.extend(src.poll(float(t)))
        chunked.extend(src.poll(1e9))
        assert [j.job_id for j in chunked] == [j.job_id for j in whole]
        assert src.exhausted

    def test_poisson_independent_of_polling_cadence(self):
        mk = lambda: PoissonBurstArrivals(0.2, seed=7, burst=1.0,
                                          horizon_s=900.0)
        one = mk().poll(900.0)
        fine, src = [], mk()
        for t in np.arange(5.0, 905.0, 5.0):
            fine.extend(src.poll(float(t)))
        sig = lambda js: [(j.job_id, j.submit_time_s, j.home_region,
                           j.exec_time_s, j.energy_kwh) for j in js]
        assert sig(fine) == sig(one)
        assert len(one) > 0
        subs = [j.submit_time_s for j in one]
        assert subs == sorted(subs)
        assert [j.job_id for j in one] == list(range(len(one)))

    def test_file_tail_consumes_complete_lines_only(self):
        line = lambda i, t: json.dumps(dict(
            job_id=i, home_region=0, submit_s=t, exec_s=60.0,
            energy_kwh=0.01)) + "\n"
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "jobs.jsonl")
            src = FileTailArrivals(path)
            assert src.poll(1e9) == []          # no file yet: no jobs
            partial = line(1, 10.0)
            with open(path, "w") as fh:
                fh.write(line(0, 5.0) + partial[:20])
            got = src.poll(1e9)
            assert [j.job_id for j in got] == [0]
            with open(path, "a") as fh:         # writer finishes the line
                fh.write(partial[20:])
            got = src.poll(1e9)
            assert [j.job_id for j in got] == [1]
            assert not src.exhausted
            src.close()
            assert src.exhausted


# ---------------------------------------------------------------------------
# Bounded admission: the queue-bound / conservation / FIFO invariants
# ---------------------------------------------------------------------------

class TestAdmissionQueue:

    def _storm(self, batches, takes, bound, policy):
        q = AdmissionQueue(bound, policy)
        next_id, taken = 0, []
        for k, n in enumerate(batches):
            jobs = [_job(next_id + i, submit=float(k)) for i in range(n)]
            next_id += n
            q.offer(jobs, float(k))
            assert len(q) <= bound              # the bound NEVER overshoots
            if takes:
                taken.extend(q.take(takes[k % len(takes)]))
        taken.extend(q.take())
        return q, taken, next_id

    def _check(self, q, taken, offered):
        assert q.offered == offered
        assert q.admitted + q.shed == q.offered         # conservation
        assert len(taken) + q.shed == q.offered         # drained: no loss
        assert len(q.shed_ids) == q.shed
        ids = [j.job_id for j in taken]
        assert ids == sorted(ids)                       # FIFO survives shed
        assert len(set(ids)) == len(ids)
        assert set(ids).isdisjoint(q.shed_ids)
        assert q.peak_depth <= q.bound

    @pytest.mark.parametrize("policy", [REJECT_NEW, DROP_OLDEST])
    def test_adversarial_burst_train(self, policy):
        # Ramping bursts with starved drains — the bound binds repeatedly.
        q, taken, offered = self._storm(
            batches=[1, 9, 30, 0, 17, 50, 2, 41], takes=[3, 0, 1],
            bound=8, policy=policy)
        self._check(q, taken, offered)
        assert q.shed > 0

    @pytest.mark.parametrize("policy", [REJECT_NEW, DROP_OLDEST])
    def test_who_pays(self, policy):
        q = AdmissionQueue(2, policy)
        q.offer([_job(0), _job(1), _job(2)], 0.0)
        kept = {REJECT_NEW: [0, 1], DROP_OLDEST: [1, 2]}[policy]
        assert [j.job_id for j in q.take()] == kept
        assert q.shed_ids == [i for i in range(3) if i not in kept]

# Module-level (not a method): the offline hypothesis stub in conftest.py
# replaces @given-tests with zero-arg skippers, which pytest can only call
# as plain functions.
@given(batches=st.lists(st.integers(0, 25), min_size=1, max_size=25),
       takes=st.lists(st.integers(0, 8), max_size=8),
       bound=st.integers(1, 15),
       policy=st.sampled_from([REJECT_NEW, DROP_OLDEST]))
@settings(max_examples=60, deadline=None)
def test_admission_invariants_property(batches, takes, bound, policy):
    t = TestAdmissionQueue()
    q, taken, offered = t._storm(batches, takes, bound, policy)
    t._check(q, taken, offered)


# ---------------------------------------------------------------------------
# Sinkhorn warm-start carry: same plan, strictly fewer iterations
# ---------------------------------------------------------------------------

class TestWarmStart:

    def test_warm_round_fewer_iters_same_plan(self, tele):
        M, S, R = 32, 8, 5
        server = footprint.m5_metal()
        offsets = np.arange(S) * 1800.0
        rng = np.random.default_rng(0)
        snap = tele.at(0.0)
        jobs = [_job(i, region=i % R, exec_s=600.0 + 10 * i)
                for i in range(M)]
        cap = np.full(R, max(2, M // R + 1))
        inst = problem.build(jobs, tele, 0.0, cap, server, snap=snap)
        ci = rng.random((M, S, R)) * 300 + 50
        ewif = rng.random((M, S, R)) * 2 + 0.5
        wue = rng.random((M, S, R)) * 1 + 0.2

        def solve(ws, ci):
            return fused_temporal_round(inst, 0.0, ci, ewif, wue,
                                        snap["pue"], snap["wsf"], offsets,
                                        server, 0.5, 0.5, warm_start=ws)[3]

        ws = SinkhornWarmStart()
        solve(ws, ci)                           # cold round seeds the carry
        drifted = ci * (1 + 0.03 * rng.standard_normal((M, S, R)))
        warm = solve(ws, drifted)               # warm re-pricing round
        ref = SinkhornWarmStart()
        cold = solve(ref, drifted)              # cold solve, same round
        assert ws.cold_iters and ws.warm_iters and ref.cold_iters
        # Strictly cheaper than the cold solve of the SAME instance…
        assert ws.warm_iters[0] < ref.cold_iters[0]
        assert ws.warm_iters[0] < ws.cold_iters[0]
        # …and it lands on the same scheduling decision.
        assert (warm.assign == cold.assign).all()
        assert warm.status == cold.status


# ---------------------------------------------------------------------------
# Receding-horizon re-planning: guard, hysteresis, commitment safety
# ---------------------------------------------------------------------------

def _plan(cost, allowed, S, N):
    return PricedPlan(cost=np.asarray(cost, float),
                      allowed=np.asarray(allowed, bool),
                      capacity=np.ones(S * N), overrun=np.zeros_like(
                          np.asarray(cost, float)),
                      num_regions=N, num_slots=S,
                      slot_offsets=np.arange(S) * 600.0)


class TestReplan:

    def test_guard_keeps_near_release_committed(self):
        d = ReplanQueueDeferral(guard_s=0.0, replan_guard_s=900.0)
        j0, j1 = _job(0), _job(1)
        d.hold(j0, 500.0, 0.0)                  # releases inside the guard
        d.hold(j1, 5000.0, 0.0)                 # far beyond the guard
        due, held = d.admit([j0, j1], 0.0, capacity=10)
        assert [j.job_id for j in due] == [1]   # only j1 re-enters pricing
        assert [j.job_id for j in held] == [0]
        assert d.replans == 1 and 1 in d._carried

    def test_replan_capped_at_spare_capacity(self):
        d = ReplanQueueDeferral(guard_s=0.0, replan_guard_s=100.0)
        for i in range(4):
            d.hold(_job(i), 5000.0, 0.0)
        fresh = [_job(10), _job(11)]
        due, held = d.admit(fresh + [_job(i) for i in range(4)], 0.0,
                            capacity=3)
        # 2 genuinely due jobs leave spare=1: exactly one held job re-plans.
        assert sum(j.job_id < 10 for j in due) == 1
        assert len(held) == 3

    def test_revise_hysteresis(self):
        d = ReplanQueueDeferral(guard_s=0.0, replan_guard_s=100.0,
                                replan_margin=0.5)
        S, N = 4, 2
        j = _job(0)
        d.hold(j, 1200.0, 0.0)                  # committed to slot 2
        due, _ = d.admit([j], 0.0, capacity=5)
        assert due == [j]
        cost = np.full((1, S * N), 9.0)
        cost[0, 2 * N:3 * N] = [5.0, 6.0]       # committed slot prices
        allowed = np.ones((1, S * N), bool)

        # Early run that does NOT beat the committed slot by the margin:
        # vetoed, hold restored at the original release.
        cost[0, 0] = 4.9
        act, pay = d.revise(j, RUN, 0, _plan(cost, allowed, S, N), 0, 0, 0.0)
        assert (act, pay) == (HOLD, 1200.0)
        assert d.replan_vetoes == 1 and d.replan_runs == 0

        # A genuine improvement clears the margin and runs.
        cost[0, 0] = 4.0
        act, pay = d.revise(j, RUN, 0, _plan(cost, allowed, S, N), 0, 0, 0.0)
        assert (act, pay) == (RUN, 0)
        assert d.replan_runs == 1

        # Re-confirming the committed slot is frictionless.
        col = 2 * N + 1
        act, pay = d.revise(j, HOLD, 1201.0, _plan(cost, allowed, S, N),
                            0, col, 0.0)
        assert (act, pay) == (HOLD, 1201.0)

        # Committed slot gone infeasible: the re-plan stands as priced.
        allowed[0, 2 * N:3 * N] = False
        act, pay = d.revise(j, RUN, 0, _plan(cost, allowed, S, N), 0, 0, 0.0)
        assert (act, pay) == (RUN, 0)

    def test_solver_drop_restores_commitment(self):
        d = ReplanQueueDeferral(guard_s=0.0, replan_guard_s=100.0)
        j = _job(0)
        d.hold(j, 2000.0, 0.0)
        due, _ = d.admit([j], 0.0, capacity=5)
        assert due == [j] and 0 not in d.queue
        # The solver dropped the carried row (defer / infeasible): the next
        # round's admit restores the committed hold — nothing is lost.
        due, held = d.admit([j], 1950.0, capacity=5)
        assert due == [] and held == [j]        # back inside the guard
        assert d.queue._held[0].release_s == 2000.0
        assert not d._carried

    def test_run_closes_episode(self):
        d = ReplanQueueDeferral(guard_s=0.0, replan_guard_s=100.0)
        j = _job(0)
        d.hold(j, 5000.0, 100.0)
        d.admit([j], 200.0, capacity=5)
        assert d._carried
        # Job absent next round — it ran at the pop instant; the episode
        # closes and the realized deferral (pop − held_at) is accounted.
        d.admit([], 300.0, capacity=5)
        assert not d._carried
        assert d.mean_defer_s == pytest.approx(100.0)

    def test_commit_policy_has_no_replan_surface(self):
        q = QueueDeferral(guard_s=0.0)
        j = _job(0)
        plan = _plan(np.ones((1, 4)), np.ones((1, 4), bool), 2, 2)
        assert q.revise(j, RUN, 1, plan, 0, 1, 0.0) == (RUN, 1)


# ---------------------------------------------------------------------------
# The service smoke: storm in, accounting exact, report coherent
# ---------------------------------------------------------------------------

class TestDecisionLoop:

    def _serve(self, tele, bound, policy, duration=240.0, rate=0.5):
        src = PoissonBurstArrivals(rate, seed=1,
                                   num_regions=tele.num_regions,
                                   tolerance=4.0, burst=1.0,
                                   horizon_s=duration)
        probe = PoissonBurstArrivals(rate, seed=1,
                                     num_regions=tele.num_regions,
                                     tolerance=4.0, burst=1.0,
                                     horizon_s=duration)
        cap = scale_capacity_for_utilization(probe.poll(duration),
                                             duration / 86400.0,
                                             tele.num_regions, 0.15)
        ctl = forecast_pipeline(tele, forecaster="oracle", risk=0.0,
                                defer_eps=1e-4, backend="fused", warm=True)
        loop = DecisionLoop(EventSimulator(tele, cap, SimConfig()), ctl,
                            src, ServeConfig(round_s=30.0,
                                             queue_bound=bound,
                                             shed_policy=policy))
        return loop, loop.run(duration)

    def test_clean_service_zero_misses(self, tele):
        loop, rep = self._serve(tele, bound=10_000, policy=REJECT_NEW)
        assert rep.jobs_in > 0
        assert rep.shed == 0 and rep.deadline_misses == rep.violations == 0
        assert rep.placed == rep.admitted == rep.jobs_in
        assert rep.rounds == 8                  # 240s / 30s boundaries
        assert rep.engine_rounds >= rep.rounds
        assert rep.p99_round_ms >= rep.p50_round_ms > 0
        assert rep.sinkhorn_cold_iters > 0      # warm carry was live
        d = rep.to_dict()
        assert d["carbon_kg"] > 0 and d["water_kl"] > 0

    def test_storm_sheds_accountably(self, tele):
        loop, rep = self._serve(tele, bound=5, policy=DROP_OLDEST,
                                duration=120.0, rate=1.0)
        assert rep.shed > 0
        assert rep.jobs_in == rep.admitted + rep.shed
        assert rep.placed == rep.admitted       # drained: admitted all ran
        assert rep.deadline_misses == rep.violations + rep.shed
        assert rep.max_admission_depth <= 5
        assert sorted(loop.admission.shed_ids) == loop.admission.shed_ids
