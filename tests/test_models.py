"""Per-architecture smoke tests (reduced configs) + cache-consistency.

Every assigned architecture: one forward/train step on CPU with shape and
finiteness assertions, plus a full optimizer step. Cache correctness:
prefill-then-decode logits must match the one-shot forward at the same
position (validates every cache layout: KV, MLA-latent, SSD state, RG-LRU
state, conv tails, cross-attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import Model
from repro.models.common import split_tree
from repro.optim import adamw
from repro.runtime.train_loop import make_train_step

ARCHS = list(list_archs())


def _batch(cfg, B=2, S=32, seed=1):
    key = jax.random.PRNGKey(seed)
    batch = dict(tokens=jax.random.randint(key, (B, S), 0, cfg.vocab),
                 labels=jax.random.randint(key, (B, S), 0, cfg.vocab))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            cfg.compute_dtype)
    if cfg.family == "vision":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model), cfg.compute_dtype)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = adamw()
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    new_params, new_state, metrics = step(params, state, batch,
                                          jax.random.PRNGKey(2))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed and kept structure/shapes
    jax.tree.map(lambda a, b: None if a.shape == b.shape else 1 / 0,
                 params, new_params)
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params))
    assert max(moved) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_shapes(arch):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert cache is not None


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2_1_5b", "gemma3_4b", "mamba2_2_7b",
                                  "recurrentgemma_2b", "minicpm3_4b",
                                  "dbrx_132b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode from a prefilled cache reproduces the one-shot
    forward logits at every decoded position (greedy path identical)."""
    from repro.runtime.serve_loop import _splice
    cfg = get_config(arch, reduced=True)
    if cfg.n_experts:
        # Capacity-based MoE can drop tokens in the teacher-forced full
        # forward but never in single-token decode; compare dropless.
        cfg = cfg.replace(moe_capacity_factor=8.0)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S, extra = 2, 16, 4
    total = S + extra
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab)
    batch_full = dict(tokens=toks)
    if cfg.family == "vision":
        batch_full["patches"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model), cfg.compute_dtype)
    # one-shot forward over the whole sequence
    from repro.models import transformer
    full_logits, _ = transformer.apply(cfg, params, batch_full, "train")

    # prefill on the first S tokens, then teacher-forced decode
    batch_prefill = dict(batch_full)
    batch_prefill["tokens"] = toks[:, :S]
    _, built = model.prefill(params, batch_prefill)
    ctree = model.init_cache(B, total, n_img=cfg.n_img_tokens)
    cache, _ = split_tree(ctree)
    cache = _splice(cache, built, S)
    for t in range(S, total):
        logits, cache = model.decode(params, cache, toks[:, t:t + 1], t)
        ref = full_logits[:, t]
        a = np.asarray(logits, np.float32)
        b = np.asarray(ref, np.float32)
        # bf16 models: greedy path must match up to exact near-ties — where
        # argmax differs, the decoded token's reference logit must be within
        # the comparison tolerance of the reference max.
        ai, bi = a.argmax(-1), b.argmax(-1)
        rows = np.arange(a.shape[0])
        tie_gap = b[rows, bi] - b[rows, ai]
        assert ((ai == bi) | (tie_gap <= 0.15)).all(), \
            f"pos {t}: argmax {ai} vs {bi}, gap {tie_gap}"
        np.testing.assert_allclose(a, b, atol=0.15, rtol=0.1)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3_4b")
    idx = np.arange(cfg.n_layers)
    flags = (idx % cfg.attn_every) == cfg.attn_every - 1
    assert flags.sum() == cfg.n_layers // cfg.attn_every
    assert not flags[:5].any() and flags[5]


@pytest.mark.parametrize("arch,expected_b", [
    ("dbrx_132b", 132), ("deepseek_v2_236b", 236), ("qwen2_72b", 72),
    ("mamba2_2_7b", 2.7), ("gemma3_4b", 3.9), ("qwen2_1_5b", 1.5),
])
def test_full_param_counts(arch, expected_b):
    n = Model(get_config(arch)).param_count()
    assert abs(n / 1e9 - expected_b) / expected_b < 0.08


def test_moe_load_is_routed():
    """Different tokens reach different experts and gates renormalize."""
    from repro.models import moe
    cfg = get_config("dbrx_132b", reduced=True)
    key = jax.random.PRNGKey(0)
    p = jax.tree.map(lambda x: x,
                     moe.init(key, 32, 64, 4, dtype=jnp.float32))
    from repro.models.common import split_tree as st_
    params, _ = st_(p)
    x = jax.random.normal(key, (2, 16, 32))
    out = moe.apply(x, params, top_k=2, n_experts=4)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(out).max()) > 0
