"""Device-parallel cell execution: ``fused_round_batch`` ≡ ``fused_solve``
bit-parity, cell-group batching invariants (property-tested), the
``device`` executor backend ≡ ``serial`` on a pinned plan, extended solver
row buckets (>4096 rows), and the safe XLA host-platform flag helper."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import experiments
from repro.core import round as fused_round
from repro.core.round import SolveRequest, fused_round_batch, group_requests
from repro.core.solvers import jax_solver
from repro.core.solvers.jax_solver import BUCKETS, bucket_for
from repro.launch import devices as launch_devices


def _request(rng, M=12, C=4, soften=False, dtype=np.float64):
    cost = rng.uniform(1.0, 5.0, (M, C)).astype(dtype)
    allowed = rng.random((M, C)) > 0.2
    allowed[:, 0] = True                     # every job has an arc
    return SolveRequest(
        cost=cost, allowed=allowed, capacity=np.full(C, M, np.int64),
        soften=soften, overrun=rng.uniform(0.0, 2.0, (M, C)),
        tol=rng.uniform(0.0, 1.0, M), sigma=8.0)


def _assert_same_result(a, b):
    assert a.status == b.status
    assert a.objective == b.objective        # bit-identical, not approx
    np.testing.assert_array_equal(a.assign, b.assign)
    np.testing.assert_array_equal(a.penalties, b.penalties)


# ---------------------------------------------------------------------------
# fused_round_batch ≡ fused_solve (the tentpole's bit-parity contract)
# ---------------------------------------------------------------------------

def test_batch_matches_single_cell_fused_solve_bitwise():
    """The batched (vmapped) program must produce bitwise-identical
    decisions to per-cell ``fused_solve`` calls — mixed sizes, mixed
    hard/soft, one call."""
    rng = np.random.default_rng(0)
    reqs = [_request(rng, M=10 + 3 * k, soften=(k % 2 == 0))
            for k in range(6)]
    batch = fused_round_batch(reqs, devices=1)
    for r, b in zip(reqs, batch):
        single = fused_round.fused_solve(
            r.cost, r.allowed, r.capacity, soften=r.soften,
            overrun=r.overrun, tol=r.tol, sigma=r.sigma)
        assert b.backend == "fused"
        _assert_same_result(single, b)


def test_batch_matches_across_all_visible_devices():
    """Same contract with the shard_map path over every visible device
    (CI forces a 4-device host split; a 1-device box degrades to vmap)."""
    import jax

    n = len(jax.devices())
    rng = np.random.default_rng(1)
    reqs = [_request(rng, M=16, soften=False) for _ in range(2 * n)]
    batch = fused_round_batch(reqs, devices=n)
    for r, b in zip(reqs, batch):
        single = fused_round.fused_solve(
            r.cost, r.allowed, r.capacity, soften=r.soften,
            overrun=r.overrun, tol=r.tol, sigma=r.sigma)
        _assert_same_result(single, b)


def test_batch_devices_validation():
    import jax

    rng = np.random.default_rng(2)
    with pytest.raises(ValueError, match="exceeds"):
        fused_round_batch([_request(rng)], devices=len(jax.devices()) + 1)


def test_batch_infeasible_requests_short_circuit():
    """Per-request infeasibility (capacity shortfall, fully masked row)
    resolves exactly like ``fused_solve`` without touching the device."""
    rng = np.random.default_rng(3)
    good = _request(rng, M=8)
    short = _request(rng, M=8)
    short.capacity = np.full(4, 1, np.int64)         # sum 4 < 8 jobs
    masked = _request(rng, M=8)
    masked.allowed = np.zeros((8, 4), bool)
    out = fused_round_batch([good, short, masked], devices=1)
    assert out[0].feasible
    assert out[1].status == "infeasible" and not out[1].feasible
    assert out[2].status == "infeasible"
    for req, res in zip([short, masked], out[1:]):
        single = fused_round.fused_solve(req.cost, req.allowed, req.capacity,
                                         soften=req.soften,
                                         overrun=req.overrun, tol=req.tol,
                                         sigma=req.sigma)
        _assert_same_result(single, res)


def test_batch_compile_reuse_across_calls():
    """A second batch with the same (bucket, statics) signature reuses the
    compiled program — no retrace even for a different group size (padded
    to the same power-of-two batch shape)."""
    rng = np.random.default_rng(4)
    fused_round_batch([_request(rng, M=9) for _ in range(3)], devices=1)
    fn = fused_round._batch_callable(
        1, **fused_round._request_statics(_request(rng, M=9)))
    before = fn._cache_size()
    fused_round_batch([_request(rng, M=11) for _ in range(4)], devices=1)
    assert fn._cache_size() == before        # same bucket 16, same batch 4


# ---------------------------------------------------------------------------
# group_requests invariants (pure bookkeeping, property-tested)
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(1, 40),      # rows M
                          st.integers(2, 5),       # cols C
                          st.booleans(),           # soften
                          st.sampled_from([np.float32, np.float64])),
                min_size=1, max_size=16))
@settings(max_examples=50, deadline=None)
def test_group_requests_never_mixes_buckets_or_dtypes(shapes):
    rng = np.random.default_rng(5)
    reqs = [_request(rng, M=m, C=c, soften=s, dtype=dt)
            for m, c, s, dt in shapes]
    groups = group_requests(reqs)
    seen = sorted(i for idxs in groups.values() for i in idxs)
    assert seen == list(range(len(reqs)))     # exact cover, no dup/loss
    for key, idxs in groups.items():
        buckets = {bucket_for(reqs[i].cost.shape[0] + 1) for i in idxs}
        cols = {reqs[i].cost.shape[1] for i in idxs}
        dtypes = {np.asarray(reqs[i].cost).dtype for i in idxs}
        softs = {reqs[i].soften for i in idxs}
        assert len(buckets) == len(cols) == len(dtypes) == len(softs) == 1
        assert (bucket_for(reqs[idxs[0]].cost.shape[0] + 1),
                reqs[idxs[0]].cost.shape[1]) == key[:2]


def test_batch_size_is_device_multiple_power_of_two():
    assert fused_round._batch_size(1, 1) == 1
    assert fused_round._batch_size(3, 1) == 4
    assert fused_round._batch_size(5, 4) == 8
    assert fused_round._batch_size(8, 4) == 8
    assert fused_round._batch_size(9, 4) == 16


# ---------------------------------------------------------------------------
# Extended row buckets: >4096-job rounds solve and reuse compiles
# ---------------------------------------------------------------------------

def test_buckets_extend_to_16384_and_warn_once(recwarn):
    assert BUCKETS[-1] == 16384
    assert bucket_for(5000) == 8192
    assert bucket_for(16000) == 16384
    jax_solver._OVERFLOW_WARNED.discard(32768)
    with pytest.warns(RuntimeWarning, match="exceeds the largest padded"):
        assert bucket_for(20000) == 32768
    # second overflow of the same size is silent (warn once per size)
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert bucket_for(20000) == 32768


@pytest.mark.slow
def test_large_instance_solves_and_reuses_compile():
    """Regression for the old 4096 ceiling: a >4096-row instance lands in
    the 8192 bucket, solves correctly, and a second instance in the same
    bucket reuses the compile (no retrace)."""
    rng = np.random.default_rng(6)
    C = 4

    def solve(M):
        cost = rng.uniform(1.0, 5.0, (M, C))
        allowed = np.ones((M, C), bool)
        return fused_round.fused_solve(cost, allowed,
                                       np.full(C, M, np.int64))

    res = solve(4100)
    assert res.feasible and res.assign.shape == (4100,)
    from repro.kernels.sinkhorn import ops as sink_ops
    fn = fused_round._assignment_program
    before = fn._cache_size()
    res2 = solve(4200)                        # same 8192 bucket
    assert res2.feasible
    assert fn._cache_size() == before         # compile reuse across sizes
    del sink_ops


# ---------------------------------------------------------------------------
# The device executor backend
# ---------------------------------------------------------------------------

def test_device_executor_spec_grammar():
    ex = experiments.get_executor("device[devices=2,max_cells=8]")
    assert (ex.devices, ex.max_cells) == (2, 8)
    ex = experiments.get_executor("device")
    assert (ex.devices, ex.max_cells) == (0, 0)
    assert "device" in experiments.list_executors()


def test_device_executor_matches_serial_rows():
    """Acceptance: ``device`` ≡ ``serial`` bit-identical rows on a
    2-scenario × 2-policy plan — including the stateful forecast-driven
    policy, which cannot batch and must fall back cleanly."""
    plan = experiments.ExperimentPlan.build(
        scenarios=["diurnal[days=0.05,jobs_per_day=20000.0,tolerance=0.5]",
                   "nominal[days=0.05,jobs_per_day=20000.0]"],
        policies=["waterwise[backend=fused]", "waterwise-forecast"])
    serial = plan.run(executor="serial")
    device = plan.run(executor="device")
    assert len(serial) == len(device) == 4
    nondet = ("wall_s", "mean_solve_ms", "utilization")
    for s, d in zip(serial, device):
        assert not s["error"] and not d["error"]
        for key in s:
            if key in nondet or key.startswith("_"):
                continue
            assert s[key] == d[key], \
                f"column {key!r}: {s[key]} != {d[key]}"
        assert s["carbon_kg"] == d["carbon_kg"]
        assert s["water_kl"] == d["water_kl"]
        assert s["violation_pct"] == d["violation_pct"]


def test_device_executor_batchable_classification():
    from repro.experiments.executor import DeviceExecutor
    from repro.experiments.plan import Cell

    def cell(pol):
        return Cell(scenario="nominal", policy=pol, seed=0)

    assert DeviceExecutor._batchable(cell("waterwise[backend=fused]"))
    assert not DeviceExecutor._batchable(cell("waterwise"))  # default: flow
    assert not DeviceExecutor._batchable(cell("waterwise[backend=flow]"))
    assert not DeviceExecutor._batchable(cell("waterwise-forecast"))
    assert not DeviceExecutor._batchable(cell("baseline"))
    assert not DeviceExecutor._batchable(cell("no-such-policy"))


def test_cell_batcher_flushes_on_finish_and_broadcasts_errors():
    """Barrier liveness: a finishing thread flushes waiters; a flush
    exception reaches every waiting submit."""
    from repro.experiments.executor import _CellBatcher

    calls = []

    def flush(reqs):
        calls.append(len(reqs))
        return [r * 10 for r in reqs]

    b = _CellBatcher(flush)
    b.register()
    assert b.submit(7) == 70                 # active=1 → immediate flush
    b.finish()
    assert calls == [1]

    def boom(reqs):
        raise RuntimeError("device exploded")

    b = _CellBatcher(boom)
    b.register()
    with pytest.raises(RuntimeError, match="device exploded"):
        b.submit(1)
    b.finish()


# ---------------------------------------------------------------------------
# Safe XLA host-platform flag configuration (repro.launch.devices)
# ---------------------------------------------------------------------------

def test_merge_xla_flag_preserves_other_flags():
    merged = launch_devices.merge_xla_flag(
        "--xla_cpu_foo=1 --xla_force_host_platform_device_count=2 --bar",
        "--xla_force_host_platform_device_count", 8)
    assert merged == ("--xla_cpu_foo=1 --bar "
                      "--xla_force_host_platform_device_count=8")
    assert launch_devices.merge_xla_flag(
        None, "--xla_force_host_platform_device_count", 4) == \
        "--xla_force_host_platform_device_count=4"
    # valueless occurrence of the same flag is also replaced
    assert launch_devices.merge_xla_flag(
        "--f", "--f", 3) == "--f=3"


def test_set_host_platform_device_count_rejects_bad_n():
    with pytest.raises(ValueError, match=">= 1"):
        launch_devices.set_host_platform_device_count(0)


def test_set_host_platform_device_count_after_backend_init():
    """This test file has long since initialized the backend — setting a
    *different* count must raise (strict) or warn-and-return-False, never
    silently no-op; re-asserting the live count is fine."""
    import jax

    live = len(jax.devices())
    assert launch_devices.backend_initialized()
    assert launch_devices.set_host_platform_device_count(live) is True
    with pytest.raises(RuntimeError, match="already initialized"):
        launch_devices.set_host_platform_device_count(live + 1)
    assert launch_devices.set_host_platform_device_count(
        live + 1, strict=False) is False
