"""repro.workflows: DAG model, critical-path slack, precedence release.

Property tests (hypothesis) pin the three contracts the subsystem is built
on: generated task graphs are acyclic and topologically consistent; the
critical-path deadline never lets the Eq (11) mask admit an arc the task
cannot finish behind; and the engine never starts a task before every
predecessor has finished — in batch replay and in the streamed decision
loop, which must agree bit for bit."""
import copy

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import footprint, problem, telemetry
from repro.sim.engine import EventSimulator, SimConfig
from repro.sim.scenarios import get_scenario
from repro.workflows import (CycleError, WorkflowSpec, assign_deadlines,
                             critical_path_s, longest_path_to_sink,
                             precedence_violations, workflow_miss_rate,
                             workflow_trace)
from repro.workflows.cpath import edges_from_deps, topological_order

_TELE = None


def _tele():
    global _TELE
    if _TELE is None:
        _TELE = telemetry.generate(days=1, seed=0)
    return _TELE


def _task(job_id, deps=(), exec_s=100.0, submit=0.0, deadline=None):
    return problem.Job(job_id=job_id, home_region=0, submit_time_s=submit,
                       exec_time_s=exec_s, energy_kwh=0.5, tolerance=0.5,
                       deps=tuple(deps), deadline_override_s=deadline)


# ---------------------------------------------------------------------------
# Critical-path math: exact pins on the diamond
# ---------------------------------------------------------------------------

def test_diamond_longest_path_and_deadlines():
    #   0 -> 1 -> 3,  0 -> 2 -> 3;  exec = [10, 20, 15, 10]
    exec_s = np.array([10.0, 20.0, 15.0, 10.0])
    edges = np.array([[0, 1], [0, 2], [1, 3], [2, 3]])
    L = longest_path_to_sink(exec_s, edges)
    assert L.tolist() == [40.0, 30.0, 25.0, 10.0]
    assert critical_path_s(exec_s, edges) == 40.0
    dl, wf = assign_deadlines(exec_s, edges, submit_s=0.0, tolerance=0.5)
    assert wf == 60.0                        # (1 + 0.5) * 40
    assert dl.tolist() == [30.0, 50.0, 50.0, 60.0]


def test_single_task_degenerates_to_plain_deadline():
    """A 1-node workflow's critical-path deadline equals the plain-job
    deadline — DAG semantics are a strict extension."""
    dl, wf = assign_deadlines(np.array([200.0]), np.zeros((0, 2), np.int64),
                              submit_s=50.0, tolerance=0.25)
    plain = _task(0, submit=50.0, exec_s=200.0)
    plain = problem.Job(**{**plain.__dict__, "tolerance": 0.25})
    assert dl[0] == wf == plain.deadline_s


def test_cycle_raises():
    with pytest.raises(CycleError):
        WorkflowSpec(workflow_id=0,
                     tasks=(_task(0, deps=(1,)), _task(1, deps=(0,))))


def test_unknown_dep_raises():
    with pytest.raises(CycleError):
        edges_from_deps([0, 1], [(), (7,)])


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_random_dags_acyclic_and_topo_consistent(data):
    """Graphs built by drawing predecessors from earlier nodes are acyclic
    by construction; the layered depths must order every edge and the topo
    permutation must be a valid linearization."""
    n = data.draw(st.integers(2, 12))
    deps = [tuple(data.draw(st.sets(st.integers(0, i - 1), max_size=3)))
            if i else () for i in range(n)]
    edges = edges_from_deps(list(range(n)), deps)
    order = topological_order(n, edges)
    assert sorted(order.tolist()) == list(range(n))
    pos = np.empty(n, np.int64)
    pos[order] = np.arange(n)
    for u, v in edges:
        assert pos[u] < pos[v]
    exec_s = np.full(n, 10.0)
    L = longest_path_to_sink(exec_s, edges)
    for u, v in edges:
        assert L[u] >= exec_s[u] + L[v]      # longest-path Bellman condition


@given(seed=st.integers(0, 30))
@settings(max_examples=20, deadline=None)
def test_generated_traces_are_valid_workflows(seed):
    jobs = workflow_trace(days=0.05, seed=seed, workflows_per_day=300.0)
    assert jobs, "generator produced an empty trace"
    by_wf = {}
    for j in jobs:
        assert j.workflow_id is not None
        assert j.deadline_override_s is not None
        by_wf.setdefault(j.workflow_id, []).append(j)
    ids = {j.job_id for j in jobs}
    assert len(ids) == len(jobs)
    for tasks in by_wf.values():
        # Deps stay inside the workflow; re-validating never raises.
        task_ids = {t.job_id for t in tasks}
        assert all(d in task_ids for t in tasks for d in t.deps)
        WorkflowSpec(workflow_id=tasks[0].workflow_id,
                     tasks=tuple(tasks))


def test_generator_deterministic():
    a = workflow_trace(days=0.05, seed=7, workflows_per_day=200.0)
    b = workflow_trace(days=0.05, seed=7, workflows_per_day=200.0)
    assert [(j.job_id, j.submit_time_s, j.exec_time_s, j.deps,
             j.deadline_override_s) for j in a] \
        == [(j.job_id, j.submit_time_s, j.exec_time_s, j.deps,
             j.deadline_override_s) for j in b]


# ---------------------------------------------------------------------------
# Shared slack definition: vectorized == scalar, and Eq (11) feasibility
# ---------------------------------------------------------------------------

def test_slack_budget_vector_matches_scalar_exactly():
    jobs = [_task(0, exec_s=300.0, submit=10.0),
            _task(1, exec_s=100.0, submit=0.0, deadline=900.0),
            _task(2, exec_s=50.0, submit=200.0),
            _task(3, exec_s=700.0, submit=40.0, deadline=5000.0)]
    for now in (0.0, 55.0, 123.456, 4000.0):
        vec = problem.slack_budget(jobs, now)
        for j, v in zip(jobs, vec):
            assert v == j.slack_budget_s(now)        # bitwise, not approx


@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_mask_never_admits_infeasible_override_arc(data):
    """Eq (11) through the critical-path slack: if ``allowed[i, r]`` then
    starting task i in region r *now* (after the transfer) still meets its
    absolute deadline. The deferral queue and the solver mask both read
    this arc filter, so this is the no-missed-deadline-by-construction
    invariant."""
    now = data.draw(st.floats(0.0, 5000.0))
    n = data.draw(st.integers(1, 8))
    jobs = []
    for i in range(n):
        exec_s = data.draw(st.floats(10.0, 2000.0))
        submit = data.draw(st.floats(0.0, now)) if now else 0.0
        slack = data.draw(st.floats(-500.0, 5000.0))
        jobs.append(problem.Job(
            job_id=i, home_region=data.draw(st.integers(0, 4)),
            submit_time_s=submit, exec_time_s=exec_s, energy_kwh=1.0,
            tolerance=0.5, deadline_override_s=now + exec_s + slack))
    tele = _tele()
    inst = problem.build(jobs, tele, now, np.full(tele.num_regions, 4),
                         footprint.m5_metal())
    for i, j in enumerate(jobs):
        for r in range(tele.num_regions):
            if inst.allowed[i, r]:
                finish = now + inst.latency[i, r] + j.exec_time_s
                assert finish <= j.deadline_override_s \
                    + 1e-12 * j.exec_time_s + 1e-6


# ---------------------------------------------------------------------------
# Engine precedence release: batch, stream, and their bit parity
# ---------------------------------------------------------------------------

def _dag_cell(days=0.04, seed=2, jobs_per_day=3000.0):
    return get_scenario("workflow-diurnal").build(days, seed, jobs_per_day,
                                                  0.15)


@given(seed=st.integers(0, 12))
@settings(max_examples=8, deadline=None)
def test_engine_never_violates_precedence(seed):
    inst = _dag_cell(seed=seed)
    res = EventSimulator(inst.tele, inst.capacity, SimConfig()).run(
        copy.deepcopy(inst.jobs), "waterwise")
    assert res["unfinished"] == 0
    assert precedence_violations(res["records"]) == 0


def test_stream_matches_batch_bit_for_bit():
    from repro.policy.pipeline import forecast_pipeline
    from repro.serve import DecisionLoop, ReplayArrivals, ServeConfig

    inst = _dag_cell(days=0.05, seed=1)

    def pipe():
        return forecast_pipeline(inst.tele, forecaster="oracle", risk=0.0,
                                 defer_eps=1e-4, backend="fused")

    days = 0.05
    batch = EventSimulator(inst.tele, inst.capacity, SimConfig()).run(
        copy.deepcopy(inst.jobs), pipe())
    sim = EventSimulator(inst.tele, inst.capacity, SimConfig())
    loop = DecisionLoop(sim, pipe(), ReplayArrivals(copy.deepcopy(inst.jobs)),
                        ServeConfig(round_s=300.0, queue_bound=1 << 30))
    loop.run(days * 86400.0)
    stream = loop.stepper.result()

    key = lambda r: (r.job.job_id, r.region, r.start_s, r.finish_s,
                     r.carbon_g, r.water_l, r.embodied_g)
    assert [key(r) for r in batch["records"]] \
        == [key(r) for r in stream["records"]]
    assert precedence_violations(batch["records"]) == 0
    assert precedence_violations(stream["records"]) == 0


def test_plain_jobs_unaffected_by_dag_machinery():
    """A depless trace routes entirely through the pre-DAG pending path —
    same records as ever (covered in depth by test_engine golden parity);
    here: the blocked queue stays unused and no overrides appear."""
    from repro.sim import borg_trace
    from repro.sim.trace import scale_capacity_for_utilization

    jobs = borg_trace(days=0.03, seed=5, tolerance=0.5)
    cap = scale_capacity_for_utilization(jobs, 0.03, 5, utilization=0.15)
    res = EventSimulator(_tele(), cap, SimConfig()).run(
        copy.deepcopy(jobs), "waterwise")
    assert all(r.job.deadline_override_s is None for r in res["records"])
    assert np.isnan(res["frame"]["deadline_s"]).all()


# ---------------------------------------------------------------------------
# Accounting: embodied column + workflow metrics
# ---------------------------------------------------------------------------

def test_embodied_column_matches_closed_form():
    from repro.sim import metrics

    inst = _dag_cell()
    res = EventSimulator(inst.tele, inst.capacity, SimConfig()).run(
        copy.deepcopy(inst.jobs), "waterwise")
    server = footprint.m5_metal()
    scale = footprint.region_embodied_scale(inst.tele.num_regions)
    for r in res["records"][:50]:
        expect = footprint.job_embodied(r.finish_s - r.start_s, server,
                                        region_scale=scale[r.region],
                                        servers=r.job.servers)
        assert r.embodied_g == pytest.approx(expect, rel=1e-9)
    s = metrics.summarize(res)
    assert s["embodied_kg"] == pytest.approx(
        sum(r.embodied_g for r in res["records"]) / 1e3, rel=1e-9)
    miss, n_wf = workflow_miss_rate(res["records"])
    assert n_wf > 0 and 0.0 <= miss <= 1.0


def test_waterwise_embodied_registered():
    from repro.policy.registry import get_policy

    spec = get_policy("waterwise-embodied")
    assert "lam_embodied" in spec.params
    inst = _dag_cell(days=0.03)
    res = EventSimulator(inst.tele, inst.capacity, SimConfig()).run(
        copy.deepcopy(inst.jobs), "waterwise-embodied[lam_embodied=0.35]")
    assert res["unfinished"] == 0
    assert precedence_violations(res["records"]) == 0
