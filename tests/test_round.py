"""Parity + invariants for the fused scheduling round (core.round).

The contract pinned here: for identical inputs the fused single-program
path and the unfused staged path produce **bit-identical scheduling
decisions** — the same assignment vector and status per round (and
therefore bit-identical engine records end-to-end). Plus the Eq-11 safety
property that the fused deadline mask can never admit an infeasible slot,
and gradient parity of the RG-LRU kernel's custom VJP (what lets the
learned forecaster *train* through the Pallas kernel).
"""
import copy

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.core import footprint, problem, solvers, telemetry
from repro.core.round import fused_solve, fused_temporal_round, _pad_rows
from repro.core.solvers.jax_solver import bucket_for
from repro.forecast import build_temporal_plan


@pytest.fixture(scope="module")
def tele():
    return telemetry.generate(days=6, seed=0)


def _rand_instance(rng, M, N, tight=False):
    cost = rng.random((M, N)) * 10
    allowed = rng.random((M, N)) > 0.2
    allowed[np.arange(M), rng.integers(0, N, M)] = True   # no empty rows
    slack = 0 if tight else N
    cap = np.full(N, (M + slack) // N + 1)
    return cost, allowed, cap


# ---------------------------------------------------------------------------
# Solver backend "fused" vs "jax": bit-exact per shape bucket
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N", [(3, 4), (10, 5), (60, 6), (200, 8)])
def test_fused_backend_matches_jax_bitwise(M, N):
    """Hard assignment parity across shape buckets: same assignment vector,
    same status, and the same float64 objective (both paths price the
    rounded plan from the identical host-side effective costs)."""
    rng = np.random.default_rng(M * 1000 + N)
    cost, allowed, cap = _rand_instance(rng, M, N)
    r_jax = solvers.solve(cost, allowed, cap, backend="jax")
    r_fused = solvers.solve(cost, allowed, cap, backend="fused")
    assert r_fused.backend == "fused"
    assert r_jax.status == r_fused.status
    np.testing.assert_array_equal(r_jax.assign, r_fused.assign)
    assert r_jax.objective == r_fused.objective            # bit-equal


@pytest.mark.parametrize("M,N", [(6, 4), (40, 5)])
def test_fused_backend_soft_path_matches_jax(M, N):
    rng = np.random.default_rng(M * 7 + N)
    cost, allowed, cap = _rand_instance(rng, M, N)
    overrun = rng.random((M, N)) * 3
    tol = rng.random(M) * 2
    kw = dict(soften=True, overrun=overrun, tol=tol, sigma=10.0)
    r_jax = solvers.solve(cost, allowed, cap, backend="jax", **kw)
    r_fused = solvers.solve(cost, allowed, cap, backend="fused", **kw)
    assert r_jax.status == r_fused.status
    np.testing.assert_array_equal(r_jax.assign, r_fused.assign)
    assert r_jax.objective == r_fused.objective
    np.testing.assert_array_equal(r_jax.penalties, r_fused.penalties)


def test_fused_backend_infeasible():
    cost = np.ones((4, 2))
    allowed = np.ones((4, 2), bool)
    res = solvers.solve(cost, allowed, np.array([1, 1]), backend="fused")
    assert res.status == "infeasible" and (res.assign == -1).all()
    # A row with no allowed arc is infeasible in the hard path too.
    allowed[0] = False
    res = solvers.solve(cost, allowed, np.array([4, 4]), backend="fused")
    assert res.status == "infeasible"


def test_fused_registered_in_registry():
    assert "fused" in solvers.available_backends()


# ---------------------------------------------------------------------------
# The fused temporal round vs the unfused planner + solver
# ---------------------------------------------------------------------------

def _temporal_case(tele, rng, M, S=8, R=5, tolerance=4.0):
    server = footprint.m5_metal()
    offsets = np.arange(S) * 1800.0
    jobs = [problem.Job(job_id=i, home_region=i % R, submit_time_s=0.0,
                        exec_time_s=600.0 + 10 * i, energy_kwh=0.05,
                        tolerance=tolerance) for i in range(M)]
    cap = np.full(R, max(2, M // R + 1))
    snap = tele.at(0.0)
    inst = problem.build(jobs, tele, 0.0, cap, server, snap=snap)
    ci = rng.random((M, S, R)) * 300 + 50
    ewif = rng.random((M, S, R)) * 2 + 0.5
    wue = rng.random((M, S, R)) * 1 + 0.2
    return inst, snap, server, offsets, ci, ewif, wue


@pytest.mark.parametrize("lam_co2,lam_h2o", [(0.5, 0.5), (1.0, 0.0),
                                             (0.0, 1.0)])
@pytest.mark.parametrize("M", [3, 17, 60])
def test_fused_temporal_round_matches_unfused(tele, lam_co2, lam_h2o, M):
    """waterwise / carbon-only / water-only pricing, three shape buckets:
    the fused program's decisions are bit-identical to build_temporal_plan
    + the jax solver."""
    rng = np.random.default_rng(M)
    inst, snap, server, offsets, ci, ewif, wue = _temporal_case(tele, rng, M)
    plan = build_temporal_plan(inst, 0.0, ci, ewif, wue, snap["pue"],
                               snap["wsf"], offsets, server, lam_co2,
                               lam_h2o)
    r_ref = solvers.solve(plan.cost, plan.allowed, plan.capacity,
                          backend="jax")
    _, _, cap_t, r_fused = fused_temporal_round(
        inst, 0.0, ci, ewif, wue, snap["pue"], snap["wsf"], offsets, server,
        lam_co2, lam_h2o)
    assert r_fused.backend == "fused"
    assert r_ref.status == r_fused.status
    np.testing.assert_array_equal(r_ref.assign, r_fused.assign)
    np.testing.assert_array_equal(cap_t, plan.capacity)


def test_fused_temporal_round_want_plan_matches_planner(tele):
    """want_plan=True returns the priced cost/mask tensors; they must agree
    with the host planner's (mask exactly; costs to float32 round-trip —
    the tensors price on device in f32)."""
    rng = np.random.default_rng(7)
    inst, snap, server, offsets, ci, ewif, wue = _temporal_case(tele, rng, 9)
    plan = build_temporal_plan(inst, 0.0, ci, ewif, wue, snap["pue"],
                               snap["wsf"], offsets, server, 0.5, 0.5)
    cost, allowed, cap_t, res = fused_temporal_round(
        inst, 0.0, ci, ewif, wue, snap["pue"], snap["wsf"], offsets, server,
        0.5, 0.5, want_plan=True)
    np.testing.assert_array_equal(allowed, plan.allowed)
    np.testing.assert_allclose(cost[allowed], plan.cost[plan.allowed],
                               rtol=2e-6)
    assert res.feasible


def test_round_buckets_match_solver_buckets():
    """Host-side padding must land every M on a compiled-bucket shape so a
    full simulation compiles once per bucket, exactly like jax_solver."""
    for M in (1, 3, 4, 15, 16, 63, 200):
        bucket, pad = _pad_rows(M)
        assert bucket == bucket_for(M + 1)
        assert bucket - 1 - M == pad >= 0


# ---------------------------------------------------------------------------
# Eq-11 safety: the fused mask never admits a deadline-infeasible slot
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_fused_mask_never_admits_infeasible_slot(data):
    """Property: whatever the (budget, latency, offsets, guard) draw, an
    admitted (job, slot ≥ 1, region) arc always satisfies
    offset + latency + guard ≤ slack budget, and slot 0 reproduces the
    instance's Eq-11 mask exactly."""
    tele_p = telemetry.generate(days=1, seed=1)
    R = tele_p.num_regions
    M = data.draw(st.integers(1, 7), label="jobs")
    S = data.draw(st.integers(2, 6), label="slots")
    slot_s = data.draw(st.sampled_from([600.0, 1800.0, 3600.0]))
    guard_s = data.draw(st.sampled_from([0.0, 240.0, 900.0]))
    tolerance = data.draw(st.floats(0.1, 6.0), label="tolerance")
    server = footprint.m5_metal()
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
    jobs = [problem.Job(job_id=i, home_region=i % R, submit_time_s=0.0,
                        exec_time_s=float(rng.uniform(60, 4000)),
                        energy_kwh=0.05, tolerance=tolerance)
            for i in range(M)]
    cap = np.full(R, M + 1)
    snap = tele_p.at(0.0)
    inst = problem.build(jobs, tele_p, 0.0, cap, server, snap=snap)
    offsets = np.arange(S) * slot_s
    ci = rng.random((M, S, R)) * 300 + 1
    ewif = rng.random((M, S, R)) + 0.1
    wue = rng.random((M, S, R)) + 0.1
    _, allowed, _, _ = fused_temporal_round(
        inst, 0.0, ci, ewif, wue, snap["pue"], snap["wsf"], offsets, server,
        0.5, 0.5, guard_s=guard_s, want_plan=True)
    budget = np.array([j.slack_budget_s(0.0) for j in jobs])
    grid = allowed.reshape(M, S, R)
    np.testing.assert_array_equal(grid[:, 0, :], inst.allowed)
    need = offsets[None, 1:, None] + inst.latency[:, None, :] + guard_s
    admitted = grid[:, 1:, :]
    assert (need[admitted] <= budget[:, None, None]
            .repeat(S - 1, 1).repeat(R, 2)[admitted] + 1e-9).all()


# ---------------------------------------------------------------------------
# End-to-end: bit-identical engine records through the event simulator
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_e2e_records_bit_identical_jax_vs_fused(tele):
    """The standard diurnal cell through the waterwise-forecast pipeline:
    every scheduled record (region, start, finish, carbon, water) is
    bit-identical between backend="jax" and backend="fused"."""
    from repro.policy.pipeline import forecast_pipeline
    from repro.sim.engine import EventSimulator, SimConfig
    from repro.sim.trace import borg_trace, scale_capacity_for_utilization

    jobs = borg_trace(days=0.03, seed=3, tolerance=4.0,
                      target_jobs_per_day=23000.0)
    cap = scale_capacity_for_utilization(jobs, 0.03, 5, 0.15)

    def run(backend):
        ctl = forecast_pipeline(tele, forecaster="oracle", risk=0.0,
                                defer_eps=1e-4, backend=backend)
        return EventSimulator(tele, cap, SimConfig()).run(
            copy.deepcopy(jobs), ctl)

    def key(r):
        return (r.job.job_id, r.region, r.start_s, r.finish_s,
                r.carbon_g, r.water_l)

    r_jax, r_fused = run("jax"), run("fused")
    assert r_jax["unfinished"] == r_fused["unfinished"] == 0
    assert [key(r) for r in r_jax["records"]] \
        == [key(r) for r in r_fused["records"]]


# ---------------------------------------------------------------------------
# RG-LRU custom VJP: training gradients through the Pallas kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W,chunk", [(2, 32, 16, 16), (3, 48, 8, 48),
                                         (1, 64, 4, 16)])
def test_rglru_vjp_matches_associative_scan(B, S, W, chunk):
    """The kernel's custom VJP (reverse recurrence run as one more forward
    kernel scan) must match autodiff through the associative scan."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.rglru_scan.ops import rglru_scan

    rng = np.random.default_rng(B * 100 + S)
    a = jnp.asarray(rng.uniform(0.2, 0.95, (B, S, W)), jnp.float32)
    bx = jnp.asarray(rng.normal(size=(B, S, W)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(B, S, W)), jnp.float32)

    def ref_scan(a, bx):
        def op(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        _, y = jax.lax.associative_scan(op, (a, bx), axis=1)
        return y

    loss_k = lambda a, bx: jnp.sum(w * rglru_scan(a, bx, chunk=chunk))
    loss_r = lambda a, bx: jnp.sum(w * ref_scan(a, bx))
    gk = jax.grad(loss_k, argnums=(0, 1))(a, bx)
    gr = jax.grad(loss_r, argnums=(0, 1))(a, bx)
    np.testing.assert_allclose(gk[0], gr[0], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(gk[1], gr[1], atol=1e-4, rtol=1e-4)


def test_learned_forecaster_trains_through_pallas(tele):
    """scan_impl="pallas" now trains (custom VJP) and must land on the
    same parameters as the associative scan on the same draw."""
    from repro import forecast

    fits = {}
    for impl in ("assoc", "pallas"):
        f = forecast.make_forecaster("learned", train_steps=3, seed=0,
                                     scan_impl=impl)
        f.fit(tele.ci[:96])
        assert f.train_count == 1
        fits[impl] = (f.last_loss, f.predict(6).mean)
    assert fits["assoc"][0] == pytest.approx(fits["pallas"][0], rel=1e-5)
    np.testing.assert_allclose(fits["assoc"][1], fits["pallas"][1],
                               rtol=1e-5, atol=1e-8)


def test_learned_cache_stats_shape():
    from repro.forecast import learned

    stats = learned.cache_stats()
    for name in ("train_step", "predict_fn"):
        assert {"hits", "misses", "currsize", "maxsize",
                "builds"} <= set(stats[name])
        assert stats[name]["maxsize"] == learned.CACHE_CONFIGS
        assert stats[name]["builds"] >= stats[name]["currsize"]
