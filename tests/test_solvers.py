"""Solver cross-validation: exactness of ``flow``, the soft-cost fold, and
the Sinkhorn backend's integrality gap (paper Eqs 8-13)."""
import numpy as np
import pytest
import scipy.optimize as sopt
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from repro.core import solvers


def _random_instance(rng, M=None, N=None, feasible=True):
    M = M or int(rng.integers(3, 40))
    N = N or int(rng.integers(2, 6))
    cost = rng.random((M, N)) * 10
    allowed = rng.random((M, N)) < 0.8
    if feasible:
        allowed[np.arange(M), rng.integers(0, N, M)] = True
    cap = rng.integers(1, max(M // max(N - 1, 1), 2), N)
    while feasible and cap.sum() < M:
        cap[rng.integers(0, N)] += 1
    return cost, allowed, cap


@pytest.mark.parametrize("seed", range(10))
def test_flow_matches_scipy_exactly(seed):
    rng = np.random.default_rng(seed)
    cost, allowed, cap = _random_instance(rng)
    r_ref = solvers.solve(cost, allowed, cap, backend="scipy")
    r_flow = solvers.solve(cost, allowed, cap, backend="flow")
    assert r_ref.status == "optimal"
    assert r_flow.status == "optimal"
    assert np.isclose(r_flow.objective, r_ref.objective, atol=1e-8)


@pytest.mark.parametrize("seed", range(5))
def test_jax_sinkhorn_gap_small(seed):
    rng = np.random.default_rng(100 + seed)
    cost, allowed, cap = _random_instance(rng)
    r_ref = solvers.solve(cost, allowed, cap, backend="scipy")
    r_jax = solvers.solve(cost, allowed, cap, backend="jax")
    assert r_jax.feasible
    gap = (r_jax.objective - r_ref.objective) / max(abs(r_ref.objective),
                                                    1e-9)
    assert gap <= 0.02, f"integrality gap {gap:.2%}"
    # capacity respected
    counts = np.bincount(r_jax.assign, minlength=len(cap))
    assert (counts <= cap).all()


def _literal_soft_milp(cost, allowed, capacity, overrun, tol, sigma):
    """Eqs 12-13 with EXPLICIT penalty variables P[m,n] (the literal paper
    formulation) via scipy.milp — proves the folded-cost reduction exact."""
    M, N = cost.shape
    nx = M * N
    # variables: x (binary, M*N) then p (continuous >= 0, M*N)
    c = np.concatenate([cost.reshape(-1), sigma * np.ones(nx)])
    rows, cols, vals, lb, ub = [], [], [], [], []
    r = 0
    for m in range(M):                       # assignment == 1
        for n in range(N):
            rows.append(r); cols.append(m * N + n); vals.append(1.0)
        lb.append(1.0); ub.append(1.0); r += 1
    for n in range(N):                       # capacity
        for m in range(M):
            rows.append(r); cols.append(m * N + n); vals.append(1.0)
        lb.append(0.0); ub.append(float(capacity[n])); r += 1
    for m in range(M):                       # Eq 13 per job
        for n in range(N):
            rows.append(r); cols.append(m * N + n)
            vals.append(float(overrun[m, n]))
            rows.append(r); cols.append(nx + m * N + n); vals.append(-1.0)
        lb.append(-np.inf); ub.append(float(tol[m])); r += 1
    A = sp.csr_matrix((vals, (rows, cols)), shape=(r, 2 * nx))
    res = sopt.milp(
        c=c, constraints=sopt.LinearConstraint(A, lb, ub),
        integrality=np.concatenate([np.ones(nx), np.zeros(nx)]),
        bounds=sopt.Bounds(np.zeros(2 * nx),
                           np.concatenate([np.ones(nx),
                                           np.full(nx, np.inf)])))
    assert res.success
    return res.fun


@pytest.mark.parametrize("seed", range(5))
def test_soft_fold_equals_literal_formulation(seed):
    """The folded per-arc penalty (solvers.soft_cost) is exactly the
    literal Eq 12-13 MILP optimum."""
    rng = np.random.default_rng(200 + seed)
    M, N = int(rng.integers(3, 10)), int(rng.integers(2, 5))
    cost = rng.random((M, N))
    overrun = rng.random((M, N)) * 2
    tol = rng.random(M)
    allowed = overrun <= tol[:, None]
    cap = np.full(N, M)
    sigma = 3.0
    folded = solvers.solve(cost, allowed, cap, backend="flow", soften=True,
                           overrun=overrun, tol=tol, sigma=sigma)
    literal = _literal_soft_milp(cost, allowed, cap, overrun, tol, sigma)
    assert np.isclose(folded.objective, literal, atol=1e-7)


def test_infeasible_detection():
    cost = np.ones((3, 2))
    allowed = np.zeros((3, 2), bool)
    cap = np.array([1, 1])
    for backend in ("scipy", "flow", "jax"):
        r = solvers.solve(cost, allowed, cap, backend=backend)
        assert not r.feasible


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_flow_optimality_property(seed):
    """Property: flow's assignment is feasible and its objective matches the
    exact LP/MILP optimum on every random instance."""
    rng = np.random.default_rng(seed)
    cost, allowed, cap = _random_instance(rng, M=int(rng.integers(3, 25)))
    r_flow = solvers.solve(cost, allowed, cap, backend="flow")
    r_ref = solvers.solve(cost, allowed, cap, backend="scipy")
    assert r_flow.status == r_ref.status == "optimal"
    counts = np.bincount(r_flow.assign, minlength=len(cap))
    assert (counts <= cap).all()
    assert all(allowed[m, r_flow.assign[m]] for m in range(cost.shape[0]))
    assert np.isclose(r_flow.objective, r_ref.objective, atol=1e-8)
