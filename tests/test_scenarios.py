"""Scenario registry + sweep runner + batched solver entry points."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import solvers, telemetry
from repro.sim import scenarios

SWEEP_KW = dict(days=0.05, seed=0, jobs_per_day=23000.0, max_workers=1)


def test_registry_contains_required_scenarios():
    names = scenarios.list_scenarios()
    for required in ("nominal", "drought-summer", "decarbonization",
                     "capacity-loss", "burst-storm", "water-stress-weighted"):
        assert required in names
    with pytest.raises(KeyError):
        scenarios.get_scenario("no-such-regime")


def test_scenario_builders_are_deterministic():
    for name in scenarios.list_scenarios():
        a = scenarios.get_scenario(name).build(0.05, 0, 23000.0, 0.15)
        b = scenarios.get_scenario(name).build(0.05, 0, 23000.0, 0.15)
        assert len(a.jobs) == len(b.jobs)
        assert [j.submit_time_s for j in a.jobs] == \
               [j.submit_time_s for j in b.jobs]
        assert [j.home_region for j in a.jobs] == \
               [j.home_region for j in b.jobs]
        np.testing.assert_array_equal(a.capacity, b.capacity)
        np.testing.assert_array_equal(a.tele.ci, b.tele.ci)
        np.testing.assert_array_equal(a.tele.wue, b.tele.wue)


def test_perturbations_move_the_right_signals():
    base = scenarios.get_scenario("nominal").build(0.05, 0, 23000.0, 0.15)
    drought = scenarios.get_scenario("drought-summer").build(
        0.05, 0, 23000.0, 0.15)
    assert (drought.tele.wue > base.tele.wue).all()
    assert (drought.tele.wsf >= base.tele.wsf).all()
    for days in (0.2, 1.0):
        decarb = scenarios.get_scenario("decarbonization").build(
            days, 0, 23000.0, 0.15)
        nominal = scenarios.get_scenario("nominal").build(
            days, 0, 23000.0, 0.15)
        sim_hours = int(days * 24)
        window = slice(0, max(sim_hours, 1))
        # The ramp must land inside the *simulated* window, not just
        # somewhere in the (longer) telemetry horizon.
        assert decarb.tele.ci[window].sum() < nominal.tele.ci[window].sum()
        np.testing.assert_array_equal(decarb.tele.ci[0], nominal.tele.ci[0])


def test_capacity_loss_scenario_has_events():
    inst = scenarios.get_scenario("capacity-loss").build(
        1.0, 0, 23000.0, 0.15)
    assert len(inst.capacity_events) == 2
    (t0, degraded), (t1, restored) = inst.capacity_events
    assert 0 < t0 < t1
    assert degraded.sum() < restored.sum()
    assert (degraded == 0).any()


def test_sweep_rows_and_savings():
    rows = scenarios.sweep(["baseline", "least-load"],
                           ["nominal", "drought-summer"], **SWEEP_KW)
    assert len(rows) == 4
    for row in rows:
        assert {"scenario", "scheduler", "carbon_kg", "water_kl",
                "stress_water_kl", "wall_s"} <= set(row)
        if row["scheduler"] == "baseline":
            assert row["carbon_savings_pct"] == 0.0
    table = scenarios.to_table(rows)
    assert "drought-summer" in table and "least-load" in table


def test_sweep_parallel_matches_serial():
    serial = scenarios.sweep(["baseline"], ["nominal"], **SWEEP_KW)
    par_kw = dict(SWEEP_KW, max_workers=2)
    parallel = scenarios.sweep(["baseline"], ["nominal"], **par_kw)
    assert serial[0]["carbon_kg"] == parallel[0]["carbon_kg"]
    assert serial[0]["water_kl"] == parallel[0]["water_kl"]


def test_stress_weighting_changes_reported_water_only():
    kw = dict(SWEEP_KW)
    plain = scenarios.sweep(["baseline"], ["nominal"], **kw)[0]
    stressed = scenarios.sweep(["baseline"], ["water-stress-weighted"],
                               **kw)[0]
    # Same physics -> same raw footprints; only the stress view differs.
    assert stressed["carbon_kg"] == pytest.approx(plain["carbon_kg"])
    assert stressed["water_kl"] == pytest.approx(plain["water_kl"])
    assert stressed["stress_water_kl"] != pytest.approx(
        stressed["water_kl"], rel=1e-3)
    assert plain["stress_water_kl"] == pytest.approx(plain["water_kl"])


# ---------------------------------------------------------------------------
# Batched / padded solver entry points
# ---------------------------------------------------------------------------

def _random_instance(rng):
    M = int(rng.integers(3, 30))
    N = int(rng.integers(2, 6))
    cost = rng.random((M, N)) * 10
    allowed = rng.random((M, N)) < 0.85
    allowed[np.arange(M), rng.integers(0, N, M)] = True
    cap = rng.integers(1, max(M // max(N - 1, 1), 2), N)
    while cap.sum() < M:
        cap[rng.integers(0, N)] += 1
    return cost, allowed, cap


def test_padded_solve_matches_exact_flow():
    rng = np.random.default_rng(7)
    for _ in range(8):
        cost, allowed, cap = _random_instance(rng)
        r_ref = solvers.solve(cost, allowed, cap, backend="flow")
        r_jax = solvers.solve(cost, allowed, cap, backend="jax")
        if not r_ref.feasible:
            continue
        assert r_jax.feasible
        gap = (r_jax.objective - r_ref.objective) / max(
            abs(r_ref.objective), 1e-9)
        assert gap <= 0.02


@pytest.mark.slow
def test_solve_many_matches_single_solves():
    rng = np.random.default_rng(11)
    insts = [_random_instance(rng) for _ in range(12)]
    costs, alloweds, caps = map(list, zip(*insts))
    batched = solvers.solve_many(costs, alloweds, caps, backend="jax")
    singles = [solvers.solve(c, a, p, backend="jax")
               for c, a, p in insts]
    assert len(batched) == len(singles)
    for rb, rs in zip(batched, singles):
        assert rb.feasible == rs.feasible
        if rb.feasible:
            assert rb.objective == pytest.approx(rs.objective, abs=1e-5)
    for (c, a, p), rb in zip(insts, batched):
        if rb.feasible:
            counts = np.bincount(rb.assign, minlength=len(p))
            assert (counts <= p).all()


def test_solve_many_loop_fallback_backend():
    rng = np.random.default_rng(13)
    insts = [_random_instance(rng) for _ in range(4)]
    costs, alloweds, caps = map(list, zip(*insts))
    rs = solvers.solve_many(costs, alloweds, caps, backend="flow")
    for (c, a, p), r in zip(insts, rs):
        ref = solvers.solve(c, a, p, backend="flow")
        assert r.status == ref.status
        if r.feasible:
            assert r.objective == pytest.approx(ref.objective, abs=1e-9)


def test_bucket_for_is_monotone_and_covering():
    from repro.core.solvers import jax_solver
    last = 0
    for b in jax_solver.BUCKETS:
        assert b > last
        last = b
    for m in (1, 3, 4, 5, 17, 1000, 5000, 10000):
        assert jax_solver.bucket_for(m) >= m


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_padded_solver_capacity_property(seed):
    rng = np.random.default_rng(seed)
    cost, allowed, cap = _random_instance(rng)
    r = solvers.solve(cost, allowed, cap, backend="jax")
    if r.feasible:
        counts = np.bincount(r.assign, minlength=len(cap))
        assert (counts <= cap).all()
        assert all(allowed[m, r.assign[m]] for m in range(cost.shape[0]))
