"""End-to-end simulator behaviour: the paper's §6 claims in miniature."""
import copy

import numpy as np
import pytest

from repro.core import telemetry
from repro.core.baselines import make_scheduler
from repro.sim import Simulator, borg_trace, savings_vs, summarize
from repro.sim.trace import alibaba_trace, scale_capacity_for_utilization


@pytest.fixture(scope="module")
def setup():
    tele = telemetry.generate(days=1, seed=0)
    jobs = borg_trace(days=0.15, seed=0, tolerance=0.5)
    cap = scale_capacity_for_utilization(jobs, 0.15, 5, utilization=0.15)
    return tele, jobs, cap


def _run(setup, name, **kw):
    tele, jobs, cap = setup
    sched = make_scheduler(name, tele, **kw)
    return summarize(Simulator(tele, cap).run(copy.deepcopy(jobs), sched))


def test_baseline_stays_home(setup):
    s = _run(setup, "baseline")
    assert s["moved_pct"] == 0.0
    assert s["violation_pct"] == 0.0


def test_waterwise_saves_both_metrics(setup):
    base = _run(setup, "baseline")
    ww = _run(setup, "waterwise")
    sv = savings_vs(base, ww)
    assert sv["carbon_savings_pct"] > 5.0
    assert sv["water_savings_pct"] > 5.0
    assert ww["violation_pct"] < 3.0               # paper Table 2 regime
    assert ww["mean_service_ratio"] < 1.5


def test_carbon_water_tension(setup):
    """Paper Observation 3: each greedy oracle wins its own metric but is
    suboptimal on the other; WaterWise sits between."""
    base = _run(setup, "baseline")
    cg = _run(setup, "carbon-greedy-opt")
    wg = _run(setup, "water-greedy-opt")
    ww = _run(setup, "waterwise")
    assert cg["carbon_kg"] < ww["carbon_kg"] < wg["carbon_kg"]
    assert wg["water_kl"] < ww["water_kl"] < cg["water_kl"]


def test_load_balancers_are_unaware(setup):
    """Round-Robin / Least-Load must not beat WaterWise on either metric."""
    ww = _run(setup, "waterwise")
    for name in ("round-robin", "least-load"):
        s = _run(setup, name)
        assert ww["carbon_kg"] < s["carbon_kg"]
        assert ww["water_kl"] < s["water_kl"]


def test_delay_tolerance_monotonicity():
    """Higher TOL% → (weakly) more savings (paper Fig 5)."""
    tele = telemetry.generate(days=1, seed=0)
    outs = {}
    for tol in (0.25, 1.0):
        jobs = borg_trace(days=0.1, seed=0, tolerance=tol)
        cap = scale_capacity_for_utilization(jobs, 0.1, 5, utilization=0.15)
        base = summarize(Simulator(tele, cap).run(
            copy.deepcopy(jobs), make_scheduler("baseline", tele)))
        ww = summarize(Simulator(tele, cap).run(
            copy.deepcopy(jobs), make_scheduler("waterwise", tele)))
        outs[tol] = savings_vs(base, ww)
    assert (outs[1.0]["carbon_savings_pct"]
            >= outs[0.25]["carbon_savings_pct"] - 1.0)


def test_alibaba_trace_rate():
    borg = borg_trace(days=0.1, seed=0)
    ali = alibaba_trace(days=0.1, seed=0)
    assert len(ali) > 5 * len(borg)                  # ~8.5× invocation rate


def test_simulator_determinism(setup):
    a = _run(setup, "waterwise")
    b = _run(setup, "waterwise")
    assert a["carbon_kg"] == b["carbon_kg"]
    assert a["jobs"] == b["jobs"]
