"""Controller (Algorithm 1), slack manager (Eq 14), history learner, and
telemetry calibration tests."""
import numpy as np
import pytest

from repro.core import slack, telemetry
from repro.core.controller import Controller
from repro.core.problem import Job


@pytest.fixture(scope="module")
def tele():
    return telemetry.generate(days=2, seed=0)


def _jobs(n, tol=0.5, t=600.0, submit=0.0):
    return [Job(job_id=i, home_region=i % 5, submit_time_s=submit,
                exec_time_s=t, energy_kwh=0.05, tolerance=tol)
            for i in range(n)]


def test_urgency_decreases_with_waiting(tele):
    jobs = _jobs(1)
    u0 = slack.urgency(jobs, now_s=0.0)[0]
    u1 = slack.urgency(jobs, now_s=100.0)[0]
    assert u1 == pytest.approx(u0 - 100.0)


def test_slack_manager_picks_most_urgent(tele):
    a = Job(0, 0, 0.0, 100.0, 0.01, tolerance=0.25)   # little slack
    b = Job(1, 0, 0.0, 10_000.0, 0.01, tolerance=1.0)  # lots of slack
    chosen, deferred = slack.pick_most_urgent([b, a], 0.0, 1)
    assert chosen == [a] and deferred == [b]


def test_controller_respects_capacity(tele):
    ctl = Controller(tele)
    jobs = _jobs(10)
    cap = np.array([1, 1, 1, 1, 1])                    # only 5 slots
    dec = ctl.schedule(jobs, 0.0, cap)
    assert len(dec.scheduled) == 5
    assert len(dec.deferred) == 5
    counts = np.bincount(dec.assign, minlength=5)
    assert (counts <= cap).all()


def test_controller_soft_fallback_on_infeasible(tele):
    """Jobs whose tolerance cannot admit any remote arc AND whose home is
    full must still be placed via the soft path (Algorithm 1 lines 10-11)."""
    ctl = Controller(tele)
    # 3 jobs, all home=0, capacity 1 at home; zero tolerance forbids moves.
    jobs = [Job(i, 0, 0.0, 60.0, 0.01, tolerance=0.0) for i in range(3)]
    cap = np.array([1, 3, 3, 3, 3])
    dec = ctl.schedule(jobs, 0.0, cap)
    assert dec.softened
    assert len(dec.scheduled) == 3                     # all placed anyway
    assert (dec.solver.penalties >= 0).all()


def test_weights_shift_decisions(tele):
    """λ_CO2=1 should (weakly) beat λ_H2O=1 on carbon and vice versa."""
    jobs_a, jobs_b = _jobs(40), _jobs(40)
    cap = np.array([20] * 5)
    snap = tele.at(0.0)
    carbon_ctl = Controller(tele, lam_co2=1.0, lam_h2o=0.0)
    water_ctl = Controller(tele, lam_co2=0.0, lam_h2o=1.0)
    da = carbon_ctl.schedule(jobs_a, 0.0, cap.copy())
    db = water_ctl.schedule(jobs_b, 0.0, cap.copy())
    ci = snap["ci"]
    wi = snap["water_intensity"]
    assert ci[da.assign].mean() <= ci[db.assign].mean() + 1e-9
    assert wi[db.assign].mean() <= wi[da.assign].mean() + 1e-9


def test_history_learner_window(tele):
    ctl = Controller(tele, window=3)
    for h in range(5):
        ctl.history.observe(tele.at(h * 3600.0))
    assert len(ctl.history.ci) == 3
    assert ctl.history.co2_ref.shape == (5,)


# -- telemetry calibration (paper Fig 1 / Fig 2) ---------------------------

def test_fig1_source_constants():
    assert telemetry.SOURCE_CI["coal"] / telemetry.SOURCE_CI["hydro"] > 60
    assert (telemetry.EWIF_MACKNICK["hydro"]
            / telemetry.EWIF_MACKNICK["coal"]) > 10


def test_fig2_regional_structure(tele):
    ci_mean = tele.ci.mean(axis=0)
    ewif_mean = tele.ewif.mean(axis=0)
    zurich = telemetry.REGION_INDEX["Zurich"]
    mumbai = telemetry.REGION_INDEX["Mumbai"]
    assert ci_mean[zurich] == ci_mean.min()        # lowest carbon intensity
    assert ci_mean[mumbai] == ci_mean.max()        # highest carbon intensity
    assert ewif_mean[zurich] == ewif_mean.max()    # most water-thirsty grid
    # temporal variation exists (Fig 2e)
    assert (tele.ci.std(axis=0) > 1.0).all()
    # carbon-water tension: CI and water intensity not positively aligned
    wi_mean = tele.water_intensity.mean(axis=0)
    assert np.corrcoef(ci_mean, wi_mean)[0, 1] < 0.5


def test_transfer_latency_properties():
    lat = telemetry.transfer_latency_s(2e9, 0, 1)
    assert lat > telemetry.transfer_latency_s(2e9, 0, 0) == 0.0
    assert (telemetry.transfer_latency_s(4e9, 0, 1)
            > telemetry.transfer_latency_s(2e9, 0, 1))


def test_region_subset_keeps_wan_identity():
    """Non-prefix region subsets (fig12 ablations) must price transfers
    with the named regions' WAN rows, not whatever occupies the same local
    index in the global tables."""
    sub = [r for r in telemetry.REGIONS
           if r.name in ("Zurich", "Milan", "Mumbai")]
    tele3 = telemetry.generate(days=1, seed=0, regions=sub)
    zur, mum = 0, 2                      # local indices in the subset
    assert tele3.transfer_latency_s(2e9, zur, mum) == \
        telemetry.transfer_latency_s(2e9, telemetry.REGION_INDEX["Zurich"],
                                     telemetry.REGION_INDEX["Mumbai"])
    full = telemetry.generate(days=1, seed=0)
    np.testing.assert_array_equal(full.wan_bw_gbps, telemetry.WAN_BW_GBPS)
    np.testing.assert_array_equal(full.wan_rtt_s, telemetry.WAN_RTT_S)
