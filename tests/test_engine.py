"""Event-driven engine: golden parity vs the windowed oracle + invariants.

The golden parity test is the contract that lets the event engine replace
the seed engine everywhere: identical scheduler-visible decision points ⇒
identical placements; accounting may differ only by the oracle's trapezoid
sub-sampling error (the event engine integrates the piecewise-linear
telemetry exactly)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import telemetry
from repro.core.baselines import make_scheduler
from repro.sim import EventSimulator, WindowedSimulator, borg_trace, summarize
from repro.sim.engine import SimConfig
from repro.sim.trace import scale_capacity_for_utilization

ACCOUNTING_RTOL = 5e-3          # trapezoid-vs-exact integration tolerance


@pytest.fixture(scope="module")
def setup():
    tele = telemetry.generate(days=1, seed=0)
    jobs = borg_trace(days=0.08, seed=3, tolerance=0.5)
    cap = scale_capacity_for_utilization(jobs, 0.08, 5, utilization=0.15)
    return tele, jobs, cap


def _clone(jobs):
    import copy
    return copy.deepcopy(jobs)


@pytest.mark.parametrize("sched", ["baseline", "round-robin", "least-load",
                                   "ecovisor", "carbon-greedy-opt",
                                   "waterwise"])
def test_golden_parity_with_windowed_engine(setup, sched):
    """Per-job records (region, start, finish) are bit-identical; carbon and
    water agree within the oracle's integration tolerance."""
    tele, jobs, cap = setup
    r_old = WindowedSimulator(tele, cap).run(_clone(jobs),
                                             make_scheduler(sched, tele))
    r_new = EventSimulator(tele, cap).run(_clone(jobs),
                                          make_scheduler(sched, tele))
    ro = sorted(r_old["records"], key=lambda r: r.job.job_id)
    rn = sorted(r_new["records"], key=lambda r: r.job.job_id)
    assert len(ro) == len(rn) == len(jobs)
    for a, b in zip(ro, rn):
        assert a.job.job_id == b.job.job_id
        assert a.region == b.region
        assert a.start_s == b.start_s
        assert a.finish_s == b.finish_s
        assert b.carbon_g == pytest.approx(a.carbon_g, rel=ACCOUNTING_RTOL)
        assert b.water_l == pytest.approx(a.water_l, rel=ACCOUNTING_RTOL)


def test_parity_summary_metrics(setup):
    tele, jobs, cap = setup
    r_old = WindowedSimulator(tele, cap).run(_clone(jobs),
                                             make_scheduler("waterwise", tele))
    r_new = EventSimulator(tele, cap).run(_clone(jobs),
                                          make_scheduler("waterwise", tele))
    s_old, s_new = summarize(r_old), summarize(r_new)
    assert s_new["carbon_kg"] == pytest.approx(s_old["carbon_kg"],
                                               rel=ACCOUNTING_RTOL)
    assert s_new["water_kl"] == pytest.approx(s_old["water_kl"],
                                              rel=ACCOUNTING_RTOL)
    assert s_new["violation_pct"] == s_old["violation_pct"]
    assert s_new["mean_service_ratio"] == pytest.approx(
        s_old["mean_service_ratio"], rel=1e-12)


def test_capacity_never_exceeded(setup):
    tele, jobs, cap = setup
    res = EventSimulator(tele, cap).run(_clone(jobs),
                                        make_scheduler("least-load", tele))
    assert (res["peak_busy"] <= cap).all()


def test_every_job_scheduled_or_deferred_exactly_once(setup):
    tele, jobs, cap = setup
    res = EventSimulator(tele, cap).run(_clone(jobs),
                                        make_scheduler("waterwise", tele))
    ids = [r.job.job_id for r in res["records"]]
    assert len(ids) == len(set(ids))                 # no double placement
    assert len(ids) + res["unfinished"] == len(jobs)


def test_engine_determinism(setup):
    tele, jobs, cap = setup
    a = summarize(EventSimulator(tele, cap).run(
        _clone(jobs), make_scheduler("waterwise", tele)))
    b = summarize(EventSimulator(tele, cap).run(
        _clone(jobs), make_scheduler("waterwise", tele)))
    assert a["carbon_kg"] == b["carbon_kg"]
    assert a["water_kl"] == b["water_kl"]
    assert a["jobs"] == b["jobs"]


def test_capacity_event_blocks_dispatch():
    """During a full outage no new job is dispatched into the dead region;
    after restoration the region serves again."""
    tele = telemetry.generate(days=1, seed=0)
    jobs = borg_trace(days=0.2, seed=1, tolerance=0.5)
    cap = scale_capacity_for_utilization(jobs, 0.2, 5, utilization=0.15)
    dead = 1
    out = cap.copy()
    out[dead] = 0
    t0, t1 = 4000.0, 9000.0
    sim = EventSimulator(tele, cap, capacity_events=[(t0, out), (t1, cap)])
    res = sim.run(jobs, make_scheduler("round-robin", tele))
    in_dead = [r for r in res["records"] if r.region == dead]
    assert in_dead, "region must serve outside the outage"
    for r in in_dead:
        lat = telemetry.transfer_latency_s(r.job.package_bytes,
                                           r.job.home_region, dead)
        dispatch = r.start_s - lat
        # Events apply at the first round with now >= event time (closed on
        # the left): a dispatch exactly at t1 is legal, one at t0 is not.
        assert not (t0 <= dispatch < t1), \
            f"dispatch at {dispatch} inside outage [{t0}, {t1})"


def test_outage_restoration_after_lull_not_stalled():
    """All arrivals land before a total fleet outage; the restoration event
    comes long after the queue has drained of progress. The engine must
    fast-forward to the restoration instead of tripping the deadlock guard,
    and utilization must stay finite (capacity-integral denominator)."""
    tele = telemetry.generate(days=1, seed=0)
    jobs = borg_trace(days=0.005, seed=2, tolerance=0.5)
    cap = scale_capacity_for_utilization(jobs, 0.005, 5, utilization=0.15)
    dead = np.zeros_like(cap)
    t_restore = 50_000.0
    sim = EventSimulator(tele, cap,
                         capacity_events=[(0.0, dead), (t_restore, cap)])
    res = sim.run(jobs, make_scheduler("least-load", tele))
    assert res["unfinished"] == 0
    assert len(res["records"]) == len(jobs)
    late = [r for r in res["records"] if r.start_s >= t_restore]
    assert late, "jobs queued through the outage must run after restoration"
    assert np.isfinite(res["utilization"])
    # The outage interval is provisioned at zero capacity, so it must not
    # dilute the denominator: utilization reflects only the served window.
    assert 0.01 <= res["utilization"] <= 1.0


def test_capacity_integral_not_billed_retroactively():
    """A capacity change settles the provisioned-time integral up to the
    event instant — the pre-event interval is billed at the old capacity."""
    from repro.sim.cluster import Cluster
    c = Cluster(np.array([10]))
    c.set_capacity(np.array([0]))          # outage at t=0
    c.advance(100.0)                       # dead fleet for 100 s
    c.set_capacity(np.array([10]))         # restored at t=100
    c.advance(250.0)
    assert c.cap_integral_s == pytest.approx(10 * 150.0)


def test_idle_gap_fast_forward_is_cheap():
    """A multi-day gap between two arrival clumps costs O(1) rounds, not
    O(gap / window)."""
    tele = telemetry.generate(days=10, seed=0)
    early = borg_trace(days=0.01, seed=0, tolerance=0.5)
    late = borg_trace(days=0.01, seed=1, tolerance=0.5)
    for j in late:
        j.submit_time_s += 8.0 * 86400.0
        j.job_id += 10_000_000
    jobs = early + late
    cap = scale_capacity_for_utilization(jobs, 10.0, 5, utilization=0.15) + 50
    res = EventSimulator(tele, cap).run(jobs, make_scheduler("baseline", tele))
    assert len(res["records"]) == len(jobs)
    # 8 idle days at 30 s windows would be ~23k rounds; event-driven skips.
    assert res["rounds"] < 2000


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_parity_property(seed):
    """Golden parity holds for arbitrary trace seeds (tiny slices)."""
    tele = telemetry.generate(days=1, seed=0)
    jobs = borg_trace(days=0.02, seed=seed, tolerance=0.5)
    if not jobs:
        return
    cap = scale_capacity_for_utilization(jobs, 0.02, 5, utilization=0.15)
    r_old = WindowedSimulator(tele, cap).run(_clone(jobs),
                                             make_scheduler("baseline", tele))
    r_new = EventSimulator(tele, cap).run(_clone(jobs),
                                          make_scheduler("baseline", tele))
    ro = sorted(r_old["records"], key=lambda r: r.job.job_id)
    rn = sorted(r_new["records"], key=lambda r: r.job.job_id)
    assert [(a.region, a.start_s, a.finish_s) for a in ro] == \
           [(b.region, b.start_s, b.finish_s) for b in rn]
