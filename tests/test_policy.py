"""Declarative policy-spec API: grammar round-trip, registry validation,
pipeline parity with the deprecated ``make_scheduler`` shim, and sweeps
driven from spec strings alone."""
import copy

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import policy
from repro.core import telemetry
from repro.core.baselines import make_scheduler
from repro.sim import scenarios
from repro.sim.engine import EventSimulator


@pytest.fixture(scope="module")
def tele():
    return telemetry.generate(days=2, seed=0)


# ---------------------------------------------------------------------------
# Grammar: parse / format
# ---------------------------------------------------------------------------

def test_parse_typed_params_and_round_trip():
    spec = policy.parse("waterwise[lam_h2o=0.7,backend=jax]")
    assert spec.name == "waterwise"
    assert spec.params == {"lam_h2o": 0.7, "backend": "jax"}
    assert isinstance(spec.params["lam_h2o"], float)
    assert isinstance(spec.params["backend"], str)
    assert policy.parse(str(spec)) == spec
    # Whitespace and empty brackets are tolerated; params stay explicit-only.
    assert policy.parse("  waterwise [ lam_h2o = 0.7 ]  ").params == \
        {"lam_h2o": 0.7}
    assert policy.parse("waterwise[]") == policy.parse("waterwise")


def test_parse_accepts_spec_objects_and_bool_int():
    spec = policy.parse(policy.PolicySpec("waterwise-forecast",
                                          {"horizon_slots": "4",
                                           "record_windows": "true"}))
    assert spec.params == {"horizon_slots": 4, "record_windows": True}
    assert str(spec) == "waterwise-forecast[horizon_slots=4," \
                        "record_windows=true]"


def test_unknown_policy_has_did_you_mean():
    with pytest.raises(policy.UnknownPolicyError, match="waterwise"):
        policy.parse("waterwize")
    # Backward compatible with the old lambda-table KeyError contract.
    with pytest.raises(KeyError):
        policy.parse("no-such-policy")


def test_unknown_param_has_did_you_mean():
    with pytest.raises(policy.UnknownParamError, match="lam_h2o"):
        policy.parse("waterwise[lam_h20=1.0]")
    with pytest.raises(policy.UnknownParamError, match="accepts no"):
        policy.parse("round-robin[x=1]")
    # A reactive-only param on a forecast policy is unknown, not silently
    # dropped (the old frozenset behavior).
    with pytest.raises(policy.UnknownParamError):
        policy.parse("waterwise-oracle[forecaster=oracle]")


def test_ill_typed_params():
    with pytest.raises(policy.ParamValueError, match="float"):
        policy.parse("waterwise[lam_h2o=abc]")
    with pytest.raises(policy.ParamValueError, match="int"):
        policy.parse("waterwise-forecast[horizon_slots=2.5]")
    with pytest.raises(policy.ParamValueError, match="bool"):
        policy.parse("waterwise[record_windows=maybe]")


def test_malformed_bracket_syntax():
    for bad in ("waterwise[lam_h2o=1", "waterwise[a]", "waterwise[=1]",
                "waterwise[lam_h2o=]", "waterwise[x=1][y=2]",
                "waterwise[lam_h2o=1,lam_h2o=2]", "[x=1]", ""):
        with pytest.raises(policy.SpecSyntaxError):
            policy.parse(bad)


def test_with_params_and_with_defaults(tele):
    spec = policy.parse("waterwise[lam_h2o=0.7]")
    over = spec.with_params(lam_h2o=0.9, backend="flow")
    assert over.params == {"lam_h2o": 0.9, "backend": "flow"}
    kept = spec.with_defaults(lam_h2o=0.1, sigma=5.0)
    assert kept.params == {"lam_h2o": 0.7, "sigma": 5.0}
    with pytest.raises(policy.UnknownParamError):
        spec.with_params(nope=1)


def test_split_specs_honours_brackets():
    assert policy.split_specs(
        "baseline, waterwise[lam_co2=0.3,lam_h2o=0.7] ,least-load") == \
        ["baseline", "waterwise[lam_co2=0.3,lam_h2o=0.7]", "least-load"]


def test_registry_covers_all_legacy_names():
    names = set(policy.list_policies())
    assert {"baseline", "round-robin", "least-load", "carbon-greedy-opt",
            "water-greedy-opt", "ecovisor", "waterwise",
            "waterwise-forecast", "waterwise-oracle",
            "carbon-forecast"} <= names
    for n in names:
        e = policy.get_policy(n)
        assert e.description
    assert policy.get_policy("waterwise-forecast").forecast_driven
    assert not policy.get_policy("waterwise").forecast_driven
    # describe() renders every policy in both formats.
    text, md = policy.describe(), policy.describe(markdown=True)
    for n in names:
        assert n in text and f"`{n}`" in md


# ---------------------------------------------------------------------------
# Property: parse ∘ format is the identity over schema-valid specs
# ---------------------------------------------------------------------------

def _spec_strategy():
    def params_for(name):
        entry = policy.get_policy(name)
        by_type = {
            float: st.floats(allow_nan=False, allow_infinity=False,
                             width=64),
            int: st.integers(-10**9, 10**9),
            bool: st.booleans(),
            str: st.text(alphabet="abcdefghijklmnopqrstuvwxyz0123456789_-",
                         min_size=1, max_size=12),
        }
        opts = {k: by_type[p.type] for k, p in entry.params.items()}
        return st.fixed_dictionaries(
            {}, optional=opts).map(lambda d: policy.PolicySpec(name, d))
    return st.sampled_from(policy.list_policies()).flatmap(params_for)


@settings(max_examples=200, deadline=None)
@given(spec=_spec_strategy())
def test_spec_format_parse_round_trip_property(spec):
    text = spec.format()
    back = policy.parse(text)
    assert back == spec
    assert back.format() == text


# ---------------------------------------------------------------------------
# Pipeline construction + shim parity
# ---------------------------------------------------------------------------

def test_build_configures_pipeline(tele):
    ctl = policy.build("waterwise[lam_h2o=0.7,backend=jax,window=5]", tele)
    assert isinstance(ctl, policy.PolicyPipeline)
    assert (ctl.lam_h2o, ctl.lam_co2) == (0.7, pytest.approx(0.3))
    assert ctl.backend == "jax" and ctl.history.window == 5
    assert isinstance(ctl.pricer, policy.SnapshotPricer)
    assert isinstance(ctl.deferral, policy.NextRoundDeferral)
    assert not hasattr(ctl, "forecast_mape")

    fc = policy.build("waterwise-oracle[horizon_slots=4,guard_s=100]", tele)
    assert isinstance(fc.pricer, policy.ForecastPricer)
    assert isinstance(fc.deferral, policy.QueueDeferral)
    assert fc.forecaster_name == "oracle" and fc.horizon_slots == 4
    assert fc.queue.guard_s == 100.0 and fc.pricer.guard_s == 100.0
    assert hasattr(fc, "forecast_mape")

    cf = policy.build("carbon-forecast", tele)
    assert (cf.lam_co2, cf.lam_h2o) == (1.0, 0.0)


def test_make_scheduler_shim_matches_registry_bit_for_bit(tele):
    """Acceptance: the deprecated shim and the registry path produce
    bit-identical footprints on the 0.05-day nominal cell."""
    inst = scenarios.get_scenario("nominal").build(0.05, 0, 23000.0, 0.15)

    def footprints(sched):
        sim = EventSimulator(inst.tele, inst.capacity)
        res = sim.run(copy.deepcopy(inst.jobs), sched)
        return (sum(r.carbon_g for r in res["records"]),
                sum(r.water_l for r in res["records"]),
                [(r.job.job_id, r.region, r.start_s)
                 for r in res["records"]])

    for name in ("waterwise", "baseline", "ecovisor"):
        old = footprints(make_scheduler(name, inst.tele))
        new = footprints(policy.build(name, inst.tele))
        assert old == new    # bit-identical, not approx

    # Kwarg path: the shim forwards through the same validation.
    old = footprints(make_scheduler("waterwise", inst.tele, lam_co2=0.3,
                                    lam_h2o=0.7))
    new = footprints(policy.build("waterwise[lam_co2=0.3,lam_h2o=0.7]",
                                  inst.tele))
    assert old == new
    with pytest.raises(policy.UnknownParamError):
        make_scheduler("round-robin", inst.tele, lam_h2o=0.7)


def test_engine_accepts_spec_strings(tele):
    from repro.sim.trace import (borg_trace, scale_capacity_for_utilization)
    jobs = borg_trace(days=0.02, seed=0, tolerance=0.5)
    cap = scale_capacity_for_utilization(jobs, 0.02, 5, 0.15)
    res = EventSimulator(tele, cap).run(copy.deepcopy(jobs), "least-load")
    assert len(res["records"]) == len(jobs)
    res2 = EventSimulator(tele, cap).run(
        copy.deepcopy(jobs), policy.parse("waterwise[backend=flow]"))
    assert len(res2["records"]) == len(jobs)


# ---------------------------------------------------------------------------
# Sweeps from spec strings alone (acceptance criterion)
# ---------------------------------------------------------------------------

def test_run_cell_rejects_sched_kwargs_for_paramless_policy():
    """The silent-kwarg-drop fix: tuning kwargs on a policy that has no
    params raise instead of vanishing."""
    with pytest.raises(policy.UnknownParamError, match="round-robin"):
        scenarios.run_cell("nominal", "round-robin", days=0.02,
                           sched_kwargs={"lam_h2o": 0.7})
    with pytest.raises(policy.UnknownParamError, match="did you mean"):
        scenarios.run_cell("nominal", "waterwise", days=0.02,
                           sched_kwargs={"lam_h20": 0.7})


def test_sweep_from_spec_strings_emits_reparseable_spec_column(tmp_path):
    rows = scenarios.sweep(
        ["baseline", "waterwise[lam_h2o=0.7,backend=flow]"],
        ["nominal", "drought-summer"], days=0.05, seed=0, max_workers=1)
    assert len(rows) == 4
    for row in rows:
        spec = policy.parse(row["spec"])
        assert spec.name == row["scheduler"]
        if row["scheduler"] == "waterwise":
            assert spec == policy.parse("waterwise[lam_h2o=0.7,backend=flow]")
    # The spec column survives CSV round-trips (commas inside brackets).
    import csv
    path = tmp_path / "sweep.csv"
    scenarios.to_csv(rows, str(path))
    with open(path, newline="") as f:
        read = list(csv.DictReader(f))
    assert len(read) == len(rows)
    for line in read:
        assert policy.parse(line["spec"]).name == line["scheduler"]


def test_forecast_error_regime_resolves_into_spec_column():
    row = scenarios.run_cell("forecast-error", "waterwise-oracle", days=0.02,
                             seed=3)
    spec = policy.parse(row["spec"])
    assert spec.params["forecast_bias"] == pytest.approx(1.30)
    assert spec.params["forecast_noise"] == pytest.approx(0.15)
    assert spec.params["forecast_seed"] == 3
    # Re-building from the row's spec reproduces the injected forecaster.
    tele = telemetry.generate(days=1, seed=0)
    ctl = policy.build(spec, tele)
    assert ctl.forecast_bias == pytest.approx(1.30)
