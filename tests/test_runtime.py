"""Runtime substrate tests: sharding rules, checkpoint/eleastic, data
pipeline, optimizer, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint,
                              checkpoint_bytes)
from repro.data import SyntheticTokens
from repro.optim import adamw, clip_by_global_norm
from repro.optim.compression import int8_roundtrip, topk_error_feedback
from repro.runtime import elastic, sharding


class FakeMesh:
    """Duck-typed mesh: .shape mapping only (what the resolver reads)."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


# -- sharding rules ---------------------------------------------------------

def test_spec_divisibility_fallback():
    mesh = FakeMesh(data=16, model=16)
    # heads=12 not divisible by 16 → None; mlp=8960 divisible → model
    spec = sharding.spec_for(("embed", "heads", "head_dim"),
                             (1536, 12, 128), mesh)
    assert spec == jax.sharding.PartitionSpec("data", None, None)
    spec = sharding.spec_for(("embed", "mlp"), (1536, 8960), mesh)
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_spec_no_axis_reuse():
    mesh = FakeMesh(data=16, model=16)
    # experts takes model; mlp then must NOT reuse model
    spec = sharding.spec_for(("experts", "embed", "mlp"),
                             (16, 6144, 10752), mesh)
    assert spec == jax.sharding.PartitionSpec("model", "data", None)


def test_cache_batch_vs_seq_context_dependence():
    """Batched decode shards the cache on batch; long-context (batch=1)
    automatically falls through to sequence sharding (SP)."""
    mesh = FakeMesh(data=16, model=16)
    batched = sharding.spec_for(
        ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
        (128, 32768, 8, 128), mesh)
    assert batched[0] == "data" and batched[1] is None
    longctx = sharding.spec_for(
        ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
        (1, 524288, 4, 256), mesh)
    assert longctx[0] is None and longctx[1] == "data"


def test_multi_axis_batch():
    mesh = FakeMesh(pod=2, data=16, model=16)
    spec = sharding.spec_for(("act_batch", "act_seq"), (256, 4096), mesh)
    assert spec[0] == ("pod", "data")


# -- checkpoint + elastic ---------------------------------------------------

def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return dict(w=jax.random.normal(k, (8, 4)),
                step=jnp.zeros((), jnp.int32))


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), 7, state)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_checkpoint_bytes_matches_manifest(tmp_path):
    state = _state()
    b = checkpoint_bytes(state)
    assert b == 8 * 4 * 4 + 4


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), every=2)
    st = _state()
    assert not ck.maybe_save(1, st)
    assert ck.maybe_save(2, st)
    ck.wait()
    assert latest_step(str(tmp_path)) == 2


def test_elastic_restart_exactly_recovers(tmp_path):
    """Training with injected failures ends in EXACTLY the same state as an
    uninterrupted run (checkpoint/restart is bitwise at step granularity)."""
    def step_fn(state, batch, step):
        return dict(w=state["w"] + batch,
                    step=state["step"] + 1)

    def batch_fn(step):
        return jnp.float32(step + 1)

    clean = elastic.run_elastic(
        _state(), step_fn, batch_fn, num_steps=12,
        ckpt_dir=str(tmp_path / "a"), ckpt_every=3)
    faulty = elastic.run_elastic(
        _state(), step_fn, batch_fn, num_steps=12,
        ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
        injector=elastic.FailureInjector(fail_after_steps=(5, 9)))
    assert faulty["restarts"] == 2
    np.testing.assert_array_equal(np.asarray(clean["state"]["w"]),
                                  np.asarray(faulty["state"]["w"]))


def test_watchdog_flags_stragglers():
    wd = elastic.StepWatchdog(deadline_s=0.1)
    assert not wd.observe(0.05)
    assert wd.observe(0.5)


# -- data pipeline ----------------------------------------------------------

def test_data_deterministic_and_resumable():
    src = SyntheticTokens(vocab=128, seq_len=16, global_batch=4, seed=0)
    b5a = src.batch(5)
    b5b = src.batch(5)
    np.testing.assert_array_equal(np.asarray(b5a["tokens"]),
                                  np.asarray(b5b["tokens"]))
    # labels are next-token shifted
    assert b5a["tokens"].shape == (4, 16)
    b6 = src.batch(6)
    assert not np.array_equal(np.asarray(b5a["tokens"]),
                              np.asarray(b6["tokens"]))


# -- optimizer + compression -------------------------------------------------

def test_adamw_minimizes_quadratic():
    opt = adamw(lr=lambda s: 0.1, weight_decay=0.0)
    params = dict(w=jnp.array([3.0, -2.0]))
    state = opt.init(params)
    for _ in range(60):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_clip_by_global_norm():
    g = dict(a=jnp.ones((10,)) * 10.0)
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert n2 == pytest.approx(1.0, rel=1e-5)


def test_int8_roundtrip_error_bound():
    g = dict(w=jax.random.normal(jax.random.PRNGKey(0), (256,)))
    out = int8_roundtrip(g, jax.random.PRNGKey(1))
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert float(jnp.abs(out["w"] - g["w"]).max()) <= scale * 1.01


def test_topk_error_feedback_conserves_mass():
    g = dict(w=jax.random.normal(jax.random.PRNGKey(0), (100,)))
    sent, res = topk_error_feedback(g, None, frac=0.1)
    np.testing.assert_allclose(np.asarray(sent["w"] + res["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    assert int((np.asarray(sent["w"]) != 0).sum()) <= 11
