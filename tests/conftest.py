import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Hypothesis shim: the property tests use hypothesis when it is installed
# (CI installs requirements-dev.txt), but the offline image may not ship it.
# Instead of failing collection, install a stub module whose @given-decorated
# tests skip — every non-property test in the same module still runs.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        """Opaque stand-in for any hypothesis strategy expression."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __repr__(self):
            return "<hypothesis-stub strategy>"

    _ANY = _Strategy()

    def _given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed (see "
                            "requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _ANY
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-second end-to-end runs; deselect with -m 'not slow' "
        "(the fast CI lane)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
