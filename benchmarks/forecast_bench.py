"""Forecast-quality benchmark: every registered forecaster + the oracle,
walk-forward on one telemetry signal.

  PYTHONPATH=src python -m benchmarks.run --forecast-bench
  PYTHONPATH=src python -m benchmarks.run --forecast-bench \\
      --days 10 --train-steps 600 --signal wue

One row per model: walk-forward MAPE (%), pinball loss at the 10/90 band,
band coverage, number of origins, and wall seconds (the learned row's wall
is dominated by its training time; ``--refit-every`` sets the walk-forward
full-refit cadence). The oracle row reads the true future — it must
lower-bound every model's MAPE, and this module asserts that ordering so
the CI smoke run is a real check, not just a render.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence


def run_bench(days: float = 10.0, seed: int = 0, signal: str = "ci", *,
              horizon: int = 6, warmup: Optional[int] = None, stride: int = 6,
              refit_every: int = 4, train_steps: int = 300,
              models: Optional[Sequence[str]] = None) -> List[Dict]:
    """Backtest every model (+ oracle) on one telemetry signal; returns
    tidy rows sorted by MAPE and asserts the oracle lower-bounds them.

    ``warmup=None`` auto-sizes the first origin: 7 days of history when the
    series is long enough for a few origins after it, else 4 days (the
    minimum the learned forecaster trains on), so tiny CI runs still
    exercise the real training path.
    """
    from repro import forecast
    from repro.core import telemetry

    tele = telemetry.generate(days=max(int(round(days)), 1), seed=seed)
    if warmup is None:
        T = tele.ci.shape[0]
        warmup = 168 if T - 168 - horizon >= 2 * stride else 96
        if T - warmup - horizon < 0:
            raise ValueError(f"telemetry too short ({T}h) for the bench "
                             f"(needs ≥ {96 + horizon}h; raise --days)")
    names = list(models) if models else forecast.list_forecasters() + ["oracle"]
    rows: List[Dict] = []
    for name in names:
        kw = dict(train_steps=train_steps, seed=seed) \
            if name == "learned" else {}
        t0 = time.perf_counter()
        r = forecast.backtest_telemetry(
            tele, signal, name, horizon=horizon, warmup=warmup,
            stride=stride, refit_every=refit_every, **kw)
        rows.append(dict(forecaster=name, mape=r["mape"],
                         pinball=r["pinball"], coverage=r["coverage"],
                         n_origins=r["n_origins"],
                         wall_s=time.perf_counter() - t0))
    if "oracle" in names:
        oracle = next(r for r in rows if r["forecaster"] == "oracle")
        best = min(r["mape"] for r in rows)
        assert oracle["mape"] <= best + 1e-9, \
            "oracle must lower-bound every model's walk-forward MAPE"
    rows.sort(key=lambda r: r["mape"])
    return rows


def to_table(rows: Sequence[Dict]) -> str:
    """Render through the shared experiments table layout (floats
    pre-formatted to 3 decimals — forecast metrics need the precision)."""
    from repro import experiments

    cols = ("forecaster", "mape", "pinball", "coverage", "n_origins",
            "wall_s")
    fmt_rows = [{c: (f"{r[c]:.3f}" if isinstance(r.get(c), float)
                     else r.get(c, "")) for c in cols} for r in rows]
    return experiments.to_table(fmt_rows, cols, ci=False)


def main(args) -> None:
    # The telemetry generator takes whole days; report what actually ran.
    days = max(int(round(args.days)), 1) if args.days is not None else 10
    t0 = time.time()
    rows = run_bench(days=days, seed=args.seed, signal=args.signal,
                     refit_every=args.refit_every,
                     train_steps=args.train_steps,
                     warmup=args.warmup)
    print(to_table(rows))
    print(f"\n# forecast-bench: signal={args.signal!r}, {days}-day "
          f"telemetry, train_steps={args.train_steps}, "
          f"{time.time() - t0:.1f}s wall (oracle ≤ every model: ok)")
