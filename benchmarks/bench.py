"""Persisted perf harness for the fused scheduling round (BENCH_6.json).

  PYTHONPATH=src python -m benchmarks.bench                  # print only
  PYTHONPATH=src python -m benchmarks.bench --out BENCH_6.json
  PYTHONPATH=src python -m benchmarks.bench --check BENCH_6.json \\
      --tolerance 0.10                                       # CI gate

Three sections, one JSON document (``schema_version`` pins the layout; see
benchmarks/README.md for the field-by-field schema):

  solver      per-bucket temporal-round wall (unfused planner + jax solve
              vs the single fused program) and solver-level jobs/sec
  e2e         end-to-end jobs/sec on the standard diurnal cell
              (waterwise-forecast oracle pipeline, jax vs fused backend)
  forecaster  learned-forecaster fit/infer wall + jit retrace counts
              (repro.forecast.learned.cache_stats)

The CI gate compares only *machine-relative ratio* metrics (the fused
speedups) and correctness flags against the committed baseline — absolute
wall-clock differs across runner generations, but "fused beats unfused by
roughly this much on the same machine" is portable. ``--check`` fails when
any gated ratio drops more than ``--tolerance`` below the baseline, or when
parity (``records_equal`` / ``assign_equal``) regresses.
"""
from __future__ import annotations

import argparse
import copy
import json
import sys
import time
from typing import Callable, Dict, List, Optional, Tuple

SCHEMA_VERSION = 2

#: Ratio metrics the CI gate enforces (dotted paths into the document).
#: Absolute walls are recorded for humans but never gated.
GATED_RATIOS = (
    "e2e.fused_speedup",
    "solver.buckets.*.fused_speedup",
)

#: Correctness flags that must stay True.
GATED_FLAGS = (
    "e2e.records_equal",
    "solver.buckets.*.assign_equal",
)


def _timeit(fn: Callable, reps: int) -> float:
    """Median-free mean wall seconds per call after one warm call."""
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


# ---------------------------------------------------------------------------
# solver section: fused program vs unfused planner+solver, per bucket
# ---------------------------------------------------------------------------

def bench_solver(sizes: Tuple[int, ...] = (4, 16, 64, 256),
                 reps: int = 10, seed: int = 0) -> Dict:
    import numpy as np
    from repro.core import telemetry, problem, footprint, solvers
    from repro.core.round import fused_temporal_round
    from repro.forecast import build_temporal_plan

    tele = telemetry.generate(days=2, seed=0)
    server = footprint.m5_metal()
    S, R = 8, 5
    offsets = np.arange(S) * 1800.0
    rng = np.random.default_rng(seed)
    snap = tele.at(0.0)
    buckets: Dict[str, Dict] = {}
    for M in sizes:
        jobs = [problem.Job(job_id=i, home_region=i % R, submit_time_s=0.0,
                            exec_time_s=600.0 + 10 * i, energy_kwh=0.05,
                            tolerance=4.0) for i in range(M)]
        cap = np.full(R, max(2, M // R + 1))
        inst = problem.build(jobs, tele, 0.0, cap, server, snap=snap)
        ci = rng.random((M, S, R)) * 300 + 50
        ewif = rng.random((M, S, R)) * 2 + 0.5
        wue = rng.random((M, S, R)) * 1 + 0.2

        def unfused():
            plan = build_temporal_plan(inst, 0.0, ci, ewif, wue,
                                       snap["pue"], snap["wsf"], offsets,
                                       server, 0.5, 0.5)
            return solvers.solve(plan.cost, plan.allowed, plan.capacity,
                                 backend="jax")

        def fused():
            return fused_temporal_round(inst, 0.0, ci, ewif, wue,
                                        snap["pue"], snap["wsf"], offsets,
                                        server, 0.5, 0.5)[3]

        unfused(), fused()                  # warm compile caches
        tu = tf = 0.0                       # interleave: shared noise floor
        for _ in range(reps):
            t0 = time.perf_counter()
            unfused()
            tu += time.perf_counter() - t0
            t0 = time.perf_counter()
            fused()
            tf += time.perf_counter() - t0
        tu /= reps
        tf /= reps
        eq = bool((unfused().assign == fused().assign).all())
        buckets[str(M)] = dict(
            jobs=M, unfused_ms=tu * 1e3, fused_ms=tf * 1e3,
            unfused_jobs_per_s=M / tu, fused_jobs_per_s=M / tf,
            fused_speedup=tu / tf, assign_equal=eq)
    return dict(slots=S, regions=R, reps=reps, buckets=buckets)


# ---------------------------------------------------------------------------
# e2e section: the standard diurnal cell through the event engine
# ---------------------------------------------------------------------------

def bench_e2e(days: float = 0.05, seed: int = 3, reps: int = 3) -> Dict:
    from repro.core import telemetry
    from repro.policy.pipeline import forecast_pipeline
    from repro.sim.engine import EventSimulator, SimConfig
    from repro.sim.trace import borg_trace, scale_capacity_for_utilization

    tele = telemetry.generate(days=2, seed=0)
    jobs = borg_trace(days=days, seed=seed, tolerance=4.0,
                      target_jobs_per_day=23000.0)
    cap = scale_capacity_for_utilization(jobs, days, tele.num_regions, 0.15)

    def run(backend: str):
        ctl = forecast_pipeline(tele, forecaster="oracle", risk=0.0,
                                defer_eps=1e-4, backend=backend)
        t0 = time.perf_counter()
        res = EventSimulator(tele, cap, SimConfig()).run(
            copy.deepcopy(jobs), ctl)
        return res, time.perf_counter() - t0

    run("jax")                              # warm both compile caches
    run("fused")
    # Engine runs are ~1s and noisy; alternate backends and take the best
    # wall per backend so the gated speedup is a stable machine-relative
    # ratio, not a race between two single samples.
    w_jax = w_fused = float("inf")
    r_jax = r_fused = None
    for _ in range(reps):
        r, w = run("jax")
        if w < w_jax:
            r_jax, w_jax = r, w
        r, w = run("fused")
        if w < w_fused:
            r_fused, w_fused = r, w

    def key(r):
        return (r.job.job_id, r.region, r.start_s, r.finish_s,
                r.carbon_g, r.water_l)

    eq = ([key(r) for r in r_jax["records"]]
          == [key(r) for r in r_fused["records"]])

    # One extra obs-instrumented fused run — after the timed reps, so
    # span bookkeeping never perturbs the gated walls — collects the
    # per-round latency distribution (schema v2 fields).
    import repro.obs as obs
    from repro.core.solvers import jax_solver
    with obs.capture(fold=False) as reg:
        run("fused")
        h = reg.hists.get("engine.round")
        round_ms = (dict(rounds=h.count,
                         p50=h.quantile(50) * 1e3,
                         p95=h.quantile(95) * 1e3,
                         p99=h.quantile(99) * 1e3) if h is not None else None)
    return dict(cell="diurnal[borg]", days=days, seed=seed,
                jobs=len(jobs), unfinished=r_fused["unfinished"],
                jax_wall_s=w_jax, fused_wall_s=w_fused,
                jax_jobs_per_s=len(jobs) / w_jax,
                fused_jobs_per_s=len(jobs) / w_fused,
                fused_speedup=w_jax / w_fused, records_equal=bool(eq),
                round_latency_ms=round_ms,
                sinkhorn_iters=jax_solver.SINKHORN_ITERS
                * jax_solver.SINKHORN_STAGES)


# ---------------------------------------------------------------------------
# forecaster section: fit / infer wall + retrace accounting
# ---------------------------------------------------------------------------

def bench_forecaster(train_steps: int = 60, infer_reps: int = 20,
                     seed: int = 0) -> Dict:
    from repro import forecast
    from repro.core import telemetry
    from repro.forecast import learned

    tele = telemetry.generate(days=5, seed=0)
    before = learned.cache_stats()
    f = forecast.make_forecaster("learned", train_steps=train_steps,
                                 seed=seed)
    t0 = time.perf_counter()
    f.fit(tele.ci[:96])
    fit_wall = time.perf_counter() - t0
    # The jitted inference runs when the forecaster (re-)conditions on a
    # history tail (update); predict() then just slices the conditioned
    # horizon. Time the real path: re-condition + read one horizon.
    hist = tele.ci[:100]
    infer_wall = _timeit(lambda: (f.update(hist), f.predict(8)), infer_reps)
    after = learned.cache_stats()
    return dict(train_steps=train_steps, fit_wall_s=fit_wall,
                infer_wall_s=infer_wall,
                train_retraces=(after["train_step"]["builds"]
                                - before["train_step"]["builds"]),
                predict_retraces=(after["predict_fn"]["builds"]
                                  - before["predict_fn"]["builds"]),
                cache_stats=after)


# ---------------------------------------------------------------------------
# document assembly / gate
# ---------------------------------------------------------------------------

def run_bench(quick: bool = False) -> Dict:
    import jax

    dev = jax.devices()[0]
    sizes = (4, 16, 64) if quick else (4, 16, 64, 256)
    doc = dict(
        schema_version=SCHEMA_VERSION,
        bench="round-fusion",
        env=dict(platform=sys.platform, device=dev.platform,
                 jax=jax.__version__,
                 python=".".join(map(str, sys.version_info[:3]))),
        solver=bench_solver(sizes=sizes, reps=4 if quick else 10),
        e2e=bench_e2e(days=0.03 if quick else 0.05, reps=2 if quick else 3),
        forecaster=bench_forecaster(train_steps=30 if quick else 60),
    )
    return doc


def _lookup(doc: Dict, path: str) -> List[Tuple[str, object]]:
    """Resolve a dotted path; ``*`` fans out over dict keys present in
    BOTH documents' parent node (handled by the caller intersecting)."""
    nodes = [("", doc)]
    for part in path.split("."):
        nxt = []
        for prefix, node in nodes:
            if part == "*":
                for k, v in sorted(node.items()):
                    nxt.append((f"{prefix}{k}.", v))
            elif isinstance(node, dict) and part in node:
                nxt.append((f"{prefix}{part}.", node[part]))
        nodes = nxt
    return [(p.rstrip("."), v) for p, v in nodes]


def check(current: Dict, baseline: Dict, tolerance: float = 0.10) -> List[str]:
    """Return failure strings (empty == pass). Gates ratio metrics at
    ``baseline * (1 - tolerance)`` and correctness flags at True."""
    fails: List[str] = []
    if current.get("schema_version") != baseline.get("schema_version"):
        fails.append(f"schema_version {current.get('schema_version')} != "
                     f"baseline {baseline.get('schema_version')}")
        return fails
    for path in GATED_RATIOS:
        base_vals = dict(_lookup(baseline, path))
        for name, cur in _lookup(current, path):
            base = base_vals.get(name)
            if base is None:
                continue                    # bucket absent from baseline
            floor = base * (1.0 - tolerance)
            if cur < floor:
                fails.append(f"{name}: {cur:.3f} < floor {floor:.3f} "
                             f"(baseline {base:.3f}, tol {tolerance:.0%})")
    for path in GATED_FLAGS:
        for name, cur in _lookup(current, path):
            if cur is not True:
                fails.append(f"{name}: expected True, got {cur!r}")
    return fails


def to_text(doc: Dict) -> str:
    lines = [f"# round-fusion bench (schema v{doc['schema_version']}, "
             f"device={doc['env']['device']})",
             "", "| jobs | unfused ms | fused ms | speedup | assign == |",
             "|---|---|---|---|---|"]
    for k, b in sorted(doc["solver"]["buckets"].items(),
                       key=lambda kv: int(kv[0])):
        lines.append(f"| {b['jobs']} | {b['unfused_ms']:.2f} "
                     f"| {b['fused_ms']:.2f} | {b['fused_speedup']:.2f}x "
                     f"| {b['assign_equal']} |")
    e = doc["e2e"]
    lines += ["",
              f"e2e {e['cell']}: {e['jobs']} jobs — jax "
              f"{e['jax_jobs_per_s']:.0f} jobs/s, fused "
              f"{e['fused_jobs_per_s']:.0f} jobs/s "
              f"({e['fused_speedup']:.2f}x), records_equal="
              f"{e['records_equal']}"]
    rl = e.get("round_latency_ms")
    if rl:
        lines += [f"round latency (fused): p50 {rl['p50']:.1f}ms "
                  f"p95 {rl['p95']:.1f}ms p99 {rl['p99']:.1f}ms over "
                  f"{rl['rounds']} rounds "
                  f"({e.get('sinkhorn_iters', '?')} sinkhorn iters/solve)"]
    f = doc["forecaster"]
    lines += [f"forecaster: fit {f['fit_wall_s']:.2f}s "
              f"({f['train_steps']} steps), infer "
              f"{f['infer_wall_s'] * 1e3:.1f}ms, retraces "
              f"train={f['train_retraces']} predict={f['predict_retraces']}"]
    return "\n".join(lines)


README_BEGIN = "<!-- BENCH_6:begin (benchmarks.bench --update-readme) -->"
README_END = "<!-- BENCH_6:end -->"


def to_readme(doc: Dict) -> str:
    """The README perf block, regenerated verbatim from the document."""
    e, fc = doc["e2e"], doc["forecaster"]
    lines = [README_BEGIN,
             f"Committed baseline (`BENCH_6.json`, schema "
             f"v{doc['schema_version']}, {doc['env']['device']} / jax "
             f"{doc['env']['jax']}):", "",
             "| temporal round | unfused | fused | speedup | bit-equal |",
             "|---|---|---|---|---|"]
    for k, b in sorted(doc["solver"]["buckets"].items(),
                       key=lambda kv: int(kv[0])):
        lines.append(f"| {b['jobs']} jobs × {doc['solver']['slots']} slots "
                     f"× {doc['solver']['regions']} regions "
                     f"| {b['unfused_ms']:.1f} ms | {b['fused_ms']:.1f} ms "
                     f"| {b['fused_speedup']:.2f}× | {b['assign_equal']} |")
    lines += [
        "",
        f"End-to-end on the standard diurnal cell ({e['jobs']} borg-trace "
        f"jobs through the `waterwise-forecast` oracle pipeline): "
        f"**{e['jax_jobs_per_s']:.0f} jobs/s** unfused → "
        f"**{e['fused_jobs_per_s']:.0f} jobs/s** fused "
        f"({e['fused_speedup']:.2f}×), engine records bit-identical "
        f"(`records_equal={e['records_equal']}`). Learned forecaster: "
        f"fit {fc['fit_wall_s']:.1f} s ({fc['train_steps']} steps), "
        f"re-condition + predict {fc['infer_wall_s'] * 1e3:.1f} ms, "
        f"{fc['train_retraces']} train / {fc['predict_retraces']} predict "
        f"retrace(s)."
        + (f" Fused round latency: p50 "
           f"{e['round_latency_ms']['p50']:.0f} ms / p99 "
           f"{e['round_latency_ms']['p99']:.0f} ms over "
           f"{e['round_latency_ms']['rounds']} rounds."
           if e.get("round_latency_ms") else ""),
        README_END]
    return "\n".join(lines)


def update_readme(doc: Dict, path: str = "README.md") -> None:
    with open(path) as fh:
        text = fh.read()
    i, j = text.index(README_BEGIN), text.index(README_END)
    text = text[:i] + to_readme(doc) + text[j + len(README_END):]
    with open(path, "w") as fh:
        fh.write(text)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", help="write the JSON document here")
    ap.add_argument("--check", metavar="BASELINE",
                    help="compare against a committed baseline JSON; "
                         "exit 1 on regression")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative drop in gated ratios "
                         "(default 0.10)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller buckets / fewer reps (CI lane)")
    ap.add_argument("--update-readme", action="store_true",
                    help="regenerate the README perf block from the "
                         "document")
    ap.add_argument("--load", metavar="FILE",
                    help="load an existing document instead of running "
                         "the bench (for --update-readme / --check "
                         "plumbing)")
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.load:
        with open(args.load) as fh:
            doc = json.load(fh)
    else:
        doc = run_bench(quick=args.quick)
    print(to_text(doc))
    print(f"\n# bench wall: {time.time() - t0:.1f}s")
    if args.update_readme:
        update_readme(doc)
        print("# updated README.md perf block")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.out}")
    if args.check:
        with open(args.check) as fh:
            baseline = json.load(fh)
        fails = check(doc, baseline, args.tolerance)
        if fails:
            print("# REGRESSION GATE FAILED:", file=sys.stderr)
            for f in fails:
                print(f"#   {f}", file=sys.stderr)
            return 1
        print(f"# gate ok vs {args.check} (tol {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
